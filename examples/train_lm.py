"""End-to-end training driver: a ~20M-param same-family Qwen3 model for a
few hundred steps on CPU, with checkpoint/restart and the synthetic data
pipeline.  (On a real pod, drop --reduced and pass --mesh single.)

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import subprocess
import sys
import os

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="qwen3_32b")
args = ap.parse_args()

root = os.path.join(os.path.dirname(__file__), "..")
cmd = [
    sys.executable, "-m", "repro.launch.train",
    "--arch", args.arch, "--reduced",
    "--steps", str(args.steps), "--batch", "8", "--seq", "128",
    "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50",
    "--log-every", "20",
]
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(root, "src")
raise SystemExit(subprocess.call(cmd, env=env))
