"""Dynamic graph analytics under concurrent updates — a miniature of the
paper's Section 5 study (Figures 6-8): PG-Cn vs PG-Icn vs a Ligra-style
static engine, on an R-MAT graph with a 40/10/50 workload.

    PYTHONPATH=src python examples/dynamic_analytics.py
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

import numpy as np

from workload import load_graph, make_ops, run_mix

N = 512
rng = np.random.default_rng(0)
graph = load_graph(N)
print(f"R-MAT graph: |V|={N}, |E|~{N*10} "
      f"(a=.5 b=.1 c=.1 d=.3, weights in [1, log2 N])\n")

for query in ("bfs", "sssp", "bc"):
    ops = make_ops(rng, 45, N, (0.4, 0.1, 0.5))
    print(f"--- {query.upper()}: 45 ops @ 40% update / 10% search / "
          f"50% query ---")
    for mode, label in (("pgcn", "PG-Cn  (linearizable)"),
                        ("pgicn", "PG-Icn (single collect)"),
                        ("static", "Static (dense semiring)")):
        r = run_mix(graph, ops, query, mode)
        per_q = r.seconds / max(r.queries, 1) * 1e3
        extra = ""
        if mode == "pgcn":
            extra = (f"  collects/scan={r.collects / max(r.queries, 1):.2f}"
                     f"  interrupts/query="
                     f"{r.interrupts / max(r.queries, 1):.1f}")
        print(f"  {label:26s} {per_q:9.2f} ms/query{extra}")
    print()

print("Same qualitative picture as the paper: PG-Icn trades consistency\n"
      "for an order of magnitude of throughput; PG-Cn pays for retries in\n"
      "proportion to the interrupting-update rate (Figs 12-13).")
