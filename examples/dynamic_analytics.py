"""Dynamic graph analytics under concurrent updates — a miniature of the
paper's Section 5 study (Figures 6-8): PG-Cn vs PG-Icn vs a Ligra-style
static engine, on an R-MAT graph with a 40/10/50 workload.

    PYTHONPATH=src python examples/dynamic_analytics.py
"""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

import numpy as np

from workload import load_graph, make_ops, run_mix
from repro.core import PUTE, REME
from repro.engine import GraphService

N = 512
rng = np.random.default_rng(0)
graph = load_graph(N)
print(f"R-MAT graph: |V|={N}, |E|~{N*10} "
      f"(a=.5 b=.1 c=.1 d=.3, weights in [1, log2 N])\n")

for query in ("bfs", "sssp", "bc"):
    ops = make_ops(rng, 45, N, (0.4, 0.1, 0.5))
    print(f"--- {query.upper()}: 45 ops @ 40% update / 10% search / "
          f"50% query ---")
    for mode, label in (("pgcn", "PG-Cn  (linearizable)"),
                        ("pgicn", "PG-Icn (single collect)"),
                        ("static", "Static (dense semiring)")):
        r = run_mix(graph, ops, query, mode)
        per_q = r.seconds / max(r.queries, 1) * 1e3
        extra = ""
        if mode == "pgcn":
            extra = (f"  collects/scan={r.collects / max(r.queries, 1):.2f}"
                     f"  interrupts/query="
                     f"{r.interrupts / max(r.queries, 1):.1f}")
        print(f"  {label:26s} {per_q:9.2f} ms/query{extra}")
    print()

print("Same qualitative picture as the paper: PG-Icn trades consistency\n"
      "for an order of magnitude of throughput; PG-Cn pays for retries in\n"
      "proportion to the interrupting-update rate (Figs 12-13).\n")

# --- The incremental engine on the same workload -------------------------
# GraphService streams the updates through the version ring and answers
# repeated queries from cached results + per-commit dirty sets, so most
# collects are a few delta relax passes instead of a full fixed point.
print("--- repro.engine.GraphService: streaming updates, delta queries ---")
svc = GraphService(graph, batch_size=16, ring_depth=16)
hot = rng.choice(N, size=max(2, N // 20), replace=False)  # ~5% hot set
t0 = time.perf_counter()
for _ in range(12):
    for _ in range(16):
        u, v = int(rng.choice(hot)), int(rng.integers(0, N))
        if rng.random() < 0.6:
            svc.submit((PUTE, u, v, float(rng.integers(1, 9))))
        else:
            svc.submit((REME, u, v))
    svc.flush()
    svc.query("bfs", 0)
    svc.query("sssp", 0, mode="cn")
dt = time.perf_counter() - t0
s = svc.stats
print(f"  {s.queries} queries over {svc.version} committed versions in "
      f"{dt * 1e3:.0f} ms\n"
      f"  answer modes: unchanged={s.unchanged} delta={s.delta} "
      f"full={s.full}  (cn retries={s.cn_retries})")
