"""Quickstart: the PANIGRAHAM dynamic-graph ADT and linearizable queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    PUTE, PUTV, REME, REMV, GETE,
    StateRef, apply_ops, bc, bfs, get_e, make_graph, num_edges,
    num_vertices, op_inconsistent, op_linearizable, sssp,
)

# --- build a small directed weighted graph (the ADT of Section 2) --------
g = make_graph(vcap=16, ecap=64)
g, res = apply_ops(g, [
    (PUTV, 0), (PUTV, 1), (PUTV, 2), (PUTV, 3), (PUTV, 4),
    (PUTE, 0, 1, 1.0), (PUTE, 1, 2, 2.0), (PUTE, 0, 2, 5.0),
    (PUTE, 2, 3, 1.0), (PUTE, 3, 4, 1.0),
])
print(f"graph: |V|={int(num_vertices(g))} |E|={int(num_edges(g))} "
      f"version={int(g.version)}")

# per-op ADT return values (exactly the paper's semantics)
g, res = apply_ops(g, [(PUTE, 0, 1, 3.0),    # replace -> (True, old=1.0)
                       (PUTE, 0, 1, 3.0),    # same weight -> (False, 3.0)
                       (REME, 9, 1)])        # missing vertex -> (False, inf)
print("PutE replace:", bool(res.ok[0]), float(res.val[0]))
print("PutE same   :", bool(res.ok[1]), float(res.val[1]))
print("RemE missing:", bool(res.ok[2]), float(res.val[2]))

# --- queries --------------------------------------------------------------
r = bfs(g, 0)
print("BFS dist from 0:", np.asarray(r.dist)[:5])
s = sssp(g, 0)
print("SSSP dist from 0:", np.asarray(s.dist)[:5], "negcycle:",
      bool(s.negcycle))
print("BC(2) over all sources:", float(bc(g, 2, sources=jnp.arange(5))))

# --- the snapshot protocol: PG-Cn vs PG-Icn -------------------------------
ref = StateRef(g)
_, stats = op_linearizable(ref, "sssp", 0)
print(f"PG-Cn : collects={stats.collects} validated={stats.validated}")

# an update stream that interferes with the first collects
updates = iter([[(PUTE, 0, 3, 0.5)], [(REME, 0, 3)]])


def interrupt(r):
    ops = next(updates, None)
    if ops:
        ns, _ = apply_ops(r.state, ops)
        r.commit(ns)


ref2 = StateRef(g, on_read=[interrupt])
_, stats = op_linearizable(ref2, "sssp", 0)
print(f"PG-Cn under updates: collects={stats.collects} "
      f"interrupting_updates={stats.interrupting_updates}")
_, stats = op_inconsistent(StateRef(g), "sssp", 0)
print(f"PG-Icn: collects={stats.collects} (no validation)")
