"""Serve a small model with batched requests: prefill + incremental decode
through the snapshot-validated parameter store.

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys
import os

root = os.path.join(os.path.dirname(__file__), "..")
cmd = [
    sys.executable, "-m", "repro.launch.serve",
    "--arch", "granite_moe_1b", "--reduced",
    "--batch", "4", "--prompt-len", "32", "--gen", "16",
]
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(root, "src")
raise SystemExit(subprocess.call(cmd, env=env))
