"""The graph query operations: BFS, SSSP (+negative-cycle check), BC.

Non-recursive traversals (the paper's queue/stack machinery) become
*edge-parallel frontier fixed points* under ``lax.while_loop``:

  * BFS      -- boolean-semiring frontier expansion (scatter-or per level);
  * SSSP     -- Bellman-Ford relax to fixed point, plus the paper's
                CHECKNEGCYCLE: one extra relax pass; any improvement implies a
                negative cycle reachable from the source;
  * BC       -- Brandes: forward level/sigma counting, backward dependency
                accumulation per level.

Each query also has a *dense batched* variant (vmap over sources becomes a
semiring matmul on the MXU -- see ``semiring.py`` / ``repro.kernels``), which
is both the Ligra-style static baseline and the TPU-native path the paper's
CPU design could not exploit.

All functions are pure and jitted; masks/dists are fixed-shape ``[vcap]``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .graph_state import INF, NOKEY, GraphState, densify, live_edge_mask
from . import semiring


class BFSResult(NamedTuple):
    ok: jax.Array        # bool[]  source was alive
    reached: jax.Array   # bool[vcap]
    dist: jax.Array      # int32[vcap]  (-1 = unreached)
    parent: jax.Array    # int32[vcap]  (NOKEY = none; BFS-tree edges)


class SSSPResult(NamedTuple):
    ok: jax.Array        # bool[]  source alive and no negative cycle
    negcycle: jax.Array  # bool[]
    dist: jax.Array      # f32[vcap]  (+inf = unreachable)
    parent: jax.Array    # int32[vcap]


class BCResult(NamedTuple):
    ok: jax.Array        # bool[]
    delta: jax.Array     # f32[vcap]  dependencies delta(s|v) of source s
    sigma: jax.Array     # f32[vcap]  shortest-path counts from s
    level: jax.Array     # int32[vcap]


def _edge_views(state: GraphState):
    vcap = state.vcap
    live = live_edge_mask(state)
    srcc = jnp.where(live, state.esrc, 0)
    dstc = jnp.where(live, state.edst, 0)
    return live, srcc, dstc


# --------------------------------- BFS -----------------------------------

@jax.jit
def bfs(state: GraphState, src) -> BFSResult:
    src = jnp.asarray(src, jnp.int32)
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ok = state.alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)

    reached0 = jnp.zeros((vcap,), jnp.bool_).at[src].set(ok, mode="drop")
    dist0 = jnp.where(reached0, 0, -1).astype(jnp.int32)
    parent0 = jnp.full((vcap,), NOKEY, jnp.int32)

    def cond(carry):
        _, _, _, frontier, lvl = carry
        return frontier.any() & (lvl < vcap)

    def body(carry):
        reached, dist, parent, frontier, lvl = carry
        act = live & frontier[srcc]
        hit = jnp.zeros((vcap,), jnp.bool_).at[dstc].max(act, mode="drop")
        newly = hit & ~reached
        cand_par = jnp.full((vcap,), NOKEY, jnp.int32).at[dstc].min(
            jnp.where(act, srcc, NOKEY), mode="drop")
        parent = jnp.where(newly, cand_par, parent)
        dist = jnp.where(newly, lvl + 1, dist)
        return reached | newly, dist, parent, newly, lvl + 1

    reached, dist, parent, _, _ = lax.while_loop(
        cond, body, (reached0, dist0, parent0, reached0, jnp.int32(0)))
    return BFSResult(ok, reached, dist, parent)


# --------------------------------- SSSP ----------------------------------

def _relax_once(dist, live, srcc, dstc, ew, vcap):
    cand = jnp.full((vcap,), INF).at[dstc].min(
        jnp.where(live, dist[srcc] + ew, INF), mode="drop")
    return jnp.minimum(dist, cand)


def relax_fixpoint(dist0, live, srcc, dstc, ew, vcap):
    """Bellman-Ford label-correcting fixed point from admissible upper bounds.

    Returns ``(dist, changed-at-exit, iterations)``.  Shared by ``sssp`` and
    the engine's delta queries (``repro.engine.incremental``) so the two
    paths cannot drift apart — their bit-identical guarantee rests on
    running the exact same relax pass.
    """

    def cond(carry):
        _, changed, it = carry
        return changed & (it < vcap)

    def body(carry):
        dist, _, it = carry
        nd = _relax_once(dist, live, srcc, dstc, ew, vcap)
        return nd, (nd < dist).any(), it + 1

    return lax.while_loop(cond, body, (dist0, jnp.bool_(True), jnp.int32(0)))


@jax.jit
def sssp(state: GraphState, src) -> SSSPResult:
    src = jnp.asarray(src, jnp.int32)
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ew = jnp.where(live, state.ew, INF)
    ok_src = state.alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)

    dist0 = jnp.full((vcap,), INF).at[src].set(
        jnp.where(ok_src, 0.0, INF), mode="drop")

    dist, changed, _ = relax_fixpoint(dist0, live, srcc, dstc, ew, vcap)

    # The paper's CHECKNEGCYCLE for free: the fixed-point loop only exits
    # with ``changed`` still True when the vcap-th pass improved something,
    # which (shortest simple paths having < vcap edges) happens iff a
    # negative cycle is reachable — the extra relax pass it would otherwise
    # take to prove convergence is the loop's own final no-change pass.
    negcycle = changed

    # Parent reconstruction: any tight edge dist[v] == dist[u] + w(u,v);
    # deterministic tie-break = min source id.
    tight = live & (dist[dstc] == dist[srcc] + ew) & (dist[srcc] < INF)
    parent = jnp.full((vcap,), NOKEY, jnp.int32).at[dstc].min(
        jnp.where(tight, srcc, NOKEY), mode="drop")
    parent = parent.at[jnp.clip(src, 0, vcap - 1)].set(NOKEY)
    return SSSPResult(ok_src & ~negcycle, negcycle, dist, parent)


# ---------------------------------- BC -----------------------------------

def _bc_coo_sweep(live, srcc, dstc, vcap, level0, sigma0, front0, lvl0):
    """Brandes forward + backward over COO edges from a (possibly warm) start.

    The shared body of ``bc_dependencies`` (cold start: source frontier at
    level 0) and the engine's level-cut ``delta_bc`` (warm start: the prior
    forward tree above the cut, frontier at ``cut - 1``).  Warm starts
    produce bit-identical results because the loop state at pass ``lvl0``
    equals the cold run's state at that pass — the levels below ``lvl0``
    are required to be exactly what a cold run would have computed.
    """

    # Forward phase: levels + shortest-path counts.
    def fcond(carry):
        _, _, frontier, lvl = carry
        return frontier.any() & (lvl < vcap)

    def fbody(carry):
        level, sigma, frontier, lvl = carry
        act = live & frontier[srcc]
        hit = jnp.zeros((vcap,), jnp.bool_).at[dstc].max(act, mode="drop")
        newly = hit & (level < 0)
        adds = jnp.zeros((vcap,), jnp.float32).at[dstc].add(
            jnp.where(act, sigma[srcc], 0.0), mode="drop")
        sigma = jnp.where(newly, adds, sigma)
        level = jnp.where(newly, lvl + 1, level)
        return level, sigma, newly, lvl + 1

    level, sigma, _, _ = lax.while_loop(
        fcond, fbody, (level0, sigma0, front0, jnp.asarray(lvl0, jnp.int32)))

    # Backward phase: delta[u] += sum over tree edges (u,w) at level l->l+1
    # of sigma[u]/sigma[w] * (1 + delta[w]), from the deepest level down
    # (max(level) == deepest reached level; -1 when nothing is reached).
    sig_src = sigma[srcc]
    sig_dst = jnp.where(sigma[dstc] > 0, sigma[dstc], 1.0)

    def bcond(carry):
        _, l = carry
        return l >= 0

    def bbody(carry):
        delta, l = carry
        on_lvl = live & (level[srcc] == l) & (level[dstc] == l + 1)
        contrib = jnp.where(on_lvl, sig_src / sig_dst * (1.0 + delta[dstc]), 0.0)
        delta = delta + jnp.zeros((vcap,), jnp.float32).at[srcc].add(
            contrib, mode="drop")
        return delta, l - 1

    delta, _ = lax.while_loop(
        bcond, bbody, (jnp.zeros((vcap,), jnp.float32), jnp.max(level)))
    delta = jnp.where(level == 0, 0.0, delta)  # source contributes nothing
    return level, sigma, delta


@jax.jit
def bc_dependencies(state: GraphState, src) -> BCResult:
    """Brandes single-source dependency accumulation delta(src | .)."""
    src = jnp.asarray(src, jnp.int32)
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ok = state.alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)

    level0 = jnp.full((vcap,), -1, jnp.int32).at[src].set(
        jnp.where(ok, 0, -1), mode="drop")
    sigma0 = jnp.zeros((vcap,), jnp.float32).at[src].set(
        jnp.where(ok, 1.0, 0.0), mode="drop")
    front0 = level0 == 0

    level, sigma, delta = _bc_coo_sweep(
        live, srcc, dstc, vcap, level0, sigma0, front0, jnp.int32(0))
    return BCResult(ok, delta, sigma, level)


@jax.jit
def bc_level_cut(prior_level, dirty, alive):
    """Shallowest forward level a dirty set can have poisoned, per source.

    ``prior_level`` is ``int32[vcap]`` (one source) or ``int32[S, vcap]``
    (batched; ``dirty``/``alive`` broadcast over sources).  Levels strictly
    below the returned cut are guaranteed untouched: BFS level sets are
    determined level-by-level by the out-edge lists of the previous level's
    vertices, every edge mutation dirties the edge's *source*, and a
    liveness flip dirties the vertex itself — so a dirty vertex at prior
    level ``l`` can disturb levels ``>= l + 1`` through its out-edges, or
    level ``l`` itself only by dying.  Sources untouched by the dirty set
    get a cut past every level (pure reuse); a cut of 0 means the source
    itself is suspect and the caller must recompute that source cold.
    """
    reached = prior_level >= 0
    d = dirty & reached
    died = d & ~alive
    big = jnp.int32(prior_level.shape[-1] + 1)  # deeper than any level
    c1 = jnp.min(jnp.where(died, prior_level, big), axis=-1)
    c2 = jnp.min(jnp.where(d, prior_level + 1, big), axis=-1)
    return jnp.minimum(c1, c2)


# ------------------------ traversal-tree parents ---------------------------

@jax.jit
def bfs_tree_parents(state: GraphState, dist: jax.Array,
                     srcs: jax.Array) -> jax.Array:
    """Canonical BFS-tree parents from final distances, batched over sources.

    ``dist`` is ``int32[S, vcap]`` (-1 unreached); returns
    ``int32[S, vcap]`` parents identical to per-source ``queries.bfs``: the
    frontier at level ``l`` is exactly ``{u : dist[u] == l}``, so the
    min-source over tree edges ``dist[u] + 1 == dist[v]`` reproduces the
    per-level min-source candidate.  Shared by the engine's ``delta_bfs``
    and the sharded queries (``repro.shard.queries``) so every path derives
    parents from one definition.
    """
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)

    def one(d, s):
        distf = jnp.where(d >= 0, d.astype(jnp.float32), INF)
        tree = live & (distf[srcc] + 1.0 == distf[dstc]) & (distf[srcc] < INF)
        parent = jnp.full((vcap,), NOKEY, jnp.int32).at[dstc].min(
            jnp.where(tree, srcc, NOKEY), mode="drop")
        parent = jnp.where(d >= 0, parent, NOKEY)
        return parent.at[jnp.clip(s, 0, vcap - 1)].set(NOKEY)

    return jax.vmap(one)(dist, srcs)


@jax.jit
def sssp_tree_parents(state: GraphState, dist: jax.Array,
                      srcs: jax.Array) -> jax.Array:
    """Tight-edge parents from final distances, batched over sources.

    ``dist`` is ``f32[S, vcap]`` (+inf unreachable); identical to
    per-source ``queries.sssp``: any tight edge
    ``dist[v] == dist[u] + w(u, v)``, min source id as tie-break.
    """
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ew = jnp.where(live, state.ew, INF)

    def one(d, s):
        tight = live & (d[dstc] == d[srcc] + ew) & (d[srcc] < INF)
        parent = jnp.full((vcap,), NOKEY, jnp.int32).at[dstc].min(
            jnp.where(tight, srcc, NOKEY), mode="drop")
        return parent.at[jnp.clip(s, 0, vcap - 1)].set(NOKEY)

    return jax.vmap(one)(dist, srcs)


def bc_map(state: GraphState, v, sources) -> jax.Array:
    """Per-source Brandes baseline: ``lax.map`` of ``bc_dependencies``.

    Kept as the oracle/benchmark baseline for ``bc``'s batched path.
    """
    v = jnp.asarray(v, jnp.int32)

    def one(s):
        r = bc_dependencies(state, s)
        return jnp.where(r.ok, r.delta[jnp.clip(v, 0, state.vcap - 1)], 0.0)

    return jnp.sum(lax.map(one, jnp.asarray(sources, jnp.int32)))


def bc(state: GraphState, v, sources=None, *, method: str = "batched",
       use_kernel: bool = False, tile_view=None,
       src_chunk: int | None = None) -> jax.Array:
    """Betweenness centrality of ``v``: sum_s delta(s|v).

    ``sources`` defaults to every vertex slot (dead sources contribute 0 —
    exact Brandes over the alive set).  The default ``method="batched"``
    runs every source at once as level-synchronous semiring matmuls
    (``bc_batched_dense``); ``method="map"`` is the per-source ``lax.map``
    baseline.  ``tile_view`` (see ``repro.core.tiles``) supplies the dense
    weights plus the tile-occupancy mask so the semiring products skip
    empty tiles.  ``src_chunk`` bounds the batched path's S x V scratch
    (see ``bc_batched_dense``).
    """
    v = jnp.asarray(v, jnp.int32)
    if sources is None:
        sources = jnp.arange(state.vcap, dtype=jnp.int32)
    sources = jnp.asarray(sources, jnp.int32)
    ok = state.alive[jnp.clip(v, 0, state.vcap - 1)]
    if method == "map":
        total = bc_map(state, v, sources)
        return jnp.where(ok, total, jnp.nan)
    if method != "batched":
        raise ValueError(f"unknown bc method {method!r}")
    tile = 128
    if tile_view is not None:
        from .tiles import dense_views_from_tiles
        adj_mask, _, alive = dense_views_from_tiles(state, tile_view)
        amask, tile = tile_view.occ, tile_view.tile
    else:
        adj_mask, _, alive = dense_views(state)
        amask = None
    delta, _, _, src_ok = bc_batched_dense(
        adj_mask, sources, alive, use_kernel=use_kernel, amask=amask,
        tile=tile, src_chunk=src_chunk)
    vals = jnp.where(src_ok, delta[:, jnp.clip(v, 0, state.vcap - 1)], 0.0)
    return jnp.where(ok, jnp.sum(vals), jnp.nan)


# ------------------------ dense batched variants --------------------------
# vmap-over-sources == semiring matmuls: the MXU path (and the "static
# parallel analytics" baseline corresponding to Ligra in the paper's study).

@partial(jax.jit, static_argnames=("use_kernel", "tile"))
def bfs_batched_dense(adj_mask: jax.Array, srcs: jax.Array,
                      alive: jax.Array, use_kernel: bool = False,
                      amask: jax.Array | None = None, tile: int = 128):
    """Multi-source BFS over a dense adjacency mask.  Returns dist[S, V].

    ``amask``: optional tile-occupancy grid of the adjacency (see
    ``repro.core.tiles``) — empty tiles are skipped by the semiring product.
    """
    V = adj_mask.shape[0]
    a = (adj_mask & alive[:, None] & alive[None, :]).astype(jnp.float32)
    ok = alive[jnp.clip(srcs, 0, V - 1)]
    front0 = jax.nn.one_hot(srcs, V, dtype=jnp.float32) * ok[:, None]
    dist0 = jnp.where(front0 > 0, 0, -1).astype(jnp.int32)

    def cond(c):
        _, front, lvl = c
        return (front > 0).any() & (lvl < V)

    def body(c):
        dist, front, lvl = c
        nxt = semiring.bool_mm(front, a, use_kernel=use_kernel,
                               amask=amask, tile=tile)
        newly = (nxt > 0) & (dist < 0)
        dist = jnp.where(newly, lvl + 1, dist)
        return dist, newly.astype(jnp.float32), lvl + 1

    dist, _, _ = lax.while_loop(cond, body, (dist0, front0, jnp.int32(0)))
    return dist


@partial(jax.jit, static_argnames=("use_kernel", "tile"))
def sssp_batched_dense(w_dense: jax.Array, srcs: jax.Array,
                       alive: jax.Array, use_kernel: bool = False,
                       amask: jax.Array | None = None, tile: int = 128):
    """Multi-source Bellman-Ford over dense weights.  Returns (dist[S,V], negcycle[S])."""
    V = w_dense.shape[0]
    S = srcs.shape[0]
    big = jnp.where(alive[:, None] & alive[None, :], w_dense, INF)
    ok = alive[jnp.clip(srcs, 0, V - 1)]
    dist0 = jnp.where(
        jax.nn.one_hot(srcs, V, dtype=jnp.float32) * ok[:, None] > 0, 0.0, INF)

    def cond(c):
        _, changed, it = c
        return changed.any() & (it < V)

    def body(c):
        dist, _, it = c
        nd = jnp.minimum(dist, semiring.minplus_mm(dist, big,
                                                   use_kernel=use_kernel,
                                                   amask=amask, tile=tile))
        return nd, (nd < dist).any(axis=1), it + 1

    # The paper's CHECKNEGCYCLE from the loop's own exit state (PR 1 applied
    # this to the COO path): row s of the per-source changed vector is still
    # True at exit only when the V-th relax pass improved that source's
    # distances, which — shortest simple paths having < V edges — happens
    # iff a negative cycle is reachable from s.  No extra relax pass needed.
    dist, changed, _ = lax.while_loop(
        cond, body, (dist0, jnp.ones((S,), jnp.bool_), jnp.int32(0)))
    return dist, changed


def dense_views(state: GraphState):
    """Snapshot -> (adjacency mask, dense weights, alive) for batched queries."""
    w = densify(state)
    return w < INF, w, state.alive


# ------------------------- batched Brandes (BC) ---------------------------

def bc_sweep_ops(fwd_mm, bwd_mm, srcs: jax.Array, alive: jax.Array, V: int,
                 prior_level=None, prior_sigma=None, cut=None,
                 sync_any=None, sync_max=None):
    """One forward+backward Brandes sweep over *abstract* semiring products.

    The sweep never touches the adjacency itself — it only calls

      * ``fwd_mm(x)``  with the frontier-masked sigma ``x: f32[S, V]`` and
        expects the counting product ``x @ A``  (``f32[S, V]``);
      * ``bwd_mm(g)``  with the dependency flow ``g: f32[S, V]`` and
        expects ``g @ A^T`` (``f32[S, V]``)

    — which is what lets one sweep body serve both the dense chunked path
    (``bc_batched_dense``: one ``count_mm`` against the full matrix) and
    the sharded SUMMA-style ring path (``repro.shard.queries``: the
    products are assembled from O(V^2/n) bands rotated around the mesh
    with ``lax.ppermute``, no adjacency ever materialised per shard).
    Levels and sigma are bit-identical across providers: sigma counts are
    exact integers in f32 (< 2^24), so the band summation order cannot
    change them; only the backward ``delta`` sees f32 reassociation.

    ``sync_any``/``sync_max`` (default: identity) merge the loop-control
    predicates across whatever the products span.  A provider whose
    ``fwd_mm``/``bwd_mm`` contain collectives (the ring) MUST run its
    level loops in lock-step on every shard — a shard that exited early
    would abandon the rotation mid-ring — so the ring passes ``pmax``
    reductions here; extra lock-step iterations are exact no-ops (empty
    frontiers add zeros).

    ``prior_level``/``prior_sigma``/``cut`` warm-start the forward sweep
    per source (the level-cut delta-BC path): levels strictly below
    ``cut[s]`` are reused from the prior forward tree and source ``s``
    resumes expanding from its frontier at level ``cut[s] - 1``; a cut of
    0 (source itself suspect) restarts that source cold, and a cut past
    every level (untouched source) reuses its whole tree with zero forward
    passes.  The per-source level counter makes rows independent, so mixed
    cuts share one loop; each row's state at its resume pass equals the
    cold run's state at that pass, hence levels/sigma stay bit-identical
    and the (full) backward sweep reproduces delta bit-identically too.
    """
    if sync_any is None:
        sync_any = lambda p: p  # noqa: E731
    if sync_max is None:
        sync_max = lambda x: x  # noqa: E731
    S = srcs.shape[0]
    ok = alive[jnp.clip(srcs, 0, V - 1)] & (srcs >= 0) & (srcs < V)
    cold_front = jax.nn.one_hot(srcs, V, dtype=jnp.float32) * ok[:, None]
    level0 = jnp.where(cold_front > 0, 0, -1).astype(jnp.int32)
    sigma0 = cold_front
    lvl0 = jnp.zeros((S,), jnp.int32)
    if prior_level is not None:
        cut = jnp.broadcast_to(jnp.asarray(cut, jnp.int32), (S,))
        # A now-ok source whose prior tree is EMPTY (it was dead when the
        # prior was computed and has been resurrected since) looks
        # untouched to the level cut — its row has no reached levels for
        # the dirty set to intersect — but must restart cold.
        rows = jnp.arange(S, dtype=jnp.int32)
        revived = ok & (prior_level[rows, jnp.clip(srcs, 0, V - 1)] < 0)
        cut = jnp.where(revived, 0, cut)
        warm = (cut >= 1)[:, None]
        keep = warm & (prior_level >= 0) & (prior_level < cut[:, None])
        level0 = jnp.where(warm, jnp.where(keep, prior_level, -1), level0)
        sigma0 = jnp.where(warm, jnp.where(keep, prior_sigma, 0.0), sigma0)
        lvl0 = jnp.maximum(cut - 1, 0)
    front0 = (level0 == lvl0[:, None]).astype(jnp.float32)

    # Forward phase: levels + shortest-path counts.  The continue flag is
    # computed in the body and carried (rather than derived in the cond)
    # so a collective sync_any stays legal — while-loop conds must be
    # collective-free.
    def _more(front, lvl):
        return sync_any((front > 0).any() & (lvl < V).any())

    def fcond(c):
        return c[4]

    def fbody(c):
        level, sigma, front, lvl, _ = c
        # One counting product per level does both jobs: frontier sigma is
        # >= 1 on every frontier vertex and counts are exact integers in
        # f32 (below 2^24), so adds > 0 is precisely the bool_mm frontier
        # hit — no separate boolean product needed.
        adds = fwd_mm(jnp.where(front > 0, sigma, 0.0))
        newly = (adds > 0) & (level < 0)
        sigma = jnp.where(newly, adds, sigma)
        level = jnp.where(newly, lvl[:, None] + 1, level)
        front = newly.astype(jnp.float32)
        return level, sigma, front, lvl + 1, _more(front, lvl + 1)

    level, sigma, _, _, _ = lax.while_loop(
        fcond, fbody, (level0, sigma0, front0, lvl0, _more(front0, lvl0)))

    # Backward phase, deepest level first.  g carries the per-vertex
    # dependency flow of the level below; pulling it across edges is a
    # counting product against A^T.
    sig_safe = jnp.where(sigma > 0, sigma, 1.0)

    def bcond(c):
        _, l = c
        return l >= 0

    def bbody(c):
        delta, l = c
        g = jnp.where(level == l + 1, (1.0 + delta) / sig_safe, 0.0)
        pulled = bwd_mm(g)
        delta = delta + jnp.where(level == l, sigma * pulled, 0.0)
        return delta, l - 1

    # The deepest *edge* layer is (max level - 1) -> (max level); with
    # per-source resume passes the loop counter no longer bounds the depth,
    # so take it off the levels themselves.  sync_max keeps lock-step
    # providers iterating to the deepest level of ANY shard's chunk — the
    # extra iterations pull zero flow.
    delta, _ = lax.while_loop(
        bcond, bbody, (jnp.zeros_like(sigma), sync_max(jnp.max(level)) - 1))
    delta = jnp.where(level == 0, 0.0, delta)  # sources contribute nothing
    return delta, sigma, level, ok


@partial(jax.jit, static_argnames=("use_kernel", "tile", "src_chunk"))
def bc_batched_dense(adj_mask: jax.Array, srcs: jax.Array, alive: jax.Array,
                     use_kernel: bool = False,
                     amask: jax.Array | None = None, tile: int = 128,
                     src_chunk: int | None = None,
                     prior_level: jax.Array | None = None,
                     prior_sigma: jax.Array | None = None,
                     cut: jax.Array | None = None):
    """Multi-source Brandes as level-synchronous semiring matmuls.

    Forward sweep: bool_mm expands the per-source frontier (levels) while
    count_mm accumulates sigma, the number of shortest paths (integers in
    f32 — exact below 2^24).  Backward sweep: per level ``l`` (deepest
    first) the dependency flow  delta[u] += sigma[u] * sum_w A[u,w] *
    [level[w] = l+1] * (1 + delta[w]) / sigma[w]  is one count_mm against
    the transposed adjacency.  Levels and sigma match per-source
    ``bc_dependencies`` bit-exactly; delta agrees up to float summation
    order (the scatter-add vs MXU-dot reassociation).

    Returns ``(delta[S,V], sigma[S,V], level[S,V], ok[S])``.

    ``amask``: optional tile-occupancy grid of the adjacency — both sweeps
    skip empty tiles (the transposed sweep uses the transposed grid).

    ``src_chunk``: process the source axis in chunks of this size (the
    tail chunk may be ragged), one full forward+backward sweep per chunk
    with the chunk's forward levels reused by its backward sweep.  Peak
    scratch drops from 4 x S x V to 4 x src_chunk x V f32, which is what
    lets all-source BC run past vcap ~ 16k; per-source results are
    independent of the chunking (levels/sigma bit-exact; the matmul k
    reduction is unchanged, so delta only sees the padding's exact +0.0
    terms).

    ``prior_level``/``prior_sigma`` (``[S, V]``, a prior call's forward
    tree on the same sources) + ``cut`` (``int32[S]`` or scalar, from
    ``bc_level_cut``) select the level-cut delta path: each source reuses
    its cached levels/sigma strictly below its cut and re-runs the forward
    only from there (the backward sweep always runs in full — dependency
    flow crosses the cut upward).  Results are bit-identical to the cold
    call on every source (see ``bc_sweep_ops``).
    """
    a = (adj_mask & alive[:, None] & alive[None, :]).astype(jnp.float32)
    at = a.T
    amask_t = None if amask is None else amask.T

    def fwd_mm(x):
        return semiring.count_mm(x, a, use_kernel=use_kernel, amask=amask,
                                 tile=tile)

    def bwd_mm(g):
        return semiring.count_mm(g, at, use_kernel=use_kernel, amask=amask_t,
                                 tile=tile)

    return bc_batched_ops(fwd_mm, bwd_mm, srcs, alive, a.shape[0],
                          src_chunk=src_chunk, prior_level=prior_level,
                          prior_sigma=prior_sigma, cut=cut)


def bc_batched_ops(fwd_mm, bwd_mm, srcs: jax.Array, alive: jax.Array, V: int,
                   *, src_chunk: int | None = None,
                   prior_level: jax.Array | None = None,
                   prior_sigma: jax.Array | None = None,
                   cut: jax.Array | None = None,
                   sync_any=None, sync_max=None):
    """The chunked batched-Brandes driver over abstract semiring products.

    Exactly ``bc_batched_dense``'s source-chunking loop (one full
    forward+backward ``bc_sweep_ops`` per chunk, tail chunk ragged, warm
    state sliced per chunk) but consuming ``fwd_mm``/``bwd_mm`` providers
    instead of a materialised adjacency — the hook the sharded ring BC
    uses to run the identical per-chunk sweep over rotated O(V^2/n) bands.
    ``sync_any``/``sync_max`` are forwarded to every chunk's sweep (see
    ``bc_sweep_ops``).
    """
    S = srcs.shape[0]
    warm = prior_level is not None
    if warm:
        if prior_sigma is None or cut is None:
            raise ValueError("warm start needs prior_level, prior_sigma "
                             "and cut together")
        cut = jnp.broadcast_to(jnp.asarray(cut, jnp.int32), (S,))
    if src_chunk is None or src_chunk >= S:
        return bc_sweep_ops(fwd_mm, bwd_mm, srcs, alive, V,
                            prior_level, prior_sigma, cut,
                            sync_any, sync_max)
    if src_chunk < 1:
        raise ValueError(f"src_chunk must be >= 1, got {src_chunk}")
    parts = [bc_sweep_ops(fwd_mm, bwd_mm, srcs[lo:lo + src_chunk], alive, V,
                          prior_level[lo:lo + src_chunk] if warm else None,
                          prior_sigma[lo:lo + src_chunk] if warm else None,
                          cut[lo:lo + src_chunk] if warm else None,
                          sync_any, sync_max)
             for lo in range(0, S, src_chunk)]
    return tuple(jnp.concatenate([p[i] for p in parts], axis=0)
                 for i in range(4))
