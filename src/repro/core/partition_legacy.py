"""LEGACY distributed graph engine: round-robin *edge* sharding (pre-PR-3).

Superseded by the sharded tile-grid subsystem (``repro.shard``, fronted by
``core.partition.make_distributed_query``), which shards the ``TileView``
tile rows instead and reuses the tile-skipping semiring path per shard.
This module is retained as the independent cross-implementation ORACLE for
the distributed tests (two decompositions agreeing on the same snapshot is
a far stronger check than either alone) — do not grow new features here.

The paper's 56 CPU threads become mesh devices.  The decomposition:

  * **edges are sharded** round-robin over a 1-D ``graph`` axis (we flatten
    the production mesh's ``data`` x ``model`` axes, and ``pod`` too in the
    multi-pod case): each shard owns ``ecap / n`` contiguous slots of the
    sorted edge array (a contiguous key range, like one Ligra partition);
  * **vertex arrays are replicated** (bool/int32 of size vcap -- tiny next to
    the edge table) so every shard validates liveness locally;
  * each BFS/SSSP level does local edge-parallel work then ONE ``psum`` of a
    vcap-sized vector to merge frontiers/distances -- the only collective.
    Collective bytes per query = O(levels * vcap * 4B), independent of E:
    exactly the paper's property that queries touch each vertex's metadata,
    not each edge, when validating.
  * the double-collect validation vector (reached/parent/ecnt) is computed on
    the merged arrays, identically on every shard -- cross-shard snapshot
    agreement for free (deterministic SPMD), with the version psum-checked.

``distributed_*`` functions take *already sharded* edge arrays and are meant
to be called under ``shard_map`` -- see ``make_distributed_query`` which
builds the pjit'd entry point for a given mesh, and is also what
``launch/dryrun.py`` lowers for the graph-engine dry-run cells.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .graph_state import INF, NOKEY, GraphState


GRAPH_AXES = ("data", "model")  # flattened into one logical graph axis


def _psum(x, axes):
    for ax in axes:
        x = lax.psum(x, ax)
    return x


def _pmax(x, axes):
    for ax in axes:
        x = lax.pmax(x, ax)
    return x


# Shard-local bodies (run under shard_map; edge arrays are per-shard slices).

def _bfs_sharded(alive, ecnt, esrc, edst, ew, src, axes):
    """Distributed BFS, collective-lean form (Perf §graph iter 1-2).

    Per level the ONLY collective is a pmax of an int8[vcap] hit mask
    (131 KB at the 131072-vertex Table-1 scale).  The BFS-tree parents are
    reconstructed AFTER the fixed point with one edge-parallel pass + one
    int32 merge — the paper's per-visit parent bookkeeping moved out of the
    critical path (8x less ICI volume per level than merging parents every
    level; measured in EXPERIMENTS.md §Perf).
    """
    vcap = alive.shape[0]
    live = (esrc != NOKEY) & (ew < INF)
    srcc = jnp.where(live, jnp.clip(esrc, 0, vcap - 1), 0)
    dstc = jnp.where(live, jnp.clip(edst, 0, vcap - 1), 0)
    live = live & alive[srcc] & alive[dstc]

    ok = alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)
    reached0 = jnp.zeros((vcap,), jnp.bool_).at[src].set(ok, mode="drop")
    dist0 = jnp.where(reached0, 0, -1).astype(jnp.int32)

    def cond(c):
        _, _, frontier, lvl = c
        return frontier.any() & (lvl < vcap)

    def body(c):
        reached, dist, frontier, lvl = c
        act = live & frontier[srcc]
        hit_local = jnp.zeros((vcap,), jnp.int8).at[dstc].max(
            act.astype(jnp.int8), mode="drop")
        hit = _pmax(hit_local, axes) > 0           # one int8 pmax / level
        newly = hit & ~reached
        dist = jnp.where(newly, lvl + 1, dist)
        return reached | newly, dist, newly, lvl + 1

    reached, dist, _, _ = lax.while_loop(
        cond, body, (reached0, dist0, reached0, jnp.int32(0)))

    # parent reconstruction: any tree edge dist[dst] == dist[src] + 1,
    # deterministic min-src tie-break; one int32 merge for the whole tree.
    tree_e = live & (dist[dstc] == dist[srcc] + 1) & (dist[srcc] >= 0)
    par_local = jnp.full((vcap,), NOKEY, jnp.int32).at[dstc].min(
        jnp.where(tree_e, srcc, NOKEY), mode="drop")
    parent = -_pmax(-par_local, axes)
    parent = jnp.where(reached & (dist > 0), parent, NOKEY)

    # validation vector (identical on all shards by construction)
    val_ecnt = jnp.where(reached, ecnt, 0)
    return reached, dist, parent, val_ecnt


def _sssp_sharded(alive, ecnt, esrc, edst, ew, src, axes):
    vcap = alive.shape[0]
    live = (esrc != NOKEY) & (ew < INF)
    srcc = jnp.where(live, jnp.clip(esrc, 0, vcap - 1), 0)
    dstc = jnp.where(live, jnp.clip(edst, 0, vcap - 1), 0)
    live = live & alive[srcc] & alive[dstc]
    w = jnp.where(live, ew, INF)

    ok = alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)
    dist0 = jnp.full((vcap,), INF).at[src].set(
        jnp.where(ok, 0.0, INF), mode="drop")

    def relax(dist):
        cand_local = jnp.full((vcap,), INF).at[dstc].min(
            jnp.where(live, dist[srcc] + w, INF), mode="drop")
        cand = -_pmax(-cand_local, axes)  # global min-merge
        return jnp.minimum(dist, cand)

    def cond(c):
        _, changed, it = c
        return changed & (it < vcap)

    def body(c):
        dist, _, it = c
        nd = relax(dist)
        return nd, (nd < dist).any(), it + 1

    dist, _, _ = lax.while_loop(cond, body, (dist0, jnp.bool_(True), jnp.int32(0)))
    negcycle = (relax(dist) < dist).any()
    reached = dist < INF
    val_ecnt = jnp.where(reached, ecnt, 0)
    return reached, dist, negcycle, val_ecnt


def shard_edges(state: GraphState, n_shards: int) -> GraphState:
    """Pad the edge table so ``ecap`` divides evenly across shards."""
    rem = (-state.ecap) % n_shards
    if rem == 0:
        return state
    return state._replace(
        esrc=jnp.concatenate([state.esrc, jnp.full((rem,), NOKEY, jnp.int32)]),
        edst=jnp.concatenate([state.edst, jnp.full((rem,), NOKEY, jnp.int32)]),
        ew=jnp.concatenate([state.ew, jnp.full((rem,), INF, jnp.float32)]),
    )


def make_distributed_query(mesh: Mesh, query: str = "bfs"):
    """Build the pjit'd distributed query for ``mesh``.

    Edge arrays sharded over every mesh axis (flattened); vertex arrays
    replicated.  Returns ``(fn, in_shardings, out_shardings)`` where
    ``fn(alive, ecnt, esrc, edst, ew, src)``.
    """
    axes = tuple(mesh.axis_names)
    espec = P(axes)          # edge arrays: fully sharded over all axes
    vspec = P()              # vertex arrays: replicated
    body = {"bfs": _bfs_sharded, "sssp": _sssp_sharded}[query]

    fn = shard_map(
        partial(body, axes=axes),
        mesh=mesh,
        in_specs=(vspec, vspec, espec, espec, espec, vspec),
        out_specs=vspec,
        check_rep=False,
    )
    in_sh = (
        NamedSharding(mesh, vspec), NamedSharding(mesh, vspec),
        NamedSharding(mesh, espec), NamedSharding(mesh, espec),
        NamedSharding(mesh, espec), NamedSharding(mesh, vspec),
    )
    out_sh = NamedSharding(mesh, vspec)
    return fn, in_sh, out_sh


def distributed_query_specs(vcap: int, ecap: int, mesh: Mesh):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    n = mesh.devices.size
    ecap_p = ecap + ((-ecap) % n)
    sds = jax.ShapeDtypeStruct
    return (
        sds((vcap,), jnp.bool_),
        sds((vcap,), jnp.int32),
        sds((ecap_p,), jnp.int32),
        sds((ecap_p,), jnp.int32),
        sds((ecap_p,), jnp.float32),
        sds((), jnp.int32),
    )
