"""Versioned, fixed-capacity, functional dynamic-graph state.

TPU-native adaptation of PANIGRAHAM's composite data structure (lock-free
hash-table of VNodes + lock-free BST edge-lists):

  * The vertex "hash table" is a direct-indexed table of capacity ``vcap``:
    ``alive[v]`` (vertex liveness), ``ecnt[v]`` (the paper's per-vertex edge
    version counter, bumped on every edge mutation incident at ``v``) and a
    global ``version`` (bumped once per committed update batch -- each batch
    commit is a linearization boundary).
  * The per-vertex BST edge-lists become ONE lexicographically sorted
    ``(src, dst)`` key array with slack capacity ``ecap``.  Binary search over
    the sorted pairs (``pair_searchsorted``) is the vectorized analogue of the
    BST's O(log E) descent, applied to whole update batches at once.
  * The paper's *logical removal* (pointer marking / bit stealing) maps to
    weight tombstones: a removed edge keeps its key slot (preserving the sort
    invariant, exactly like a marked-but-not-unlinked BST node) with
    ``weight = +inf``.  ``compact`` is the physical unlink ("helping").
  * Empty slots carry the sentinel key ``(NOKEY, NOKEY)`` which sorts last, so
    the array is totally sorted at full capacity at all times.

All operations are pure: they take a ``GraphState`` and return a new one.
A new state with a bumped ``version`` is a new MVCC snapshot -- the paper's
CAS-committed heap mutation becomes a value commit.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

# Sentinel for empty edge slots / invalid vertex ids.  Must be the maximum
# int32 so empty slots sort after every real key.
NOKEY: int = 2**31 - 1
# Weight tombstone: logically-removed edge (and "no edge" in dense form).
INF = jnp.float32(jnp.inf)


class GraphState(NamedTuple):
    """A committed snapshot of the dynamic graph. All fields are arrays."""

    # --- vertex table (the "hash table") ---
    alive: jax.Array      # bool[vcap]   vertex liveness
    ecnt: jax.Array       # int32[vcap]  per-vertex edge version counter
    # --- edge table (the composed "BSTs"), lexicographically sorted ---
    esrc: jax.Array       # int32[ecap]  source vertex id (NOKEY = empty slot)
    edst: jax.Array       # int32[ecap]  destination vertex id
    ew: jax.Array         # f32[ecap]    weight; +inf = logically removed
    # --- global MVCC version, one bump per committed batch ---
    version: jax.Array    # int32[] scalar

    @property
    def vcap(self) -> int:
        return self.alive.shape[0]

    @property
    def ecap(self) -> int:
        return self.esrc.shape[0]


def make_graph(vcap: int, ecap: int) -> GraphState:
    """An empty graph with capacity for ``vcap`` vertices and ``ecap`` edges."""
    return GraphState(
        alive=jnp.zeros((vcap,), jnp.bool_),
        ecnt=jnp.zeros((vcap,), jnp.int32),
        esrc=jnp.full((ecap,), NOKEY, jnp.int32),
        edst=jnp.full((ecap,), NOKEY, jnp.int32),
        ew=jnp.full((ecap,), INF, jnp.float32),
        version=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Sorted-pair binary search: the vectorized BST descent.
# ---------------------------------------------------------------------------

def _pair_less(a_src, a_dst, b_src, b_dst):
    return (a_src < b_src) | ((a_src == b_src) & (a_dst < b_dst))


def pair_searchsorted(esrc: jax.Array, edst: jax.Array,
                      qu: jax.Array, qv: jax.Array) -> jax.Array:
    """Leftmost index where ``(esrc, edst) >= (qu, qv)``, vectorized over q.

    ``(esrc, edst)`` must be lexicographically sorted (empty slots = NOKEY
    sort last).  int32-only -- no 64-bit composite keys needed.
    """
    ecap = esrc.shape[0]
    steps = max(1, int(math.ceil(math.log2(max(ecap, 2)))) + 1)
    lo = jnp.zeros(qu.shape, jnp.int32)
    hi = jnp.full(qu.shape, ecap, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        midc = jnp.clip(mid, 0, ecap - 1)
        less = _pair_less(esrc[midc], edst[midc], qu, qv)
        return jnp.where(less, mid + 1, lo), jnp.where(less, hi, mid)

    lo, hi = lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def find_edge_slots(state: GraphState, qu: jax.Array, qv: jax.Array):
    """Locate edge keys. Returns ``(idx, key_present, live)``.

    ``key_present``: the key occupies a slot (live or tombstoned).
    ``live``: key present AND not logically removed AND both endpoints alive.
    """
    idx = pair_searchsorted(state.esrc, state.edst, qu, qv)
    idxc = jnp.clip(idx, 0, state.ecap - 1)
    key_present = (state.esrc[idxc] == qu) & (state.edst[idxc] == qv) & (qu != NOKEY)
    quc = jnp.clip(qu, 0, state.vcap - 1)
    qvc = jnp.clip(qv, 0, state.vcap - 1)
    live = key_present & (state.ew[idxc] < INF) & state.alive[quc] & state.alive[qvc]
    return idxc, key_present, live


# ---------------------------------------------------------------------------
# Derived views & maintenance.
# ---------------------------------------------------------------------------

def live_edge_mask(state: GraphState) -> jax.Array:
    """bool[ecap]: slots holding a live (unmarked, endpoints-alive) edge."""
    src_ok = state.alive[jnp.clip(state.esrc, 0, state.vcap - 1)]
    dst_ok = state.alive[jnp.clip(state.edst, 0, state.vcap - 1)]
    return (state.esrc != NOKEY) & (state.ew < INF) & src_ok & dst_ok


def num_vertices(state: GraphState) -> jax.Array:
    return jnp.sum(state.alive.astype(jnp.int32))


def num_edges(state: GraphState) -> jax.Array:
    return jnp.sum(live_edge_mask(state).astype(jnp.int32))


def used_slots(state: GraphState) -> jax.Array:
    """Occupied slots (live + tombstones)."""
    return jnp.sum((state.esrc != NOKEY).astype(jnp.int32))


@jax.jit
def compact(state: GraphState) -> GraphState:
    """Physically remove tombstoned edges (the paper's unlink/"helping").

    A stable sort by the removed-flag keeps live entries in lexicographic
    order and pushes tombstones (converted to empty slots) to the end.
    """
    removed = (state.ew >= INF) | (state.esrc == NOKEY)
    order = jnp.argsort(removed, stable=True)
    esrc = jnp.where(removed[order], NOKEY, state.esrc[order])
    edst = jnp.where(removed[order], NOKEY, state.edst[order])
    ew = jnp.where(removed[order], INF, state.ew[order])
    return state._replace(esrc=esrc, edst=edst, ew=ew)


def grow_edges(state: GraphState, factor: int = 2) -> GraphState:
    """Reallocate the edge table with more slack (the paper's RESIZE grow)."""
    extra = state.ecap * (factor - 1)
    return state._replace(
        esrc=jnp.concatenate([state.esrc, jnp.full((extra,), NOKEY, jnp.int32)]),
        edst=jnp.concatenate([state.edst, jnp.full((extra,), NOKEY, jnp.int32)]),
        ew=jnp.concatenate([state.ew, jnp.full((extra,), INF, jnp.float32)]),
    )


def grow_vertices(state: GraphState, factor: int = 2) -> GraphState:
    """Reallocate the vertex table (RESIZE grow for the hash table)."""
    extra = state.vcap * (factor - 1)
    return state._replace(
        alive=jnp.concatenate([state.alive, jnp.zeros((extra,), jnp.bool_)]),
        ecnt=jnp.concatenate([state.ecnt, jnp.zeros((extra,), jnp.int32)]),
    )


@jax.jit
def densify(state: GraphState) -> jax.Array:
    """Dense weight matrix ``W[f32, vcap x vcap]``; +inf = no edge.

    This is the bridge to the MXU path: batched semiring queries (and the
    Pallas kernels) operate on dense tiles derived from a snapshot.
    """
    live = live_edge_mask(state)
    srcc = jnp.where(live, state.esrc, 0)
    dstc = jnp.where(live, state.edst, 0)
    w = jnp.full((state.vcap, state.vcap), INF, jnp.float32)
    vals = jnp.where(live, state.ew, INF)
    return w.at[srcc, dstc].min(vals, mode="drop")


def from_edge_list(vcap: int, ecap: int, src, dst, w=None) -> GraphState:
    """Build a committed graph from host edge arrays (bulk load)."""
    import numpy as np

    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if w is None:
        w = np.ones_like(src, np.float32)
    w = np.asarray(w, np.float32)
    # dedup, keep last weight
    keys = src.astype(np.int64) * np.int64(vcap) + dst.astype(np.int64)
    order = np.argsort(keys, kind="stable")
    keys, src, dst, w = keys[order], src[order], dst[order], w[order]
    last = np.ones(len(keys), bool)
    last[:-1] = keys[:-1] != keys[1:]
    src, dst, w = src[last], dst[last], w[last]
    n = len(src)
    if n > ecap:
        raise ValueError(f"edge capacity {ecap} < {n} edges")
    esrc = np.full((ecap,), NOKEY, np.int32)
    edst = np.full((ecap,), NOKEY, np.int32)
    ew = np.full((ecap,), np.inf, np.float32)
    esrc[:n], edst[:n], ew[:n] = src, dst, w
    alive = np.zeros((vcap,), bool)
    touched = np.unique(np.concatenate([src, dst]))
    alive[touched] = True
    return GraphState(
        alive=jnp.asarray(alive),
        ecnt=jnp.zeros((vcap,), jnp.int32),
        esrc=jnp.asarray(esrc),
        edst=jnp.asarray(edst),
        ew=jnp.asarray(ew),
        version=jnp.zeros((), jnp.int32),
    )
