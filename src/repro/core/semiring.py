"""Semiring matmuls: the dense/MXU formulation of graph traversal.

  * bool semiring  (or, and)        -> BFS frontier expansion
  * tropical       (min, +)         -> SSSP relaxation
  * counting       (+, x) on masks  -> sigma path counting (Brandes)

``*_mm(..., use_kernel=True)`` dispatches to the Pallas TPU kernels in
``repro.kernels`` (validated in interpret mode on CPU); the default path is
pure jnp and serves as the oracle.

Each product optionally takes ``amask``, the right operand's tile-occupancy
grid (``repro.core.tiles``: nonzero iff the ``tile x tile`` block holds any
non-identity entry).  The kernel path skips per (slab, tile) block inside
the Pallas grid; the jnp fallback mirrors the skipping at k-slab granularity
— a ``lax.cond`` per k tile row elides slabs whose adjacency row is entirely
empty or whose frontier slab is all-identity.  Both produce results
identical to the unmasked dense sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

_BLOCK = 128  # MXU-aligned logical tile for the blocked jnp fallbacks


def _pad_axis(x, axis, mult, value):
    size = x.shape[axis]
    pad = -(-size // mult) * mult - size
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _check_amask(amask: jax.Array, kdim: int, n: int, tile: int, name: str):
    """Shared occupancy-grid validation (see ``kernels.backend``): both the
    fallbacks here and the ``ops`` wrappers raise identically on a grid
    that does not tile the operand."""
    from repro.kernels.backend import check_amask
    check_amask(name, amask.shape, kdim, n, tile)


def _krow_active(amask: jax.Array) -> jax.Array:
    """bool[nbk]: k tile row holds any active tile."""
    return (amask > 0).any(axis=1)


def bool_mm(f: jax.Array, a: jax.Array, use_kernel: bool = False,
            amask: jax.Array | None = None, tile: int = _BLOCK) -> jax.Array:
    """(S,V) x (V,V) boolean-semiring product, as f32 {0,1} masks."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.bool_mm(f, a, amask=amask, tile=tile)
    if amask is None:
        return (jnp.dot(f, a, precision=jax.lax.Precision.HIGHEST) > 0
                ).astype(jnp.float32)
    acc = _masked_count_accum(f.astype(jnp.float32), a.astype(jnp.float32),
                              amask, tile, "bool_mm")
    return (acc > 0).astype(jnp.float32)


def _masked_count_accum(fp_in: jax.Array, ap_in: jax.Array, amask: jax.Array,
                        tile: int, name: str) -> jax.Array:
    """Shared k-slab-skipping sum-of-dots: the masked fallback body of both
    ``bool_mm`` (which thresholds the result) and ``count_mm``."""
    _check_amask(amask, ap_in.shape[0], ap_in.shape[1], tile, name)
    fp = _pad_axis(fp_in, 1, tile, 0.0)
    ap = _pad_axis(ap_in, 0, tile, 0.0)
    nbk = fp.shape[1] // tile
    krow = _krow_active(amask)

    def body(i, acc):
        fk = lax.dynamic_slice_in_dim(fp, i * tile, tile, axis=1)
        ak = lax.dynamic_slice_in_dim(ap, i * tile, tile, axis=0)
        return lax.cond(
            krow[i] & (fk != 0).any(),
            lambda acc: acc + jnp.dot(fk, ak,
                                      precision=jax.lax.Precision.HIGHEST),
            lambda acc: acc, acc)

    return lax.fori_loop(0, nbk, body,
                         jnp.zeros((fp_in.shape[0], ap_in.shape[1]),
                                   jnp.float32))


def minplus_mm(d: jax.Array, w: jax.Array, use_kernel: bool = False,
               amask: jax.Array | None = None, tile: int = _BLOCK) -> jax.Array:
    """(S,V) x (V,V) tropical product: out[s,j] = min_k d[s,k] + w[k,j]."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.minplus_mm(d, w, amask=amask, tile=tile)
    # Blocked over k to bound the (S, K, V) broadcast working set.
    if amask is not None:
        _check_amask(amask, w.shape[0], w.shape[1], tile, "minplus_mm")
    blk = min(tile, w.shape[0])
    dp = _pad_axis(d, 1, blk, jnp.inf)
    wp = _pad_axis(w, 0, blk, jnp.inf)
    nbk = dp.shape[1] // blk
    krow = None if amask is None else _krow_active(amask)

    def compute(i, acc):
        dk = lax.dynamic_slice_in_dim(dp, i * blk, blk, axis=1)
        wk = lax.dynamic_slice_in_dim(wp, i * blk, blk, axis=0)
        cand = jnp.min(dk[:, :, None] + wk[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    if krow is None:
        body = compute
    else:
        def body(i, acc):
            dk = lax.dynamic_slice_in_dim(dp, i * blk, blk, axis=1)
            return lax.cond(krow[i] & jnp.isfinite(dk).any(),
                            lambda acc: compute(i, acc),
                            lambda acc: acc, acc)

    init = jnp.full((d.shape[0], w.shape[1]), jnp.inf, d.dtype)
    return lax.fori_loop(0, nbk, body, init)


def count_mm(s: jax.Array, a: jax.Array, use_kernel: bool = False,
             amask: jax.Array | None = None, tile: int = _BLOCK) -> jax.Array:
    """(S,V) x (V,V) counting product (plain matmul on path counts)."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.count_mm(s, a, amask=amask, tile=tile)
    if amask is None:
        return jnp.dot(s, a, precision=jax.lax.Precision.HIGHEST)
    return _masked_count_accum(s.astype(jnp.float32), a.astype(jnp.float32),
                               amask, tile, "count_mm")
