"""Semiring matmuls: the dense/MXU formulation of graph traversal.

  * bool semiring  (or, and)        -> BFS frontier expansion
  * tropical       (min, +)         -> SSSP relaxation
  * counting       (+, x) on masks  -> sigma path counting (Brandes)

``*_mm(..., use_kernel=True)`` dispatches to the Pallas TPU kernels in
``repro.kernels`` (validated in interpret mode on CPU); the default path is
pure jnp and serves as the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 128  # MXU-aligned logical tile for the blocked jnp fallbacks


def bool_mm(f: jax.Array, a: jax.Array, use_kernel: bool = False) -> jax.Array:
    """(S,V) x (V,V) boolean-semiring product, as f32 {0,1} masks."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.bool_mm(f, a)
    return (jnp.dot(f, a, precision=jax.lax.Precision.HIGHEST) > 0).astype(jnp.float32)


def minplus_mm(d: jax.Array, w: jax.Array, use_kernel: bool = False) -> jax.Array:
    """(S,V) x (V,V) tropical product: out[s,j] = min_k d[s,k] + w[k,j]."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.minplus_mm(d, w)
    # Blocked over k to bound the (S, K, V) broadcast working set.
    V = w.shape[0]
    blk = min(_BLOCK, V)
    nb = -(-V // blk)
    pad = nb * blk - V
    dp = jnp.pad(d, ((0, 0), (0, pad)), constant_values=jnp.inf)
    wp = jnp.pad(w, ((0, pad), (0, 0)), constant_values=jnp.inf)

    def body(i, acc):
        dk = jax.lax.dynamic_slice_in_dim(dp, i * blk, blk, axis=1)
        wk = jax.lax.dynamic_slice_in_dim(wp, i * blk, blk, axis=0)
        cand = jnp.min(dk[:, :, None] + wk[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    init = jnp.full((d.shape[0], w.shape[1]), jnp.inf, d.dtype)
    return jax.lax.fori_loop(0, nb, body, init)


def count_mm(s: jax.Array, a: jax.Array) -> jax.Array:
    """(S,V) x (V,V) counting product (plain matmul on path counts)."""
    return jnp.dot(s, a, precision=jax.lax.Precision.HIGHEST)
