"""Batched, vectorized implementations of the PANIGRAHAM ADT operations.

The paper linearizes individual CAS-built operations.  On an SPMD machine the
natural unit of mutation is a *batch*: ``apply_batch`` applies a fixed-size
array of operations in one jitted, fully-vectorized step and bumps the global
``version`` -- the commit is the batch's linearization boundary.  Within a
batch the sequential semantics are:

    1. vertex ops (PUTV / REMV) linearize first, in index order;
    2. edge ops (PUTE / REME) linearize next, in index order;
    3. reads (GETV / GETE) linearize at the end of the batch.

Per-op return values follow the paper's ADT exactly (including the
``<false, w>`` same-weight PutE case and the weight returned by RemE), and
intra-batch chains on the same key are resolved with true sequential
semantics via a sorted segment walk: because presence after an op depends
only on the op itself, an op's precondition depends only on its immediate
predecessor in the (key, index)-sorted order -- no sequential scan needed.

``ecnt[u]`` is bumped once per successful mutation of u's out-edge list
(PutE add / PutE weight-replace / RemE / incident-edge invalidation by RemV),
mirroring the paper's FetchAndAdd sites.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from .graph_state import (
    INF,
    NOKEY,
    GraphState,
    compact,
    find_edge_slots,
    grow_edges,
    pair_searchsorted,
    used_slots,
)

# Operation kinds.
NOP, PUTV, REMV, PUTE, REME, GETV, GETE = range(7)


class OpBatch(NamedTuple):
    kind: jax.Array   # int32[B]
    u: jax.Array      # int32[B]
    v: jax.Array      # int32[B]  (unused for vertex ops)
    w: jax.Array      # f32[B]    (PutE weight)


class OpResults(NamedTuple):
    ok: jax.Array     # bool[B]  boolean return of each op
    val: jax.Array    # f32[B]   weight return of edge ops (INF where n/a)


def make_batch(ops: Sequence[Tuple], size: int | None = None) -> OpBatch:
    """Host helper: list of (kind, u[, v[, w]]) tuples -> padded OpBatch."""
    import numpy as np

    size = size or len(ops)
    kind = np.zeros((size,), np.int32)
    u = np.full((size,), NOKEY, np.int32)
    v = np.full((size,), NOKEY, np.int32)
    w = np.full((size,), np.inf, np.float32)
    for i, op in enumerate(ops):
        kind[i] = op[0]
        if len(op) > 1:
            u[i] = op[1]
        if len(op) > 2:
            v[i] = op[2]
        if len(op) > 3:
            w[i] = op[3]
    return OpBatch(jnp.asarray(kind), jnp.asarray(u), jnp.asarray(v), jnp.asarray(w))


def _prev(arr, fill):
    rolled = jnp.roll(arr, 1)
    return rolled.at[0].set(fill)


@jax.jit
def apply_batch(state: GraphState, ops: OpBatch):
    """Apply one op batch. Returns ``(new_state, OpResults, overflow)``.

    ``overflow`` is True when appended edges did not fit in the slack; the
    caller must ``compact``/``grow_edges`` and retry (see ``apply_ops``).
    The input state is never corrupted on overflow (pure function).
    """
    vcap, ecap = state.vcap, state.ecap
    B = ops.kind.shape[0]
    idxs = jnp.arange(B, dtype=jnp.int32)

    ok_out = jnp.zeros((B,), jnp.bool_)
    val_out = jnp.full((B,), INF, jnp.float32)

    # ---------------- Phase 1: vertex ops -------------------------------
    isv = (ops.kind == PUTV) | (ops.kind == REMV)
    vkey = jnp.where(isv & (ops.u >= 0) & (ops.u < vcap), ops.u, NOKEY)
    perm = jnp.lexsort((idxs, vkey))
    sk, skind = vkey[perm], ops.kind[perm]
    first = sk != _prev(sk, jnp.int32(-1))
    pre_alive = state.alive[jnp.clip(sk, 0, vcap - 1)] & (sk != NOKEY)
    prev_is_put = _prev(skind, jnp.int32(NOP)) == PUTV
    present_before = jnp.where(first, pre_alive, prev_is_put)
    okv = jnp.where(skind == PUTV, ~present_before, present_before) & (sk != NOKEY)
    ok_out = jnp.where(isv, jnp.zeros((B,), jnp.bool_).at[perm].set(okv), ok_out)

    nxt = jnp.roll(sk, -1).at[B - 1].set(-1)
    is_last = sk != nxt
    scat_idx = jnp.where(is_last & (sk != NOKEY), sk, vcap)
    alive2 = state.alive.at[scat_idx].set(skind == PUTV, mode="drop")

    # vertices successfully removed at any point in the batch: their incident
    # edges are invalidated (fresh empty edge-list on re-add, as in the paper).
    remv_succ = okv & (skind == REMV)
    had_remv = jnp.zeros((vcap,), jnp.bool_).at[
        jnp.where(remv_succ, sk, vcap)
    ].max(jnp.ones((B,), jnp.bool_), mode="drop")

    esrcc = jnp.clip(state.esrc, 0, vcap - 1)
    edstc = jnp.clip(state.edst, 0, vcap - 1)
    kill = (state.esrc != NOKEY) & (state.ew < INF) & (
        had_remv[esrcc] | had_remv[edstc]
    )
    ew2 = jnp.where(kill, INF, state.ew)
    ecnt2 = state.ecnt.at[jnp.where(kill, state.esrc, vcap)].add(1, mode="drop")

    # ---------------- Phase 2: edge ops ---------------------------------
    ise = (ops.kind == PUTE) | (ops.kind == REME)
    in_range = (ops.u >= 0) & (ops.u < vcap) & (ops.v >= 0) & (ops.v < vcap)
    valid = ise & in_range & alive2[jnp.clip(ops.u, 0, vcap - 1)] \
        & alive2[jnp.clip(ops.v, 0, vcap - 1)]
    ku = jnp.where(valid, ops.u, NOKEY)
    kv = jnp.where(valid, ops.v, NOKEY)
    perm_e = jnp.lexsort((idxs, kv, ku))
    su, sv = ku[perm_e], kv[perm_e]
    skind_e, sw = ops.kind[perm_e], ops.w[perm_e]

    first_e = (su != _prev(su, jnp.int32(-1))) | (sv != _prev(sv, jnp.int32(-1)))
    slot = pair_searchsorted(state.esrc, state.edst, su, sv)
    slotc = jnp.clip(slot, 0, ecap - 1)
    key_present = (state.esrc[slotc] == su) & (state.edst[slotc] == sv) & (su != NOKEY)
    pre_live = key_present & (ew2[slotc] < INF)
    pre_w = jnp.where(pre_live, ew2[slotc], INF)

    prev_put = _prev(skind_e, jnp.int32(NOP)) == PUTE
    prev_w = _prev(sw, INF)
    pres_before = jnp.where(first_e, pre_live, prev_put)
    w_before = jnp.where(first_e, pre_w, jnp.where(prev_put, prev_w, INF))

    is_pute = skind_e == PUTE
    # Invalid ops (NOKEY-keyed) must not chain presence to one another.
    pres_before = pres_before & (su != NOKEY)
    ok_e = (su != NOKEY) & jnp.where(
        is_pute, ~pres_before | (w_before != sw), pres_before
    )
    ret_e = jnp.where(pres_before, w_before, INF)
    ok_out = jnp.where(ise, jnp.zeros((B,), jnp.bool_).at[perm_e].set(ok_e), ok_out)
    val_out = jnp.where(ise, jnp.full((B,), INF).at[perm_e].set(ret_e), val_out)

    # ecnt: one bump per successful out-edge-list mutation at the source.
    ecnt3 = ecnt2.at[jnp.where(ok_e, su, vcap)].add(1, mode="drop")

    # Final state per key = last op of each segment.
    nxt_u = jnp.roll(su, -1).at[B - 1].set(-1)
    nxt_v = jnp.roll(sv, -1).at[B - 1].set(-1)
    is_last_e = (su != nxt_u) | (sv != nxt_v)
    last_mask = is_last_e & (su != NOKEY)
    final_put = is_pute

    # In-place finals (key already occupies a slot, live or tombstoned).
    inplace = last_mask & key_present
    ew3 = ew2.at[jnp.where(inplace, slot, ecap)].set(
        jnp.where(final_put, sw, INF), mode="drop"
    )

    # Appends: final PutE on a key with no slot.  ``su`` is sorted, so the
    # compressed append list stays sorted.
    app = last_mask & final_put & ~key_present
    app_rank = jnp.cumsum(app.astype(jnp.int32)) - 1
    comp_idx = jnp.where(app, app_rank, B)
    cu = jnp.full((B,), NOKEY, jnp.int32).at[comp_idx].set(su, mode="drop")
    cv = jnp.full((B,), NOKEY, jnp.int32).at[comp_idx].set(sv, mode="drop")
    cw = jnp.full((B,), INF, jnp.float32).at[comp_idx].set(sw, mode="drop")
    n_app = jnp.sum(app.astype(jnp.int32))
    overflow = used_slots(state) + n_app > ecap

    # Merge-scatter: shift old entries right past their insertion points.
    pos = pair_searchsorted(state.esrc, state.edst, cu, cv)
    shift_old = jnp.searchsorted(pos, jnp.arange(ecap, dtype=jnp.int32),
                                 side="right").astype(jnp.int32)
    dest_old = jnp.arange(ecap, dtype=jnp.int32) + shift_old
    esrc3 = jnp.full((ecap,), NOKEY, jnp.int32).at[dest_old].set(state.esrc, mode="drop")
    edst3 = jnp.full((ecap,), NOKEY, jnp.int32).at[dest_old].set(state.edst, mode="drop")
    ew4 = jnp.full((ecap,), INF, jnp.float32).at[dest_old].set(ew3, mode="drop")
    dest_new = jnp.where(cu != NOKEY, pos + jnp.arange(B, dtype=jnp.int32), ecap)
    esrc3 = esrc3.at[dest_new].set(cu, mode="drop")
    edst3 = edst3.at[dest_new].set(cv, mode="drop")
    ew4 = ew4.at[dest_new].set(cw, mode="drop")

    new_state = GraphState(
        alive=alive2, ecnt=ecnt3, esrc=esrc3, edst=edst3, ew=ew4,
        version=state.version + 1,
    )

    # ---------------- Phase 3: reads (GETV / GETE) ----------------------
    isgv = ops.kind == GETV
    isge = ops.kind == GETE
    gv_ok = alive2[jnp.clip(ops.u, 0, vcap - 1)] & in_range
    _, _, ge_live = find_edge_slots(new_state, jnp.where(isge, ops.u, NOKEY),
                                    jnp.where(isge, ops.v, NOKEY))
    ge_slot = pair_searchsorted(esrc3, edst3, ops.u, ops.v)
    ge_w = jnp.where(ge_live, ew4[jnp.clip(ge_slot, 0, ecap - 1)], INF)
    ok_out = jnp.where(isgv, gv_ok, ok_out)
    ok_out = jnp.where(isge, ge_live, ok_out)
    val_out = jnp.where(isge, ge_w, val_out)

    return new_state, OpResults(ok_out, val_out), overflow


def apply_ops(state: GraphState, ops: Sequence[Tuple], batch_size: int | None = None):
    """Host convenience: apply ops with automatic compact/grow on overflow.

    Each retry applies the batch at most once: on overflow we ``compact``,
    and — when even a tombstone-free table cannot hold the worst case of one
    append per batch slot — ``grow_edges`` before the single retry.  The
    worst-case bound (``used + B <= ecap``) guarantees the retry cannot
    overflow again, at the cost of occasionally growing a table that a
    tighter count would have squeezed the batch into.
    """
    batch = make_batch(ops, batch_size)
    B = int(batch.kind.shape[0])
    while True:
        new_state, res, overflow = apply_batch(state, batch)
        if not bool(overflow):
            return new_state, res
        state = compact(state)
        while int(used_slots(state)) + B > state.ecap:
            state = grow_edges(state)


# ------------------------- dirty-set helpers ----------------------------
# The engine's version ring (``repro.engine``) derives per-commit
# *dirty-vertex sets* from these: the set of vertices whose out-edge list or
# liveness may differ between two committed snapshots.  ``ecnt[u]`` is bumped
# on every successful mutation of u's out-edges (including RemV-driven
# incident-edge invalidation, which bumps the *source* of every killed edge),
# so the ecnt delta alone covers every edge change; the alive delta covers
# vertex insertion/removal.  This is the paper's SNode/ecnt selectivity made
# into a first-class index.

@jax.jit
def dirty_vertices(prev: GraphState, new: GraphState) -> jax.Array:
    """bool[vcap]: vertices whose edge list or liveness changed prev -> new.

    Both states must share ``vcap`` (use ``dirty_vertices_padded`` across a
    ``grow_vertices`` boundary).
    """
    return (prev.ecnt != new.ecnt) | (prev.alive != new.alive)


def dirty_vertices_padded(prev: GraphState, new: GraphState) -> jax.Array:
    """``dirty_vertices`` tolerant of vertex-table growth between commits.

    Vertices that exist only in ``new`` are dirty iff alive or touched
    (their prev-side ecnt/alive are taken as zero/False).
    """
    if prev.vcap == new.vcap:
        return dirty_vertices(prev, new)
    if prev.vcap > new.vcap:
        raise ValueError("vertex table shrank between commits")
    pad = new.vcap - prev.vcap
    grown = prev._replace(
        alive=jnp.concatenate([prev.alive, jnp.zeros((pad,), jnp.bool_)]),
        ecnt=jnp.concatenate([prev.ecnt, jnp.zeros((pad,), jnp.int32)]),
    )
    return dirty_vertices(grown, new)


# ------------------------- standalone reads -----------------------------

@jax.jit
def get_v(state: GraphState, u) -> jax.Array:
    u = jnp.asarray(u, jnp.int32)
    return state.alive[jnp.clip(u, 0, state.vcap - 1)] & (u >= 0) & (u < state.vcap)


@jax.jit
def get_e(state: GraphState, u, v):
    u = jnp.asarray(u, jnp.int32)
    v = jnp.asarray(v, jnp.int32)
    idx, _, live = find_edge_slots(state, u, v)
    return live, jnp.where(live, state.ew[idx], INF)
