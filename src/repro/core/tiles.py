"""Blocked adjacency view of a snapshot: dense tiles + live-edge occupancy.

The batched semiring queries operate on the dense ``vcap x vcap`` weight
matrix, but sparse real-world graphs leave most of its ``T x T`` tiles with
no live edge at all — every one of those tiles is pure semiring identity
(+inf / 0) and the MXU/VPU sweep over it is wasted work.  A :class:`TileView`
makes that sparsity first-class:

  * ``w``   — the dense weight matrix padded up to a whole number of tiles
    (+inf = no edge), the operand the Pallas kernels consume;
  * ``occ`` — the ``(Vp/T) x (Vp/T)`` int32 grid of live-edge counts per
    tile.  ``occ[i, j] == 0`` iff tile ``(i, j)`` is all-identity, which is
    exactly the contract the tile-skipping kernels
    (``repro.kernels.*_mm_masked``) and the blocked jnp fallbacks
    (``repro.core.semiring``) require of their ``amask``.

``build_tile_view`` derives both from scratch in O(vcap^2 + ecap).
``refresh_tile_view`` is the incremental path the engine uses: a committed
update batch reports the vertices it disturbed (the version ring's
dirty-vertex set), and only those vertices' *rows* of ``w`` — and the tile
rows containing them — are re-derived.  This is sound because every change
to the dense matrix lives in a dirty row: an edge mutation bumps ``ecnt`` at
the edge's source (dirtying it), and RemV tombstones every incident edge
while bumping each *source's* ``ecnt`` — so column-side liveness changes are
always mirrored by a dirty source row (see ``core.updates``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .graph_state import INF, NOKEY, GraphState, live_edge_mask

TILE = 128  # default tile edge; matches the MXU-aligned kernel blocks


class TileView(NamedTuple):
    """Blocked adjacency snapshot: padded dense weights + tile occupancy."""

    w: jax.Array    # f32[Vp, Vp]   dense weights, +inf = no edge, Vp % T == 0
    occ: jax.Array  # int32[nt, nt] live-edge count per (src-tile, dst-tile)

    @property
    def vp(self) -> int:
        return self.w.shape[0]

    @property
    def n_tiles(self) -> int:
        return self.occ.shape[0]

    @property
    def tile(self) -> int:
        return self.vp // self.occ.shape[0]


def _padded_dim(vcap: int, tile: int) -> int:
    return -(-vcap // tile) * tile


def active_tile_mask(view: TileView) -> jax.Array:
    """bool[nt, nt]: tiles holding at least one live edge."""
    return view.occ > 0


def occupancy_stats(view: TileView) -> dict:
    """Host-side summary: how much of the tile grid the kernels can skip."""
    occ = jax.device_get(view.occ)
    total = int(occ.size)
    active = int((occ > 0).sum())
    return {
        "tile": view.tile,
        "grid": [view.n_tiles, view.n_tiles],
        "tiles_total": total,
        "tiles_active": active,
        "tile_skip_rate": (total - active) / total if total else 0.0,
        "live_edges": int(occ.sum()),
    }


@partial(jax.jit, static_argnames=("tile",))
def build_tile_view(state: GraphState, tile: int = TILE) -> TileView:
    """Full O(vcap^2 + ecap) derivation of the blocked view from a snapshot."""
    vcap = state.vcap
    vp = _padded_dim(vcap, tile)
    nt = vp // tile
    live = live_edge_mask(state)
    srcc = jnp.where(live, state.esrc, 0)
    dstc = jnp.where(live, state.edst, 0)
    w = jnp.full((vp, vp), INF, jnp.float32)
    w = w.at[srcc, dstc].min(jnp.where(live, state.ew, INF), mode="drop")
    occ = jnp.zeros((nt, nt), jnp.int32).at[srcc // tile, dstc // tile].add(
        live.astype(jnp.int32), mode="drop")
    return TileView(w, occ)


def row_window_slab(esrc: jax.Array, edst: jax.Array, ew: jax.Array,
                    alive: jax.Array, r, lo, *, tile: int, width: int,
                    vp: int, nt: int):
    """Re-derive global tile row ``r``: scatter-min its live edges into a
    fresh identity ``tile x vp`` slab (bit-identical to the full build —
    min is order-free) plus the matching ``1 x nt`` occupancy row.

    O(row) instead of O(graph) because the edge table is sorted by
    ``(src, dst)``: row ``r``'s edges are the contiguous segment starting
    at ``lo`` (host-computed via searchsorted), and only a static
    ``width``-wide window around it is scanned, masked down to exactly the
    row's live edges.  Shared by the single-device ``_refresh_row`` and
    the sharded row refresh (``repro.shard.tile_shard``) so the two views
    cannot drift apart.
    """
    vcap = alive.shape[0]
    ecap = esrc.shape[0]
    r = jnp.asarray(r, jnp.int32)
    start = jnp.clip(jnp.asarray(lo, jnp.int32), 0, ecap - width)
    es = lax.dynamic_slice_in_dim(esrc, start, width)
    ed = lax.dynamic_slice_in_dim(edst, start, width)
    ws = lax.dynamic_slice_in_dim(ew, start, width)
    live = ((es != NOKEY) & (ws < INF)
            & alive[jnp.clip(es, 0, vcap - 1)]
            & alive[jnp.clip(ed, 0, vcap - 1)])
    in_row = live & (es // tile == r)
    srcc = jnp.where(in_row, es, 0)
    dstc = jnp.where(in_row, ed, 0)
    slab = jnp.full((tile, vp), INF, jnp.float32).at[
        jnp.where(in_row, srcc - r * tile, 0), dstc,
    ].min(jnp.where(in_row, ws, INF), mode="drop")
    occ_row = jnp.zeros((1, nt), jnp.int32).at[
        0, jnp.where(in_row, dstc // tile, 0)
    ].add(in_row.astype(jnp.int32), mode="drop")
    return slab, occ_row


@partial(jax.jit, static_argnames=("tile", "width"), donate_argnums=(1, 2))
def _refresh_row(state: GraphState, w: jax.Array, occ: jax.Array,
                 r, lo, tile: int, width: int):
    """Recompute tile row ``r`` in place: the shared ``row_window_slab``
    derivation, written back with ``dynamic_update_slice``.  ``w``/``occ``
    are *donated*, so the row writes happen in place instead of copying
    the O(Vp^2) matrix per row; ``r``/``lo`` are traced, so every dirty
    row with the same window width reuses one compiled program.
    """
    r = jnp.asarray(r, jnp.int32)
    slab, occ_row = row_window_slab(
        state.esrc, state.edst, state.ew, state.alive, r, lo,
        tile=tile, width=width, vp=w.shape[0], nt=occ.shape[0])
    return (lax.dynamic_update_slice(w, slab, (r * tile, jnp.int32(0))),
            lax.dynamic_update_slice(occ, occ_row, (r, jnp.int32(0))))


@partial(jax.jit, static_argnames=("nt", "tile"))
def _dirty_tile_rows(dirty: jax.Array, nt: int, tile: int) -> jax.Array:
    ids = jnp.arange(dirty.shape[0], dtype=jnp.int32)
    return jnp.zeros((nt,), jnp.bool_).at[ids // tile].max(dirty, mode="drop")


def dirty_row_windows(state: GraphState, dirty: jax.Array, nt: int,
                      tile: int):
    """Host-side refresh plan from a dirty-vertex set.

    ``None`` means more than half the tile rows moved — a full rebuild is
    cheaper; otherwise the (possibly empty) list of ``(row, lo, width)``
    windows to re-derive: each dirty tile row's contiguous segment of the
    sorted edge table (searchsorted bounds, widened to the next power of
    two so a handful of widths cover every row with few compiles).  Shared
    by ``refresh_tile_view`` and the sharded refresh so both sides pick
    strategies — and windows — identically.
    """
    rows = np.flatnonzero(
        np.asarray(jax.device_get(_dirty_tile_rows(dirty, nt, tile))))
    if rows.size > nt // 2:
        return None
    if rows.size == 0:
        return []
    esrc_host = np.asarray(jax.device_get(state.esrc))
    los = np.searchsorted(esrc_host, rows * tile, side="left")
    his = np.searchsorted(esrc_host, (rows + 1) * tile - 1, side="right")
    plan = []
    for r, lo, hi in zip(rows, los, his):
        width = 64
        while width < hi - lo:
            width *= 2
        plan.append((int(r), int(lo), min(width, state.ecap)))
    return plan


def refresh_tile_view(state: GraphState, prev: TileView, dirty: jax.Array,
                      tile: int = TILE) -> TileView:
    """Incremental rebuild from a dirty-vertex set (full rebuild fallback).

    ``dirty`` must cover every vertex whose out-edge list or liveness
    changed since ``prev`` was derived (a superset only costs time) — the
    version ring's ``dirty_between`` provides exactly that.  Host-side
    strategy pick per call: no dirty tile row returns ``prev`` as-is; a
    few dirty rows re-derive only those rows (one jitted ``_refresh_row``
    each — a whole-row recompute, not just dirty-vertex cells, because
    clean sources share the tile row); and when more than half the rows
    moved — or the vertex table was resized, or there is no dirty info —
    the full build is cheaper and exact by construction.

    When the row path runs, ``prev``'s buffers are DONATED to the in-place
    row updates: treat the call as *consuming* ``prev`` (hold only the
    returned view afterwards), exactly how ``GraphService.tile_view``
    rotates it.  Without donation every refreshed row would copy the whole
    O(Vp^2) matrix and the incremental path could never beat the rebuild.
    """
    if (prev is None or dirty is None
            or prev.vp != _padded_dim(state.vcap, tile)
            or prev.tile != tile  # same vp, different grid: occ would corrupt
            or dirty.shape[0] != state.vcap):
        return build_tile_view(state, tile)
    plan = dirty_row_windows(state, dirty, prev.n_tiles, tile)
    if plan is None:
        return build_tile_view(state, tile)
    if not plan:
        return prev
    w, occ = prev.w, prev.occ
    for r, lo, width in plan:
        w, occ = _refresh_row(state, w, occ, jnp.int32(r), jnp.int32(lo),
                              tile=tile, width=width)
    return TileView(w, occ)


def dense_views_from_tiles(state: GraphState, view: TileView):
    """TileView -> (adj mask, weights, alive) shaped like ``dense_views``.

    Slices the padding back off; the batched queries re-pad internally and
    the occupancy grid stays aligned because padding always restores the
    same ``Vp``.
    """
    w = view.w[:state.vcap, :state.vcap]
    return w < INF, w, state.alive
