"""Distributed graph queries over a device mesh — tile-grid sharding.

The paper's 56 CPU threads become mesh devices.  Since PR 3 this front end
is rebased onto the sharded tile-grid subsystem (``repro.shard``): the
``TileView`` tile grid is partitioned by tile *rows* over a 1-D logical
``graph`` axis (a multi-axis production mesh is flattened — ``data`` x
``model``, and ``pod`` too in the multi-pod case), each shard runs local
tile-skipping semiring work per level, and ONE vcap-sized collective
merges frontiers — collective bytes per level O(S x vcap), independent of
E, exactly the paper's queries-validate-on-vertex-metadata property.  The
double-collect version check is psum-validated so all shards agree on the
snapshot.

``make_distributed_query`` builds the jitted shard_map entry point for a
given mesh and query kind (``"bfs"`` | ``"sssp"`` | ``"bc"`` |
``"bc_ring"`` — the SUMMA-style band-rotation BC that never gathers the
adjacency); it is also what ``launch/dryrun.py`` lowers for the
graph-engine dry-run cells.  The
pre-PR-3 round-robin *edge* sharding survives in ``partition_legacy`` as
the cross-implementation oracle for the distributed tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.shard.queries import query_fn, query_shardings
from repro.shard.tile_shard import (
    _padded_dim,
    as_graph_mesh,
    build_sharded_view,
)
from .tiles import TILE

from .partition_legacy import shard_edges  # noqa: F401  (legacy oracle API)

SUPPORTED_KINDS = ("bfs", "sssp", "bc", "bc_ring")


def make_distributed_query(mesh: Mesh, kind: str = "bfs", *,
                           tile: int = TILE, use_kernel: bool = False,
                           src_chunk: int | None = None):
    """Build the jitted distributed query for ``mesh``.

    Returns ``(fn, in_shardings, out_shardings)`` where
    ``fn(w, occ, alive, ecnt, srcs, version)`` takes the GLOBAL arrays of a
    :class:`~repro.shard.tile_shard.ShardedTileView` built on the flattened
    1-D graph mesh (``w``/``occ`` row-sharded, vertex arrays replicated,
    ``srcs`` replicated for bfs/sssp and sharded over the source axis for
    bc — length a multiple of the device count).  ``fn`` is already jitted;
    the shardings are for AOT lowering (``jit(fn).lower`` on
    ShapeDtypeStructs, see ``launch/dryrun.py``).
    """
    if kind not in SUPPORTED_KINDS:
        raise ValueError(
            f"unknown query kind {kind!r}; supported kinds: "
            f"{', '.join(SUPPORTED_KINDS)}")
    gmesh = as_graph_mesh(mesh)
    fn = query_fn(gmesh, kind, tile, use_kernel, src_chunk)
    in_sh, out_sh = query_shardings(gmesh, kind)
    return fn, in_sh, out_sh


def build_query_inputs(state, mesh: Mesh, srcs, *, tile: int = TILE):
    """Snapshot -> the argument tuple ``make_distributed_query``'s fn wants
    (building the sharded view on the flattened graph mesh)."""
    gmesh = as_graph_mesh(mesh)
    view = build_sharded_view(state, gmesh, tile)
    srcs = jnp.atleast_1d(jnp.asarray(srcs, jnp.int32))
    return (view.w, view.occ, state.alive, state.ecnt, srcs, state.version)


def distributed_query_specs(vcap: int, mesh: Mesh, *, tile: int = TILE,
                            n_sources: int = 8):
    """ShapeDtypeStructs for the dry-run (no allocation).

    ``n_sources`` must be a multiple of the device count for ``"bc"``.
    """
    gmesh = as_graph_mesh(mesh)
    n = int(gmesh.devices.size)
    vp = _padded_dim(vcap, tile, n)
    nt = vp // tile
    sds = jax.ShapeDtypeStruct
    return (
        sds((vp, vp), jnp.float32),       # w     (sharded P(graph, None))
        sds((nt, nt), jnp.int32),         # occ   (sharded P(graph, None))
        sds((vcap,), jnp.bool_),          # alive (replicated)
        sds((vcap,), jnp.int32),          # ecnt  (replicated)
        sds((n_sources,), jnp.int32),     # srcs
        sds((), jnp.int32),               # version
    )
