"""PANIGRAHAM framework: multi-scan/validate snapshots (OP / SCAN / CMPTREE).

The paper's interface operation OP(v):

    1. validate the query vertex is alive;
    2. SCAN: repeatedly TREECOLLECT partial snapshots until two *consecutive*
       collects compare equal (CMPTREE over (vertex set, parents, ecnt));
    3. the matched collect is linearizable (LP = last read of the (m-1)-th
       collect).

Here a TREECOLLECT is an atomic jitted query over one committed MVCC state
version; "interrupting updates" are the batches committed between collects
(by the workload harness, or by other shards in the distributed setting).
CMPTREE compares exactly what the paper compares:

    * the reached vertex set            (vertex added/removed in window),
    * the traversal-tree parents        (path changed),
    * per-vertex ``ecnt`` of the snapshot region  (edge removed & re-added:
      the ABA case version counters exist for).

Note the global ``version`` is *deliberately not* compared: an update outside
the query's snapshot region must not invalidate the query -- that selectivity
is the point of the paper's SNode/ecnt design.  ``benchmarks/bench_scan_stats.py``
measures it directly (collects and interrupting updates across update rates,
mirroring the paper's Fig 12/13), and ``repro.engine`` turns it into an index:
per-commit dirty-vertex sets drive the delta queries benchmarked by
``benchmarks/bench_engine.py``.

Execution modes (paper section 5):
    * PG-Cn  -- linearizable: double-collect until match;
    * PG-Icn -- single collect, no validation (best-effort consistency).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .graph_state import NOKEY, GraphState
from . import queries


class Collect(NamedTuple):
    """One TREECOLLECT: a query result + its validation vector."""
    result: object        # BFSResult | SSSPResult | BCResult
    reached: jax.Array    # bool[vcap]   snapshot region
    parent: jax.Array     # int32[vcap]  traversal tree (NOKEY outside region)
    ecnt: jax.Array       # int32[vcap]  ecnt masked to the region
    payload: jax.Array    # f32[vcap]    dist/delta values masked to the region


@jax.jit
def cmp_tree(a: Collect, b: Collect) -> jax.Array:
    """The paper's CMPTREE: equality of region, tree, ecnt (and payloads)."""
    return (
        jnp.array_equal(a.reached, b.reached)
        & jnp.array_equal(a.parent, b.parent)
        & jnp.array_equal(a.ecnt, b.ecnt)
        & jnp.array_equal(a.payload, b.payload)
    )


# ----------------------------- collectors --------------------------------

@jax.jit
def collect_bfs(state: GraphState, src) -> Collect:
    r = queries.bfs(state, src)
    m = r.reached
    return Collect(
        result=r,
        reached=m,
        parent=jnp.where(m, r.parent, NOKEY),
        ecnt=jnp.where(m, state.ecnt, 0),
        payload=jnp.where(m, r.dist.astype(jnp.float32), 0.0),
    )


@jax.jit
def collect_sssp(state: GraphState, src) -> Collect:
    r = queries.sssp(state, src)
    m = r.dist < jnp.inf
    return Collect(
        result=r,
        reached=m,
        parent=jnp.where(m, r.parent, NOKEY),
        ecnt=jnp.where(m, state.ecnt, 0),
        payload=jnp.where(m, r.dist, 0.0) + r.negcycle.astype(jnp.float32),
    )


@jax.jit
def collect_bc(state: GraphState, src) -> Collect:
    r = queries.bc_dependencies(state, src)
    m = r.level >= 0
    return Collect(
        result=r,
        reached=m,
        parent=jnp.where(m, r.level, NOKEY),   # level array plays the tree role
        ecnt=jnp.where(m, state.ecnt, 0),
        payload=jnp.where(m, r.delta + r.sigma, 0.0),
    )


COLLECTORS: dict[str, Callable] = {
    "bfs": collect_bfs,
    "sssp": collect_sssp,
    "bc": collect_bc,
}


# ----------------------------- OP drivers --------------------------------

@dataclass
class ScanStats:
    """Per-query statistics mirroring the paper's Fig 12/13."""
    collects: int = 0               # TREECOLLECT invocations in the SCAN
    interrupting_updates: int = 0   # committed batches during the query
    validated: bool = True


@dataclass
class StateRef:
    """Mutable cell holding the latest committed state (the 'shared heap').

    The update stream commits new versions into the ref; queries read whatever
    version is current at each collect -- this is how "concurrency" manifests
    at batch granularity in the functional setting.
    """
    state: GraphState
    commits: int = 0
    on_read: list = field(default_factory=list)  # callbacks, for harnesses

    def commit(self, new_state: GraphState) -> None:
        self.state = new_state
        self.commits += 1

    def read(self) -> GraphState:
        for cb in self.on_read:
            cb(self)
        return self.state


def op_linearizable(ref: StateRef, query: str, src, max_collects: int = 64):
    """PG-Cn: the paper's OP -- double-collect until CMPTREE matches.

    Returns ``(Collect | None, ScanStats)``.  None when the source vertex is
    not alive at the first read (the paper's NULL return).
    """
    coll = COLLECTORS[query]
    stats = ScanStats()
    commits0 = ref.commits

    state = ref.read()
    src_i = int(src)
    if not (0 <= src_i < state.vcap) or not bool(state.alive[src_i]):
        stats.interrupting_updates = ref.commits - commits0
        return None, stats

    prev = coll(state, src)
    stats.collects = 1
    while stats.collects < max_collects:
        cur = coll(ref.read(), src)
        stats.collects += 1
        if bool(cmp_tree(prev, cur)):
            stats.interrupting_updates = ref.commits - commits0
            return cur, stats
        prev = cur
    stats.validated = False
    stats.interrupting_updates = ref.commits - commits0
    return prev, stats


def op_inconsistent(ref: StateRef, query: str, src):
    """PG-Icn: single collect, no validation (the throughput/consistency dial)."""
    state = ref.read()
    if not (0 <= int(src) < state.vcap) or not bool(state.alive[int(src)]):
        return None, ScanStats(collects=0, validated=False)
    return COLLECTORS[query](state, src), ScanStats(collects=1, validated=False)


# ------------------- fully-jitted PG-Cn (on-device retry loop) ------------

def op_linearizable_jit(state: GraphState, batches, src,
                        max_collects: int = 32):
    """Beyond-paper: the whole OP pipeline — update commits, collects, and
    CMPTREE retries — inside ONE jitted ``lax.while_loop``, so the snapshot
    protocol runs entirely on-device (no host round-trip per collect; on a
    real TPU the retry loop costs device steps, not dispatch latency).

    ``batches``: a stacked OpBatch (leading axis = pending update batches)
    committed one per collect, modelling the paper's concurrent updaters.
    Returns ``(final_state, Collect, collects_used, validated)``.
    """
    import jax
    from jax import lax
    from .updates import apply_batch

    n_batches = batches.kind.shape[0]

    def one_collect(st):
        return collect_bfs(st, src)

    def commit_next(st, i):
        batch = jax.tree.map(lambda x: x[jnp.minimum(i, n_batches - 1)],
                             batches)
        new_st, _, _ = apply_batch(st, batch)
        return jax.tree.map(
            lambda a, b: jnp.where(i < n_batches, a, b), new_st, st)

    c0 = one_collect(state)

    def cond(carry):
        st, prev, i, matched = carry
        return (~matched) & (i < max_collects)

    def body(carry):
        st, prev, i, _ = carry
        st = commit_next(st, i - 1)          # an "interrupting" update
        cur = one_collect(st)
        matched = cmp_tree(prev, cur)
        return st, cur, i + 1, matched

    st, coll, collects, matched = lax.while_loop(
        cond, body, (state, c0, jnp.int32(1), jnp.bool_(False)))
    return st, coll, collects, matched
