"""OpenMetrics exposition of the telemetry registry.

``MetricsRegistry.snapshot()`` is a JSON blob nobody scrapes; this module
renders the same instruments in the `OpenMetrics text format
<https://prometheus.io/docs/specs/om/open_metrics_spec/>`_ so a real
monitoring stack can watch a serving process:

  * counters become ``counter`` families (``<name>_total`` sample lines),
  * gauges become ``gauge`` families,
  * histograms become ``summary`` families (``{quantile="0.5|0.95|0.99"}``
    sample lines plus ``_count`` / ``_sum``) — the exact quantiles the
    benches read via ``registry.merged_quantiles``, so a live scrape and
    ``BENCH_engine.json`` report the same numbers from the same surface;

plus the telemetry-internal tallies that live outside the registry (the
tracer's ``sink_errors`` / ``dropped`` / ``rotations``) and, when a
:class:`repro.resil.OpJournal` is attached, the WAL depth (ops whose
commit barrier has not landed — the crash-loss exposure).

Serving: ``Telemetry.serve(port=...)`` (see ``obs/__init__``) runs
:class:`ExpoServer` — a stdlib ``http.server`` on a daemon thread that
renders a fresh exposition per ``GET /metrics``.  The services are
single-threaded and the render path only *reads* plain-python counters,
so a concurrent scrape can at worst see a torn-between-queries snapshot,
never corrupt one.

One-shot CLI (the offline twin of a live scrape)::

    PYTHONPATH=src python -m repro.obs.expo TRACE.jsonl [...] \
        [--check] [--serve PORT]

rebuilds a registry from trace JSONL file(s) — ``query_wall_us`` /
``query_device_us`` histograms and the per-service query/degraded/error
counters — and prints (or serves) its exposition.

:func:`validate_openmetrics` is the line-format checker CI scrapes
through: TYPE/HELP present per family, counter samples suffixed
``_total``, label values correctly escaped, ``# EOF`` terminator.
"""
from __future__ import annotations

import argparse
import math
import re
import sys
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Iterable, List, Optional, Tuple

from .metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["CONTENT_TYPE", "ExpoServer", "render_openmetrics",
           "validate_openmetrics"]

#: the content type OpenMetrics scrapers negotiate for.
CONTENT_TYPE = "application/openmetrics-text; version=1.0.0; charset=utf-8"

#: quantiles exposed per histogram — the same three the registry snapshot
#: and the bench p50/p99 fields are built from.
QUANTILES = (0.5, 0.95, 0.99)

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_OK = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

_HELP = {
    "service_queries": "Successful queries answered (one ladder rung each).",
    "service_unchanged": "Queries served by the unchanged shortcut.",
    "service_delta": "Queries served by the delta (poison+re-relax) path.",
    "service_full": "Queries served by a full recompute.",
    "service_errors": "Collect attempts that raised.",
    "service_degraded": "Stale-but-correct degraded replies served.",
    "service_retries": "Demoted re-collect attempts the resilience ladder ran.",
    "query_wall_us": "End-to-end query wall time in microseconds.",
    "query_device_us": "Per-query device-side time in microseconds "
                       "(block_until_ready deltas summed over collects).",
    "adaptive_dirty_threshold": "Current per-kind delta-vs-full crossover "
                                "threshold the ladder consults.",
    "adaptive_adjustments": "Threshold adjustments the controller applied.",
    "trace_sink_errors": "Trace records lost to a failing JSONL sink.",
    "trace_rotations": "Size-based rotations of the JSONL trace sink.",
    "trace_dropped": "In-memory trace records evicted by the bound.",
    "journal_depth": "Journaled ops not yet covered by a commit barrier.",
}


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace("\"", "\\\"")
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _sanitize_name(name: str) -> str:
    name = re.sub(r"[^a-zA-Z0-9_:]", "_", str(name))
    if not name or not _NAME_OK.match(name):
        name = "_" + name
    return name


def _fmt_labels(labels: Iterable[Tuple[str, str]]) -> str:
    items = [f'{_sanitize_name(k)}="{_escape_label(v)}"' for k, v in labels]
    return "{" + ",".join(items) + "}" if items else ""


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if isinstance(v, float) and not v.is_integer():
        return repr(v)
    return str(int(v))


def render_openmetrics(registry: MetricsRegistry, *,
                       extra_counters: Optional[Dict[str, int]] = None,
                       extra_gauges: Optional[Dict[str, float]] = None) -> str:
    """The registry's instruments as one OpenMetrics exposition string.

    ``extra_counters`` / ``extra_gauges`` fold in label-less tallies that
    live outside the registry (tracer sink counters, journal depth) so
    the scrape is the *whole* telemetry surface, not just the registry.
    """
    families: Dict[str, List[object]] = {}
    kinds: Dict[str, str] = {}
    for inst in registry.instruments():
        name = _sanitize_name(inst.name)
        fam_kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "summary"}[type(inst)]
        prev = kinds.setdefault(name, fam_kind)
        if prev != fam_kind:
            # same family name with conflicting instrument kinds: expose
            # under a suffixed family rather than emit an invalid mix
            name = f"{name}_{fam_kind}"
            kinds.setdefault(name, fam_kind)
        families.setdefault(name, []).append(inst)
    for name, value in (extra_counters or {}).items():
        name = _sanitize_name(name)
        kinds[name] = "counter"
        families[name] = [Counter(name)]
        families[name][0].set(int(value))
    for name, value in (extra_gauges or {}).items():
        name = _sanitize_name(name)
        kinds[name] = "gauge"
        families[name] = [Gauge(name)]
        families[name][0].set(float(value))

    lines: List[str] = []
    for name in sorted(families):
        kind = kinds[name]
        help_text = _HELP.get(name, f"repro {kind} {name}.")
        lines.append(f"# TYPE {name} {kind}")
        lines.append(f"# HELP {name} {_escape_help(help_text)}")
        for inst in families[name]:
            labels = tuple(getattr(inst, "labels", ()))
            if kind == "counter":
                lines.append(f"{name}_total{_fmt_labels(labels)} "
                             f"{_fmt_value(inst.value)}")
            elif kind == "gauge":
                lines.append(f"{name}{_fmt_labels(labels)} "
                             f"{_fmt_value(inst.value)}")
            else:
                qs = inst.quantiles(QUANTILES)
                if inst.count:
                    for q in QUANTILES:
                        ql = labels + (("quantile", str(q)),)
                        lines.append(f"{name}{_fmt_labels(ql)} "
                                     f"{_fmt_value(qs[q])}")
                lines.append(f"{name}_count{_fmt_labels(labels)} "
                             f"{inst.count}")
                lines.append(f"{name}_sum{_fmt_labels(labels)} "
                             f"{_fmt_value(float(inst.total))}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def telemetry_exposition(telemetry, journal=None) -> str:
    """Render a :class:`repro.obs.Telemetry` bundle (registry + the
    tracer's out-of-registry tallies + optional WAL depth)."""
    tracer = telemetry.tracer
    extra_counters = {
        "trace_sink_errors": tracer.sink_errors,
        "trace_rotations": tracer.rotations,
        "trace_dropped": tracer.dropped,
    }
    extra_gauges = {}
    if journal is not None:
        extra_gauges["journal_depth"] = journal.depth
        extra_counters["journal_ops_logged"] = journal.ops_logged
        extra_counters["journal_barriers_logged"] = journal.barriers_logged
    return render_openmetrics(telemetry.registry,
                              extra_counters=extra_counters,
                              extra_gauges=extra_gauges)


# ------------------------------- validation --------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{.*\})?"
    r" (?P<value>\S+)(?: \S+)?$")
_LABEL_ITEM_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_VALUE_RE = re.compile(r"^(NaN|[+-]?Inf|[+-]?\d+(\.\d+)?([eE][+-]?\d+)?)$")
_KINDS = ("counter", "gauge", "summary", "histogram", "info", "unknown")
_SUFFIXES = {"counter": ("_total", "_created"),
             "summary": ("", "_count", "_sum", "_created"),
             "histogram": ("_bucket", "_count", "_sum", "_created")}


def _family_of(sample_name: str, types: Dict[str, str]) -> Optional[str]:
    """Longest declared family whose allowed suffixes produce this name."""
    for fam in sorted(types, key=len, reverse=True):
        kind = types[fam]
        for suf in _SUFFIXES.get(kind, ("",)):
            if sample_name == fam + suf:
                return fam
    return None


def validate_openmetrics(text: str) -> List[str]:
    """Line-format errors in an exposition (empty list == valid).

    Checks the subset of the OpenMetrics spec a scraper trips on first:
    every sample belongs to a family declared by a preceding ``# TYPE``
    with a ``# HELP`` line, counters expose ``_total`` samples, label
    pairs parse with correct ``\\"``/``\\n``/``\\\\`` escaping, values
    are numbers, the exposition ends with ``# EOF``, and no family is
    declared twice.
    """
    errors: List[str] = []
    types: Dict[str, str] = {}
    helps: Dict[str, bool] = {}
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines = lines[:-1]
    if not lines or lines[-1] != "# EOF":
        errors.append("missing '# EOF' terminator")
    for i, line in enumerate(lines, 1):
        if not line:
            errors.append(f"line {i}: blank line")
            continue
        if line == "# EOF":
            if i != len(lines):
                errors.append(f"line {i}: '# EOF' before end of exposition")
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) != 4 or parts[3] not in _KINDS:
                errors.append(f"line {i}: malformed TYPE line: {line!r}")
                continue
            name = parts[2]
            if name in types:
                errors.append(f"line {i}: family {name!r} declared twice")
            types[name] = parts[3]
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4:
                errors.append(f"line {i}: malformed HELP line: {line!r}")
                continue
            helps[parts[2]] = True
            continue
        if line.startswith("#"):
            errors.append(f"line {i}: unknown comment {line!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            errors.append(f"line {i}: unparseable sample line: {line!r}")
            continue
        fam = _family_of(m.group("name"), types)
        if fam is None:
            # a bare counter-family name is the sharper diagnosis: the
            # writer forgot the mandatory _total sample suffix
            if types.get(m.group("name")) == "counter":
                errors.append(f"line {i}: counter sample "
                              f"{m.group('name')!r} must end with _total")
            else:
                errors.append(f"line {i}: sample {m.group('name')!r} has "
                              f"no preceding TYPE declaration")
        labels = m.group("labels")
        if labels is not None:
            body = labels[1:-1]
            consumed = _LABEL_ITEM_RE.sub("", body).replace(",", "")
            if consumed.strip():
                errors.append(f"line {i}: malformed labels {labels!r}")
            for lm in _LABEL_ITEM_RE.finditer(body):
                raw = lm.group(2)
                # an unescaped backslash or a raw newline cannot appear
                if re.search(r'(?<!\\)(?:\\\\)*\\(?![\\"n])', raw):
                    errors.append(f"line {i}: bad escape in label value "
                                  f"{raw!r}")
        if not _VALUE_RE.match(m.group("value")):
            errors.append(f"line {i}: non-numeric value "
                          f"{m.group('value')!r}")
    for fam in types:
        if fam not in helps:
            errors.append(f"family {fam!r} has TYPE but no HELP line")
    return errors


# --------------------------------- server ----------------------------------

class ExpoServer:
    """Scrape endpoint on a daemon thread: ``GET /metrics`` (or ``/``)
    renders a fresh exposition of the bound telemetry each request."""

    def __init__(self, telemetry, *, port: int = 0, host: str = "127.0.0.1",
                 journal=None):
        self.telemetry = telemetry
        self.journal = journal
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API)
                if self.path not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                body = telemetry_exposition(
                    outer.telemetry, outer.journal).encode()
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr noise
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-obs-expo", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ----------------------------------- CLI -----------------------------------

def registry_from_trace(records: list) -> MetricsRegistry:
    """Rebuild the scrape-facing registry a traced run would have fed.

    Query records become ``query_wall_us`` / ``query_device_us``
    histogram samples and per-service ``service_queries`` /
    ``service_degraded`` / ``service_errors`` counters — the same names,
    labels and quantile math as the live service, so the one-shot CLI and
    a live scrape expose identical surfaces.
    """
    reg = MetricsRegistry()
    for r in records:
        if r.get("span") != "query":
            continue
        service = r.get("service", "?")
        if "error" in r:
            reg.counter("service_errors", service=service).inc()
            continue
        kind, mode = r.get("kind", "?"), r.get("mode", "?")
        reg.histogram("query_wall_us", service=service, kind=kind,
                      mode=mode).observe(r.get("wall_us", 0.0))
        if r.get("device_us") is not None:
            reg.histogram("query_device_us", service=service, kind=kind,
                          mode=mode).observe(r["device_us"])
        if r.get("degraded"):
            reg.counter("service_degraded", service=service).inc()
        else:
            reg.counter("service_queries", service=service).inc()
    return reg


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.expo",
        description="Render trace JSONL file(s) as an OpenMetrics "
                    "exposition (one-shot), optionally serving it.")
    p.add_argument("traces", nargs="+", help="JSONL trace file(s)")
    p.add_argument("--check", action="store_true",
                   help="validate the exposition line format; non-zero "
                        "exit on any error")
    p.add_argument("--serve", type=int, default=None, metavar="PORT",
                   help="serve the exposition on this port instead of "
                        "printing it (0 = ephemeral; blocks)")
    a = p.parse_args(argv)

    from . import Telemetry
    from .report import load_many
    records = load_many(a.traces)
    tel = Telemetry(registry=registry_from_trace(records))
    text = telemetry_exposition(tel)
    if a.check:
        errors = validate_openmetrics(text)
        if errors:
            for e in errors:
                print(f"EXPO FAIL: {e}", file=sys.stderr)
            return 1
    if a.serve is not None:
        srv = ExpoServer(tel, port=a.serve)
        print(f"serving {srv.url} "
              f"({len(records)} records)", flush=True)
        try:
            threading.Event().wait()
        except KeyboardInterrupt:
            srv.close()
        return 0
    print(text, end="")
    if a.check:
        print(f"EXPO OK: {len(text.splitlines())} lines", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
