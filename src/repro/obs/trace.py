"""Span-based tracing with JSONL export.

A :class:`Tracer` turns instrumented regions into flat trace records: each
``with tracer.span("query", kind="bfs") as sp`` emits one dict carrying the
span name, its wall time, an ``id``/``parent`` pair (nesting is tracked
through a :mod:`contextvars` variable, so spans opened anywhere down the
call stack — scheduler commits, tile refreshes, collect loops — attach to
the enclosing query span without threading a handle through every layer),
and whatever attributes the region set.  Records are kept in memory
(``tracer.records``, bounded) and, when a path is given, appended to a
JSONL file that ``python -m repro.obs.report`` renders into the
per-kind/per-mode summary table.

:func:`annotate` is the deliberately tiny hook the engine internals use:
it sets attributes on the *current* span if one is active and costs one
contextvar read otherwise — so ``engine.incremental`` can report dirty
counts without knowing whether anyone is tracing.

Telemetry is best-effort by design: a failing JSONL sink (disk full,
rotated-away file, or the injected ``obs.sink`` fault) must never fail
the query it was observing.  ``_emit`` swallows sink ``OSError``s and
injected faults, keeps the in-memory record, and counts the loss in
``tracer.sink_errors``.

The sink itself is bounded (the WAL's bug class: an append-only file on
a long stream grows without limit): with ``max_bytes`` set, a write that
would cross the limit first rotates ``trace.jsonl`` → ``trace.jsonl.1``
(shifting older rotations up to ``keep``, dropping the oldest) and
reopens fresh — counted in ``tracer.rotations``.

Thread-safety: span *nesting* is already per-thread for free
(:mod:`contextvars` — each serving thread sees its own current-span
stack), but id assignment and record emission mutate shared tracer
state, so both run under a tracer lock; interleaved spans from the
dispatcher and the committer each come out as complete, well-parented
records.
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from contextlib import contextmanager
from typing import IO, Optional

from repro.resil.faults import P_OBS_SINK, InjectedFault, inject

__all__ = ["TRACE_SCHEMA", "Span", "Tracer", "annotate", "current_span"]

#: bump when the record layout changes; readers reject unknown majors.
#: 2: query spans additionally carry device_us + flops (PR 8).
TRACE_SCHEMA = 2

_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_span", default=None)


def current_span() -> Optional["Span"]:
    return _CURRENT.get()


def annotate(**attrs) -> None:
    """Attach attributes to the innermost active span (no-op untraced)."""
    sp = _CURRENT.get()
    if sp is not None:
        sp.set(**attrs)


class Span:
    """One open region; becomes a single trace record on exit."""

    __slots__ = ("name", "id", "parent", "attrs", "t0", "wall_us")

    def __init__(self, name: str, span_id: int, parent: Optional[int],
                 attrs: dict):
        self.name = name
        self.id = span_id
        self.parent = parent
        self.attrs = attrs
        self.t0 = time.perf_counter()
        self.wall_us = 0.0

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def setdefault(self, **attrs) -> None:
        for k, v in attrs.items():
            self.attrs.setdefault(k, v)


class Tracer:
    """Collects span records; optionally streams them to a JSONL file.

    ``max_records`` bounds the in-memory list (oldest dropped) so an
    always-on tracer cannot grow a long-lived service without bound; the
    JSONL sink, when given, sees every record regardless.
    """

    def __init__(self, path: Optional[str] = None, max_records: int = 100000,
                 max_bytes: Optional[int] = None, keep: int = 3):
        self.path = path
        self.max_records = max_records
        self.max_bytes = max_bytes
        self.keep = max(1, keep)
        self.records: list = []
        self.dropped = 0
        self.sink_errors = 0
        self.rotations = 0
        self._next_id = 0
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._sink: Optional[IO] = open(path, "a") if path else None
        self._sink_bytes = (os.path.getsize(path)
                            if path and os.path.exists(path) else 0)

    @contextmanager
    def span(self, name: str, **attrs):
        with self._lock:
            span_id = self._next_id
            self._next_id += 1
        sp = Span(name, span_id, getattr(_CURRENT.get(), "id", None), attrs)
        token = _CURRENT.set(sp)
        try:
            yield sp
        finally:
            _CURRENT.reset(token)
            sp.wall_us = (time.perf_counter() - sp.t0) * 1e6
            self._emit(sp)

    def _emit(self, sp: Span) -> None:
        rec = {"schema": TRACE_SCHEMA, "span": sp.name, "id": sp.id,
               "parent": sp.parent,
               "t_s": round(sp.t0 - self._t0, 6),
               "wall_us": round(sp.wall_us, 1)}
        rec.update(sp.attrs)
        with self._lock:
            if len(self.records) >= self.max_records:
                self.records.pop(0)
                self.dropped += 1
            self.records.append(rec)
            if self._sink is None:
                return
            try:
                inject(P_OBS_SINK)
                line = json.dumps(rec) + "\n"
                if (self.max_bytes is not None and self._sink_bytes > 0
                        and self._sink_bytes + len(line) > self.max_bytes):
                    self._rotate()
                self._sink.write(line)
                self._sink.flush()
                self._sink_bytes += len(line)
            except (OSError, ValueError, InjectedFault):
                # Best-effort sink: losing a trace line must never fail
                # the observed operation.  The in-memory record survives.
                self.sink_errors += 1

    def _rotate(self) -> None:
        """Shift ``path`` → ``path.1`` → ... → ``path.keep`` (oldest
        dropped) and reopen fresh.  A failing rename is swallowed — the
        sink reopens on whatever file is there (possibly still the
        oversized one) and the caller's record is appended regardless, so
        a stuck filesystem degrades to an unrotated file, never to a
        dead or lossy trace stream."""
        self._sink.close()
        try:
            oldest = f"{self.path}.{self.keep}"
            if os.path.exists(oldest):
                os.remove(oldest)
            for i in range(self.keep - 1, 0, -1):
                src = f"{self.path}.{i}"
                if os.path.exists(src):
                    os.replace(src, f"{self.path}.{i + 1}")
            os.replace(self.path, f"{self.path}.1")
            self.rotations += 1
        except OSError:
            pass
        finally:
            self._sink = open(self.path, "a")
            self._sink_bytes = os.path.getsize(self.path)

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


@contextmanager
def maybe_span(tracer: Optional[Tracer], name: str, **attrs):
    """``tracer.span`` when tracing, a reusable null span otherwise — so
    instrumented code writes one code path and pays a single ``None``
    check when telemetry is off."""
    if tracer is None:
        yield _NULL_SPAN
    else:
        with tracer.span(name, **attrs) as sp:
            yield sp


class _NullSpan:
    __slots__ = ()
    id = None
    wall_us = 0.0

    def set(self, **attrs) -> None:
        pass

    def setdefault(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()
