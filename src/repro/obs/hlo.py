"""HLO cost accounting: per-program collective bytes / temp memory / flops.

PR 3 and PR 5 proved the sharded queries' collective-byte and temp-memory
formulas against the compiled HLO, but only inside ``bench_shard`` — the
numbers vanished the moment the bench exited.  The accountant here makes
them an always-on metric: the first time a (kind, shapes, mesh) program
signature is seen, the caller's ``compile_fn`` lowers and compiles the
very jitted program the query just ran, and the result is distilled into
one small dict

    {"collective_bytes": int, "collectives": {op: bytes, ...},
     "temp_bytes": int | None, "peak_bytes": int | None,
     "flops": float | None}

cached (by default process-wide, shared across accountant instances — a
re-created service must not recompile programs XLA already built this
process) and attached to every subsequent query's trace record for free.

The HLO text parser mirrors ``launch.dryrun.parse_collective_bytes`` but
lives here import-free: dryrun prepends a 512-device XLA flag at import
time, which must never leak into a serving process.
"""
from __future__ import annotations

import re
from typing import Callable, Dict, Optional

__all__ = ["HLOCostAccountant", "account_jit", "analyze_compiled",
           "parse_collective_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes of every collective op in a per-device HLO dump."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
        out["count"] = out.get("count", 0) + 1
    return out


def analyze_compiled(compiled) -> dict:
    """Distill one jax ``Compiled`` into the accountant's cost dict.

    Every probe is individually guarded: backends without memory stats or
    cost analysis degrade to ``None`` fields instead of breaking serving.
    """
    cost = {"collective_bytes": 0, "collectives": {},
            "temp_bytes": None, "peak_bytes": None, "flops": None}
    try:
        coll = parse_collective_bytes(compiled.as_text())
        cost["collective_bytes"] = coll.pop("total", 0)
        coll.pop("count", None)
        cost["collectives"] = coll
    except Exception:
        pass
    try:
        ma = compiled.memory_analysis()
        cost["temp_bytes"] = int(ma.temp_size_in_bytes)
        cost["peak_bytes"] = (int(ma.temp_size_in_bytes)
                              + int(ma.argument_size_in_bytes)
                              + int(ma.output_size_in_bytes))
    except Exception:
        pass
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        flops = ca.get("flops")
        if flops is not None:
            cost["flops"] = float(flops)
    except Exception:
        pass
    return cost


class HLOCostAccountant:
    """Cache of program-signature -> cost dict.

    ``shared=True`` (default) keys into one process-wide cache: compiled
    analysis depends only on the program signature, and re-lowering is the
    expensive step being amortized.  ``last`` always holds the cost of the
    most recent :meth:`account` call so host wrappers can deposit it and
    their caller (the service) can pick it up without widening return
    types.
    """

    _SHARED: Dict[tuple, dict] = {}

    def __init__(self, shared: bool = True):
        self._cache = HLOCostAccountant._SHARED if shared else {}
        self.last: Optional[dict] = None

    def account(self, key: tuple, compile_fn: Callable[[], object]) -> dict:
        cost = self._cache.get(key)
        if cost is None:
            try:
                cost = analyze_compiled(compile_fn())
            except Exception:  # never let accounting break the query
                cost = {"collective_bytes": 0, "collectives": {},
                        "temp_bytes": None, "peak_bytes": None, "flops": None}
            self._cache[key] = cost
        self.last = cost
        return cost

    def snapshot(self) -> dict:
        return {repr(k): v for k, v in self._cache.items()}


def account_jit(accountant: Optional[HLOCostAccountant], key: tuple,
                fn, *args) -> Optional[dict]:
    """Deposit the cost of one jitted program with the accountant.

    The local-engine twin of ``shard.queries._account``: ``fn`` is the
    ``jax.jit``-wrapped callable the caller just ran with ``args``; the
    first time ``key`` (the program signature — kind/mode plus the
    shape-determining dims) is seen, the program is re-lowered and
    compiled once for ``cost_analysis``, then every later call is a cache
    hit that only refreshes ``accountant.last``.  No-op without an
    accountant, so the untelemetered path pays one ``None`` check.
    """
    if accountant is None:
        return None
    return accountant.account(key, lambda: fn.lower(*args).compile())
