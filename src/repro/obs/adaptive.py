"""Self-tuning ``dirty_threshold``: the observability loop closed.

The unchanged → delta → full ladder pivots on a dirty-fraction threshold
that PR 1 guessed at ``0.25`` and every service has hard-coded since.
The profitable delta-vs-full crossover is a *workload* property — it
moves with graph size, churn locality, and backend — and the service
already measures everything needed to find it: every query feeds a
``query_wall_us`` histogram labelled (service, kind, mode) and annotates
its observed dirty fraction.

:class:`AdaptiveThresholds` turns those observations into control:

  * **observe** — per successful query the service reports
    ``(kind, mode, wall_us, dirty_frac)``; full-mode walls land in a
    per-kind reservoir, delta-mode ``(frac, wall)`` pairs in another.
  * **probe** — a threshold that only ever shrinks would starve itself of
    full-mode samples (a healthy delta ladder answers almost everything
    cheaply).  Every ``probe_every``-th consult the controller returns a
    threshold of ``0.0``, demoting that one query to a full recompute —
    answers are bit-identical (the full path is the ladder's own oracle),
    only the cost moves, and the observed wall refreshes the full-cost
    estimate.
  * **fit** — with enough of both, model the delta cost as linear in the
    dirty fraction (least squares over the pair reservoir), take
    ``t_full`` as the median full wall, and solve ``a + b·f = t_full``
    for the crossover fraction ``f*``.
  * **adjust** — step the per-kind threshold toward ``f*`` by a damped
    ``alpha`` fraction per adjustment, clamped to ``[lo, hi]``; every
    adjustment emits a ``threshold_adjust`` span carrying the decision
    inputs (old/new, t_full, fit slope/intercept, crossover, sample
    counts) and updates the ``adaptive_dirty_threshold`` gauge +
    ``adaptive_adjustments`` counter, so the controller's behaviour is
    itself observable through the same trace/scrape surface it feeds on.

The controller is deliberately conservative: no samples → no movement
(the static default keeps ruling), a degenerate fit (non-positive slope:
delta not measurably dearer with dirtiness) → no movement, and clamps
bound the worst case — a bad fit can cost performance, never
correctness, because every rung returns the same answer.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional, Tuple

from .metrics import MetricsRegistry
from .trace import Tracer

__all__ = ["AdaptiveThresholds"]

#: query kinds the services run the ladder for.
LADDER_KINDS = ("bfs", "sssp", "bc")


class AdaptiveThresholds:
    """Per-kind ``dirty_threshold`` controller (see module docstring).

    ``base`` seeds the thresholds — one float for every kind, or a
    per-kind mapping (the services pass their static per-kind defaults:
    BC's profitable crossover sits an order of magnitude below
    BFS/SSSP's, see ``repro.engine.service.DEFAULT_DIRTY_THRESHOLDS``);
    ``lo``/``hi`` clamp it — ``lo`` defaults low enough (0.005) that the
    controller can actually reach BC's few-percent crossover instead of
    being pinned above it; ``alpha`` damps each step toward the fitted
    crossover; ``period`` is the adjustment cadence in observations per
    kind; ``min_full``/``min_delta`` gate the fit on sample coverage;
    ``probe_every`` forces every Nth threshold consult to a full
    recompute (0 disables probing).  ``bind`` attaches the registry /
    tracer / service label — unbound controllers still tune, they just
    don't export.
    """

    def __init__(self, *, base=0.25, lo: float = 0.005,
                 hi: float = 0.75, alpha: float = 0.5, period: int = 16,
                 min_full: int = 2, min_delta: int = 6,
                 probe_every: int = 16, max_samples: int = 512,
                 kinds: Tuple[str, ...] = LADDER_KINDS):
        self.kinds = tuple(kinds)
        if isinstance(base, (int, float)):
            bases = {k: float(base) for k in self.kinds}
        else:
            bases = {k: float(base[k]) for k in self.kinds}
        for k, b in bases.items():
            if not (0.0 <= lo <= b <= hi <= 1.0):
                raise ValueError(f"need 0 <= lo <= base <= hi <= 1, got "
                                 f"{lo}/{b} ({k})/{hi}")
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.base, self.lo, self.hi, self.alpha = bases, lo, hi, alpha
        self.period, self.min_full, self.min_delta = period, min_full, \
            min_delta
        self.probe_every = probe_every
        self._thr: Dict[str, float] = dict(bases)
        self._full: Dict[str, deque] = {
            k: deque(maxlen=max_samples) for k in self.kinds}
        self._pairs: Dict[str, deque] = {
            k: deque(maxlen=max_samples) for k in self.kinds}
        self._since_adjust: Dict[str, int] = {k: 0 for k in self.kinds}
        self._consults: Dict[str, int] = {k: 0 for k in self.kinds}
        self.adjustments = 0
        self.probes = 0
        self._registry: Optional[MetricsRegistry] = None
        self._tracer: Optional[Tracer] = None
        self._service = "service"

    # ------------------------------ binding ------------------------------

    def bind(self, registry: Optional[MetricsRegistry],
             tracer: Optional[Tracer], service: str) -> "AdaptiveThresholds":
        self._registry = registry
        self._tracer = tracer
        self._service = service
        if registry is not None:
            for k in self.kinds:
                registry.gauge("adaptive_dirty_threshold",
                               service=service, kind=k).set(self._thr[k])
        return self

    # ------------------------------ consults -----------------------------

    def threshold(self, kind: str) -> float:
        """The dirty-fraction bound the ladder should use *now*.

        Every ``probe_every``-th consult per kind returns 0.0, demoting a
        would-be delta to a full recompute so the full-cost estimate
        stays fresh.  A probe that lands on a query the unchanged
        shortcut ends up answering anyway (the local ladder consults the
        threshold before the unchanged test) is a harmless no-op — the
        answer is the cached one either way.
        """
        if kind not in self._thr:
            return self.base.get(kind, 0.25)
        self._consults[kind] += 1
        if self.probe_every and self._consults[kind] % self.probe_every == 0:
            self.probes += 1
            return 0.0
        return self._thr[kind]

    def thresholds(self) -> Dict[str, float]:
        return dict(self._thr)

    def restore(self, thresholds: Dict[str, float]) -> "AdaptiveThresholds":
        """Adopt previously learned per-kind thresholds (e.g. off a
        compaction snapshot's manifest), clamped to ``[lo, hi]``; unknown
        kinds are ignored, missing kinds keep their current value.
        Bound gauges are refreshed so the scrape surface agrees."""
        for k, v in thresholds.items():
            if k not in self._thr:
                continue
            self._thr[k] = min(self.hi, max(self.lo, float(v)))
            if self._registry is not None:
                self._registry.gauge("adaptive_dirty_threshold",
                                     service=self._service,
                                     kind=k).set(self._thr[k])
        return self

    # ---------------------------- observations ---------------------------

    def observe(self, kind: str, mode: str, wall_us: float,
                dirty_frac: Optional[float]) -> None:
        """One successful query's outcome; may trigger an adjustment."""
        if kind not in self._thr:
            return
        if mode == "full":
            self._full[kind].append(float(wall_us))
        elif mode == "delta" and dirty_frac is not None:
            self._pairs[kind].append((float(dirty_frac), float(wall_us)))
        else:
            return  # unchanged replies say nothing about the crossover
        self._since_adjust[kind] += 1
        if self._since_adjust[kind] >= self.period:
            self._since_adjust[kind] = 0
            self._maybe_adjust(kind)

    # ------------------------------- control -----------------------------

    def _fit(self, kind: str):
        """(intercept, slope) of wall_us ~ dirty_frac over the delta pairs,
        or None when the pairs are degenerate (all one fraction)."""
        pairs = self._pairs[kind]
        n = len(pairs)
        sx = sum(f for f, _ in pairs)
        sy = sum(w for _, w in pairs)
        sxx = sum(f * f for f, _ in pairs)
        sxy = sum(f * w for f, w in pairs)
        denom = n * sxx - sx * sx
        if denom <= 0:
            return None
        b = (n * sxy - sx * sy) / denom
        a = (sy - b * sx) / n
        return a, b

    def _maybe_adjust(self, kind: str) -> None:
        n_full, n_delta = len(self._full[kind]), len(self._pairs[kind])
        if n_full < self.min_full or n_delta < self.min_delta:
            return
        fit = self._fit(kind)
        if fit is None:
            return
        a, b = fit
        if b <= 0:
            # delta not measurably dearer with dirtiness: the data gives
            # no crossover; leave the threshold where it is
            return
        full_sorted = sorted(self._full[kind])
        t_full = full_sorted[len(full_sorted) // 2]
        crossover = (t_full - a) / b
        target = min(self.hi, max(self.lo, crossover))
        old = self._thr[kind]
        new = min(self.hi, max(self.lo, old + self.alpha * (target - old)))
        if abs(new - old) < 1e-9:
            return
        self._thr[kind] = new
        self.adjustments += 1
        if self._registry is not None:
            self._registry.gauge("adaptive_dirty_threshold",
                                 service=self._service, kind=kind).set(new)
            self._registry.counter("adaptive_adjustments",
                                   service=self._service, kind=kind).inc()
        if self._tracer is not None:
            with self._tracer.span("threshold_adjust",
                                   service=self._service, kind=kind) as sp:
                sp.set(old=round(old, 6), new=round(new, 6),
                       t_full_us=round(t_full, 1),
                       fit_intercept_us=round(a, 1),
                       fit_slope_us=round(b, 1),
                       crossover=round(crossover, 6),
                       clamped=bool(crossover != target),
                       n_full=n_full, n_delta=n_delta)

    # ------------------------------- export ------------------------------

    def snapshot(self) -> dict:
        return {
            "thresholds": {k: round(v, 6) for k, v in self._thr.items()},
            "clamps": {"lo": self.lo, "hi": self.hi},
            "base": {k: round(v, 6) for k, v in self.base.items()},
            "adjustments": self.adjustments,
            "probes": self.probes,
            "samples": {k: {"full": len(self._full[k]),
                            "delta": len(self._pairs[k])}
                        for k in self.kinds},
        }

    def __repr__(self):
        thr = ", ".join(f"{k}={v:.3f}" for k, v in self._thr.items())
        return (f"AdaptiveThresholds({thr}, adjustments={self.adjustments}, "
                f"probes={self.probes})")
