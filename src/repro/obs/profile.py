"""Per-span device-time attribution.

The query spans measure host wall time; ``block_us`` measures one final
``block_until_ready`` at the end of the query.  Neither says how much of
a query was *device execution*: an unchanged-shortcut reply does zero
device work but still pays host time for the dirty-set check, while a
full sharded collect is almost all device time hidden behind jax's async
dispatch.

:class:`DeviceTimer` closes that gap with the dispatch-gap method: a
collect returns as soon as its programs are enqueued, so the time spent
blocking on the result *from that moment* is device execution that had
not finished when the host moved on — per collect

    t0 = now();  jax.block_until_ready(result);  device_us += now() - t0

Summed over a query's collects this is the query's attributable device
time: ~0 for unchanged replies (the cached result is already concrete),
and asymptotically the program runtime for compute-bound collects (exact
up to whatever device execution overlapped the host's return path, which
the dispatch gap cannot see — it is a lower bound, where ``wall_us`` is
the upper).  When a `jax.profiler
<https://docs.jax.dev/en/latest/profiling.html>`_ trace is active, every
measured region is additionally wrapped in a
``jax.profiler.TraceAnnotation`` named after its span, so offline
profiler timelines carry the same attribution boundaries the JSONL trace
does.

:class:`NullDeviceTimer` is the null object: ``measure`` neither blocks
nor times (device_us 0.0), for callers that pipeline async dispatches
and must not introduce synchronization points.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Optional

__all__ = ["DeviceTimer", "NullDeviceTimer", "profiler_trace"]


def _trace_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` when available, else a no-op.

    Guarded per call: the annotation itself is cheap (a TraceMe that is
    inert unless a profiler session is collecting), but older/stubbed jax
    builds may lack it entirely.
    """
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return nullcontext()


class DeviceTimer:
    """Blocking device-time attribution (the default).

    ``measure(result, name)`` blocks ``result`` and returns the dispatch
    gap in microseconds; ``total_us`` accumulates across calls so a
    service can difference it per query without threading a handle
    through every collect.
    """

    blocking = True

    def __init__(self, annotate: bool = True):
        self.annotate = annotate
        self.total_us = 0.0
        self.measures = 0

    def measure(self, result, name: str = "device") -> float:
        import jax
        t0 = time.perf_counter()
        with _trace_annotation(name) if self.annotate else nullcontext():
            jax.block_until_ready(result)
        us = (time.perf_counter() - t0) * 1e6
        self.total_us += us
        self.measures += 1
        return us


class NullDeviceTimer:
    """No synchronization, no timing: ``measure`` returns 0.0 untouched."""

    blocking = False
    total_us = 0.0
    measures = 0

    def measure(self, result, name: str = "device") -> float:
        return 0.0


def profiler_trace(logdir: str) -> Optional[object]:
    """Start a jax profiler trace session when the backend supports one.

    Returns a closer with ``.close()`` (calls ``stop_trace``), or ``None``
    when profiling is unavailable — callers treat the session as
    best-effort extra visibility, never a dependency.
    """
    try:
        import jax.profiler
        jax.profiler.start_trace(logdir)
    except Exception:
        return None

    class _Session:
        def close(self):
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass

    import jax.profiler  # noqa: F811 (close over the module, post-start)
    return _Session()
