"""Metrics registry: counters, gauges, and quantile histograms.

One process-local registry unifies the ad-hoc tally objects the engine
grew organically (``ServiceStats``, ``bc_scores_stats``,
``refresh_stats``, ``SchedulerStats``): each is now a thin attribute shim
over named :class:`Counter` instruments in a :class:`MetricsRegistry`
(see :class:`CounterStruct` / :class:`ModeCounters`), so the same numbers
that drive the existing tests and benches are also exportable as one
structured snapshot — and the serving benches read their p50/p95/p99
latency straight from the :class:`Histogram` instruments the service
feeds per query.

Instruments are keyed by ``(name, sorted(labels))``; asking for the same
key twice returns the same instrument, so shims and tracers can share
counters without coordination.  Everything here is plain Python — no jax
import.

Thread-safety: the async serving front end (``repro.serve``) drives one
registry from several threads (admission, dispatcher, committer), so
instrument *creation* is serialized by a registry lock and instrument
*mutation* (``inc``/``set``/``observe``) by a shared module lock — both
far off any device-dispatch hot path.  The attribute shims'
``stats.field += k`` surface remains a read-then-write pair: each shim's
counters must stay owned by one thread (the serve layer's threading
model guarantees this — the dispatcher owns query stats, the committer
owns scheduler stats); cross-thread tallies should use plain ``inc``.
"""
from __future__ import annotations

import threading
from collections import deque
from collections.abc import MutableMapping
from typing import Dict, Iterable, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "CounterStruct",
    "ModeCounters", "LADDER_MODES",
]

#: the rungs of the unchanged -> delta -> full query ladder.
LADDER_MODES = ("unchanged", "delta", "full")

#: one lock for every instrument mutation: cheap (host bookkeeping only)
#: and makes ``inc``/``observe`` atomic across serving threads.
_MUT_LOCK = threading.Lock()


class Counter:
    """Monotonic tally.  ``inc`` is atomic under concurrent callers;
    ``set`` exists for the attribute shims (``stats.field += k`` reads
    then writes — single-owner-thread only) — use ``inc`` elsewhere."""

    __slots__ = ("name", "labels", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._value = 0

    @property
    def value(self) -> int:
        return self._value

    def inc(self, n: int = 1) -> None:
        with _MUT_LOCK:
            self._value += n

    def set(self, v: int) -> None:
        self._value = int(v)

    def __repr__(self):
        return f"Counter({self.name}{dict(self.labels)}={self._value})"


class Gauge:
    """Last-write-wins scalar (ring depth, cache size, ...)."""

    __slots__ = ("name", "labels", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0

    @property
    def value(self) -> float:
        return self._value

    def set(self, v: float) -> None:
        self._value = float(v)

    def __repr__(self):
        return f"Gauge({self.name}{dict(self.labels)}={self._value})"


class Histogram:
    """Sample reservoir with exact quantiles over the newest samples.

    Keeps up to ``max_samples`` most-recent observations (a bounded deque,
    so a long-lived service cannot grow without bound) plus exact running
    ``count``/``total``; quantiles are computed on demand by sorting the
    reservoir — the export path, not the hot path, pays.
    """

    __slots__ = ("name", "labels", "_samples", "count", "total")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 max_samples: int = 65536):
        self.name = name
        self.labels = labels
        self._samples: deque = deque(maxlen=max_samples)
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        with _MUT_LOCK:
            self._samples.append(v)
            self.count += 1
            self.total += v

    @property
    def samples(self) -> list:
        with _MUT_LOCK:
            return list(self._samples)

    def quantile(self, q: float) -> float:
        return quantile(self.samples, q)

    def quantiles(self, qs: Iterable[float]) -> Dict[float, float]:
        s = sorted(self.samples)
        return {q: _q_sorted(s, q) for q in qs}

    def __repr__(self):
        return (f"Histogram({self.name}{dict(self.labels)} "
                f"count={self.count} p50={self.quantile(0.5):.1f})")


def _q_sorted(s: list, q: float) -> float:
    """Nearest-rank quantile over an already-sorted sample list."""
    if not s:
        return float("nan")
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[idx]


def quantile(samples: Iterable[float], q: float) -> float:
    return _q_sorted(sorted(samples), q)


class MetricsRegistry:
    """Get-or-create instrument store keyed by ``(name, labels)``."""

    def __init__(self):
        self._metrics: Dict[Tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (cls.__name__, name, tuple(sorted(labels.items())))
        with self._lock:
            inst = self._metrics.get(key)
            if inst is None:
                inst = cls(name, tuple(sorted(labels.items())), **kw)
                self._metrics[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def instruments(self) -> list:
        """Every registered instrument, in registration order (the
        exposition renderer groups them into OpenMetrics families)."""
        with self._lock:
            return list(self._metrics.values())

    def find(self, name: str, **label_filter) -> list:
        """Every instrument called ``name`` whose labels cover the filter."""
        out = []
        for inst in self.instruments():
            if inst.name != name:
                continue
            labels = dict(inst.labels)
            if all(labels.get(k) == v for k, v in label_filter.items()):
                out.append(inst)
        return out

    def merged_quantiles(self, name: str, qs: Iterable[float],
                         **label_filter) -> Dict[float, float]:
        """Quantiles over the pooled samples of every matching histogram
        (e.g. one latency distribution across all ladder modes)."""
        pooled: list = []
        for h in self.find(name, **label_filter):
            if isinstance(h, Histogram):
                pooled.extend(h.samples)
        pooled.sort()
        return {q: _q_sorted(pooled, q) for q in qs}

    def snapshot(self) -> list:
        """JSON-able dump of every instrument (histograms as summaries)."""
        out = []
        for inst in self.instruments():
            rec = {"name": inst.name, "labels": dict(inst.labels),
                   "kind": type(inst).__name__.lower()}
            if isinstance(inst, Histogram):
                qs = inst.quantiles((0.5, 0.95, 0.99))
                rec.update(count=inst.count, total=inst.total,
                           p50=qs[0.5], p95=qs[0.95], p99=qs[0.99])
            else:
                rec["value"] = inst.value
            out.append(rec)
        return out


class CounterStruct:
    """Attribute-named counter bundle: the deprecation-shim base that lets
    ``ServiceStats`` / ``RefreshStats`` / ``SchedulerStats`` keep their
    ``stats.field`` / ``stats.field += k`` surface while the values live
    in a :class:`MetricsRegistry` (their own private one when the owning
    service has no telemetry attached).

    Subclasses set ``_FIELDS`` (attribute names) and ``_PREFIX`` (metric
    name prefix); constructor labels land on every counter.
    """

    _FIELDS: Tuple[str, ...] = ()
    _PREFIX: str = ""

    def __init__(self, registry: Optional[MetricsRegistry] = None, **labels):
        reg = registry if registry is not None else MetricsRegistry()
        object.__setattr__(self, "registry", reg)
        object.__setattr__(self, "_counters", {
            f: reg.counter(self._PREFIX + f, **labels) for f in self._FIELDS})

    def __getattr__(self, name):
        # only reached when normal lookup fails -> counter fields
        counters = object.__getattribute__(self, "_counters")
        if name in counters:
            return counters[name].value
        raise AttributeError(name)

    def __setattr__(self, name, value):
        if name in self._FIELDS:
            self._counters[name].set(value)
        else:
            object.__setattr__(self, name, value)

    def as_dict(self) -> Dict[str, int]:
        return {f: c.value for f, c in self._counters.items()}

    def __repr__(self):
        body = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"{type(self).__name__}({body})"


class ModeCounters(MutableMapping):
    """Dict-shaped shim over per-mode counters (``bc_scores_stats``):
    supports exactly the ``d[mode]`` / ``d[mode] += 1`` surface of the
    plain dict it replaces, backed by labelled registry counters."""

    def __init__(self, registry: MetricsRegistry, name: str,
                 modes: Tuple[str, ...] = LADDER_MODES, **labels):
        self._counters = {m: registry.counter(name, mode=m, **labels)
                          for m in modes}

    def __getitem__(self, mode):
        return self._counters[mode].value

    def __setitem__(self, mode, value):
        self._counters[mode].set(value)

    def __delitem__(self, mode):
        raise TypeError("ModeCounters keys are fixed")

    def __iter__(self):
        return iter(self._counters)

    def __len__(self):
        return len(self._counters)

    def __repr__(self):
        return f"ModeCounters({dict(self)})"
