"""Unified telemetry: metrics, tracing, cost accounting, and control.

One instrument surface for the whole serving ladder (ROADMAP: the
measurement substrate the serving/ingest work is judged against):

  * :mod:`repro.obs.metrics` — counters / gauges / p50-p95-p99 histograms
    in a :class:`MetricsRegistry`; the engine's ad-hoc tally objects
    (``ServiceStats``, ``bc_scores_stats``, ``refresh_stats``,
    ``SchedulerStats``) are now attribute shims over it;
  * :mod:`repro.obs.trace` — span-based tracing with contextvar nesting
    and size-rotated JSONL export; every ``query()`` through either
    service emits a record carrying kind / ring version / ladder mode /
    wall time / device time / collective bytes, with child spans for
    scheduler commits, tile refresh, and each collect of the PG-Cn loop;
  * :mod:`repro.obs.hlo` — compiled-program cost accounting
    (``cost_analysis`` / ``memory_analysis`` / HLO collective-byte
    parsing) cached per program signature and attributed to every
    query — sharded *and* local since PR 8;
  * :mod:`repro.obs.profile` — per-span device-time attribution
    (dispatch-gap ``block_until_ready`` deltas, ``jax.profiler``
    annotations when a profiler session is live) behind a null-object
    default;
  * :mod:`repro.obs.expo` — OpenMetrics exposition of the registry,
    served live (:meth:`Telemetry.serve`) or one-shot
    (``python -m repro.obs.expo``), so scrapes and ``BENCH_*.json``
    read the same surface;
  * :mod:`repro.obs.adaptive` — the :class:`AdaptiveThresholds`
    controller that closes the loop: it fits the delta-vs-full crossover
    from the service's own latency/dirty-fraction observations and tunes
    the ladder's ``dirty_threshold`` per kind within clamps;
  * :mod:`repro.obs.report` — ``python -m repro.obs.report TRACE.jsonl``
    renders the per-kind/per-mode summary table (and is the CI gate over
    traced streams).

:class:`Telemetry` bundles the runtime pieces; pass one to a service
(``GraphService(..., telemetry=Telemetry.make())``) to turn the
instruments on.  Without one, services still tally their shim counters
(each shim owns a private registry) but trace nothing and never compile
for accounting — the off path stays a single ``None`` check per query.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .adaptive import AdaptiveThresholds  # noqa: F401
from .hlo import (  # noqa: F401
    HLOCostAccountant,
    account_jit,
    analyze_compiled,
    parse_collective_bytes,
)
from .metrics import (  # noqa: F401
    LADDER_MODES,
    Counter,
    CounterStruct,
    Gauge,
    Histogram,
    MetricsRegistry,
    ModeCounters,
    quantile,
)
from .profile import DeviceTimer, NullDeviceTimer  # noqa: F401
from .trace import TRACE_SCHEMA, Span, Tracer, annotate, current_span, maybe_span  # noqa: F401


@dataclass
class Telemetry:
    """The bundle a service consumes: registry + tracer + accountant +
    device timer.

    ``block``: when True (default) a traced query blocks its result before
    the span closes, so the histogram / trace wall times are end-to-end
    device latencies (what a serving benchmark quotes as p50/p99), not
    dispatch times.  Callers that pipeline async dispatches can turn it
    off and keep tracing.

    ``profiler``: the device-time attributor (``repro.obs.profile``).
    The default :class:`DeviceTimer` blocks each collect's result to
    measure its dispatch gap — every query span then carries
    ``device_us``; :class:`NullDeviceTimer` (``make(profile=False)``)
    reports 0.0 without synchronizing.
    """

    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: Tracer = field(default_factory=Tracer)
    accountant: Optional[HLOCostAccountant] = field(
        default_factory=HLOCostAccountant)
    block: bool = True
    profiler: object = field(default_factory=DeviceTimer)

    @classmethod
    def make(cls, trace_path: Optional[str] = None, *, block: bool = True,
             hlo: bool = True, profile: bool = True,
             trace_max_bytes: Optional[int] = None,
             trace_keep: int = 3) -> "Telemetry":
        """One-call construction: in-memory by default, JSONL-sinking when
        ``trace_path`` is given (size-rotated at ``trace_max_bytes``,
        keeping ``trace_keep`` rotated files); ``hlo=False`` skips cost
        accounting (no extra compiles — e.g. compile-latency-sensitive
        tests); ``profile=False`` skips device-time attribution (no
        per-collect synchronization)."""
        return cls(registry=MetricsRegistry(),
                   tracer=Tracer(path=trace_path, max_bytes=trace_max_bytes,
                                 keep=trace_keep),
                   accountant=HLOCostAccountant() if hlo else None,
                   block=block,
                   profiler=DeviceTimer() if profile else NullDeviceTimer())

    def serve(self, port: int = 0, *, host: str = "127.0.0.1",
              journal=None):
        """Start the OpenMetrics scrape endpoint (``GET /metrics``) on a
        daemon thread; returns the :class:`repro.obs.expo.ExpoServer`
        (``.url``, ``.port``, ``.close()``).  ``journal`` additionally
        exposes the WAL depth gauge."""
        from .expo import ExpoServer
        return ExpoServer(self, port=port, host=host, journal=journal)

    def exposition(self, journal=None) -> str:
        """The current OpenMetrics exposition text (what a scrape of
        :meth:`serve` returns right now)."""
        from .expo import telemetry_exposition
        return telemetry_exposition(self, journal=journal)

    def close(self) -> None:
        self.tracer.close()
