"""Unified telemetry: metrics registry, span tracing, HLO cost accounting.

One instrument surface for the whole serving ladder (ROADMAP: the
measurement substrate the serving/ingest work is judged against):

  * :mod:`repro.obs.metrics` — counters / gauges / p50-p95-p99 histograms
    in a :class:`MetricsRegistry`; the engine's ad-hoc tally objects
    (``ServiceStats``, ``bc_scores_stats``, ``refresh_stats``,
    ``SchedulerStats``) are now attribute shims over it;
  * :mod:`repro.obs.trace` — span-based tracing with contextvar nesting
    and JSONL export; every ``query()`` through either service emits a
    record carrying kind / ring version / ladder mode / wall time /
    collective bytes, with child spans for scheduler commits, tile
    refresh, and each collect of the PG-Cn loop;
  * :mod:`repro.obs.hlo` — compiled-program cost accounting
    (``cost_analysis`` / ``memory_analysis`` / HLO collective-byte
    parsing) cached per program signature and attributed to every
    sharded query;
  * :mod:`repro.obs.report` — ``python -m repro.obs.report TRACE.jsonl``
    renders the per-kind/per-mode summary table (and is the CI gate over
    traced streams).

:class:`Telemetry` bundles the three runtime pieces; pass one to a
service (``GraphService(..., telemetry=Telemetry.make())``) to turn the
instruments on.  Without one, services still tally their shim counters
(each shim owns a private registry) but trace nothing and never compile
for accounting — the off path stays a single ``None`` check per query.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .hlo import HLOCostAccountant, analyze_compiled, parse_collective_bytes  # noqa: F401
from .metrics import (  # noqa: F401
    LADDER_MODES,
    Counter,
    CounterStruct,
    Gauge,
    Histogram,
    MetricsRegistry,
    ModeCounters,
    quantile,
)
from .trace import TRACE_SCHEMA, Span, Tracer, annotate, current_span, maybe_span  # noqa: F401


@dataclass
class Telemetry:
    """The bundle a service consumes: registry + tracer + HLO accountant.

    ``block``: when True (default) a traced query blocks its result before
    the span closes, so the histogram / trace wall times are end-to-end
    device latencies (what a serving benchmark quotes as p50/p99), not
    dispatch times.  Callers that pipeline async dispatches can turn it
    off and keep tracing.
    """

    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: Tracer = field(default_factory=Tracer)
    accountant: Optional[HLOCostAccountant] = field(
        default_factory=HLOCostAccountant)
    block: bool = True

    @classmethod
    def make(cls, trace_path: Optional[str] = None, *, block: bool = True,
             hlo: bool = True) -> "Telemetry":
        """One-call construction: in-memory by default, JSONL-sinking when
        ``trace_path`` is given; ``hlo=False`` skips cost accounting (no
        extra compiles — e.g. compile-latency-sensitive tests)."""
        return cls(registry=MetricsRegistry(),
                   tracer=Tracer(path=trace_path),
                   accountant=HLOCostAccountant() if hlo else None,
                   block=block)

    def close(self) -> None:
        self.tracer.close()
