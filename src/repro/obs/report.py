"""Render a per-kind/per-mode summary table from JSONL trace file(s).

    PYTHONPATH=src python -m repro.obs.report TRACE.jsonl [MORE.jsonl ...] \\
        [--check] [--require-modes unchanged,delta,full] [--format json]

Aggregates the ``span == "query"`` records a traced
``GraphService``/``ShardedGraphService`` emitted: one row per
(service, kind, ladder mode) with query counts, wall-time quantiles,
device-time medians, validated counts, degraded counts, and mean
HLO-attributed collective bytes.  Multiple trace files (a rotated sink's
``trace.jsonl.N`` siblings, or per-process traces) are merged and sorted
by span id before aggregation.  ``--check`` turns the reader into a CI
gate: every completed query record must carry the full schema
(kind/version/mode/degraded/wall/device-time/collective-bytes/flops);
records that ended in an error (they carry an ``error`` field and no
version/mode to claim) are exempt from the field check but counted.
``--require-modes`` demands a non-empty row per named ladder mode;
``--require-degraded`` demands at least one degraded record (the
chaos-smoke job's proof the ladder actually exercised its bottom rung);
``--require-spans ladder_pinned`` demands each named span appear at
least once anywhere in the trace (the breaker-trip gate).
``--format json`` emits the summary rows as machine-readable JSON for
CI consumers (``--json`` is the legacy spelling).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Sequence

from .metrics import quantile
from .trace import TRACE_SCHEMA

#: fields every completed query trace record must carry (the acceptance
#: schema); error-terminated records carry ``error`` instead.
QUERY_FIELDS = ("schema", "span", "wall_us", "kind", "version", "mode",
                "coll_bytes", "service", "degraded", "device_us", "flops")


def load(path: str) -> list:
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i + 1}: invalid JSON: {e}")
    return records


def load_many(paths: Sequence[str]) -> list:
    """Merge several trace files, sorted by span id (stable, so records
    from different tracers with colliding ids keep their file order)."""
    records = []
    for path in paths:
        records.extend(load(path))
    records.sort(key=lambda r: r.get("id", 0))
    return records


def query_records(records: list) -> list:
    return [r for r in records if r.get("span") == "query"]


def validate(records: list, require_modes=(),
             require_degraded: bool = False, require_spans=()) -> list:
    """Schema + coverage errors (empty list == valid)."""
    errors = []
    qrecs = query_records(records)
    if not qrecs:
        errors.append("no query records in trace")
    seen_spans = {r.get("span") for r in records}
    for span in require_spans:
        if span not in seen_spans:
            errors.append(f"required span {span!r} has no trace records "
                          f"(saw {sorted(s for s in seen_spans if s)})")
    for i, r in enumerate(qrecs):
        if "error" in r:
            # the query raised: no version/mode to claim, record is exempt
            continue
        missing = [f for f in QUERY_FIELDS if f not in r]
        if missing:
            errors.append(f"query record {i} missing fields: {missing}")
        elif r["schema"] != TRACE_SCHEMA:
            errors.append(f"query record {i}: schema {r['schema']} != "
                          f"{TRACE_SCHEMA}")
    seen_modes = {r.get("mode") for r in qrecs if "error" not in r}
    for mode in require_modes:
        if mode not in seen_modes:
            errors.append(f"required ladder mode {mode!r} has no query "
                          f"records (saw {sorted(m for m in seen_modes if m)})")
    if require_degraded and not any(r.get("degraded") for r in qrecs):
        errors.append("no degraded query records (ladder bottom rung "
                      "never exercised)")
    return errors


def summarize(records: list) -> list:
    """Rows of (service, kind, mode) aggregates over the query records."""
    groups = defaultdict(list)
    for r in query_records(records):
        groups[(r.get("service", "?"), r.get("kind", "?"),
                r.get("mode", "?"))].append(r)
    rows = []
    for (service, kind, mode), rs in sorted(groups.items()):
        walls = [r.get("wall_us", 0.0) for r in rs]
        devs = [r.get("device_us", 0.0) or 0.0 for r in rs]
        rows.append({
            "service": service, "kind": kind, "mode": mode,
            "queries": len(rs),
            "p50_us": round(quantile(walls, 0.50), 1),
            "p95_us": round(quantile(walls, 0.95), 1),
            "p99_us": round(quantile(walls, 0.99), 1),
            "device_p50_us": round(quantile(devs, 0.50), 1),
            "validated": sum(bool(r.get("validated")) for r in rs),
            "degraded": sum(bool(r.get("degraded")) for r in rs),
            "errors": sum("error" in r for r in rs),
            "coll_bytes_mean": round(
                sum(r.get("coll_bytes", 0) or 0 for r in rs) / len(rs)),
        })
    return rows


def render(rows: list) -> str:
    cols = ("service", "kind", "mode", "queries", "p50_us", "p95_us",
            "p99_us", "device_p50_us", "validated", "degraded", "errors",
            "coll_bytes_mean")
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) if rows
              else len(c) for c in cols}
    lines = ["  ".join(c.ljust(widths[c]) for c in cols),
             "  ".join("-" * widths[c] for c in cols)]
    for r in rows:
        lines.append("  ".join(str(r[c]).ljust(widths[c]) for c in cols))
    return "\n".join(lines)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description=__doc__.splitlines()[0])
    p.add_argument("traces", nargs="+",
                   help="JSONL trace file(s) (Tracer export); several are "
                        "merged and sorted by span id")
    p.add_argument("--check", action="store_true",
                   help="validate schema; non-zero exit on any error")
    p.add_argument("--require-modes", default="",
                   help="comma-separated ladder modes that must each have "
                        "at least one query record (implies --check)")
    p.add_argument("--require-degraded", action="store_true",
                   help="fail unless at least one query record is degraded "
                        "(implies --check)")
    p.add_argument("--require-spans", default="",
                   help="comma-separated span names that must each appear "
                        "at least once in the trace, e.g. ladder_pinned "
                        "(implies --check)")
    p.add_argument("--format", choices=("table", "json"), default="table",
                   help="summary output format (json = machine output "
                        "for CI)")
    p.add_argument("--json", action="store_true",
                   help="legacy alias for --format json")
    a = p.parse_args(argv)

    records = load_many(a.traces)
    rows = summarize(records)
    if a.json or a.format == "json":
        print(json.dumps(rows, indent=2))
    else:
        print(render(rows))

    require = tuple(m for m in a.require_modes.split(",") if m)
    require_spans = tuple(s for s in a.require_spans.split(",") if s)
    if a.check or require or a.require_degraded or require_spans:
        errors = validate(records, require_modes=require,
                          require_degraded=a.require_degraded,
                          require_spans=require_spans)
        if errors:
            for e in errors:
                print(f"CHECK FAIL: {e}", file=sys.stderr)
            return 1
        n = len(query_records(records))
        print(f"CHECK OK: {n} query records, {len(rows)} summary rows, "
              f"schema {TRACE_SCHEMA}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
