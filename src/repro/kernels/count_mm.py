"""Pallas TPU kernel: counting-semiring blocked matmul (Brandes sigma).

out[s, j] = sum_k s[s, k] * a[k, j] — a plain f32 matmul on the MXU, but over
shortest-path *counts* flowing along adjacency masks, which is the third
semiring the batched Brandes sweep needs (bool for levels, count for sigma
and the backward dependency accumulation).  Counts are integers carried in
f32: exact as long as they stay below 2^24, which holds for the graph sizes
this reproduction targets.

Grid = (S/bm, V/bn, V/bk), k innermost with VMEM accumulation, identical to
``bool_mm`` minus the threshold epilogue.  ``count_mm_masked`` skips the MXU
dot for (slab, tile) pairs whose occupancy masks say the contribution is
all-zero (the (+, x) semiring identity), driven by the same SMEM occupancy
grids as the other masked kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import INTERPRET, check_blocks

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _kernel(s_ref, a_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(s_ref[...], a_ref[...],
                          preferred_element_type=jnp.float32)


def _masked_kernel(sm_ref, am_ref, s_ref, a_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when((sm_ref[0, 0] > 0) & (am_ref[0, 0] > 0))
    def _compute():
        o_ref[...] += jnp.dot(s_ref[...], a_ref[...],
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def count_mm(s: jax.Array, a: jax.Array, *, bm: int = DEFAULT_BM,
             bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
             interpret: bool = INTERPRET) -> jax.Array:
    """s: [S, V] f32 counts; a: [V, V'] f32 -> [S, V'] f32 (plain matmul)."""
    m, kdim = s.shape
    _, n = a.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    check_blocks("count_mm", m, kdim, n, bm, bk, bn)
    grid = (m // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(s, a)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def count_mm_masked(s: jax.Array, a: jax.Array, smask: jax.Array,
                    amask: jax.Array, *, bm: int = DEFAULT_BM,
                    bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                    interpret: bool = INTERPRET) -> jax.Array:
    """Tile-skipping counting product.

    ``smask``: int32 [S/bm, K/bk] — nonzero iff the count slab has any
    nonzero entry; ``amask``: int32 [K/bk, N/bn] — nonzero iff the
    adjacency tile has any live edge.  A zero mask MUST imply an all-zero
    block.
    """
    m, kdim = s.shape
    _, n = a.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    check_blocks("count_mm", m, kdim, n, bm, bk, bn)
    grid = (m // bm, n // bn, kdim // bk)
    if smask.shape != (grid[0], grid[2]) or amask.shape != (grid[2], grid[1]):
        raise ValueError(
            f"count_mm_masked: mask shapes {smask.shape}/{amask.shape} do "
            f"not match the block grid ({grid[0]}, {grid[2]})/"
            f"({grid[2]}, {grid[1]})")
    return pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(smask.astype(jnp.int32), amask.astype(jnp.int32), s, a)
