"""jit'd public wrappers around the Pallas kernels (padding + dispatch).

On a real TPU these run compiled (``interpret=False``); in this CPU container
they execute the kernel bodies in interpret mode, validated against
``ref.py`` in ``tests/test_kernels.py``.

Each semiring wrapper optionally takes ``amask``, the tile-occupancy grid of
the right-hand (adjacency/weight) operand at ``tile`` granularity — see
``repro.core.tiles`` — and dispatches to the tile-skipping kernel variant:
the wrapper coarsens ``amask`` to the kernel's (bk, bn) block grid, derives
the left operand's slab-occupancy mask from the operand itself (frontier
slabs go all-identity as BFS/SSSP/BC levels saturate), and the kernel skips
every (slab, tile) pair whose contribution is the semiring identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import bool_mm as _bool
from . import count_mm as _count
from . import minplus_mm as _minplus
from . import flash_attention as _flash
from .backend import INTERPRET, check_amask  # noqa: F401  (INTERPRET re-exported)


def _pad2(x, bm, bn, value=0.0):
    m, n = x.shape
    mp, np_ = -(-m // bm) * bm, -(-n // bn) * bn
    return jnp.pad(x, ((0, mp - m), (0, np_ - n)), constant_values=value), (m, n)


def _block_ranges(nblocks: int, blk: int, tile: int, ntiles: int):
    """Static (first, last) tile index covered by each kernel block."""
    t0 = (np.arange(nblocks) * blk) // tile
    t1 = ((np.arange(nblocks) + 1) * blk - 1) // tile
    return (np.clip(t0, 0, ntiles - 1).astype(np.int32),
            np.clip(t1, 0, ntiles - 1).astype(np.int32))


def _coarsen_mask(occ: jax.Array, tile: int, blk_r: int, nbr: int,
                  blk_c: int, nbc: int) -> jax.Array:
    """Tile-granularity occupancy -> kernel-block granularity (any-reduce).

    Works for any (tile, block) size relation via prefix sums over the tile
    grid gathered at statically computed block->tile ranges.  Blocks that
    extend past the tile grid (operand padding) clip to the last tile — at
    worst an identity block is marked active, never the reverse.
    """
    occ_b = (occ > 0).astype(jnp.int32)
    nt_r, nt_c = occ_b.shape
    r0, r1 = _block_ranges(nbr, blk_r, tile, nt_r)
    cum_r = jnp.concatenate(
        [jnp.zeros((1, nt_c), jnp.int32), jnp.cumsum(occ_b, axis=0)], axis=0)
    rows = ((cum_r[r1 + 1] - cum_r[r0]) > 0).astype(jnp.int32)  # [nbr, nt_c]
    c0, c1 = _block_ranges(nbc, blk_c, tile, nt_c)
    cum_c = jnp.concatenate(
        [jnp.zeros((nbr, 1), jnp.int32), jnp.cumsum(rows, axis=1)], axis=1)
    return ((cum_c[:, c1 + 1] - cum_c[:, c0]) > 0).astype(jnp.int32)


def _slab_mask(xp: jax.Array, bm: int, bk: int, nonidentity) -> jax.Array:
    """Blockwise any(non-identity) over a padded left operand."""
    mp, kp = xp.shape
    return nonidentity(xp).reshape(
        mp // bm, bm, kp // bk, bk).any(axis=(1, 3)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "tile"))
def bool_mm(f: jax.Array, a: jax.Array, bm: int = 128, bn: int = 128,
            bk: int = 512, amask: jax.Array | None = None,
            tile: int = 128) -> jax.Array:
    """Padded boolean-semiring matmul; any (S, V) x (V, V') shapes.

    ``amask``: optional tile-occupancy grid of ``a`` (nonzero iff the
    ``tile`` x ``tile`` block holds any set bit) enabling tile skipping.
    """
    fp, (s, _) = _pad2(f.astype(jnp.float32), bm, bk)
    ap, (_, n) = _pad2(a.astype(jnp.float32), bk, bn)
    if amask is None:
        out = _bool.bool_mm(fp, ap, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    else:
        check_amask("bool_mm", amask.shape, a.shape[0], a.shape[1], tile)
        nbk, nbn = fp.shape[1] // bk, ap.shape[1] // bn
        fmask = _slab_mask(fp, bm, bk, lambda x: x != 0)
        am = _coarsen_mask(amask, tile, bk, nbk, bn, nbn)
        out = _bool.bool_mm_masked(fp, ap, fmask, am, bm=bm, bn=bn, bk=bk,
                                   interpret=INTERPRET)
    return out[:s, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "tile"))
def minplus_mm(d: jax.Array, w: jax.Array, bm: int = 128, bn: int = 128,
               bk: int = 16, amask: jax.Array | None = None,
               tile: int = 128) -> jax.Array:
    """Padded tropical matmul; +inf padding is the semiring identity.

    ``amask``: optional tile-occupancy grid of ``w`` (nonzero iff the
    ``tile`` x ``tile`` block holds any finite weight).
    """
    dp, (s, _) = _pad2(d, bm, bk, value=jnp.inf)
    wp, (_, n) = _pad2(w, bk, bn, value=jnp.inf)
    if amask is None:
        out = _minplus.minplus_mm(dp, wp, bm=bm, bn=bn, bk=bk,
                                  interpret=INTERPRET)
    else:
        check_amask("minplus_mm", amask.shape, w.shape[0], w.shape[1], tile)
        nbk, nbn = dp.shape[1] // bk, wp.shape[1] // bn
        dmask = _slab_mask(dp, bm, bk, jnp.isfinite)
        am = _coarsen_mask(amask, tile, bk, nbk, bn, nbn)
        out = _minplus.minplus_mm_masked(dp, wp, dmask, am, bm=bm, bn=bn,
                                         bk=bk, interpret=INTERPRET)
    return out[:s, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "tile"))
def count_mm(s: jax.Array, a: jax.Array, bm: int = 128, bn: int = 128,
             bk: int = 512, amask: jax.Array | None = None,
             tile: int = 128) -> jax.Array:
    """Padded counting matmul (Brandes sigma); zero padding is the identity.

    ``amask``: optional tile-occupancy grid of ``a``.
    """
    sp, (m, _) = _pad2(s.astype(jnp.float32), bm, bk)
    ap, (_, n) = _pad2(a.astype(jnp.float32), bk, bn)
    if amask is None:
        out = _count.count_mm(sp, ap, bm=bm, bn=bn, bk=bk,
                              interpret=INTERPRET)
    else:
        check_amask("count_mm", amask.shape, a.shape[0], a.shape[1], tile)
        nbk, nbn = sp.shape[1] // bk, ap.shape[1] // bn
        smask = _slab_mask(sp, bm, bk, lambda x: x != 0)
        am = _coarsen_mask(amask, tile, bk, nbk, bn, nbn)
        out = _count.count_mm_masked(sp, ap, smask, am, bm=bm, bn=bn, bk=bk,
                                     interpret=INTERPRET)
    return out[:m, :n]


def flash_attention(q, k, v, causal: bool = True, sm_scale=None, window=None,
                    bq: int = 128, bk: int = 128):
    """Causal GQA flash attention; q [B,Hq,S,D], kv [B,Hkv,S,D]."""
    return _flash.flash_attention(
        q, k, v, causal=causal, sm_scale=sm_scale, window=window,
        bq=bq, bk=bk, interpret=INTERPRET)
