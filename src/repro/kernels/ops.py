"""jit'd public wrappers around the Pallas kernels (padding + dispatch).

On a real TPU these run compiled (``interpret=False``); in this CPU container
they execute the kernel bodies in interpret mode, validated against
``ref.py`` in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bool_mm as _bool
from . import minplus_mm as _minplus
from . import flash_attention as _flash

INTERPRET = jax.default_backend() != "tpu"


def _pad2(x, bm, bn, value=0.0):
    m, n = x.shape
    mp, np_ = -(-m // bm) * bm, -(-n // bn) * bn
    return jnp.pad(x, ((0, mp - m), (0, np_ - n)), constant_values=value), (m, n)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def bool_mm(f: jax.Array, a: jax.Array, bm: int = 128, bn: int = 128,
            bk: int = 512) -> jax.Array:
    """Padded boolean-semiring matmul; any (S, V) x (V, V') shapes."""
    fp, (s, _) = _pad2(f.astype(jnp.float32), bm, bk)
    ap, (_, n) = _pad2(a.astype(jnp.float32), bk, bn)
    out = _bool.bool_mm(fp, ap, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return out[:s, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def minplus_mm(d: jax.Array, w: jax.Array, bm: int = 128, bn: int = 128,
               bk: int = 16) -> jax.Array:
    """Padded tropical matmul; +inf padding is the semiring identity."""
    dp, (s, _) = _pad2(d, bm, bk, value=jnp.inf)
    wp, (_, n) = _pad2(w, bk, bn, value=jnp.inf)
    out = _minplus.minplus_mm(dp, wp, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return out[:s, :n]


def flash_attention(q, k, v, causal: bool = True, sm_scale=None, window=None,
                    bq: int = 128, bk: int = 128):
    """Causal GQA flash attention; q [B,Hq,S,D], kv [B,Hkv,S,D]."""
    return _flash.flash_attention(
        q, k, v, causal=causal, sm_scale=sm_scale, window=window,
        bq=bq, bk=bk, interpret=INTERPRET)
