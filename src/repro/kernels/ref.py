"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bool_mm_ref(f: jax.Array, a: jax.Array) -> jax.Array:
    """Boolean-semiring matmul on {0,1} f32 masks: out = (f @ a) > 0."""
    return (jnp.dot(f.astype(jnp.float32), a.astype(jnp.float32),
                    precision=jax.lax.Precision.HIGHEST) > 0).astype(jnp.float32)


def minplus_mm_ref(d: jax.Array, w: jax.Array) -> jax.Array:
    """Tropical matmul: out[s, j] = min_k d[s, k] + w[k, j]."""
    return jnp.min(d[:, :, None] + w[None, :, :], axis=1)


def count_mm_ref(s: jax.Array, a: jax.Array) -> jax.Array:
    """Counting matmul (Brandes sigma): plain f32 product of path counts."""
    return jnp.dot(s.astype(jnp.float32), a.astype(jnp.float32),
                   precision=jax.lax.Precision.HIGHEST)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        sm_scale: float | None = None) -> jax.Array:
    """GQA attention oracle.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] with Hq % Hkv == 0.
    Causal masking aligns the *ends* of q and kv (decode/prefill convention):
    query i attends to kv j iff j <= i + (Skv - Sq).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kq,
                        precision=jax.lax.Precision.HIGHEST) * scale
    if causal:
        offs = skv - sq
        mask = jnp.arange(skv)[None, :] <= (jnp.arange(sq)[:, None] + offs)
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vq,
                      precision=jax.lax.Precision.HIGHEST)
