"""Pallas TPU kernel: tropical (min,+) blocked matmul (SSSP relaxation).

out[s, j] = min_k d[s, k] + w[k, j].  No MXU analogue exists for (min,+), so
the inner product runs on the VPU via a broadcast-add + min-reduce over a
*small* k slab (bk=16) to bound the (bm, bk, bn) broadcast working set:
128*16*128*4B = 1 MB in VMEM.  Grid = (S/bm, V/bn, V/bk), k innermost with
output-tile accumulation (running elementwise min) across the k sweep.

+inf entries (absent edges / unreached sources) flow through min() untouched,
so the tombstone encoding of the graph state needs no special-casing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 16


def _kernel(d_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    d = d_ref[...]          # (bm, bk)
    w = w_ref[...]          # (bk, bn)
    cand = jnp.min(d[:, :, None] + w[None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_mm(d: jax.Array, w: jax.Array, *, bm: int = DEFAULT_BM,
               bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
               interpret: bool = True) -> jax.Array:
    """d: [S, V] f32; w: [V, V'] f32 -> [S, V'] f32 (min-plus product)."""
    s, kdim = d.shape
    _, n = w.shape
    bm, bn, bk = min(bm, s), min(bn, n), min(bk, kdim)
    grid = (s // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=interpret,
    )(d, w)
