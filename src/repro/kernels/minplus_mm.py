"""Pallas TPU kernel: tropical (min,+) blocked matmul (SSSP relaxation).

out[s, j] = min_k d[s, k] + w[k, j].  No MXU analogue exists for (min,+), so
the inner product runs on the VPU via a broadcast-add + min-reduce over a
*small* k slab (bk=16) to bound the (bm, bk, bn) broadcast working set:
128*16*128*4B = 1 MB in VMEM.  Grid = (S/bm, V/bn, V/bk), k innermost with
output-tile accumulation (running elementwise min) across the k sweep.

+inf entries (absent edges / unreached sources) flow through min() untouched,
so the tombstone encoding of the graph state needs no special-casing.

``minplus_mm_masked`` is the tile-skipping variant: two scalar occupancy
grids ride along in SMEM — ``dmask[S/bm, K/bk]`` (frontier slab holds any
finite distance) and ``wmask[K/bk, N/bn]`` (weight tile holds any live
edge) — and a ``pl.when`` guard skips the broadcast-min for (slab, tile)
pairs whose product is all-+inf, i.e. the semiring identity.  Output-tile
init still runs at k == 0, so a fully skipped output tile is +inf, exactly
what the dense kernel computes for it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import INTERPRET, check_blocks

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 16


def _kernel(d_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    d = d_ref[...]          # (bm, bk)
    w = w_ref[...]          # (bk, bn)
    cand = jnp.min(d[:, :, None] + w[None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


def _masked_kernel(dm_ref, wm_ref, d_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    @pl.when((dm_ref[0, 0] > 0) & (wm_ref[0, 0] > 0))
    def _compute():
        d = d_ref[...]
        w = w_ref[...]
        cand = jnp.min(d[:, :, None] + w[None, :, :], axis=1)
        o_ref[...] = jnp.minimum(o_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_mm(d: jax.Array, w: jax.Array, *, bm: int = DEFAULT_BM,
               bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
               interpret: bool = INTERPRET) -> jax.Array:
    """d: [S, V] f32; w: [V, V'] f32 -> [S, V'] f32 (min-plus product)."""
    s, kdim = d.shape
    _, n = w.shape
    bm, bn, bk = min(bm, s), min(bn, n), min(bk, kdim)
    check_blocks("minplus_mm", s, kdim, n, bm, bk, bn)
    grid = (s // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=interpret,
    )(d, w)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_mm_masked(d: jax.Array, w: jax.Array, dmask: jax.Array,
                      wmask: jax.Array, *, bm: int = DEFAULT_BM,
                      bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                      interpret: bool = INTERPRET) -> jax.Array:
    """Tile-skipping min-plus product.

    ``dmask``: int32 [S/bm, K/bk] — nonzero iff the d slab has a finite
    entry; ``wmask``: int32 [K/bk, N/bn] — nonzero iff the w tile has a
    finite entry.  A zero mask MUST imply the block is all-+inf (the
    semiring identity); callers derive both from the tile occupancy index
    (``repro.core.tiles``) or directly from the operands.
    """
    s, kdim = d.shape
    _, n = w.shape
    bm, bn, bk = min(bm, s), min(bn, n), min(bk, kdim)
    check_blocks("minplus_mm", s, kdim, n, bm, bk, bn)
    grid = (s // bm, n // bn, kdim // bk)
    if dmask.shape != (grid[0], grid[2]) or wmask.shape != (grid[2], grid[1]):
        raise ValueError(
            f"minplus_mm_masked: mask shapes {dmask.shape}/{wmask.shape} do "
            f"not match the block grid ({grid[0]}, {grid[2]})/"
            f"({grid[2]}, {grid[1]})")
    return pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=interpret,
    )(dmask.astype(jnp.int32), wmask.astype(jnp.int32), d, w)
