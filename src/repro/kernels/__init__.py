"""Pallas TPU kernels for the perf-critical compute layers.

  * ``bool_mm``      -- boolean-semiring matmul (batched BFS, MXU)
  * ``minplus_mm``   -- tropical matmul (batched SSSP relax, VPU)
  * ``count_mm``     -- counting matmul (batched Brandes sigma, MXU)
  * ``flash_attention`` -- causal GQA flash attention (LM train/prefill)

Each semiring kernel also has a ``*_mm_masked`` tile-skipping variant driven
by SMEM occupancy grids (see ``repro.core.tiles``).  Each kernel:
``<name>.py`` (pl.pallas_call + BlockSpec), validated against the pure-jnp
oracle in ``ref.py``; ``ops.py`` holds the jit'd padding wrappers.
"""
from . import ops, ref  # noqa: F401
