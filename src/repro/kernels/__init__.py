"""Pallas TPU kernels for the perf-critical compute layers.

  * ``bool_mm``      -- boolean-semiring matmul (batched BFS, MXU)
  * ``minplus_mm``   -- tropical matmul (batched SSSP relax, VPU)
  * ``flash_attention`` -- causal GQA flash attention (LM train/prefill)

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), validated against
the pure-jnp oracle in ``ref.py``; ``ops.py`` holds the jit'd wrappers.
"""
from . import ops, ref  # noqa: F401
