"""Pallas TPU kernel: causal GQA flash attention with online softmax.

Grid = (B*Hq, Sq/bq, Skv/bk), kv innermost.  Running max / denominator /
accumulator live in VMEM scratch and persist across the kv sweep (TPU grids
iterate sequentially, so scratch carries state between k steps of the same
(bh, q) tile).  Fully-masked kv blocks are skipped with ``pl.when`` -- for
causal training this halves the work; with a sliding window only
O(window/bk) blocks per query tile execute at all.

GQA is handled in the index map: query head h reads kv head h // group, so
no materialized ``repeat`` of K/V ever exists (the repeat in the oracle is
exactly the HBM traffic this kernel removes).

VMEM per step: q (bq,d) + k,v (bk,d each) + acc (bq,d) + p (bq,bk)
~= (3*128*128 + 2*128*128)*4B ~= 0.3 MB at the default 128 blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import INTERPRET


DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            nk: int, bq: int, bk: int, scale: float, offs: int,
            q_len: int, kv_len: int, window: int | None):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_start = qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Skip kv blocks entirely above the causal diagonal (or outside the
    # sliding window): no compute, no VMEM traffic beyond the prefetch.
    relevant = k_start <= q_start + bq - 1 + offs
    if window is not None:
        relevant &= k_start + bk - 1 >= q_start + offs - (window - 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale     # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kpos <= qpos + offs) & (kpos < kv_len) & (qpos < q_len)
        if window is not None:
            mask &= kpos > qpos + offs - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                  # (bq, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_cur))
        p = jnp.where(m_cur == NEG_INF, 0.0, jnp.exp(s - m_cur))
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ki == nk - 1)
    def _final():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    window: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = INTERPRET) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D].

    Causal alignment matches the oracle: query i sees kv j iff
    j <= i + (Skv - Sq).  ``window`` enables sliding-window (local) masking.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else float(d) ** -0.5
    offs = skv - sq

    bq_ = min(bq, _round_up(sq, 8))
    bk_ = min(bk, _round_up(skv, 8))
    sq_p, skv_p = _round_up(sq, bq_), _round_up(skv, bk_)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    qf = qp.reshape(b * hq, sq_p, d)
    kf = kp.reshape(b * hkv, skv_p, d)
    vf = vp.reshape(b * hkv, skv_p, d)

    nq, nk = sq_p // bq_, skv_p // bk_
    if not causal:
        offs_eff = skv_p  # everything visible
    else:
        offs_eff = offs

    def kv_index(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, bq=bq_, bk=bk_, scale=scale, offs=offs_eff,
            q_len=sq, kv_len=skv, window=window),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk_, d), kv_index),
            pl.BlockSpec((1, bk_, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq_p, d)[:, :, :sq, :]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
