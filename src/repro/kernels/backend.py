"""Backend detection + shared guards for the kernel modules.

Lives in its own module (rather than ``ops.py``) so the raw kernel modules
can default to the detected mode without importing ``ops`` back — ``ops``
imports the kernel modules, and a reverse import would be a cycle.
"""
from __future__ import annotations

import jax

# Pallas kernels compile only on TPU; everywhere else (this CPU container
# included) they run the kernel body in interpret mode, which is what the
# oracle tests validate against.
INTERPRET: bool = jax.default_backend() != "tpu"


def check_blocks(name: str, s: int, kdim: int, n: int,
                 bm: int, bk: int, bn: int) -> None:
    """Refuse shapes the kernel grid would silently truncate.

    ``grid = (s // bm, n // bn, kdim // bk)`` drops trailing rows/columns
    when a dimension is not a block multiple; every raw kernel entry point
    calls this so a direct call can't return wrong-shaped results (the
    ``ops`` wrappers pad first and never trip it).
    """
    if s % bm or kdim % bk or n % bn:
        raise ValueError(
            f"{name}: shapes ({s}, {kdim}) x ({kdim}, {n}) are not "
            f"multiples of blocks (bm={bm}, bk={bk}, bn={bn}); grid "
            "truncation would drop trailing rows/columns — pad the operands "
            f"(repro.kernels.ops.{name} does) or pass dividing blocks")


def check_amask(name: str, amask_shape, kdim: int, n: int, tile: int) -> None:
    """The tile-occupancy grid must tile the right operand exactly.

    A mismatched grid (e.g. a ``TileView`` built at a different ``tile``)
    would be silently clipped by the block-mask coarsening and skip live
    slabs; shared by the ``ops`` wrappers and the jnp fallbacks in
    ``repro.core.semiring`` so both paths raise identically.
    """
    expect = (-(-kdim // tile), -(-n // tile))
    if tuple(amask_shape) != expect:
        raise ValueError(
            f"{name}: amask shape {tuple(amask_shape)} does not tile the "
            f"({kdim}, {n}) operand at tile={tile} (expected {expect}); "
            "was the tile view built with a different tile size?")
