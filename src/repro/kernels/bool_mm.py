"""Pallas TPU kernel: boolean-semiring blocked matmul (BFS frontier expansion).

out[s, j] = OR_k f[s, k] AND a[k, j], computed as an f32 {0,1} mask matmul on
the MXU with a threshold epilogue.  Grid = (S/bm, V/bn, V/bk) with k innermost
so the output tile accumulates in VMEM across the k sweep (revisiting).

Block sizes are MXU-aligned (128x128 tiles, bk=512 to amortize the epilogue);
VMEM working set per step = bm*bk + bk*bn + bm*bn floats ~= (128*512*2 +
128*128)*4B ~= 0.6 MB, far under the ~16 MB/core budget, leaving room for
double buffering of the HBM->VMEM pipeline.

``bool_mm_masked`` adds SMEM occupancy grids (frontier slab nonzero, weight
tile holds a live edge) and skips the MXU dot where either is empty — a
skipped contribution is all-zero, the (or, and) semiring identity, so the
accumulator is untouched.  Init (k == 0) and the threshold epilogue
(k == nk-1) stay unconditional.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import INTERPRET, check_blocks

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _kernel(f_ref, a_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(f_ref[...], a_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = (o_ref[...] > 0).astype(jnp.float32)


def _masked_kernel(fm_ref, am_ref, f_ref, a_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when((fm_ref[0, 0] > 0) & (am_ref[0, 0] > 0))
    def _compute():
        o_ref[...] += jnp.dot(f_ref[...], a_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = (o_ref[...] > 0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def bool_mm(f: jax.Array, a: jax.Array, *, bm: int = DEFAULT_BM,
            bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
            interpret: bool = INTERPRET) -> jax.Array:
    """f: [S, V] {0,1} f32; a: [V, V'] {0,1} f32 -> [S, V'] {0,1} f32.

    Shapes must be multiples of the block sizes (``ops.bool_mm`` pads).
    """
    s, kdim = f.shape
    _, n = a.shape
    bm, bn, bk = min(bm, s), min(bn, n), min(bk, kdim)
    check_blocks("bool_mm", s, kdim, n, bm, bk, bn)
    grid = (s // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=interpret,
    )(f, a)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def bool_mm_masked(f: jax.Array, a: jax.Array, fmask: jax.Array,
                   amask: jax.Array, *, bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                   interpret: bool = INTERPRET) -> jax.Array:
    """Tile-skipping boolean-semiring product.

    ``fmask``: int32 [S/bm, K/bk] — nonzero iff the frontier slab has any
    set bit; ``amask``: int32 [K/bk, N/bn] — nonzero iff the adjacency tile
    has any live edge.  A zero mask MUST imply an all-zero block.
    """
    s, kdim = f.shape
    _, n = a.shape
    bm, bn, bk = min(bm, s), min(bn, n), min(bk, kdim)
    check_blocks("bool_mm", s, kdim, n, bm, bk, bn)
    grid = (s // bm, n // bn, kdim // bk)
    if fmask.shape != (grid[0], grid[2]) or amask.shape != (grid[2], grid[1]):
        raise ValueError(
            f"bool_mm_masked: mask shapes {fmask.shape}/{amask.shape} do "
            f"not match the block grid ({grid[0]}, {grid[2]})/"
            f"({grid[2]}, {grid[1]})")
    return pl.pallas_call(
        functools.partial(_masked_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=interpret,
    )(fmask.astype(jnp.int32), amask.astype(jnp.int32), f, a)
