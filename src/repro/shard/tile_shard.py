"""Sharded tile grid: the ``TileView`` partitioned over a 1-D device mesh.

The paper's scalability story is "more workers, same consistent snapshot";
the mesh analogue shards the blocked adjacency of ``repro.core.tiles`` by
**tile rows**: device ``i`` of an ``n``-device graph axis owns a contiguous
band of source vertices — ``Vp/n`` rows of the padded dense weights plus
the matching ``nt/n`` rows of the occupancy grid.  Row sharding is the
natural cut for level-synchronous semiring queries: a frontier product
against the band is entirely local (the band's occupancy grid is exactly
the ``amask`` the tile-skipping kernels and jnp fallbacks already accept),
and one vcap-sized collective per level merges the partial frontiers
(``repro.shard.queries``).

Both arrays are **global jax.Arrays** carrying a ``NamedSharding`` of
``P(axis, None)`` — the GSPMD layout: host code addresses them like any
``TileView`` while every jit/shard_map consumer sees only its local band.

``build_sharded_view`` derives the view from a snapshot (padding ``vcap``
up to a multiple of ``n * tile`` so whole tile rows land on each shard).
``refresh_sharded_view`` is the incremental path: the version ring's
dirty-vertex sets name the disturbed tile rows, and each dirty row is
re-derived by ONE owning shard under ``shard_map`` (every other shard
rewrites its current contents) — a small commit costs O(row), never an
O(Vp^2) rebuild or a cross-shard reshard.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.graph_state import INF, GraphState
from repro.core.tiles import (
    TILE,
    TileView,
    dirty_row_windows,
    row_window_slab,
)
from repro.obs import CounterStruct

GRAPH_AXIS = "graph"


def as_graph_mesh(mesh: Mesh | None = None, axis_name: str = GRAPH_AXIS) -> Mesh:
    """A 1-D logical graph mesh over every device of ``mesh`` (flattening a
    multi-axis production mesh), or over all local devices when ``None``."""
    if mesh is not None and tuple(mesh.axis_names) == (axis_name,):
        return mesh
    devices = (mesh.devices.reshape(-1) if mesh is not None
               else np.asarray(jax.devices()))
    return Mesh(devices, (axis_name,))


def _axis(mesh: Mesh) -> str:
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"sharded tile grid needs a 1-D mesh, got axes {mesh.axis_names}; "
            "flatten with as_graph_mesh(mesh) first")
    return mesh.axis_names[0]


def _padded_dim(vcap: int, tile: int, n_shards: int) -> int:
    chunk = tile * n_shards
    return -(-vcap // chunk) * chunk


@dataclass(frozen=True)
class ShardedTileView:
    """Row-sharded blocked adjacency snapshot.

    ``w``/``occ`` are global arrays sharded ``P(axis, None)`` over ``mesh``:
    shard ``i`` holds rows ``[i * vp/n, (i+1) * vp/n)`` of the padded dense
    weights and rows ``[i * nt/n, (i+1) * nt/n)`` of the occupancy grid.
    """

    w: jax.Array    # f32[Vp, Vp]   +inf = no edge, Vp % (n * tile) == 0
    occ: jax.Array  # int32[nt, nt] live-edge count per tile
    mesh: Mesh
    tile: int

    @property
    def vp(self) -> int:
        return self.w.shape[0]

    @property
    def n_tiles(self) -> int:
        return self.occ.shape[0]

    @property
    def n_shards(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def band(self) -> int:
        """Rows of ``w`` owned by one shard."""
        return self.vp // self.n_shards

    @property
    def rows_per_shard(self) -> int:
        """Tile rows owned by one shard."""
        return self.n_tiles // self.n_shards


def sharded_occupancy_stats(view: ShardedTileView) -> dict:
    """Host-side summary incl. the per-shard tile-skip rates the kernels
    realise on each device's band."""
    occ = np.asarray(jax.device_get(view.occ))
    total = int(occ.size)
    active = int((occ > 0).sum())
    rows = view.rows_per_shard
    per_shard = []
    for i in range(view.n_shards):
        band = occ[i * rows:(i + 1) * rows]
        per_shard.append(round(float((band == 0).mean()) if band.size else 0.0,
                               4))
    return {
        "tile": view.tile,
        "grid": [view.n_tiles, view.n_tiles],
        "n_shards": view.n_shards,
        "tiles_total": total,
        "tiles_active": active,
        "tile_skip_rate": (total - active) / total if total else 0.0,
        "per_shard_tile_skip_rate": per_shard,
        "live_edges": int(occ.sum()),
    }


def gather_view(view: ShardedTileView) -> TileView:
    """Materialise the sharded view as a host-resident ``TileView`` (test
    oracle / debugging; O(Vp^2) transfer)."""
    return TileView(jnp.asarray(jax.device_get(view.w)),
                    jnp.asarray(jax.device_get(view.occ)))


# ------------------------------- build ------------------------------------

def _build_padded(state: GraphState, vp: int, tile: int):
    from repro.core.graph_state import live_edge_mask
    nt = vp // tile
    live = live_edge_mask(state)
    srcc = jnp.where(live, state.esrc, 0)
    dstc = jnp.where(live, state.edst, 0)
    w = jnp.full((vp, vp), INF, jnp.float32)
    w = w.at[srcc, dstc].min(jnp.where(live, state.ew, INF), mode="drop")
    occ = jnp.zeros((nt, nt), jnp.int32).at[srcc // tile, dstc // tile].add(
        live.astype(jnp.int32), mode="drop")
    return w, occ


@lru_cache(maxsize=None)
def _build_fn(mesh: Mesh, vp: int, tile: int):
    sh = NamedSharding(mesh, P(_axis(mesh), None))
    return jax.jit(partial(_build_padded, vp=vp, tile=tile),
                   out_shardings=(sh, sh))


def build_sharded_view(state: GraphState, mesh: Mesh,
                       tile: int = TILE) -> ShardedTileView:
    """Full O(vcap^2 + ecap) derivation, laid out row-sharded over ``mesh``."""
    ax = _axis(mesh)  # validates the mesh shape up front
    del ax
    n = int(mesh.devices.size)
    vp = _padded_dim(state.vcap, tile, n)
    w, occ = _build_fn(mesh, vp, tile)(state)
    return ShardedTileView(w, occ, mesh, tile)


# ------------------------------ refresh -----------------------------------

REFRESH_BATCH = 8  # max dirty tile rows fused into one shard_map dispatch


class RefreshStats(CounterStruct):
    """Per-process tallies of ``refresh_sharded_view``'s dispatch behavior
    (benchmarks read the deltas around a call): ``rows`` dirty tile rows
    refreshed, in ``dispatches`` shard_map program launches (the
    pre-batching cost was one launch per row == ``rows``).  Since PR 6 the
    values are ``shard_refresh_*`` counters in a
    :class:`repro.obs.MetricsRegistry`; the attribute surface (and the
    ``refresh_stats`` module global that benches delta around calls) is
    unchanged."""

    _FIELDS = ("rows", "dispatches", "rebuilds")
    _PREFIX = "shard_refresh_"


refresh_stats = RefreshStats()


@lru_cache(maxsize=None)
def _rows_refresh_fn(mesh: Mesh, tile: int, width: int, nrows: int):
    """Batched dirty-tile-row refresh as ONE shard_map program.

    Every shard receives the (replicated) edge windows of up to ``nrows``
    dirty rows and rebuilds all their slabs at once (``vmap`` over the row
    axis of the shared ``row_window_slab`` derivation), then writes each
    row back in place — only the OWNER of global tile row ``r`` keeps the
    new slab, every other shard (and every padding row, ``r == -1``)
    rewrites its current contents, so the donated buffers never move
    across shards.  Cached per (mesh, tile, window width, row-count
    bucket): under heavy churn a commit's same-width rows amortize to
    ``ceil(rows / REFRESH_BATCH)`` dispatches instead of one per row.
    """
    ax = _axis(mesh)

    def body(w_local, occ_local, esrc, edst, ew, alive, rs, los):
        vp = w_local.shape[1]
        nt = occ_local.shape[1]
        rows_per_shard = occ_local.shape[0]
        i = lax.axis_index(ax)
        slabs, occ_rows = jax.vmap(
            lambda r, lo: row_window_slab(esrc, edst, ew, alive, r, lo,
                                          tile=tile, width=width, vp=vp,
                                          nt=nt))(rs, los)

        def write(k, carry):
            w, occ = carry
            r = rs[k]
            own = (r >= 0) & ((r // rows_per_shard) == i)
            lr = jnp.where(own, r % rows_per_shard, 0)
            zero = jnp.int32(0)
            cur_w = lax.dynamic_slice(w, (lr * tile, zero), (tile, vp))
            cur_occ = lax.dynamic_slice(occ, (lr, zero), (1, nt))
            slab = jnp.where(own, slabs[k], cur_w)
            occ_row = jnp.where(own, occ_rows[k], cur_occ)
            return (lax.dynamic_update_slice(w, slab, (lr * tile, zero)),
                    lax.dynamic_update_slice(occ, occ_row, (lr, zero)))

        return lax.fori_loop(0, nrows, write, (w_local, occ_local))

    vspec, sspec = P(_axis(mesh), None), P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(vspec, vspec, sspec, sspec, sspec, sspec, sspec, sspec),
        out_specs=(vspec, vspec),
        check_rep=False,
    )
    return jax.jit(fn, donate_argnums=(0, 1))


def _batched_plan(plan):
    """Group the (row, lo, width) windows into dispatch batches: same-width
    rows fuse into chunks of up to ``REFRESH_BATCH``, each chunk padded up
    to the next power of two (padding rows are ``r = -1`` no-ops) so a
    handful of (width, bucket) program shapes cover every commit."""
    by_width: dict = {}
    for r, lo, width in plan:
        by_width.setdefault(width, []).append((r, lo))
    batches = []
    for width, rows in sorted(by_width.items()):
        for i in range(0, len(rows), REFRESH_BATCH):
            chunk = rows[i:i + REFRESH_BATCH]
            bucket = 1
            while bucket < len(chunk):
                bucket *= 2
            chunk = chunk + [(-1, 0)] * (bucket - len(chunk))
            rs = np.asarray([c[0] for c in chunk], np.int32)
            los = np.asarray([c[1] for c in chunk], np.int32)
            batches.append((width, bucket, rs, los))
    return batches


def refresh_sharded_view(state: GraphState, prev: ShardedTileView | None,
                         dirty: jax.Array | None, *,
                         mesh: Mesh | None = None,
                         tile: int | None = None) -> ShardedTileView:
    """Incremental rebuild from a dirty-vertex set (full rebuild fallback).

    Same host-side strategy pick as ``core.tiles.refresh_tile_view``: no
    dirty tile row returns ``prev``; a few dirty rows re-derive only those
    rows (same-width rows batched into one shard_map program each, writing
    in place on the owning shards); more than half the rows moved — or a
    resize / mesh change / no dirty info — rebuilds from scratch.
    ``prev``'s buffers are DONATED on the row path: treat the call as
    consuming ``prev``.  Dispatch tallies accumulate in ``refresh_stats``.
    """
    if prev is not None:
        mesh = mesh or prev.mesh
        tile = tile or prev.tile
    if mesh is None:
        raise ValueError("refresh_sharded_view needs a mesh when prev is None")
    tile = tile or TILE
    n = int(mesh.devices.size)
    if (prev is None or dirty is None
            or prev.mesh != mesh
            or prev.tile != tile
            or prev.vp != _padded_dim(state.vcap, tile, n)
            or dirty.shape[0] != state.vcap):
        refresh_stats.rebuilds += 1
        return build_sharded_view(state, mesh, tile)
    plan = dirty_row_windows(state, dirty, prev.n_tiles, tile)
    if plan is None:
        refresh_stats.rebuilds += 1
        return build_sharded_view(state, mesh, tile)
    if not plan:
        return prev
    w, occ = prev.w, prev.occ
    for width, bucket, rs, los in _batched_plan(plan):
        w, occ = _rows_refresh_fn(mesh, tile, width, bucket)(
            w, occ, state.esrc, state.edst, state.ew, state.alive,
            jnp.asarray(rs), jnp.asarray(los))
        refresh_stats.dispatches += 1
    refresh_stats.rows += len(plan)
    return ShardedTileView(w, occ, mesh, tile)
