"""Distributed tile-sparse queries: BFS / SSSP / BC over the sharded grid.

Each query is one ``shard_map`` program over the 1-D graph mesh axis.  Per
level a shard does **local** tile-skipping semiring work against its band
of the :class:`~repro.shard.tile_shard.ShardedTileView` — the very same
``bool_mm`` / ``minplus_mm`` / ``count_mm`` products (Pallas kernels or
jnp fallbacks) the single-device path runs, with the band's occupancy grid
as ``amask`` — followed by ONE vcap-sized collective merging the partial
frontiers:

  * BFS   — int8 ``pmax`` of the per-band frontier hits
            (S x Vp bytes per level);
  * SSSP  — f32 min-merge (``-pmax(-x)``) of the per-band relax candidates
            (4 x S x Vp bytes per level);
  * BC    — the **source axis** is sharded instead, each shard running the
            chunked batched-Brandes sweep over its own S/n sources (S/n x
            Vp level/sigma/delta state — the "BC at larger scale"
            decomposition) with one final psum merging the per-vertex
            scores.  How a shard sees the adjacency is the ``bc_mode``
            knob: ``"gather"`` all-gathers the row bands once per query
            (full O(Vp^2) grid per shard, zero per-level collectives — the
            oracle path), ``"ring"`` keeps only the shard's own O(Vp^2/n)
            band and SUMMA-style rotates bands around the mesh with
            ``lax.ppermute``, one revolution per level step, partial
            products accumulating (forward) / assembling (backward)
            between hops (``_ring_mms``) — per-shard memory stays
            O(Vp^2/n) at the cost of O(Vp^2/n) permute bytes per rotation.

Collective bytes per level are O(S x vcap), independent of E — exactly the
paper's property that queries validate against vertex metadata, not edges.
Cross-shard snapshot agreement is psum-validated the same way: every query
returns ``agree``, true iff all shards computed from the same committed
``version`` (the double-collect version check of ``ShardedGraphService``
then spans commits).

Results are bit-identical to the single-device ``core.queries`` batched
path on the same snapshot: BFS levels are exact integers; the SSSP min-plus
merge is order-free; BC runs the identical per-chunk sweep on the gathered
operands (levels/sigma exact, delta exact per source — only the final
score sum reassociates across shards).

**Delta queries** (``delta_bfs_sharded`` / ``delta_sssp_sharded`` /
``delta_bc_sharded``) port the engine's churn-proportional path to the
mesh.  The split follows what replicates vs what shards: the *stale-region
analysis* runs unsharded on replicated vertex-sized arrays (it is
per-vertex work with no collective), while the *recompute* warm-starts the
usual sharded level loop — local band products, ONE vcap-sized collective
per level, exactly as the full queries.  Per kind:

  * SSSP  — the engine's poison (``engine.incremental._poison``, the very
            function the local delta runs: pointer doubling over the prior
            parent tree + one weight-checked edge re-probe) certifies the
            keep set, whose distances seed the min-plus re-relax loop;
  * BFS   — the level cut (``bc_level_cut``): the poison's finer keep set
            is only consumable by a min-plus re-relax (distances can
            shrink through inserted shortcuts), which would forfeit the
            boolean sgemm/MXU loop — so delta BFS reuses levels above the
            shallowest dirty level and RESUMES ``_bfs_body``'s bool/pmax
            loop from the cut frontier, with per-source resume counters;
  * BC    — the same per-source level cut over the cached forward trees,
            threaded through ``bc_batched_dense(prior_level=, ...)``,
            sharded along the source axis like the full BC.

Every delta result is bit-identical to the full sharded recompute AND to
the local engine's delta path (distances are unique; parents come from
the shared tree reconstruction; warm sweeps replay the cold op sequence).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import semiring
from repro.core.graph_state import INF, GraphState
from repro.core.queries import (
    _edge_views,
    bc_batched_dense,
    bc_batched_ops,
    bc_level_cut,
    bfs_tree_parents,
    sssp_tree_parents,
)

from .tile_shard import ShardedTileView, _axis


class ShardedBFSResult(NamedTuple):
    ok: jax.Array        # bool[S]      source was alive
    dist: jax.Array      # int32[S, V]  (-1 = unreached)
    parent: jax.Array    # int32[S, V]  (NOKEY = none; == queries.bfs parents)
    val_ecnt: jax.Array  # int32[V]     validation vector (reached ecnt)
    agree: jax.Array     # bool[]       all shards saw the same version


class ShardedSSSPResult(NamedTuple):
    ok: jax.Array        # bool[S]  source alive and no negative cycle
    negcycle: jax.Array  # bool[S]
    dist: jax.Array      # f32[S, V]  (+inf = unreachable)
    parent: jax.Array    # int32[S, V]  (NOKEY = none; == queries.sssp parents)
    val_ecnt: jax.Array  # int32[V]
    agree: jax.Array     # bool[]


class ShardedBCResult(NamedTuple):
    ok: jax.Array        # bool[S]
    delta: jax.Array     # f32[S, V]   dependencies, sharded over sources
    sigma: jax.Array     # f32[S, V]
    level: jax.Array     # int32[S, V]
    scores: jax.Array    # f32[V]      sum_s delta[s, v] over ok sources
    val_ecnt: jax.Array  # int32[V]
    agree: jax.Array     # bool[]


def _version_agree(version, ax):
    v = jnp.asarray(version, jnp.int32)
    same = (v == lax.pmax(v, ax)).astype(jnp.int32)
    return lax.psum(same, ax) == lax.psum(1, ax)


def _band_views(w_local, alive, ax):
    """Per-shard operand prep: padded alive, the band's row slice, and the
    band's alive-masked adjacency/weights."""
    band, vp = w_local.shape
    alivep = jnp.pad(alive, (0, vp - alive.shape[0]))
    lo = lax.axis_index(ax) * band
    alive_rows = lax.dynamic_slice_in_dim(alivep, lo, band)
    edge = (w_local < INF) & alive_rows[:, None] & alivep[None, :]
    return alivep, lo, edge


# ------------------------------ BFS / SSSP ---------------------------------

def _cold_srcs(alive, srcs, vp, vcap):
    """Per-shard source prep shared by the cold bodies: ``ok`` flags and
    the one-hot source positions (as an int mask)."""
    alivep = jnp.pad(alive, (0, vp - vcap))
    ok = alivep[jnp.clip(srcs, 0, vp - 1)] & (srcs >= 0) & (srcs < vcap)
    at_src = (jnp.arange(vp, dtype=jnp.int32)[None, :] == srcs[:, None])
    return ok, at_src & ok[:, None]


def _bfs_body(w_local, occ_local, alive, ecnt, srcs, version, *,
              ax, tile, use_kernel):
    """Cold BFS == the warm loop started from the one-hot source frontier
    at pass 0 (exactly how ``bc_dependencies`` reuses ``_bc_coo_sweep``),
    so the full and delta paths cannot drift apart."""
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    _, src_hot = _cold_srcs(alive, srcs, vp, vcap)
    dist0 = jnp.where(src_hot, 0, -1)
    lvl0 = jnp.zeros(srcs.shape, jnp.int32)
    return _bfs_delta_body(w_local, occ_local, alive, ecnt, srcs, version,
                           dist0, lvl0, ax=ax, tile=tile,
                           use_kernel=use_kernel)


def _sssp_body(w_local, occ_local, alive, ecnt, srcs, version, *,
               ax, tile, use_kernel):
    """Cold Bellman-Ford == the warm re-relax from the one-hot sources.

    The pass-0 activity seed is the finite rows of ``dist0`` — only a
    source vertex can relax anything on the first pass, so bands holding
    no source skip their product until relaxation reaches them (the
    band-level frontier the activity tracking then maintains).
    """
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    _, src_hot = _cold_srcs(alive, srcs, vp, vcap)
    dist0 = jnp.where(src_hot, 0.0, INF)
    ok, changed, dist, val_ecnt, agree = _sssp_delta_body(
        w_local, occ_local, alive, ecnt, srcs, version, dist0,
        (dist0 < INF).any(axis=0),
        ax=ax, tile=tile, use_kernel=use_kernel)
    return ok & ~changed, changed, dist, val_ecnt, agree


# ----------------------------- delta re-relax -------------------------------

def _bfs_delta_body(w_local, occ_local, alive, ecnt, srcs, version, dist0,
                    lvl0, *, ax, tile, use_kernel):
    """Warm-started BFS: the EXISTING bool/pmax level loop resumed mid-way.

    ``dist0`` (replicated int32[S, Vp]) carries each source's prior levels
    strictly above its level cut (-1 elsewhere) and ``lvl0[S]`` the resume
    pass (``cut - 1``; 0 for cold rows, ``vcap`` for untouched rows, which
    therefore run zero passes).  Per-source counters keep rows independent,
    so mixed cuts share one loop; each warm row's state at its resume pass
    equals the cold run's, hence distances are bit-identical to the full
    query.  Same band bool products and ONE int8 pmax per level as
    ``_bfs_body`` — staying on the boolean formulation (sgemm/MXU) is the
    whole point of cutting by level instead of re-relaxing min-plus.

    Per-shard early-exit: a shard whose band rows hold NO frontier vertex
    this level skips the band product entirely (its bool product of a zero
    frontier is exactly zero) but still joins the per-level pmax — the
    common case when a deep level cut confines the resumed frontier to a
    few shards' bands.
    """
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    S = srcs.shape[0]
    alivep, lo, edge = _band_views(w_local, alive, ax)
    a_local = edge.astype(jnp.float32)
    band = w_local.shape[0]

    ok = alivep[jnp.clip(srcs, 0, vp - 1)] & (srcs >= 0) & (srcs < vcap)
    front0 = (dist0 == lvl0[:, None]).astype(jnp.float32)

    def cond(c):
        _, front, lvl = c
        return (front > 0).any() & (lvl < vcap).any()

    def body(c):
        dist, front, lvl = c
        fk = lax.dynamic_slice_in_dim(front, lo, band, axis=1)
        part = lax.cond(
            (fk > 0).any(),
            lambda: semiring.bool_mm(fk, a_local, use_kernel=use_kernel,
                                     amask=occ_local, tile=tile),
            lambda: jnp.zeros((S, vp), jnp.float32))
        hit = lax.pmax(part.astype(jnp.int8), ax) > 0  # one int8 pmax / level
        newly = hit & (dist < 0)
        dist = jnp.where(newly, lvl[:, None] + 1, dist)
        return dist, newly.astype(jnp.float32), lvl + 1

    dist, _, _ = lax.while_loop(cond, body, (dist0, front0, lvl0))
    reached_any = (dist[:, :vcap] >= 0).any(axis=0)
    val_ecnt = jnp.where(reached_any, ecnt, 0)
    return ok, dist, val_ecnt, _version_agree(version, ax)


def _sssp_delta_body(w_local, occ_local, alive, ecnt, srcs, version, dist0,
                     active0, *, ax, tile, use_kernel):
    """Warm-started min-plus fixed point: delta SSSP's re-relax.

    ``dist0`` (replicated f32[S, Vp]) carries the poison step's keep-set
    distances — genuine path lengths in the new graph, hence admissible
    upper bounds — so the standard label-correcting loop converges in
    ~(affected-region diameter) passes instead of ~(graph diameter).  Same
    band products and ONE f32 min-merge per level as the full
    ``_sssp_body`` loop.

    Per-shard early-exit via ``active0`` (replicated bool[Vp], the
    suspect-row seed — see ``_sssp_delta_dist0``): a band whose rows hold
    no active vertex contributes ``INF`` without running its product, but
    still joins the min-merge collective.  Sound because a row's
    contribution can only differ from what ``dist`` already absorbed when
    the row's distance changed since the pass that produced it (weights
    are fixed within a query) — so after pass 0, activity is exactly the
    vertices the previous min-merge improved, which every shard derives
    identically from the replicated post-collective distances.  Skipped
    bands therefore never change ``dist``, the pass count, or the
    exit-changed negative-cycle flag: results stay bit-identical.
    """
    band, vp = w_local.shape
    vcap = alive.shape[0]
    S = srcs.shape[0]
    alivep, lo, edge = _band_views(w_local, alive, ax)
    big_local = jnp.where(edge, w_local, INF)

    ok = alivep[jnp.clip(srcs, 0, vp - 1)] & (srcs >= 0) & (srcs < vcap)

    def cond(c):
        _, changed, _, it = c
        return changed.any() & (it < vcap)

    def body(c):
        dist, _, act, it = c
        dk = lax.dynamic_slice_in_dim(dist, lo, band, axis=1)
        cand = lax.cond(
            lax.dynamic_slice_in_dim(act, lo, band).any(),
            lambda: semiring.minplus_mm(dk, big_local, use_kernel=use_kernel,
                                        amask=occ_local, tile=tile),
            lambda: jnp.full((S, vp), INF))
        cand = -lax.pmax(-cand, ax)  # one f32 min-merge / level
        nd = jnp.minimum(dist, cand)
        improved = nd < dist
        return nd, improved.any(axis=1), improved.any(axis=0), it + 1

    # Exit-changed == negative cycle, exactly as in _sssp_body.
    dist, changed, _, _ = lax.while_loop(
        cond, body, (dist0, jnp.ones((S,), jnp.bool_), active0,
                     jnp.int32(0)))
    reached_any = (dist[:, :vcap] < INF).any(axis=0)
    val_ecnt = jnp.where(reached_any, ecnt, 0)
    return ok, changed, dist, val_ecnt, _version_agree(version, ax)


# ---------------------------------- BC -------------------------------------

def _bc_operands(w_local, occ_local, alive, ax):
    """All-gather the row bands once per query: O(Vp^2/n x 4B) per shard,
    vs O(levels x S x Vp) had the adjacency stayed sharded through both
    sweeps — and it keeps the per-chunk sweep bit-identical to the
    single-device path."""
    vp = w_local.shape[1]
    alivep = jnp.pad(alive, (0, vp - alive.shape[0]))
    w_full = lax.all_gather(w_local, ax, axis=0, tiled=True)
    occ_full = lax.all_gather(occ_local, ax, axis=0, tiled=True)
    return alivep, w_full, occ_full


def _bc_finish(level, delta, ok, ecnt, vcap, ax):
    part = jnp.sum(jnp.where(ok[:, None], delta, 0.0), axis=0)
    scores = lax.psum(part, ax)[:vcap]
    reached_any = lax.psum((level[:, :vcap] >= 0).any(axis=0)
                           .astype(jnp.int32), ax) > 0
    val_ecnt = jnp.where(reached_any, ecnt, 0)
    return scores, val_ecnt


def _bc_body(w_local, occ_local, alive, ecnt, srcs_local, version, *,
             ax, tile, use_kernel, src_chunk):
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    alivep, w_full, occ_full = _bc_operands(w_local, occ_local, alive, ax)
    delta, sigma, level, ok = bc_batched_dense(
        w_full < INF, srcs_local, alivep, use_kernel=use_kernel,
        amask=occ_full, tile=tile, src_chunk=src_chunk)
    scores, val_ecnt = _bc_finish(level, delta, ok, ecnt, vcap, ax)
    return ok, delta, sigma, level, scores, val_ecnt, _version_agree(version, ax)


def _bc_delta_body(w_local, occ_local, alive, ecnt, srcs_local, version,
                   dirty, prior_level, prior_sigma, *,
                   ax, tile, use_kernel, src_chunk):
    """Level-cut delta BC, source axis sharded like the full ``_bc_body``.

    Each shard derives the cuts for ITS sources from the replicated dirty
    set (``bc_level_cut`` — no collective needed: a source's forward tree
    is entirely local state) and warm-starts the chunked batched-Brandes
    sweep from its cached trees; only the score psum and the validation
    reduction cross shards, exactly as in the full query.
    """
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    alivep, w_full, occ_full = _bc_operands(w_local, occ_local, alive, ax)
    dirtyp = jnp.pad(dirty, (0, vp - vcap))
    cut = bc_level_cut(prior_level, dirtyp, alivep)
    delta, sigma, level, ok = bc_batched_dense(
        w_full < INF, srcs_local, alivep, use_kernel=use_kernel,
        amask=occ_full, tile=tile, src_chunk=src_chunk,
        prior_level=prior_level, prior_sigma=prior_sigma, cut=cut)
    scores, val_ecnt = _bc_finish(level, delta, ok, ecnt, vcap, ax)
    return ok, delta, sigma, level, scores, val_ecnt, _version_agree(version, ax)


# ------------------------------- BC: ring ----------------------------------

def _ring_mms(a_local, occ_local, *, ax, tile, use_kernel):
    """SUMMA-style semiring-product providers over a rotating band ring.

    The gather-mode BC materialises the full ``Vp x Vp`` adjacency per
    shard; here each shard ever holds only its own ``O(Vp^2/n)`` band plus
    the one in-flight band a ``lax.ppermute`` hop is delivering.  Per
    product the ring makes one revolution — ``n`` partial products with
    ``n - 1`` hops, each step computing the held band's tile-skipping
    partial and then passing the band (and its occupancy grid, the
    kernels' ``amask``) to the next shard; the last partial is peeled out
    of the loop so no hop is spent returning bands home (every product
    restarts from the shard's own closed-over band):

      * ``fwd_mm(x)``: holding band ``b`` (rows ``[b*band, (b+1)*band)``),
        the contribution to ``x @ A`` is ``x[:, rows(b)] @ A[rows(b), :]``
        — partials ACCUMULATE across rotations (the k axis is sharded).
        The sum is exact for sigma (integer counts in f32), so the ring's
        band-major summation order is invisible to levels/sigma.
      * ``bwd_mm(g)``: the contribution to ``g @ A^T`` is the full-k
        product ``g @ A[rows(b), :].T`` covering output columns
        ``rows(b)`` — partials ASSEMBLE by column block, each an intact
        dot against the transposed band (occupancy grid transposed too).

    Collective bytes per rotation: ``band x Vp x 4`` (f32 weights band)
    ``+ rows x nt x 4`` (int32 occupancy band) = O(Vp^2/n) — the figure
    the collective-byte regression test pins against the compiled HLO.

    Both providers contain collectives, so every shard must call them the
    same number of times: the callers run their level loops in lock-step
    via ``bc_sweep_ops``'s ``sync_any``/``sync_max`` hooks
    (``_ring_sync``).
    """
    band, vp = a_local.shape
    n = vp // band
    perm = [(j, (j + 1) % n) for j in range(n)]
    i = lax.axis_index(ax)

    def rotate(ab, ob):
        return lax.ppermute(ab, ax, perm), lax.ppermute(ob, ax, perm)

    def _revolve(combine, init):
        """n partials, n - 1 hops: loop over the first n - 1 held bands
        (combine, then rotate), then combine the last held band with no
        hop — the loop-carried bands are discarded, so a homing rotation
        would be pure wasted ICI traffic."""

        def step(t, c):
            ab, ob, acc = c
            acc = combine(t, ab, ob, acc)
            ab, ob = rotate(ab, ob)
            return ab, ob, acc

        ab, ob, acc = lax.fori_loop(0, n - 1, step,
                                    (a_local, occ_local, init))
        return combine(n - 1, ab, ob, acc)

    def fwd_mm(x):
        def combine(t, ab, ob, acc):
            b = (i - t) % n  # the band this shard holds at step t
            xk = lax.dynamic_slice_in_dim(x, b * band, band, axis=1)
            return acc + semiring.count_mm(xk, ab, use_kernel=use_kernel,
                                           amask=ob, tile=tile)

        return _revolve(combine, jnp.zeros((x.shape[0], vp), jnp.float32))

    def bwd_mm(g):
        def combine(t, ab, ob, out):
            b = (i - t) % n
            part = semiring.count_mm(g, ab.T, use_kernel=use_kernel,
                                     amask=ob.T, tile=tile)
            return lax.dynamic_update_slice(out, part, (0, b * band))

        return _revolve(combine, jnp.zeros((g.shape[0], vp), jnp.float32))

    return fwd_mm, bwd_mm


def _ring_sync(ax):
    """Lock-step hooks for ``bc_sweep_ops`` (see ``_ring_mms``): the level
    loops continue until EVERY shard's source chunk is done — one int8
    pmax per forward level, one int32 pmax per chunk for the backward
    start — and a shard's extra iterations are exact no-ops."""
    return dict(
        sync_any=lambda p: lax.pmax(p.astype(jnp.int8), ax) > 0,
        sync_max=lambda x: lax.pmax(x, ax))


def _bc_ring_prep(w_local, occ_local, alive, ax, tile, use_kernel):
    alivep, _, edge = _band_views(w_local, alive, ax)
    fwd_mm, bwd_mm = _ring_mms(edge.astype(jnp.float32), occ_local,
                               ax=ax, tile=tile, use_kernel=use_kernel)
    return alivep, fwd_mm, bwd_mm


def _bc_ring_body(w_local, occ_local, alive, ecnt, srcs_local, version, *,
                  ax, tile, use_kernel, src_chunk):
    """Ring-mode ``_bc_body``: the identical chunked batched-Brandes sweep
    (``bc_batched_ops`` == ``bc_batched_dense``'s driver) fed by rotated
    bands instead of a gathered matrix.  Levels/sigma bit-identical to the
    gather mode; per-shard adjacency memory O(Vp^2/n) instead of O(Vp^2).
    """
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    alivep, fwd_mm, bwd_mm = _bc_ring_prep(w_local, occ_local, alive, ax,
                                           tile, use_kernel)
    delta, sigma, level, ok = bc_batched_ops(
        fwd_mm, bwd_mm, srcs_local, alivep, vp, src_chunk=src_chunk,
        **_ring_sync(ax))
    scores, val_ecnt = _bc_finish(level, delta, ok, ecnt, vcap, ax)
    return ok, delta, sigma, level, scores, val_ecnt, _version_agree(version, ax)


def _bc_delta_ring_body(w_local, occ_local, alive, ecnt, srcs_local, version,
                        dirty, prior_level, prior_sigma, *,
                        ax, tile, use_kernel, src_chunk):
    """Ring-mode ``_bc_delta_body``: the same per-shard level cuts
    (replicated dirty set against the shard's own cached forward trees —
    levels/sigma are bit-identical across modes, so the cuts and the
    per-source resume counters are too), warm-starting the ring sweep.
    """
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    alivep, fwd_mm, bwd_mm = _bc_ring_prep(w_local, occ_local, alive, ax,
                                           tile, use_kernel)
    dirtyp = jnp.pad(dirty, (0, vp - vcap))
    cut = bc_level_cut(prior_level, dirtyp, alivep)
    delta, sigma, level, ok = bc_batched_ops(
        fwd_mm, bwd_mm, srcs_local, alivep, vp, src_chunk=src_chunk,
        prior_level=prior_level, prior_sigma=prior_sigma, cut=cut,
        **_ring_sync(ax))
    scores, val_ecnt = _bc_finish(level, delta, ok, ecnt, vcap, ax)
    return ok, delta, sigma, level, scores, val_ecnt, _version_agree(version, ax)


# ------------------------------ entry points -------------------------------

_KINDS = ("bfs", "sssp", "bc", "bc_ring", "bfs_delta", "sssp_delta",
          "bc_delta", "bc_delta_ring")

#: ``bc_mode`` knob -> the (full, delta) shard_map kinds it selects.
BC_MODES = {"gather": ("bc", "bc_delta"),
            "ring": ("bc_ring", "bc_delta_ring")}


def _bc_kind(bc_mode: str, delta: bool) -> str:
    if bc_mode not in BC_MODES:
        raise ValueError(f"unknown bc_mode {bc_mode!r}; "
                         f"supported modes: {', '.join(sorted(BC_MODES))}")
    return BC_MODES[bc_mode][1 if delta else 0]


@lru_cache(maxsize=None)
def query_fn(mesh: Mesh, kind: str, tile: int, use_kernel: bool = False,
             src_chunk: int | None = None):
    """The jitted shard_map program for ``kind`` on ``mesh``.

    Signature: ``fn(w, occ, alive, ecnt, srcs, version, *extras)`` over
    GLOBAL arrays — ``w``/``occ`` sharded ``P(axis, None)`` (a
    ``ShardedTileView``), vertex arrays replicated, ``srcs`` replicated for
    bfs/sssp and sharded ``P(axis)`` for the bc kinds (length must divide
    the axis size; the host wrappers pad with -1).  The ``*_ring`` bc
    kinds share the bc signatures and differ only in how the adjacency
    reaches each shard (band rotation vs all-gather).  The delta kinds
    take extras: ``bfs_delta`` a replicated warm-start ``dist0[S, Vp]``
    plus resume passes ``lvl0[S]``; ``sssp_delta`` the replicated
    ``dist0[S, Vp]`` plus the band-activity seed ``active0[Vp]``;
    ``bc_delta(_ring)`` the replicated dirty mask plus the source-sharded
    prior ``level``/``sigma``.  Cached per (mesh, kind, tile, use_kernel,
    src_chunk).
    """
    ax = _axis(mesh)
    vspec, rspec, sspec = P(ax, None), P(), P(ax)
    kw = dict(ax=ax, tile=tile, use_kernel=use_kernel)
    extra_specs = ()
    if kind == "bfs":
        body = partial(_bfs_body, **kw)
        src_spec = rspec
        out_specs = (rspec, rspec, rspec, rspec)
    elif kind == "sssp":
        body = partial(_sssp_body, **kw)
        src_spec = rspec
        out_specs = (rspec, rspec, rspec, rspec, rspec)
    elif kind == "bfs_delta":
        body = partial(_bfs_delta_body, **kw)
        src_spec = rspec
        extra_specs = (rspec, rspec)                 # dist0, lvl0
        out_specs = (rspec, rspec, rspec, rspec)
    elif kind == "sssp_delta":
        body = partial(_sssp_delta_body, **kw)
        src_spec = rspec
        extra_specs = (rspec, rspec)                 # dist0, active0
        out_specs = (rspec, rspec, rspec, rspec, rspec)
    elif kind in ("bc", "bc_ring"):
        bodies = {"bc": _bc_body, "bc_ring": _bc_ring_body}
        body = partial(bodies[kind], src_chunk=src_chunk, **kw)
        src_spec = sspec
        out_specs = (sspec, vspec, vspec, vspec, rspec, rspec, rspec)
    elif kind in ("bc_delta", "bc_delta_ring"):
        bodies = {"bc_delta": _bc_delta_body,
                  "bc_delta_ring": _bc_delta_ring_body}
        body = partial(bodies[kind], src_chunk=src_chunk, **kw)
        src_spec = sspec
        extra_specs = (rspec, vspec, vspec)          # dirty, level, sigma
        out_specs = (sspec, vspec, vspec, vspec, rspec, rspec, rspec)
    else:
        raise ValueError(f"unknown query kind {kind!r}; "
                         f"supported kinds: {', '.join(_KINDS)}")
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(vspec, vspec, rspec, rspec, src_spec, rspec) + extra_specs,
        out_specs=out_specs,
        check_rep=False,
    )
    return jax.jit(fn)


def query_shardings(mesh: Mesh, kind: str):
    """(in_shardings, out_shardings) matching ``query_fn`` — what an AOT
    ``jit(fn, in_shardings=...).lower`` (``launch/dryrun.py``) needs."""
    ax = _axis(mesh)
    v = NamedSharding(mesh, P(ax, None))
    r = NamedSharding(mesh, P())
    s = NamedSharding(mesh, P(ax))
    if kind in ("bc", "bc_ring"):
        return (v, v, r, r, s, r), (s, v, v, v, r, r, r)
    if kind in ("bc_delta", "bc_delta_ring"):
        return (v, v, r, r, s, r, r, v, v), (s, v, v, v, r, r, r)
    if kind == "bfs_delta":
        return (v, v, r, r, r, r, r, r), (r,) * 4
    if kind == "sssp_delta":
        return (v, v, r, r, r, r, r, r), (r,) * 5
    if kind not in ("bfs", "sssp"):
        raise ValueError(f"unknown query kind {kind!r}; "
                         f"supported kinds: {', '.join(_KINDS)}")
    return (v, v, r, r, r, r), (r,) * (4 if kind == "bfs" else 5)


def _srcs_array(srcs, n_shards: int = 1, pad_to_shards: bool = False):
    srcs = jnp.atleast_1d(jnp.asarray(srcs, jnp.int32))
    if pad_to_shards:
        rem = (-srcs.shape[0]) % n_shards
        if rem:
            srcs = jnp.concatenate(
                [srcs, jnp.full((rem,), -1, jnp.int32)])
    return srcs


def _host_local(view: ShardedTileView, x: jax.Array) -> jax.Array:
    """Pull a small replicated array onto ONE device of the mesh.

    The unsharded helper math (tree-parent reconstruction, the delta
    poison/cut prep) consumes the replicated per-source outputs of the
    shard_map programs; left replicated, those jitted helpers execute once
    per mesh device — pure waste on host-platform meshes where every
    placeholder device shares one CPU, and duplicated work off the
    critical path on a real mesh.  The arrays are S x vcap-sized, so the
    transfer is noise next to the O(Vp^2/n) bands.
    """
    return jax.device_put(x, view.mesh.devices.reshape(-1)[0])


def _mesh_replicated(view: ShardedTileView, x: jax.Array) -> jax.Array:
    """The inverse hop: broadcast a device-local helper output back to a
    replicated mesh array so it can enter a shard_map program (jit refuses
    to mix single-device and mesh-committed operands)."""
    return jax.device_put(x, NamedSharding(view.mesh, P()))


def _account(accountant, kind: str, view: ShardedTileView, fn, args,
             use_kernel: bool, src_chunk: int | None = None) -> None:
    """Deposit the compiled program's HLO cost with the accountant.

    Cached per program signature — (kind, mesh shape, tile, flags, operand
    shapes) — so only the FIRST query of a given shape pays one extra
    lower+compile of the very ``query_fn`` program it just ran; every
    later query reads the cached dict (collective bytes, temp memory,
    flops) that the service attributes to its trace record.  The result
    lands in ``accountant.last`` (see ``repro.obs.hlo``); return types
    stay untouched.
    """
    if accountant is None:
        return
    key = ("shard_query", kind, view.mesh.shape_tuple, view.tile,
           use_kernel, src_chunk) + tuple(
        (tuple(a.shape), str(a.dtype))
        for a in args if hasattr(a, "shape"))
    accountant.account(key, lambda: fn.lower(*args).compile())


def bfs(view: ShardedTileView, state: GraphState, srcs, *,
        use_kernel: bool = False, accountant=None) -> ShardedBFSResult:
    """Distributed multi-source BFS; ``dist`` is sliced back to ``vcap``.

    ``parent`` is reconstructed from the final distances on the replicated
    COO edge table (``bfs_tree_parents`` — O(S x ecap) per-vertex work, no
    collective), identical to per-source ``queries.bfs`` and the array the
    delta path's poison step walks.
    """
    srcs = _srcs_array(srcs)
    fn = query_fn(view.mesh, "bfs", view.tile, use_kernel)
    args = (view.w, view.occ, state.alive, state.ecnt, srcs, state.version)
    ok, dist, val_ecnt, agree = fn(*args)
    _account(accountant, "bfs", view, fn, args, use_kernel)
    dist = _host_local(view, dist)[:, :state.vcap]
    parent = bfs_tree_parents(state, dist, srcs)
    return ShardedBFSResult(ok, dist, parent, val_ecnt, agree)


def sssp(view: ShardedTileView, state: GraphState, srcs, *,
         use_kernel: bool = False, accountant=None) -> ShardedSSSPResult:
    """Distributed multi-source Bellman-Ford with negative-cycle flags.

    ``parent`` follows ``queries.sssp`` (tight edges, min-source tie-break)
    via the shared ``sssp_tree_parents`` reconstruction.
    """
    srcs = _srcs_array(srcs)
    fn = query_fn(view.mesh, "sssp", view.tile, use_kernel)
    args = (view.w, view.occ, state.alive, state.ecnt, srcs, state.version)
    ok, neg, dist, val_ecnt, agree = fn(*args)
    _account(accountant, "sssp", view, fn, args, use_kernel)
    dist = _host_local(view, dist)[:, :state.vcap]
    parent = sssp_tree_parents(state, dist, srcs)
    return ShardedSSSPResult(ok, neg, dist, parent, val_ecnt, agree)


def bc_batched(view: ShardedTileView, state: GraphState, srcs=None, *,
               use_kernel: bool = False, src_chunk: int | None = None,
               bc_mode: str = "gather", accountant=None) -> ShardedBCResult:
    """Distributed batched Brandes, source axis sharded over the mesh.

    ``srcs`` defaults to every vertex slot (exact all-sources BC); it is
    padded with -1 up to a multiple of the shard count (dead padding
    contributes nothing) and the padding is sliced back off the returned
    per-source arrays, which stay sharded ``P(axis, None)``.

    ``bc_mode`` picks how each shard sees the adjacency: ``"gather"``
    (one ``all_gather`` of the row bands per query — O(Vp^2) per-shard
    memory, zero per-level collectives; the oracle path) or ``"ring"``
    (SUMMA-style ``lax.ppermute`` band rotation — O(Vp^2/n) per-shard
    memory, one ring revolution per level step; see ``_ring_mms``).
    Levels/sigma are bit-identical across modes; delta/scores agree to
    f32 summation order.
    """
    if srcs is None:
        srcs = jnp.arange(state.vcap, dtype=jnp.int32)
    n_srcs = jnp.atleast_1d(jnp.asarray(srcs)).shape[0]
    srcs = _srcs_array(srcs, view.n_shards, pad_to_shards=True)
    fn = query_fn(view.mesh, _bc_kind(bc_mode, delta=False), view.tile,
                  use_kernel, src_chunk)
    args = (view.w, view.occ, state.alive, state.ecnt, srcs, state.version)
    ok, delta, sigma, level, scores, val_ecnt, agree = fn(*args)
    _account(accountant, _bc_kind(bc_mode, delta=False), view, fn, args,
             use_kernel, src_chunk)
    vcap = state.vcap
    return ShardedBCResult(ok[:n_srcs], delta[:n_srcs, :vcap],
                           sigma[:n_srcs, :vcap], level[:n_srcs, :vcap],
                           scores, val_ecnt, agree)


# ------------------------------ delta queries -------------------------------

@partial(jax.jit, static_argnames=("vp",))
def _sssp_delta_dist0(state: GraphState, prior_dist, prior_parent, dirty,
                      srcs, vp: int):
    """The poison step of the sharded delta SSSP, batched over sources.

    Runs the engine's ``_poison`` (pointer doubling over the prior parent
    tree + one vectorized edge re-probe, weight-checked) per source on
    REPLICATED arrays — the parent walk is per-vertex, so nothing here
    needs the mesh — and returns the warm-start ``dist0[S, vp]``:
    surviving prior distances (admissible upper bounds in the new graph),
    +inf elsewhere, source re-pinned to 0.  Identical seeding to the
    engine's ``delta_sssp``.

    Also derives ``active0[vp]``, the pass-0 band-activity seed of the
    re-relax loop's per-shard early-exit: the rows that can possibly
    improve anything on the first pass.  A kept, clean vertex relaxing a
    kept neighbour reproves what prior convergence already guarantees —
    only (a) DIRTY rows (their out-edge set or weights changed) and
    (b) rows with a live out-edge into the poisoned/unreached region
    (``dist0 == INF``) can tighten a bound, and either way only where the
    row is finite for some source.  Later passes reseed activity from the
    vertices the previous min-merge improved (see ``_sssp_delta_body``).
    """
    from repro.engine.incremental import _poison

    vcap = state.vcap

    def one(dist, parent, src):
        reached = dist < INF
        poison = _poison(state, parent, reached, dist, dirty,
                         check_weight=True)
        keep = reached & ~poison
        d0 = jnp.where(keep, dist, INF)
        ok = (state.alive[jnp.clip(src, 0, vcap - 1)]
              & (src >= 0) & (src < vcap))
        return d0.at[src].set(jnp.where(ok, 0.0, INF), mode="drop")

    dist0 = jax.vmap(one)(prior_dist, prior_parent, srcs)

    live, srcc, dstc = _edge_views(state)

    def gap_rows(d):
        gap = live & (d[srcc] < INF) & (d[dstc] == INF)
        return (jnp.zeros((vcap,), jnp.bool_)
                .at[srcc].max(gap, mode="drop"))

    finite_any = (dist0 < INF).any(axis=0)
    active0 = (dirty & finite_any) | jax.vmap(gap_rows)(dist0).any(axis=0)
    return (jnp.pad(dist0, ((0, 0), (0, vp - vcap)), constant_values=INF),
            jnp.pad(active0, (0, vp - vcap)))


@partial(jax.jit, static_argnames=("vp",))
def _bfs_delta_state0(state: GraphState, prior_dist, dirty, srcs, vp: int):
    """The cut step of the sharded delta BFS, batched over sources.

    BFS levels ARE a forward tree, so the delta reuses exactly the
    level-cut reasoning of delta-BC (``bc_level_cut``): everything
    strictly above a source's shallowest dirty level is certainly
    unchanged, everything below is suspect.  The parent-tree poison walk
    would certify MORE survivors (it re-probes individual edges), but its
    keep set is only usable by a min-plus re-relax — distances can shrink
    through inserted shortcut edges — which would forfeit the boolean
    (sgemm/MXU) formulation the sharded BFS loop is built on; the level
    cut keeps every pass on the int8-pmax loop.  Returns the warm level
    array and per-source resume pass (``cut - 1``; cold restart for
    suspect sources, ``vcap`` = zero passes for untouched ones).
    """
    vcap = state.vcap
    cut = bc_level_cut(prior_dist, dirty, state.alive)
    ok = (state.alive[jnp.clip(srcs, 0, vcap - 1)]
          & (srcs >= 0) & (srcs < vcap))
    # A now-ok source with an EMPTY prior row (dead at prior time,
    # resurrected since) is invisible to the level cut — nothing in its
    # row is reached — but must restart cold, not reuse the empty tree.
    rows = jnp.arange(srcs.shape[0], dtype=jnp.int32)
    revived = ok & (prior_dist[rows, jnp.clip(srcs, 0, vcap - 1)] < 0)
    cut = jnp.where(revived, 0, cut)
    ids = jnp.arange(vcap, dtype=jnp.int32)
    cold = jnp.where((ids[None, :] == srcs[:, None]) & ok[:, None], 0, -1)
    usable = cut >= 1
    keep = usable[:, None] & (prior_dist >= 0) & (prior_dist < cut[:, None])
    dist0 = jnp.where(usable[:, None], jnp.where(keep, prior_dist, -1), cold)
    lvl0 = jnp.where(usable, jnp.minimum(cut - 1, vcap), 0)
    dist0 = jnp.pad(dist0, ((0, 0), (0, vp - vcap)), constant_values=-1)
    return dist0.astype(jnp.int32), lvl0.astype(jnp.int32)


def delta_bfs_sharded(view: ShardedTileView, state: GraphState,
                      prior: ShardedBFSResult, dirty, srcs, *,
                      use_kernel: bool = False,
                      accountant=None) -> ShardedBFSResult:
    """Distributed delta BFS: level cut unsharded, warm loop on the mesh.

    ``prior`` must be a result for the SAME ``srcs`` at an earlier version
    whose accumulated dirty set is ``dirty`` (a superset only costs time).
    Bit-identical to both the full sharded ``bfs`` on this snapshot and
    the engine's per-source ``delta_bfs`` (BFS distances are unique and
    the parents come from the shared reconstruction); the cost is the
    passes BELOW each source's cut — churn deep in the traversal skips the
    shallow levels entirely, untouched sources run zero passes.
    """
    srcs = _srcs_array(srcs)
    dist0, lvl0 = _bfs_delta_state0(state, prior.dist, dirty, srcs,
                                    vp=view.vp)
    dist0, lvl0 = (_mesh_replicated(view, x) for x in (dist0, lvl0))
    fn = query_fn(view.mesh, "bfs_delta", view.tile, use_kernel)
    args = (view.w, view.occ, state.alive, state.ecnt, srcs, state.version,
            dist0, lvl0)
    ok, dist, val_ecnt, agree = fn(*args)
    _account(accountant, "bfs_delta", view, fn, args, use_kernel)
    dist = _host_local(view, dist)[:, :state.vcap]
    parent = bfs_tree_parents(state, dist, srcs)
    return ShardedBFSResult(ok, dist, parent, val_ecnt, agree)


def delta_sssp_sharded(view: ShardedTileView, state: GraphState,
                       prior: ShardedSSSPResult, dirty, srcs, *,
                       use_kernel: bool = False,
                       accountant=None) -> ShardedSSSPResult:
    """Distributed delta Bellman-Ford: poison unsharded, re-relax sharded.

    The prior must be negative-cycle-free (its distances must be converged
    path lengths for the poison chain walk to certify them); on detection
    in the NEW graph the caller should re-run the full query, whose
    partially-relaxed distances are the canonical answer — exactly the
    ``incremental_sssp`` contract.  Bit-identical to the full sharded
    ``sssp`` and to the engine's ``delta_sssp`` (the re-relax is the same
    fixed point, merged with an order-free f32 min per level).
    """
    srcs = _srcs_array(srcs)
    dist0, active0 = (_mesh_replicated(view, x) for x in _sssp_delta_dist0(
        state, prior.dist, prior.parent, dirty, srcs, vp=view.vp))
    fn = query_fn(view.mesh, "sssp_delta", view.tile, use_kernel)
    args = (view.w, view.occ, state.alive, state.ecnt, srcs, state.version,
            dist0, active0)
    ok, changed, dist, val_ecnt, agree = fn(*args)
    _account(accountant, "sssp_delta", view, fn, args, use_kernel)
    dist = _host_local(view, dist)[:, :state.vcap]
    parent = sssp_tree_parents(state, dist, srcs)
    return ShardedSSSPResult(ok & ~changed, changed, dist, parent,
                             val_ecnt, agree)


def delta_bc_sharded(view: ShardedTileView, state: GraphState,
                     prior: ShardedBCResult, dirty, srcs=None, *,
                     use_kernel: bool = False, src_chunk: int | None = None,
                     bc_mode: str = "gather",
                     accountant=None) -> ShardedBCResult:
    """Distributed level-cut delta BC, source axis sharded as in ``bc_batched``.

    Each shard cuts its own sources' cached forward trees at the shallowest
    dirty level (``bc_level_cut`` on the replicated dirty mask — sources
    the churn cannot have touched reuse their whole tree with zero forward
    passes; a source that is itself suspect restarts cold) and resumes the
    chunked batched-Brandes sweep.  Bit-identical to the full sharded
    ``bc_batched`` on this snapshot, scores included.  ``bc_mode`` as in
    ``bc_batched``; the prior's forward trees are mode-independent
    (level/sigma bit-identical), so the cuts and per-source resume
    counters cannot drift across modes either.
    """
    if srcs is None:
        srcs = jnp.arange(state.vcap, dtype=jnp.int32)
    n_srcs = jnp.atleast_1d(jnp.asarray(srcs)).shape[0]
    srcs = _srcs_array(srcs, view.n_shards, pad_to_shards=True)
    vcap = state.vcap
    S, vp = srcs.shape[0], view.vp
    # Re-pad the cached (sliced-back) prior to the program's [S, Vp] shape:
    # padding sources carry an empty tree and padding columns are never
    # reached, matching what the full program computes for them.
    level = jnp.full((S, vp), -1, jnp.int32).at[:n_srcs, :vcap].set(
        prior.level)
    sigma = jnp.zeros((S, vp), jnp.float32).at[:n_srcs, :vcap].set(
        prior.sigma)
    dirty = _mesh_replicated(view, dirty)
    fn = query_fn(view.mesh, _bc_kind(bc_mode, delta=True), view.tile,
                  use_kernel, src_chunk)
    args = (view.w, view.occ, state.alive, state.ecnt, srcs, state.version,
            dirty, level, sigma)
    ok, delta, sigma, level, scores, val_ecnt, agree = fn(*args)
    _account(accountant, _bc_kind(bc_mode, delta=True), view, fn, args,
             use_kernel, src_chunk)
    return ShardedBCResult(ok[:n_srcs], delta[:n_srcs, :vcap],
                           sigma[:n_srcs, :vcap], level[:n_srcs, :vcap],
                           scores, val_ecnt, agree)


def validate_incremental_sharded(view: ShardedTileView, state: GraphState,
                                 srcs, result, kind: str, *,
                                 use_kernel: bool = False,
                                 src_chunk: int | None = None,
                                 bc_mode: str = "gather") -> bool:
    """``cmp_tree``-style check for the sharded delta paths: bit-equality
    of every result field against a fresh full distributed collect on the
    same snapshot (the sharded analogue of
    ``engine.incremental.validate_incremental`` — delta BC included, since
    the warm-started sweep replays the cold op sequence exactly; a ring
    delta validates against a ring full collect so the comparison stays
    within one summation order)."""
    from repro.engine.incremental import results_equal

    if kind == "bc":
        fresh = bc_batched(view, state, srcs, use_kernel=use_kernel,
                           src_chunk=src_chunk, bc_mode=bc_mode)
    else:
        fresh = {"bfs": bfs, "sssp": sssp}[kind](view, state, srcs,
                                                 use_kernel=use_kernel)
    return results_equal(result, fresh)
