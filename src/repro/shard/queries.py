"""Distributed tile-sparse queries: BFS / SSSP / BC over the sharded grid.

Each query is one ``shard_map`` program over the 1-D graph mesh axis.  Per
level a shard does **local** tile-skipping semiring work against its band
of the :class:`~repro.shard.tile_shard.ShardedTileView` — the very same
``bool_mm`` / ``minplus_mm`` / ``count_mm`` products (Pallas kernels or
jnp fallbacks) the single-device path runs, with the band's occupancy grid
as ``amask`` — followed by ONE vcap-sized collective merging the partial
frontiers:

  * BFS   — int8 ``pmax`` of the per-band frontier hits
            (S x Vp bytes per level);
  * SSSP  — f32 min-merge (``-pmax(-x)``) of the per-band relax candidates
            (4 x S x Vp bytes per level);
  * BC    — the **source axis** is sharded instead: one ``all_gather`` of
            the row bands rebuilds the full grid per shard (Vp^2/n x 4
            bytes, once per query, not per level), then each shard runs the
            chunked batched-Brandes building block
            (``core.queries.bc_batched_dense``) over its own S/n sources,
            holding only its sources' S/n x Vp level/sigma/delta state —
            the "BC at larger scale" decomposition.  One final psum merges
            the per-vertex scores.

Collective bytes per level are O(S x vcap), independent of E — exactly the
paper's property that queries validate against vertex metadata, not edges.
Cross-shard snapshot agreement is psum-validated the same way: every query
returns ``agree``, true iff all shards computed from the same committed
``version`` (the double-collect version check of ``ShardedGraphService``
then spans commits).

Results are bit-identical to the single-device ``core.queries`` batched
path on the same snapshot: BFS levels are exact integers; the SSSP min-plus
merge is order-free; BC runs the identical per-chunk sweep on the gathered
operands (levels/sigma exact, delta exact per source — only the final
score sum reassociates across shards).
"""
from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import semiring
from repro.core.graph_state import INF, GraphState
from repro.core.queries import bc_batched_dense

from .tile_shard import ShardedTileView, _axis


class ShardedBFSResult(NamedTuple):
    ok: jax.Array        # bool[S]      source was alive
    dist: jax.Array      # int32[S, V]  (-1 = unreached)
    val_ecnt: jax.Array  # int32[V]     validation vector (reached ecnt)
    agree: jax.Array     # bool[]       all shards saw the same version


class ShardedSSSPResult(NamedTuple):
    ok: jax.Array        # bool[S]  source alive and no negative cycle
    negcycle: jax.Array  # bool[S]
    dist: jax.Array      # f32[S, V]  (+inf = unreachable)
    val_ecnt: jax.Array  # int32[V]
    agree: jax.Array     # bool[]


class ShardedBCResult(NamedTuple):
    ok: jax.Array        # bool[S]
    delta: jax.Array     # f32[S, V]   dependencies, sharded over sources
    sigma: jax.Array     # f32[S, V]
    level: jax.Array     # int32[S, V]
    scores: jax.Array    # f32[V]      sum_s delta[s, v] over ok sources
    val_ecnt: jax.Array  # int32[V]
    agree: jax.Array     # bool[]


def _version_agree(version, ax):
    v = jnp.asarray(version, jnp.int32)
    same = (v == lax.pmax(v, ax)).astype(jnp.int32)
    return lax.psum(same, ax) == lax.psum(1, ax)


def _band_views(w_local, alive, ax):
    """Per-shard operand prep: padded alive, the band's row slice, and the
    band's alive-masked adjacency/weights."""
    band, vp = w_local.shape
    alivep = jnp.pad(alive, (0, vp - alive.shape[0]))
    lo = lax.axis_index(ax) * band
    alive_rows = lax.dynamic_slice_in_dim(alivep, lo, band)
    edge = (w_local < INF) & alive_rows[:, None] & alivep[None, :]
    return alivep, lo, edge


# ------------------------------ BFS / SSSP ---------------------------------

def _bfs_body(w_local, occ_local, alive, ecnt, srcs, version, *,
              ax, tile, use_kernel):
    vp = w_local.shape[1]
    band = w_local.shape[0]
    vcap = alive.shape[0]
    alivep, lo, edge = _band_views(w_local, alive, ax)
    a_local = edge.astype(jnp.float32)

    ok = alivep[jnp.clip(srcs, 0, vp - 1)] & (srcs >= 0) & (srcs < vcap)
    front0 = jax.nn.one_hot(srcs, vp, dtype=jnp.float32) * ok[:, None]
    dist0 = jnp.where(front0 > 0, 0, -1).astype(jnp.int32)

    def cond(c):
        _, front, lvl = c
        return (front > 0).any() & (lvl < vcap)

    def body(c):
        dist, front, lvl = c
        fk = lax.dynamic_slice_in_dim(front, lo, band, axis=1)
        part = semiring.bool_mm(fk, a_local, use_kernel=use_kernel,
                                amask=occ_local, tile=tile)
        hit = lax.pmax(part.astype(jnp.int8), ax) > 0  # one int8 pmax / level
        newly = hit & (dist < 0)
        dist = jnp.where(newly, lvl + 1, dist)
        return dist, newly.astype(jnp.float32), lvl + 1

    dist, _, _ = lax.while_loop(cond, body, (dist0, front0, jnp.int32(0)))
    reached_any = (dist[:, :vcap] >= 0).any(axis=0)
    val_ecnt = jnp.where(reached_any, ecnt, 0)
    return ok, dist, val_ecnt, _version_agree(version, ax)


def _sssp_body(w_local, occ_local, alive, ecnt, srcs, version, *,
               ax, tile, use_kernel):
    vp = w_local.shape[1]
    band = w_local.shape[0]
    vcap = alive.shape[0]
    S = srcs.shape[0]
    alivep, lo, edge = _band_views(w_local, alive, ax)
    big_local = jnp.where(edge, w_local, INF)

    ok = alivep[jnp.clip(srcs, 0, vp - 1)] & (srcs >= 0) & (srcs < vcap)
    dist0 = jnp.where(
        jax.nn.one_hot(srcs, vp, dtype=jnp.float32) * ok[:, None] > 0,
        0.0, INF)

    def cond(c):
        _, changed, it = c
        return changed.any() & (it < vcap)

    def body(c):
        dist, _, it = c
        dk = lax.dynamic_slice_in_dim(dist, lo, band, axis=1)
        cand = semiring.minplus_mm(dk, big_local, use_kernel=use_kernel,
                                   amask=occ_local, tile=tile)
        cand = -lax.pmax(-cand, ax)  # one f32 min-merge / level
        nd = jnp.minimum(dist, cand)
        return nd, (nd < dist).any(axis=1), it + 1

    # Same free CHECKNEGCYCLE as sssp_batched_dense: still-changed at loop
    # exit == the vcap-th pass improved something == negative cycle.
    dist, changed, _ = lax.while_loop(
        cond, body, (dist0, jnp.ones((S,), jnp.bool_), jnp.int32(0)))
    reached_any = (dist[:, :vcap] < INF).any(axis=0)
    val_ecnt = jnp.where(reached_any, ecnt, 0)
    return ok & ~changed, changed, dist, val_ecnt, _version_agree(version, ax)


# ---------------------------------- BC -------------------------------------

def _bc_body(w_local, occ_local, alive, ecnt, srcs_local, version, *,
             ax, tile, use_kernel, src_chunk):
    vp = w_local.shape[1]
    vcap = alive.shape[0]
    alivep = jnp.pad(alive, (0, vp - vcap))
    # One gather of the row bands per query: O(Vp^2/n x 4B) per shard, vs
    # O(levels x S x Vp) had the adjacency stayed sharded through both
    # sweeps — and it keeps the per-chunk sweep bit-identical to the
    # single-device path.
    w_full = lax.all_gather(w_local, ax, axis=0, tiled=True)
    occ_full = lax.all_gather(occ_local, ax, axis=0, tiled=True)
    delta, sigma, level, ok = bc_batched_dense(
        w_full < INF, srcs_local, alivep, use_kernel=use_kernel,
        amask=occ_full, tile=tile, src_chunk=src_chunk)
    part = jnp.sum(jnp.where(ok[:, None], delta, 0.0), axis=0)
    scores = lax.psum(part, ax)[:vcap]
    reached_any = lax.psum((level[:, :vcap] >= 0).any(axis=0)
                           .astype(jnp.int32), ax) > 0
    val_ecnt = jnp.where(reached_any, ecnt, 0)
    return ok, delta, sigma, level, scores, val_ecnt, _version_agree(version, ax)


# ------------------------------ entry points -------------------------------

@lru_cache(maxsize=None)
def query_fn(mesh: Mesh, kind: str, tile: int, use_kernel: bool = False,
             src_chunk: int | None = None):
    """The jitted shard_map program for ``kind`` on ``mesh``.

    Signature: ``fn(w, occ, alive, ecnt, srcs, version)`` over GLOBAL
    arrays — ``w``/``occ`` sharded ``P(axis, None)`` (a ``ShardedTileView``),
    vertex arrays replicated, ``srcs`` replicated for bfs/sssp and sharded
    ``P(axis)`` for bc (length must divide the axis size; the host wrappers
    pad with -1).  Cached per (mesh, kind, tile, use_kernel, src_chunk).
    """
    ax = _axis(mesh)
    vspec, rspec = P(ax, None), P()
    if kind == "bfs":
        def body(w, occ, alive, ecnt, srcs, version):
            return _bfs_body(w, occ, alive, ecnt, srcs, version, ax=ax,
                             tile=tile, use_kernel=use_kernel)
        src_spec = rspec
        out_specs = (rspec, rspec, rspec, rspec)
    elif kind == "sssp":
        def body(w, occ, alive, ecnt, srcs, version):
            return _sssp_body(w, occ, alive, ecnt, srcs, version, ax=ax,
                              tile=tile, use_kernel=use_kernel)
        src_spec = rspec
        out_specs = (rspec, rspec, rspec, rspec, rspec)
    elif kind == "bc":
        def body(w, occ, alive, ecnt, srcs, version):
            return _bc_body(w, occ, alive, ecnt, srcs, version, ax=ax,
                            tile=tile, use_kernel=use_kernel,
                            src_chunk=src_chunk)
        src_spec = P(ax)
        out_specs = (P(ax), vspec, vspec, vspec, rspec, rspec, rspec)
    else:
        raise ValueError(f"unknown query kind {kind!r}; "
                         "supported kinds: bfs, sssp, bc")
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(vspec, vspec, rspec, rspec, src_spec, rspec),
        out_specs=out_specs,
        check_rep=False,
    )
    return jax.jit(fn)


def query_shardings(mesh: Mesh, kind: str):
    """(in_shardings, out_shardings) matching ``query_fn`` — what an AOT
    ``jit(fn, in_shardings=...).lower`` (``launch/dryrun.py``) needs."""
    ax = _axis(mesh)
    v = NamedSharding(mesh, P(ax, None))
    r = NamedSharding(mesh, P())
    s = NamedSharding(mesh, P(ax))
    if kind == "bc":
        return (v, v, r, r, s, r), (s, v, v, v, r, r, r)
    if kind not in ("bfs", "sssp"):
        raise ValueError(f"unknown query kind {kind!r}; "
                         "supported kinds: bfs, sssp, bc")
    return (v, v, r, r, r, r), (r,) * (4 if kind == "bfs" else 5)


def _srcs_array(srcs, n_shards: int = 1, pad_to_shards: bool = False):
    srcs = jnp.atleast_1d(jnp.asarray(srcs, jnp.int32))
    if pad_to_shards:
        rem = (-srcs.shape[0]) % n_shards
        if rem:
            srcs = jnp.concatenate(
                [srcs, jnp.full((rem,), -1, jnp.int32)])
    return srcs


def bfs(view: ShardedTileView, state: GraphState, srcs, *,
        use_kernel: bool = False) -> ShardedBFSResult:
    """Distributed multi-source BFS; ``dist`` is sliced back to ``vcap``."""
    srcs = _srcs_array(srcs)
    fn = query_fn(view.mesh, "bfs", view.tile, use_kernel)
    ok, dist, val_ecnt, agree = fn(view.w, view.occ, state.alive, state.ecnt,
                                   srcs, state.version)
    return ShardedBFSResult(ok, dist[:, :state.vcap], val_ecnt, agree)


def sssp(view: ShardedTileView, state: GraphState, srcs, *,
         use_kernel: bool = False) -> ShardedSSSPResult:
    """Distributed multi-source Bellman-Ford with negative-cycle flags."""
    srcs = _srcs_array(srcs)
    fn = query_fn(view.mesh, "sssp", view.tile, use_kernel)
    ok, neg, dist, val_ecnt, agree = fn(view.w, view.occ, state.alive,
                                        state.ecnt, srcs, state.version)
    return ShardedSSSPResult(ok, neg, dist[:, :state.vcap], val_ecnt, agree)


def bc_batched(view: ShardedTileView, state: GraphState, srcs=None, *,
               use_kernel: bool = False,
               src_chunk: int | None = None) -> ShardedBCResult:
    """Distributed batched Brandes, source axis sharded over the mesh.

    ``srcs`` defaults to every vertex slot (exact all-sources BC); it is
    padded with -1 up to a multiple of the shard count (dead padding
    contributes nothing) and the padding is sliced back off the returned
    per-source arrays, which stay sharded ``P(axis, None)``.
    """
    if srcs is None:
        srcs = jnp.arange(state.vcap, dtype=jnp.int32)
    n_srcs = jnp.atleast_1d(jnp.asarray(srcs)).shape[0]
    srcs = _srcs_array(srcs, view.n_shards, pad_to_shards=True)
    fn = query_fn(view.mesh, "bc", view.tile, use_kernel, src_chunk)
    ok, delta, sigma, level, scores, val_ecnt, agree = fn(
        view.w, view.occ, state.alive, state.ecnt, srcs, state.version)
    vcap = state.vcap
    return ShardedBCResult(ok[:n_srcs], delta[:n_srcs, :vcap],
                           sigma[:n_srcs, :vcap], level[:n_srcs, :vcap],
                           scores, val_ecnt, agree)
