"""Sharded tile-grid engine: multi-device tile-sparse graph analytics.

The tile-sparse semiring path (``repro.core.tiles`` + ``repro.kernels``)
partitioned over a 1-D logical graph mesh axis: tile *rows* -> shards, so
each device owns a contiguous band of source vertices plus that band's
occupancy grid, and BFS/SSSP/BC run as ``shard_map`` programs — local
tile-skipping semiring work, one vcap-sized collective per level.
"""
from .tile_shard import (  # noqa: F401
    GRAPH_AXIS,
    REFRESH_BATCH,
    ShardedTileView,
    as_graph_mesh,
    build_sharded_view,
    gather_view,
    refresh_sharded_view,
    refresh_stats,
    sharded_occupancy_stats,
)
from .queries import (  # noqa: F401
    BC_MODES,
    ShardedBCResult,
    ShardedBFSResult,
    ShardedSSSPResult,
    bc_batched,
    bfs,
    delta_bc_sharded,
    delta_bfs_sharded,
    delta_sssp_sharded,
    query_fn,
    query_shardings,
    sssp,
    validate_incremental_sharded,
)
from .service import ShardedGraphService  # noqa: F401
