"""ShardedGraphService: the streaming front end on a device mesh.

Shares :class:`repro.engine.service.BaseGraphService` with the local
``GraphService`` — updates enter through the
:class:`~repro.engine.scheduler.StreamScheduler` and commit into a
:class:`~repro.engine.version_ring.VersionRing`; queries are answered from
the ring with per-``(kind, sources)`` caches, the PG-Icn / PG-Cn collect
loops, the LRU cache pruning, and the mode counters all written once in
the base — but every collect here runs distributed ``shard_map`` programs
over the sharded tile grid, and the grid itself is maintained
incrementally per shard (``refresh_sharded_view`` re-derives only the
dirty tile rows named by the ring's dirty sets).

Each collect climbs the same *unchanged → delta → full* ladder as the
local engine:

  * **unchanged** — churn since the cached answer never touched its
    reached region: the cached result stands with zero device work;
  * **delta** — the engine's poison + re-relax path on the mesh
    (``shard.queries.delta_*_sharded``): the poison pointer-doubling runs
    unsharded over the replicated prior parent arrays, the re-relax
    warm-starts the sharded level loop from the keep set, and BC resumes
    its per-source level-cut warm start from the cached forward trees.
    Guarded like ``engine.incremental``'s ``_prior_usable``: the prior
    must match the current vertex table (and be negative-cycle-free for
    SSSP), the dirty span must be within the ring window and under
    ``dirty_threshold``; a delta SSSP that detects a new negative cycle
    re-runs the full query for the canonical answer;
  * **full** — the distributed fixed point.

Consistency modes match the paper at batch granularity: ``"icn"`` single
collect; ``"cn"`` double collect across ring versions until two answers
match.  Each collect additionally carries the psum-validated cross-shard
version agreement (``result.agree``) — the intra-query half of the
paper's double-collect check, spanning shards instead of time.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.graph_state import GraphState
from repro.core.tiles import TILE
from repro.engine.incremental import _dirty_stats
from repro.engine.service import BaseGraphService, QueryReply  # noqa: F401
from repro.engine.service import ServiceStats  # noqa: F401  (re-export)
from repro.engine.service import ThresholdSpec
from repro.obs import Telemetry
from repro.obs.trace import annotate as _trace_annotate
from repro.obs.trace import maybe_span
from repro.resil.faults import P_COLLECT_DELTA, P_COLLECT_DISPATCH, \
    InjectedCrash, inject
from repro.resil.policy import ResiliencePolicy

from . import queries as shard_queries
from .tile_shard import (
    ShardedTileView,
    as_graph_mesh,
    refresh_sharded_view,
    refresh_stats,
)

_QUERIES = {"bfs": shard_queries.bfs, "sssp": shard_queries.sssp,
            "bc": shard_queries.bc_batched}
_DELTA = {"bfs": shard_queries.delta_bfs_sharded,
          "sssp": shard_queries.delta_sssp_sharded,
          "bc": shard_queries.delta_bc_sharded}


def _reached_union(kind: str, result) -> jax.Array:
    """bool[vcap]: union over sources of the query's reached region."""
    if kind == "bfs":
        return (result.dist >= 0).any(axis=0)
    if kind == "sssp":
        return (result.dist < jnp.inf).any(axis=0)
    return (result.level >= 0).any(axis=0)


class ShardedGraphService(BaseGraphService):
    """submit()/query() front end over the sharded tile grid.

    ``bc_mode`` picks the adjacency strategy of every BC collect (full and
    delta): ``"gather"`` all-gathers the row bands per query (O(Vp^2)
    per-shard memory, kept as the oracle path), ``"ring"`` SUMMA-rotates
    the O(Vp^2/n) bands with ``lax.ppermute`` — see
    ``shard.queries.bc_batched``.  Levels/sigma (hence the delta ladder's
    cuts) are bit-identical across modes; scores agree to f32 summation
    order.
    """

    _kinds = ("bfs", "sssp", "bc")
    _service_name = "sharded"

    def __init__(self, initial_state: GraphState, mesh: Mesh, *,
                 tile: int = TILE, use_kernel: bool = False,
                 src_chunk: Optional[int] = None, bc_mode: str = "gather",
                 ring_depth: int = 8, batch_size: int = 32,
                 dirty_threshold: ThresholdSpec = None,
                 strict_order: bool = False,
                 coalesce: bool = False, max_collects: int = 16,
                 max_cached: int = 128,
                 telemetry: Optional[Telemetry] = None,
                 policy: Optional[ResiliencePolicy] = None,
                 journal=None, monitor=None, adaptive=None, breaker=None,
                 compact_every: Optional[int] = None):
        shard_queries._bc_kind(bc_mode, delta=False)  # validate up front
        self.mesh = as_graph_mesh(mesh)
        self.tile = tile
        self.use_kernel = use_kernel
        self.src_chunk = src_chunk
        self.bc_mode = bc_mode
        self._init_service(
            initial_state, ring_depth=ring_depth, batch_size=batch_size,
            dirty_threshold=dirty_threshold, strict_order=strict_order,
            coalesce=coalesce, max_collects=max_collects,
            max_cached=max_cached, telemetry=telemetry, policy=policy,
            journal=journal, monitor=monitor, adaptive=adaptive,
            breaker=breaker, compact_every=compact_every)
        self._view: Optional[ShardedTileView] = None
        self._view_version: int = -1

    # ------------------------------- view --------------------------------

    def view(self) -> ShardedTileView:
        """The sharded tile grid at the latest version, refreshed per shard
        from the ring's dirty sets (full rebuild on resize / window loss)."""
        entry = self.ring.latest
        if self._view is not None and self._view_version == entry.version:
            return self._view
        dirty = None
        if self._view is not None:
            dirty = self.ring.dirty_between(self._view_version, entry.version)
        tracer = self.telemetry.tracer if self.telemetry is not None else None
        rows0, disp0 = refresh_stats.rows, refresh_stats.dispatches
        with maybe_span(tracer, "tile_refresh", service=self._service_name,
                        full=(self._view is None or dirty is None)) as sp:
            self._view = refresh_sharded_view(entry.state, self._view, dirty,
                                              mesh=self.mesh, tile=self.tile)
            sp.set(version=entry.version,
                   rows=refresh_stats.rows - rows0,
                   dispatches=refresh_stats.dispatches - disp0)
        self._view_version = entry.version
        return self._view

    # ------------------------------ queries ------------------------------

    def _key(self, kind: str, srcs) -> Tuple[str, tuple]:
        if srcs is None:
            return kind, ("all",)
        arr = np.atleast_1d(np.asarray(srcs))
        return kind, tuple(int(s) for s in arr)

    def _check_srcs(self, kind: str, srcs) -> None:
        if srcs is None and kind != "bc":
            raise ValueError(f"{kind!r} needs explicit sources")

    def _icn_validated(self, result) -> bool:
        return bool(result.agree)

    def _delta_usable(self, kind: str, prior, state: GraphState) -> bool:
        """The sharded ``_prior_usable``: same-vcap prior whose cached
        payload the delta path can certify (SSSP additionally: converged,
        i.e. no prior negative cycle).  Per-source ``ok`` flips are fine —
        a source that died poisons its whole tree, one that was dead
        re-relaxes cold, and a BC source that turned suspect restarts at
        cut 0."""
        if kind == "bc":
            return prior.level.shape[1] == state.vcap
        if prior.dist.shape[1] != state.vcap:
            return False
        return kind == "bfs" or not bool(prior.negcycle.any())

    def _revived_source(self, prior, srcs, state: GraphState) -> bool:
        """True when a source that was NOT ok at prior time is alive now.

        Such a source's cached row is empty, so no dirty vertex can
        intersect it — invisible to both the unchanged test and the level
        cut — yet the row must be recomputed (the delta paths restart it
        cold once this forces them past the unchanged shortcut).
        Conservative for SSSP, where ``ok`` also folds in the negative-
        cycle flag: a cached negcycle answer is re-collected every time.
        """
        idx = (jnp.arange(prior.ok.shape[0], dtype=jnp.int32) if srcs is None
               else jnp.atleast_1d(jnp.asarray(srcs, jnp.int32)))
        alive_now = (state.alive[jnp.clip(idx, 0, state.vcap - 1)]
                     & (idx >= 0) & (idx < state.vcap))
        return bool((~prior.ok & alive_now).any())

    def _collect(self, kind: str, srcs, key, ladder: bool = True):
        """One collect against the latest ring version, climbing the
        unchanged → delta → full ladder (see module docstring).

        ``ladder=False`` (a resilience retry) pins the latest version and
        dispatches the full distributed query directly — no cache read,
        no dirty-set math — so a failed delta path cannot poison the
        retry."""
        if not ladder:
            entry = self.ring.latest
            with self.ring.pin(entry.version):
                res = self._full_collect(kind, srcs, entry.state)
            self._cache_store(key, entry.version, res)
            return entry, res, "full"
        entry = self.ring.latest
        state = entry.state
        slot = self._cache.get(key)
        mode, res = "full", None
        # A tripped breaker quarantines the cached prior: no unchanged
        # shortcut, no dirty-set math, no delta dispatch — the clean
        # full path answers until half-open probes succeed.
        use_prior = slot is not None and self._breaker_allows(kind)
        try:
            if use_prior:
                prior = slot.result
                if slot.version == entry.version:
                    mode, res = "unchanged", prior
                else:
                    dirty = self.ring.dirty_between(slot.version,
                                                    entry.version)
                    union = _reached_union(kind, prior)
                    if dirty is not None and union.shape[0] == state.vcap:
                        n_dirty, touched = (int(x) for x in
                                            _dirty_stats(union, dirty))
                        frac = n_dirty / state.vcap
                        _trace_annotate(dirty=n_dirty,
                                        dirty_frac=round(frac, 6))
                        self._note_dirty_frac(frac)
                        if not touched and self._revived_source(prior, srcs,
                                                                state):
                            touched = True
                        if not touched:
                            mode, res = "unchanged", prior
                        elif (frac <= self._threshold(kind)
                              and self._delta_usable(kind, prior, state)):
                            mode, res = "delta", self._delta_collect(
                                kind, prior, dirty, srcs, state)
                            if res is None:  # new negcycle: canonical full
                                mode, res = "full", None
            if res is None:
                res = self._full_collect(kind, srcs, state)
        except InjectedCrash:
            raise
        except Exception:
            # conservative attribution: any failure while a usable prior
            # was in play counts against the kind's delta path
            if use_prior:
                self._breaker_failure(kind)
            raise
        if use_prior:
            self._breaker_success(kind, mode)
        self._cache_store(key, entry.version, res)
        return entry, res, mode

    def _full_collect(self, kind: str, srcs, state: GraphState):
        """Dispatch the full distributed query (the ladder's bottom rung)."""
        inject(P_COLLECT_DISPATCH)
        acct = self._acct_begin()
        res = _QUERIES[kind](
            self.view(), state, srcs,
            **(self._bc_kwargs() if kind == "bc" else {}),
            use_kernel=self.use_kernel, accountant=acct)
        self._acct_charge(acct)
        return res

    def _bc_kwargs(self) -> dict:
        return {"src_chunk": self.src_chunk, "bc_mode": self.bc_mode}

    def _delta_collect(self, kind: str, prior, dirty, srcs,
                       state: GraphState):
        """Run the distributed delta query; ``None`` = fall back to full
        (delta SSSP surfaced a negative cycle born since the prior)."""
        inject(P_COLLECT_DELTA)
        view = self.view()
        acct = self._acct_begin()
        if kind == "bc":
            res = _DELTA[kind](view, state, prior, dirty, srcs,
                               use_kernel=self.use_kernel, accountant=acct,
                               **self._bc_kwargs())
            self._acct_charge(acct)
            return res
        res = _DELTA[kind](view, state, prior, dirty, srcs,
                           use_kernel=self.use_kernel, accountant=acct)
        self._acct_charge(acct)
        if kind == "sssp" and bool(res.negcycle.any()):
            return None
        return res

    # --------------------------- batched analytics ------------------------

    def bc_scores(self):
        """Exact all-vertex betweenness centrality at the latest version via
        the distributed batched-Brandes path; dead slots are NaN.  Cached
        through the regular query cache (kind ``"bc"``, all sources), so a
        localized commit pays only the level-cut delta sweep."""
        reply = self.query("bc", None)
        state = self.ring.latest.state
        scores = jnp.where(state.alive, reply.result.scores, jnp.nan)
        return scores, reply.version
