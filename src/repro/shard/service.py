"""ShardedGraphService: the streaming front end on a device mesh.

Mirrors :class:`repro.engine.service.GraphService` semantics — updates
enter through the :class:`~repro.engine.scheduler.StreamScheduler` and
commit into a :class:`~repro.engine.version_ring.VersionRing`; queries are
answered from the ring with per-``(kind, sources)`` caches and the
*unchanged* shortcut (churn that never touches a cached query's reached
region returns the cached answer with zero device work) — but every full
collect is a distributed ``shard_map`` program over the sharded tile grid,
and the grid itself is maintained incrementally per shard
(``refresh_sharded_view`` re-derives only the dirty tile rows named by the
ring's dirty sets).

Consistency modes match the paper at batch granularity:

  * ``"icn"`` — single collect against the latest commit;
  * ``"cn"``  — double collect across ring versions until two answers
    match, with pending update batches committing between collects.  Each
    collect additionally carries the psum-validated cross-shard version
    agreement (``result.agree``) — the intra-query half of the paper's
    double-collect check, spanning shards instead of time.

There is no delta path here (the sharded queries are full fixed points);
the mode split is unchanged/full, which is where most of the paper's
selectivity win lives anyway.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.graph_state import GraphState
from repro.core.snapshot import ScanStats
from repro.core.tiles import TILE
from repro.engine.incremental import results_equal
from repro.engine.scheduler import StreamScheduler
from repro.engine.service import QueryReply, ServiceStats, prune_result_cache
from repro.engine.version_ring import PinnedSnapshot, VersionRing

from . import queries as shard_queries
from .tile_shard import (
    ShardedTileView,
    as_graph_mesh,
    build_sharded_view,
    refresh_sharded_view,
)

_QUERIES = {"bfs": shard_queries.bfs, "sssp": shard_queries.sssp,
            "bc": shard_queries.bc_batched}


@dataclass
class _Slot:
    version: int
    result: object


def _reached_union(kind: str, result) -> jax.Array:
    """bool[vcap]: union over sources of the query's reached region."""
    if kind == "bfs":
        return (result.dist >= 0).any(axis=0)
    if kind == "sssp":
        return (result.dist < jnp.inf).any(axis=0)
    return (result.level >= 0).any(axis=0)


class ShardedGraphService:
    """submit()/query() front end over the sharded tile grid."""

    def __init__(self, initial_state: GraphState, mesh: Mesh, *,
                 tile: int = TILE, use_kernel: bool = False,
                 src_chunk: Optional[int] = None, ring_depth: int = 8,
                 batch_size: int = 32, strict_order: bool = False,
                 coalesce: bool = False, max_collects: int = 16,
                 max_cached: int = 128):
        self.mesh = as_graph_mesh(mesh)
        self.tile = tile
        self.use_kernel = use_kernel
        self.src_chunk = src_chunk
        self.ring = VersionRing(initial_state, depth=ring_depth)
        self.scheduler = StreamScheduler(
            self.ring, batch_size=batch_size, strict_order=strict_order,
            coalesce=coalesce)
        self.max_collects = max_collects
        self.max_cached = max_cached
        self.stats = ServiceStats()
        self._cache: Dict[Tuple[str, tuple], _Slot] = {}
        self._view: Optional[ShardedTileView] = None
        self._view_version: int = -1

    # ------------------------------ updates ------------------------------

    def submit(self, op: Tuple) -> int:
        return self.scheduler.submit(op)

    def submit_many(self, ops: Sequence[Tuple]) -> list:
        return self.scheduler.submit_many(ops)

    def flush(self):
        return self.scheduler.flush()

    @property
    def version(self) -> int:
        return self.ring.latest.version

    def pin(self, version: Optional[int] = None) -> PinnedSnapshot:
        return self.ring.pin(version)

    # ------------------------------- view --------------------------------

    def view(self) -> ShardedTileView:
        """The sharded tile grid at the latest version, refreshed per shard
        from the ring's dirty sets (full rebuild on resize / window loss)."""
        entry = self.ring.latest
        if self._view is not None and self._view_version == entry.version:
            return self._view
        dirty = None
        if self._view is not None:
            dirty = self.ring.dirty_between(self._view_version, entry.version)
        self._view = refresh_sharded_view(entry.state, self._view, dirty,
                                          mesh=self.mesh, tile=self.tile)
        self._view_version = entry.version
        return self._view

    # ------------------------------ queries ------------------------------

    def _key(self, kind: str, srcs) -> Tuple[str, tuple]:
        if srcs is None:
            return kind, ("all",)
        arr = np.atleast_1d(np.asarray(srcs))
        return kind, tuple(int(s) for s in arr)

    def _collect(self, kind: str, srcs, key):
        """One collect against the latest ring version: unchanged shortcut
        first, full distributed query otherwise."""
        entry = self.ring.latest
        slot = self._cache.get(key)
        mode, res = "full", None
        if slot is not None:
            if slot.version == entry.version:
                mode, res = "unchanged", slot.result
            else:
                dirty = self.ring.dirty_between(slot.version, entry.version)
                union = _reached_union(kind, slot.result)
                if (dirty is not None and union.shape[0] == entry.state.vcap
                        and not bool((dirty & union).any())):
                    mode, res = "unchanged", slot.result
        if mode == "full":
            res = _QUERIES[kind](
                self.view(), entry.state, srcs,
                **({"src_chunk": self.src_chunk} if kind == "bc" else {}),
                use_kernel=self.use_kernel)
        self._cache.pop(key, None)
        self._cache[key] = _Slot(entry.version, res)
        self._prune_cache()
        return entry, res, mode

    def _prune_cache(self) -> None:
        prune_result_cache(self._cache, self.max_cached,
                           self.ring.oldest_version - 1)

    def query(self, kind: str, srcs=None, mode: str = "icn") -> QueryReply:
        """Answer one distributed analytics query.

        ``kind``: ``"bfs"`` | ``"sssp"`` | ``"bc"``; ``srcs`` is an int or
        a sequence of sources (``None`` = all vertex slots, BC only).
        ``mode``: ``"icn"`` (single collect) or ``"cn"`` (double collect).
        """
        if kind not in _QUERIES:
            raise KeyError(f"unknown query kind {kind!r}")
        if mode not in ("icn", "cn"):
            raise ValueError(f"unknown mode {mode!r}")
        if srcs is None and kind != "bc":
            raise ValueError(f"{kind!r} needs explicit sources")
        self.stats.queries += 1
        key = self._key(kind, srcs)
        if mode == "icn":
            entry, res, qmode = self._collect(kind, srcs, key)
            self.stats.collects += 1
            self.stats.count(qmode)
            return QueryReply(res, entry.version, qmode, bool(res.agree),
                              ScanStats(collects=1, validated=False))
        return self._query_cn(kind, srcs, key)

    def _query_cn(self, kind: str, srcs, key) -> QueryReply:
        """PG-Cn: double-collect over ring versions until answers match,
        with one pending update batch committing between collects.  Kept
        in lockstep with ``GraphService._query_cn`` (the collect return
        shapes differ; change both together)."""
        scan = ScanStats()
        v0 = self.ring.latest.version
        entry, prev_res, qmode = self._collect(kind, srcs, key)
        scan.collects = 1
        while scan.collects < self.max_collects:
            self.scheduler.commit_one()
            cur_entry, cur_res, cur_mode = self._collect(kind, srcs, key)
            scan.collects += 1
            if cur_entry.version == entry.version or results_equal(
                    prev_res, cur_res):
                self.stats.collects += scan.collects
                self.stats.count(cur_mode)
                scan.interrupting_updates = cur_entry.version - v0
                scan.validated = True
                return QueryReply(cur_res, cur_entry.version, cur_mode,
                                  True, scan)
            self.stats.cn_retries += 1
            entry, prev_res, qmode = cur_entry, cur_res, cur_mode
        scan.validated = False
        scan.interrupting_updates = self.ring.latest.version - v0
        self.stats.collects += scan.collects
        self.stats.count(qmode)
        return QueryReply(prev_res, entry.version, qmode, False, scan)

    # --------------------------- batched analytics ------------------------

    def bc_scores(self):
        """Exact all-vertex betweenness centrality at the latest version via
        the distributed batched-Brandes path; dead slots are NaN.  Cached
        through the regular query cache (kind ``"bc"``, all sources)."""
        reply = self.query("bc", None)
        state = self.ring.latest.state
        scores = jnp.where(state.alive, reply.result.scores, jnp.nan)
        return scores, reply.version
