"""Resilient serving: deterministic faults, degrade ladder, WAL recovery.

The paper's core guarantee — a stalled or failed operation never corrupts
shared state or blocks other readers — needs a *failure story* to be
testable.  This package supplies it, in four pieces the serving stack
(`repro.engine` / `repro.shard`) threads through its hot paths:

  * :mod:`repro.resil.faults` — seeded deterministic fault injection at
    named points (``inject``/``FaultPlan``/``fault_scope``): every
    failure mode is a replayable schedule, not a flake;
  * :mod:`repro.resil.policy` — per-query deadline + bounded retry where
    each retry demotes down the ladder (delta failed → full from a
    pinned snapshot → last cached answer flagged ``degraded=True`` at a
    still-resident ``stale_version``), plus :class:`CircuitBreaker`
    fault domains: consecutive delta-collect failures trip a kind's
    ladder to ``full`` until half-open probes restore it;
  * :mod:`repro.resil.journal` — append-only JSONL op WAL with commit
    barriers, segment rotation, and snapshot compaction (the validated
    checkpoint is the truncation barrier); ``recover()`` restores the
    snapshot and replays the tail into a bit-identical ring latest, with
    batch commits atomic across any crash point;
  * :mod:`repro.resil.invariants` — ``verify_service()``: ring
    monotonicity, pin/parked and cache consistency, stats conservation —
    run after every injected fault in the chaos suites.
"""
from .faults import (  # noqa: F401
    FAULT_POINTS,
    P_CACHE_STORE,
    P_COLLECT_DELTA,
    P_COLLECT_DISPATCH,
    P_JOURNAL_BARRIER,
    P_JOURNAL_TORN,
    P_OBS_SINK,
    P_RING_EVICT,
    P_SCHED_APPLY,
    P_SCHED_RING_COMMIT,
    P_SERVE_DISPATCH,
    FaultPlan,
    InjectedCrash,
    InjectedFault,
    active_plan,
    fault_scope,
    inject,
)
from .invariants import assert_service_ok, verify_service  # noqa: F401
from .journal import (  # noqa: F401
    JOURNAL_SCHEMA,
    JournalError,
    OpJournal,
    journal_meta,
    read_journal,
    read_journal_versions,
    recover,
    segment_files,
    snapshot_dir,
)
from .policy import CircuitBreaker, ResiliencePolicy  # noqa: F401
