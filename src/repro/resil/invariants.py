"""Post-fault service invariant checker.

``verify_service`` inspects a (local or sharded) graph service and
returns every violated invariant as a human-readable string — the empty
list is the pass.  The chaos harness runs it after every injected fault:
whatever an operation failure did, the *service* must still satisfy

  * **ring monotonicity** — the window holds consecutive versions, the
    latest is the newest, dirty masks are sized to their states;
  * **pin/parked consistency** — every parked entry is still pinned,
    never duplicated in the window, pin counts are positive;
  * **cache servability** — no result-cache slot claims a version newer
    than the ring latest (a slot *older* than the window is legal: it
    merely can't serve unchanged/delta/stale hits);
  * **stats conservation** — ``unchanged + delta + full == queries``
    (queries are counted only on collect success; degraded replies and
    errors tally separately) and the scheduler's
    ``ops_submitted == ops_committed + pending`` ledger;
  * **ring/scheduler agreement** — the ring version equals the number of
    batches the scheduler committed (commits are the only writers).

Checks are read-only and cheap (no device work), so tests can afford one
after every single injected fault.
"""
from __future__ import annotations

from typing import List

__all__ = ["assert_service_ok", "verify_service"]


def verify_service(svc) -> List[str]:
    """Every violated invariant of ``svc`` (see module docstring)."""
    problems: List[str] = []
    ring = svc.ring
    window = list(ring._window)

    # ----------------------------- ring ---------------------------------
    if not window:
        problems.append("ring window is empty")
        return problems
    for prev, cur in zip(window, window[1:]):
        if cur.version != prev.version + 1:
            problems.append(
                f"ring versions not consecutive: {prev.version} -> "
                f"{cur.version}")
    if ring.latest.version != window[-1].version:
        problems.append("ring.latest is not the newest window entry")
    if len(window) > ring.depth:
        problems.append(
            f"ring window {len(window)} exceeds depth {ring.depth}")
    for e in window:
        if e.dirty.shape[0] != e.state.vcap:
            problems.append(
                f"version {e.version}: dirty mask {e.dirty.shape[0]} != "
                f"vcap {e.state.vcap}")

    # ------------------------- pins / parked -----------------------------
    window_versions = {e.version for e in window}
    for v, count in ring._pins.items():
        if count < 1:
            problems.append(f"pin count {count} for version {v}")
    for v, entry in ring._parked.items():
        if v not in ring._pins:
            problems.append(f"parked version {v} has no pin")
        if v in window_versions:
            problems.append(f"parked version {v} also resident in window")
        if entry.version != v:
            problems.append(
                f"parked entry keyed {v} carries version {entry.version}")

    # ------------------------------ cache --------------------------------
    latest = ring.latest.version
    for key, slot in getattr(svc, "_cache", {}).items():
        if slot.version > latest:
            problems.append(
                f"cache slot {key} claims future version {slot.version} "
                f"(latest {latest})")
        if slot.version < 0:
            problems.append(f"cache slot {key} has version {slot.version}")
        if slot.result is None:
            problems.append(f"cache slot {key} holds no result")

    # ------------------------------ stats --------------------------------
    st = svc.stats
    if st.unchanged + st.delta + st.full != st.queries:
        problems.append(
            f"mode conservation broken: unchanged={st.unchanged} + "
            f"delta={st.delta} + full={st.full} != queries={st.queries}")
    if st.collects < st.queries:
        problems.append(
            f"collects {st.collects} < successful queries {st.queries}")
    for f in ("errors", "degraded", "retries"):
        v = getattr(st, f)
        if v < 0:
            problems.append(f"stats.{f} = {v} < 0")

    # ---------------------------- scheduler ------------------------------
    sched = svc.scheduler
    ss = sched.stats
    if ss.ops_submitted != ss.ops_committed + sched.pending():
        problems.append(
            f"op ledger broken: submitted={ss.ops_submitted} != "
            f"committed={ss.ops_committed} + pending={sched.pending()}")
    if ring.latest.version != ss.batches_committed:
        problems.append(
            f"ring version {ring.latest.version} != batches committed "
            f"{ss.batches_committed}")

    # ------------------------------ journal ------------------------------
    journal = getattr(sched, "journal", None)
    if journal is not None:
        if journal.depth != sched.pending():
            problems.append(
                f"journal depth {journal.depth} != scheduler pending "
                f"{sched.pending()} (write-ahead ledger out of step)")
        for f in ("rotations", "compactions", "segments_dropped"):
            v = getattr(journal, f, 0)
            if v < 0:
                problems.append(f"journal.{f} = {v} < 0")

    # ------------------------------ breaker ------------------------------
    breaker = getattr(svc, "breaker", None)
    if breaker is not None:
        snap = breaker.snapshot()
        valid = {breaker.CLOSED, breaker.OPEN, breaker.HALF_OPEN}
        for kind, state in snap["states"].items():
            if state not in valid:
                problems.append(
                    f"breaker[{kind}] in unknown state {state!r}")
        if snap["trips"] < 0 or snap["restores"] < 0:
            problems.append(
                f"breaker counters negative: trips={snap['trips']} "
                f"restores={snap['restores']}")
        if snap["restores"] > snap["trips"]:
            problems.append(
                f"breaker restored {snap['restores']} times but only "
                f"tripped {snap['trips']}")
        for kind, n in snap["consecutive_failures"].items():
            if not (0 <= n < breaker.fail_threshold):
                problems.append(
                    f"breaker[{kind}] consecutive failures {n} outside "
                    f"[0, {breaker.fail_threshold})")
    return problems


def assert_service_ok(svc) -> None:
    """Raise ``AssertionError`` listing every violated invariant."""
    problems = verify_service(svc)
    assert not problems, "; ".join(problems)
