"""Crash-consistent op journal (WAL) for the streaming scheduler.

The scheduler's op log is in-memory only: a process crash loses every
uncommitted op, and — worse — leaves no record of *which* batches made it
into the ring.  :class:`OpJournal` is the durable twin: an append-only
JSONL file the :class:`~repro.engine.scheduler.StreamScheduler` writes

  * one ``op`` record per ``submit()`` (write-ahead: the intent is on
    disk before the op enters the in-memory log), and
  * one ``commit`` barrier per committed batch, written only AFTER the
    ring append succeeded — the barrier is the durability point.

Because the scheduler always commits a *prefix* of its log (strict-order
cuts included), a barrier needs only the raw op count of its chunk; the
journal therefore replays into exactly the batch boundaries the original
process cut, and :func:`recover` rebuilds a service whose ring latest is
**bit-identical** (``apply_ops`` is deterministic) with the un-barriered
tail ops back in the pending log.  Batch commits are atomic against
recovery: a crash anywhere between the first op of a batch and its
barrier yields a recovered ring WITHOUT that batch and a pending log
WITH it — all-or-nothing, never a torn prefix.

A torn final line (the classic crash-mid-write) is tolerated: JSONL is
self-synchronizing at newlines, so recovery parses up to the last
complete record and treats the fragment as never written.  Torn or
unparsable *interior* lines mean real corruption and raise
:class:`JournalError` — silently skipping history would un-order the
stream.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from .faults import P_JOURNAL_BARRIER, P_JOURNAL_TORN, InjectedCrash, \
    active_plan, inject

__all__ = ["JOURNAL_SCHEMA", "JournalError", "OpJournal", "read_journal",
           "recover"]

#: bump when the record layout changes; readers reject unknown majors.
JOURNAL_SCHEMA = 1


class JournalError(RuntimeError):
    """Unrecoverable journal corruption (torn interior line, bad schema,
    barrier counting more ops than were journaled)."""


class OpJournal:
    """Append-only JSONL WAL: ``meta`` header, ``op`` records, ``commit``
    barriers.  ``sync=True`` fsyncs every barrier (durability against OS
    crash, not just process crash) at the obvious latency cost."""

    def __init__(self, path: str, *, meta: Optional[dict] = None,
                 sync: bool = False):
        self.path = str(path)
        self.sync = sync
        self.ops_logged = 0
        self.barriers_logged = 0
        self.ops_barriered = 0
        fresh = not (os.path.exists(self.path)
                     and os.path.getsize(self.path) > 0)
        self._f = open(self.path, "a")
        if fresh:
            self._write({"t": "meta", "schema": JOURNAL_SCHEMA,
                         **(meta or {})})

    def _write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())

    def append_op(self, seq: int, op: Sequence) -> None:
        """Write-ahead one ``(kind, u[, v[, w]])`` request."""
        self._write({"t": "op", "seq": int(seq), "op": list(op)})
        self.ops_logged += 1

    def commit_barrier(self, version: int, n_ops: int) -> None:
        """Durability point of one committed batch of ``n_ops`` raw ops.

        Carries two injected crash points: ``journal.barrier`` (die with
        the barrier unwritten — the batch must roll back on recovery) and
        ``journal.torn`` (die mid-write, half a record on disk — recovery
        must shrug the fragment off)."""
        inject(P_JOURNAL_BARRIER)
        line = json.dumps({"t": "commit", "version": int(version),
                           "ops": int(n_ops)})
        plan = active_plan()
        if plan is not None and plan.check(P_JOURNAL_TORN):
            self._f.write(line[:max(1, len(line) // 2)])
            self._f.flush()
            raise InjectedCrash(P_JOURNAL_TORN,
                                plan.hits[P_JOURNAL_TORN] - 1)
        self._f.write(line + "\n")
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())
        self.barriers_logged += 1
        self.ops_barriered += int(n_ops)

    @property
    def depth(self) -> int:
        """Ops written ahead but not yet covered by a commit barrier — the
        replay exposure if the process died right now (the ``journal_depth``
        gauge on the OpenMetrics exposition)."""
        return max(0, self.ops_logged - self.ops_barriered)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_journal(path: str) -> Tuple[Dict, List[List[tuple]], List[tuple]]:
    """Parse a journal into ``(meta, committed_batches, pending_ops)``.

    A torn FINAL line is treated as never written; torn interior lines
    raise :class:`JournalError`.  Each committed batch is the exact raw
    (pre-coalesce) chunk its barrier covered, in commit order.
    """
    with open(path) as f:
        raw = f.read()
    lines = raw.split("\n")
    # a complete journal ends with "\n" -> last split element is ""; any
    # trailing fragment is a torn final record, dropped here
    if lines and lines[-1] != "":
        lines = lines[:-1]
    lines = [ln for ln in lines if ln]
    meta: Dict = {}
    pending: List[tuple] = []
    batches: List[List[tuple]] = []
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            if i == len(lines) - 1:
                break  # torn final line despite its newline: ignore
            raise JournalError(f"{path}:{i + 1}: torn interior record: {e}")
        t = rec.get("t")
        if t == "meta":
            if rec.get("schema") != JOURNAL_SCHEMA:
                raise JournalError(
                    f"{path}: schema {rec.get('schema')} != {JOURNAL_SCHEMA}")
            meta = {k: v for k, v in rec.items() if k not in ("t", "schema")}
        elif t == "op":
            pending.append(tuple(rec["op"]))
        elif t == "commit":
            n = int(rec["ops"])
            if n > len(pending):
                raise JournalError(
                    f"{path}:{i + 1}: barrier covers {n} ops but only "
                    f"{len(pending)} are journaled")
            batches.append(pending[:n])
            pending = pending[n:]
        else:
            raise JournalError(f"{path}:{i + 1}: unknown record type {t!r}")
    return meta, batches, pending


def recover(path: str, initial_state, *, make_service=None, **service_kwargs):
    """Replay a journal into a fresh service: bit-identical ring latest.

    ``initial_state`` must be the same :class:`GraphState` the journaled
    service started from (the journal records ops, not base state), and
    ``service_kwargs`` must reproduce the scheduler configuration
    (``batch_size`` / ``strict_order`` / ``coalesce``) — recovery
    cross-checks both against the journal's ``meta`` header when the
    writer recorded them.  Committed batches re-commit through the same
    scheduler pipeline (identical coalescing, identical ring versions);
    un-barriered tail ops land back in the pending log, uncommitted.
    Pass ``journal=OpJournal(new_path)`` in ``service_kwargs`` to resume
    journaling: the replay is re-logged into the new journal.
    """
    if make_service is None:
        from repro.engine import GraphService as make_service
    meta, batches, pending = read_journal(path)
    svc = make_service(initial_state, **service_kwargs)
    sched = svc.scheduler
    for key, got in (("vcap", initial_state.vcap),
                     ("ecap", initial_state.ecap),
                     ("batch_size", sched.batch_size),
                     ("strict_order", sched.strict_order),
                     ("coalesce", sched.coalesce)):
        want = meta.get(key)
        if want is not None and want != got:
            raise JournalError(
                f"{path}: journal written with {key}={want}, recovering "
                f"with {key}={got}")
    for chunk in batches:
        sched.replay_commit(chunk)
    sched.replay_pending(pending)
    return svc


def journal_meta(initial_state, scheduler_kwargs: dict) -> dict:
    """The ``meta`` header a service should stamp: enough to cross-check
    a recovery's configuration."""
    return {"vcap": int(initial_state.vcap), "ecap": int(initial_state.ecap),
            **{k: scheduler_kwargs[k] for k in
               ("batch_size", "strict_order", "coalesce")
               if k in scheduler_kwargs}}
