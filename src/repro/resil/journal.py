"""Crash-consistent op journal (WAL) with segment rotation + compaction.

The scheduler's op log is in-memory only: a process crash loses every
uncommitted op, and — worse — leaves no record of *which* batches made it
into the ring.  :class:`OpJournal` is the durable twin: an append-only
JSONL file the :class:`~repro.engine.scheduler.StreamScheduler` writes

  * one ``op`` record per ``submit()`` (write-ahead: the intent is on
    disk before the op enters the in-memory log), and
  * one ``commit`` barrier per committed batch, written only AFTER the
    ring append succeeded — the barrier is the durability point.

Because the scheduler always commits a *prefix* of its log (strict-order
cuts included), a barrier needs only the raw op count of its chunk; the
journal therefore replays into exactly the batch boundaries the original
process cut, and :func:`recover` rebuilds a service whose ring latest is
**bit-identical** (``apply_ops`` is deterministic) with the un-barriered
tail ops back in the pending log.  Batch commits are atomic against
recovery: a crash anywhere between the first op of a batch and its
barrier yields a recovered ring WITHOUT that batch and a pending log
WITH it — all-or-nothing, never a torn prefix.

A torn final line (the classic crash-mid-write) is tolerated: JSONL is
self-synchronizing at newlines, so recovery parses up to the last
complete record and treats the fragment as never written.  Torn or
unparsable *interior* lines mean real corruption and raise
:class:`JournalError` — silently skipping history would un-order the
stream.

**Segment rotation** bounds any single file: with ``segment_bytes`` set,
the active file is sealed as ``<path>.NNNNNN`` once it crosses the
threshold — only ever at a barrier boundary with no un-barriered ops
outstanding, so every sealed segment ends with a ``commit`` record
covering all its ops and is replayable in isolation.  Readers
concatenate sealed segments (in index order) with the active file; only
the very last file may end in a torn line.

**Snapshot compaction** bounds the whole log: :meth:`OpJournal.compact`
writes the ring's latest committed state through the checkpoint store's
manifest-last atomic protocol into ``<path>.ckpt`` — the double-collect
validated snapshot *is* the truncation barrier — then deletes every
sealed segment whose last commit version the snapshot covers.  The
ordering is crash-safe: the snapshot is durable (manifest renamed)
before any segment is unlinked, and a crash mid-compaction merely
leaves covered segments behind for the next compaction (recovery skips
their batches by version).  :func:`recover` then becomes
snapshot-restore + replay-of-tail: O(tail), not O(history).
"""
from __future__ import annotations

import json
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

from .faults import P_JOURNAL_BARRIER, P_JOURNAL_TORN, InjectedCrash, \
    active_plan, inject

__all__ = ["JOURNAL_SCHEMA", "JournalError", "OpJournal", "read_journal",
           "read_journal_versions", "recover", "segment_files",
           "snapshot_dir"]

#: bump when the record layout changes; readers reject unknown majors.
JOURNAL_SCHEMA = 1

_SEG_RE = re.compile(r"\.(\d{6})$")


def snapshot_dir(path: str) -> str:
    """Where :meth:`OpJournal.compact` puts the truncation snapshot."""
    return str(path) + ".ckpt"


def segment_files(path: str) -> List[Tuple[int, str]]:
    """Sealed segments of ``path``, as sorted ``(index, filepath)``."""
    d = os.path.dirname(path) or "."
    base = os.path.basename(path)
    out = []
    if not os.path.isdir(d):
        return out
    for name in os.listdir(d):
        if name.startswith(base + "."):
            m = _SEG_RE.search(name[len(base):])
            if m:
                out.append((int(m.group(1)), os.path.join(d, name)))
    return sorted(out)


class JournalError(RuntimeError):
    """Unrecoverable journal corruption (torn interior line, bad schema,
    barrier counting more ops than were journaled, replay gap)."""


class OpJournal:
    """Append-only JSONL WAL: ``meta`` header, ``op`` records, ``commit``
    barriers.  ``sync=True`` fsyncs every barrier (durability against OS
    crash, not just process crash) at the obvious latency cost.
    ``segment_bytes`` enables rotation: once the active file crosses the
    threshold it is sealed as a numbered segment at the next barrier
    boundary with no un-barriered ops outstanding."""

    def __init__(self, path: str, *, meta: Optional[dict] = None,
                 sync: bool = False, segment_bytes: Optional[int] = None):
        self.path = str(path)
        self.sync = sync
        self.segment_bytes = segment_bytes
        self.meta = dict(meta or {})
        self.ops_logged = 0
        self.barriers_logged = 0
        self.ops_barriered = 0
        self.rotations = 0
        self.compactions = 0
        self.segments_dropped = 0
        segs = segment_files(self.path)
        self._seg_idx = (segs[-1][0] + 1) if segs else 0
        fresh = not (os.path.exists(self.path)
                     and os.path.getsize(self.path) > 0)
        self._f = open(self.path, "a")
        # conservatively assume a reopened non-empty active file holds
        # history worth sealing at the next rotation opportunity
        self._commits_in_active = 0 if fresh else 1
        if fresh:
            self._write({"t": "meta", "schema": JOURNAL_SCHEMA, **self.meta})

    def _write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())

    def append_op(self, seq: int, op: Sequence) -> None:
        """Write-ahead one ``(kind, u[, v[, w]])`` request."""
        self._write({"t": "op", "seq": int(seq), "op": list(op)})
        self.ops_logged += 1

    def commit_barrier(self, version: int, n_ops: int) -> None:
        """Durability point of one committed batch of ``n_ops`` raw ops.

        Carries two injected crash points: ``journal.barrier`` (die with
        the barrier unwritten — the batch must roll back on recovery) and
        ``journal.torn`` (die mid-write, half a record on disk — recovery
        must shrug the fragment off)."""
        inject(P_JOURNAL_BARRIER)
        line = json.dumps({"t": "commit", "version": int(version),
                           "ops": int(n_ops)})
        plan = active_plan()
        if plan is not None and plan.check(P_JOURNAL_TORN):
            self._f.write(line[:max(1, len(line) // 2)])
            self._f.flush()
            raise InjectedCrash(P_JOURNAL_TORN,
                                plan.hits[P_JOURNAL_TORN] - 1)
        self._f.write(line + "\n")
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())
        self.barriers_logged += 1
        self.ops_barriered += int(n_ops)
        self._commits_in_active += 1
        self._maybe_rotate()

    @property
    def depth(self) -> int:
        """Ops written ahead but not yet covered by a commit barrier — the
        replay exposure if the process died right now (the ``journal_depth``
        gauge on the OpenMetrics exposition)."""
        return max(0, self.ops_logged - self.ops_barriered)

    # ---------------------------- rotation ----------------------------

    def _maybe_rotate(self) -> None:
        if self.segment_bytes is None or self.depth != 0:
            return
        try:
            size = self._f.tell()
        except ValueError:          # closed file; nothing to rotate
            return
        if size >= self.segment_bytes:
            self.rotate()

    def rotate(self) -> bool:
        """Seal the active file as the next numbered segment and start a
        fresh one (with its own ``meta`` header).  Only legal — and only
        attempted — when every logged op is barrier-covered, so sealed
        segments are always replayable in isolation.  Returns False when
        there is nothing to seal (no commits in the active file)."""
        if self.depth != 0:
            raise JournalError(
                f"{self.path}: cannot rotate with {self.depth} "
                f"un-barriered ops outstanding")
        if self._commits_in_active == 0:
            return False
        self._f.close()
        seg = f"{self.path}.{self._seg_idx:06d}"
        os.replace(self.path, seg)
        self._seg_idx += 1
        self.rotations += 1
        self._f = open(self.path, "a")
        self._commits_in_active = 0
        self._write({"t": "meta", "schema": JOURNAL_SCHEMA,
                     "segment": self._seg_idx - 1, **self.meta})
        return True

    # --------------------------- compaction ---------------------------

    def compact(self, state, version: int, *,
                extra: Optional[dict] = None) -> dict:
        """Snapshot ``state`` (the ring latest at ``version``) and drop
        every sealed segment the snapshot covers.

        The snapshot goes through the checkpoint store's manifest-last
        atomic rename — it is durable *before* any segment is unlinked,
        so a crash at any point leaves a recoverable journal (at worst
        with covered-but-undeleted segments, reclaimed next compaction).
        ``extra`` rides the manifest verbatim (e.g. learned thresholds,
        the op ledger) and is handed back to :func:`recover`.  Returns a
        report dict for telemetry/benchmarks."""
        from repro.checkpoint import save_checkpoint
        version = int(version)
        ckpt = snapshot_dir(self.path)
        if self.depth == 0:
            self.rotate()       # seal covered history so it can be dropped
        save_checkpoint(ckpt, version, state, version=version, extra=extra)
        # GC superseded snapshot steps (the new manifest + index are
        # already committed, so older steps are dead weight)
        for name in os.listdir(ckpt):
            if name.startswith("step_") and int(name.split("_")[1]) != version:
                d = os.path.join(ckpt, name)
                for fn in os.listdir(d):
                    os.remove(os.path.join(d, fn))
                os.rmdir(d)
        dropped = kept = 0
        for _idx, seg in segment_files(self.path):
            last = _segment_last_version(seg)
            if last is not None and last <= version:
                os.remove(seg)
                dropped += 1
            else:
                kept += 1
        self.compactions += 1
        self.segments_dropped += dropped
        step_dir = os.path.join(ckpt, f"step_{version:08d}")
        snap_bytes = sum(os.path.getsize(os.path.join(step_dir, fn))
                         for fn in os.listdir(step_dir))
        return {"version": version, "snapshot_bytes": int(snap_bytes),
                "segments_dropped": dropped, "segments_kept": kept,
                "snapshot_dir": ckpt}

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _segment_last_version(seg_path: str) -> Optional[int]:
    """Highest commit version in a sealed segment (None: no commits —
    which a rotation never produces, so treat as not-coverable)."""
    last = None
    with open(seg_path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue        # corruption surfaces loudly at read time
            if rec.get("t") == "commit":
                last = int(rec["version"])
    return last


def _parse_lines(path: str, lines: List[str], *, meta: Dict,
                 pending: List[tuple], batches: List[Tuple[int, List[tuple]]],
                 tolerate_torn_final: bool) -> None:
    for i, line in enumerate(lines):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            if tolerate_torn_final and i == len(lines) - 1:
                break  # torn final line despite its newline: ignore
            raise JournalError(f"{path}:{i + 1}: torn interior record: {e}")
        t = rec.get("t")
        if t == "meta":
            if rec.get("schema") != JOURNAL_SCHEMA:
                raise JournalError(
                    f"{path}: schema {rec.get('schema')} != {JOURNAL_SCHEMA}")
            if not meta:        # first header wins; later segments repeat it
                meta.update({k: v for k, v in rec.items()
                             if k not in ("t", "schema", "segment")})
        elif t == "op":
            pending.append(tuple(rec["op"]))
        elif t == "commit":
            n = int(rec["ops"])
            if n > len(pending):
                raise JournalError(
                    f"{path}:{i + 1}: barrier covers {n} ops but only "
                    f"{len(pending)} are journaled")
            batches.append((int(rec["version"]), pending[:n]))
            del pending[:n]
        else:
            raise JournalError(f"{path}:{i + 1}: unknown record type {t!r}")


def read_journal_versions(
        path: str) -> Tuple[Dict, List[Tuple[int, List[tuple]]], List[tuple]]:
    """Parse a (possibly rotated) journal into
    ``(meta, [(version, batch), ...], pending_ops)``.

    Sealed segments are read in index order, then the active file.  A
    torn FINAL line of the LAST file is treated as never written; torn
    interior lines (any file) raise :class:`JournalError`.  Each batch is
    the exact raw (pre-coalesce) chunk its barrier covered, tagged with
    the ring version that barrier committed.
    """
    files = [seg for _idx, seg in segment_files(path)]
    if os.path.exists(path):
        files.append(path)
    if not files:
        raise FileNotFoundError(path)
    meta: Dict = {}
    pending: List[tuple] = []
    batches: List[Tuple[int, List[tuple]]] = []
    for fi, fpath in enumerate(files):
        with open(fpath) as f:
            raw = f.read()
        lines = raw.split("\n")
        # a complete file ends with "\n" -> last split element is ""; any
        # trailing fragment is a torn final record, dropped (last file only)
        is_last = fi == len(files) - 1
        if lines and lines[-1] != "":
            if not is_last:
                raise JournalError(
                    f"{fpath}: sealed segment ends in a torn record")
            lines = lines[:-1]
        lines = [ln for ln in lines if ln]
        _parse_lines(fpath, lines, meta=meta, pending=pending,
                     batches=batches, tolerate_torn_final=is_last)
    return meta, batches, pending


def read_journal(path: str) -> Tuple[Dict, List[List[tuple]], List[tuple]]:
    """Parse a journal into ``(meta, committed_batches, pending_ops)``.

    Compatibility wrapper over :func:`read_journal_versions` (which also
    reports each batch's committed ring version).
    """
    meta, vbatches, pending = read_journal_versions(path)
    return meta, [chunk for _v, chunk in vbatches], pending


def _restore_snapshot(ckpt_dir: str, step: int):
    """Load the compaction snapshot: ``(GraphState, version, extra)``.

    The pytree skeleton comes from an empty 1-vertex graph; leaf shapes
    and dtypes come from the manifest, so the snapshot dictates capacity.
    """
    import jax

    from repro.checkpoint import read_manifest, restore_checkpoint
    from repro.checkpoint.checkpointer import _path_str
    from repro.core.graph_state import make_graph

    manifest = read_manifest(ckpt_dir, step)
    flat, treedef = jax.tree_util.tree_flatten_with_path(make_graph(1, 1))
    like = []
    for p, _leaf in flat:
        entry = manifest["leaves"][_path_str(p)]
        like.append(jax.ShapeDtypeStruct(tuple(entry["shape"]),
                                         entry["dtype"]))
    tree_like = jax.tree_util.tree_unflatten(treedef, like)
    state = restore_checkpoint(ckpt_dir, step, tree_like)
    return state, int(manifest["version"]), manifest.get("extra") or {}


def _rebase(svc, version: int, extra: dict) -> None:
    """Rewrite the fresh service's ring base entry to the snapshot
    version and seed the scheduler ledger, so invariants
    (``ring.latest.version == batches_committed``,
    ``ops_submitted == ops_committed + pending``) hold across the elided
    history.  Learned thresholds riding the snapshot are restored too."""
    ring = svc.ring
    ring._window[0] = ring._window[0]._replace(version=int(version))
    ss = svc.scheduler.stats
    ss.batches_committed += int(version)
    n = int(extra.get("ops_committed", 0))
    ss.ops_submitted += n
    ss.ops_committed += n
    adaptive = getattr(svc, "adaptive", None)
    thr = extra.get("adaptive_thresholds")
    if adaptive is not None and thr:
        adaptive.restore(thr)


def recover(path: str, initial_state=None, *, make_service=None,
            **service_kwargs):
    """Rebuild a service from a journal: bit-identical ring latest.

    With a compaction snapshot present (``<path>.ckpt``), recovery is
    snapshot-restore + replay-of-tail: the validated snapshot seeds the
    ring (rebased to the snapshot version), only batches committed after
    it replay, and ``initial_state`` may be omitted entirely.  Without a
    snapshot, ``initial_state`` must be the same :class:`GraphState` the
    journaled service started from and the full history replays.

    ``service_kwargs`` must reproduce the scheduler configuration
    (``batch_size`` / ``strict_order`` / ``coalesce``) — recovery
    cross-checks them against the journal's ``meta`` header when the
    writer recorded them.  Committed batches re-commit through the same
    scheduler pipeline (identical coalescing, identical ring versions),
    with a version-continuity check so a missing segment fails loudly;
    un-barriered tail ops land back in the pending log, uncommitted.

    ``make_service`` builds the service from ``(state, **service_kwargs)``
    — pass a closure binding a live mesh to recover a
    :class:`~repro.shard.service.ShardedGraphService`.  Pass
    ``journal=OpJournal(new_path)`` in ``service_kwargs`` to resume
    journaling: the tail replay is re-logged, and when recovery started
    from a snapshot the restored base is immediately re-compacted into
    the new journal so the new WAL is self-contained.
    """
    if make_service is None:
        from repro.engine import GraphService as make_service
    meta, vbatches, pending = read_journal_versions(path)
    snap_state = None
    snap_version = 0
    snap_extra: dict = {}
    ckpt = snapshot_dir(path)
    if os.path.isdir(ckpt):
        from repro.checkpoint import latest_step
        step = latest_step(ckpt)
        if step is not None:
            snap_state, snap_version, snap_extra = _restore_snapshot(
                ckpt, step)
    base = snap_state if snap_state is not None else initial_state
    if base is None:
        raise JournalError(
            f"{path}: no compaction snapshot and no initial_state given")
    svc = make_service(base, **service_kwargs)
    sched = svc.scheduler
    checks = [("batch_size", sched.batch_size),
              ("strict_order", sched.strict_order),
              ("coalesce", sched.coalesce)]
    if snap_state is None:
        # snapshot-restored capacities come from the manifest, which may
        # legitimately differ from the meta header's original caps
        checks = [("vcap", base.vcap), ("ecap", base.ecap)] + checks
    for key, got in checks:
        want = meta.get(key)
        if want is not None and want != got:
            raise JournalError(
                f"{path}: journal written with {key}={want}, recovering "
                f"with {key}={got}")
    if snap_state is not None:
        _rebase(svc, snap_version, snap_extra)
        new_j = getattr(sched, "journal", None)
        if new_j is not None:
            new_j.compact(svc.ring.latest.state, snap_version,
                          extra=snap_extra or None)
    for version, chunk in vbatches:
        if version <= snap_version:
            continue            # snapshot-covered (compaction raced a crash)
        want = int(svc.ring.latest.version) + 1
        if version != want:
            raise JournalError(
                f"{path}: replay gap: next batch is version {version}, "
                f"ring expects {want} (missing segment?)")
        sched.replay_commit(chunk)
    sched.replay_pending(pending)
    return svc


def journal_meta(initial_state, scheduler_kwargs: dict) -> dict:
    """The ``meta`` header a service should stamp: enough to cross-check
    a recovery's configuration."""
    return {"vcap": int(initial_state.vcap), "ecap": int(initial_state.ecap),
            **{k: scheduler_kwargs[k] for k in
               ("batch_size", "strict_order", "coalesce")
               if k in scheduler_kwargs}}
