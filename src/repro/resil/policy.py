"""Deadline / retry-ladder degradation policy for the serving stack.

The paper's posture — a failed writer never blocks a reader — becomes,
for a serving front end: a failed *collect* never takes down a query
that has anything correct left to say.  :class:`ResiliencePolicy`
parameterizes the ladder the services walk when a collect raises:

  1. the first attempt runs the normal unchanged → delta → full ladder;
  2. each retry **demotes**: the collect re-runs with the delta ladder
     disabled — a full recompute from a *pinned* snapshot of the latest
     ring version (delta failed → retry full; sharded dispatch failed →
     recompute from the pinned snapshot), after an optional exponential
     backoff;
  3. once the retry budget or the per-query deadline is exhausted, the
     service serves the last cached answer at its still-resident ring
     version, flagged ``degraded=True`` with ``stale_version`` on the
     reply — correct *at the version it claims*, never a torn read.
     With no resident cached answer, the failure propagates: there is
     nothing correct to serve, and a loud error beats a silent lie.

The policy object is pure data + arithmetic; the ladder itself lives in
:meth:`repro.engine.service.BaseGraphService._query_resilient` so both
the local and sharded services walk the identical rungs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

__all__ = ["ResiliencePolicy"]


@dataclass(frozen=True)
class ResiliencePolicy:
    """How a service degrades when collects fail or deadlines pass.

    ``deadline_ms`` bounds the *retry* budget, not the first attempt: a
    slow-but-successful first collect still returns fresh (better than
    stale); the deadline decides whether another rung is attempted.
    ``max_retries`` counts demoted re-collects after the first attempt.
    ``backoff_ms`` sleeps ``backoff_ms * backoff_factor**(attempt-1)``
    before retry ``attempt`` (keep 0 in tests).  ``allow_stale`` gates
    rung 3; with it off, an exhausted ladder re-raises the last error.
    """

    deadline_ms: float = float("inf")
    max_retries: int = 1
    backoff_ms: float = 0.0
    backoff_factor: float = 2.0
    allow_stale: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_ms < 0 or self.deadline_ms < 0:
            raise ValueError("backoff_ms / deadline_ms must be >= 0")

    def deadline_exceeded(self, t0: float) -> bool:
        """True when the budget that started at ``t0`` (perf_counter) is
        spent — no further rungs should be attempted."""
        if self.deadline_ms == float("inf"):
            return False
        return (time.perf_counter() - t0) * 1e3 >= self.deadline_ms

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based), in seconds."""
        if self.backoff_ms <= 0.0:
            return 0.0
        return (self.backoff_ms * self.backoff_factor ** (attempt - 1)) / 1e3
