"""Deadline / retry-ladder degradation policy for the serving stack.

The paper's posture — a failed writer never blocks a reader — becomes,
for a serving front end: a failed *collect* never takes down a query
that has anything correct left to say.  :class:`ResiliencePolicy`
parameterizes the ladder the services walk when a collect raises:

  1. the first attempt runs the normal unchanged → delta → full ladder;
  2. each retry **demotes**: the collect re-runs with the delta ladder
     disabled — a full recompute from a *pinned* snapshot of the latest
     ring version (delta failed → retry full; sharded dispatch failed →
     recompute from the pinned snapshot), after an optional exponential
     backoff;
  3. once the retry budget or the per-query deadline is exhausted, the
     service serves the last cached answer at its still-resident ring
     version, flagged ``degraded=True`` with ``stale_version`` on the
     reply — correct *at the version it claims*, never a torn read.
     With no resident cached answer, the failure propagates: there is
     nothing correct to serve, and a loud error beats a silent lie.

The policy object is pure data + arithmetic; the ladder itself lives in
:meth:`repro.engine.service.BaseGraphService._query_resilient` so both
the local and sharded services walk the identical rungs.

:class:`CircuitBreaker` adds the *fault-domain* dimension the ladder
lacks: the retry ladder handles one failing query, but a persistently
poisoned delta path (a bad cache line, a pathological dirty region, a
flaky collective) makes EVERY query pay the fail-then-retry tax.  The
breaker watches consecutive delta-collect failures per query kind and,
at ``fail_threshold``, **trips**: the kind's ladder is pinned at
``full`` (cached priors are quarantined, the delta path never runs), a
``ladder_pinned`` span + ``breaker_open`` gauge mark the transition,
and queries keep succeeding — bit-identical answers, just dearer.
After ``cooldown`` pinned collects the breaker goes **half-open**: the
next delta-eligible collect runs as a probe; ``probes`` consecutive
successful delta collects close the breaker (``ladder_restored`` span,
gauge back to 0), a single failure re-opens it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Tuple

__all__ = ["CircuitBreaker", "ResiliencePolicy"]


@dataclass(frozen=True)
class ResiliencePolicy:
    """How a service degrades when collects fail or deadlines pass.

    ``deadline_ms`` bounds the *retry* budget, not the first attempt: a
    slow-but-successful first collect still returns fresh (better than
    stale); the deadline decides whether another rung is attempted.
    ``max_retries`` counts demoted re-collects after the first attempt.
    ``backoff_ms`` sleeps ``backoff_ms * backoff_factor**(attempt-1)``
    before retry ``attempt`` (keep 0 in tests).  ``allow_stale`` gates
    rung 3; with it off, an exhausted ladder re-raises the last error.
    """

    deadline_ms: float = float("inf")
    max_retries: int = 1
    backoff_ms: float = 0.0
    backoff_factor: float = 2.0
    allow_stale: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_ms < 0 or self.deadline_ms < 0:
            raise ValueError("backoff_ms / deadline_ms must be >= 0")

    def deadline_exceeded(self, t0: float) -> bool:
        """True when the budget that started at ``t0`` (perf_counter) is
        spent — no further rungs should be attempted."""
        if self.deadline_ms == float("inf"):
            return False
        return (time.perf_counter() - t0) * 1e3 >= self.deadline_ms

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based), in seconds."""
        if self.backoff_ms <= 0.0:
            return 0.0
        return (self.backoff_ms * self.backoff_factor ** (attempt - 1)) / 1e3


#: query kinds the services run the ladder for (mirrors
#: ``repro.obs.adaptive.LADDER_KINDS``; kept literal so the policy layer
#: stays import-free).
BREAKER_KINDS = ("bfs", "sssp", "bc")


class CircuitBreaker:
    """Per-kind delta-path circuit breaker: closed → open → half-open.

    The services consult :meth:`allow_delta` once per collect that has a
    usable cached prior (no prior → full recompute anyway, nothing to
    gate) and report back :meth:`record_failure` (a collect raised while
    the delta path was in play) or :meth:`record_success` (a delta
    collect completed).  ``fail_threshold`` consecutive failures trip a
    kind **open**: priors are quarantined and every collect runs the
    clean full path.  After ``cooldown`` denied consults the breaker
    goes **half-open** — that consult is the probe — and ``probes``
    consecutive delta successes close it again; any half-open failure
    re-opens with a fresh cooldown.  ``bind`` attaches registry / tracer
    / service label: trips emit a ``ladder_pinned`` span + set the
    ``breaker_open`` gauge, restores emit ``ladder_restored``.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, *, fail_threshold: int = 3, cooldown: int = 4,
                 probes: int = 1,
                 kinds: Tuple[str, ...] = BREAKER_KINDS):
        if fail_threshold < 1:
            raise ValueError("fail_threshold must be >= 1")
        if cooldown < 1:
            raise ValueError("cooldown must be >= 1")
        if probes < 1:
            raise ValueError("probes must be >= 1")
        self.fail_threshold = fail_threshold
        self.cooldown = cooldown
        self.probes = probes
        self.kinds = tuple(kinds)
        self._state: Dict[str, str] = {k: self.CLOSED for k in self.kinds}
        self._consec: Dict[str, int] = {k: 0 for k in self.kinds}
        self._cool: Dict[str, int] = {k: 0 for k in self.kinds}
        self._probe_ok: Dict[str, int] = {k: 0 for k in self.kinds}
        self.trips = 0
        self.restores = 0
        self._registry = None
        self._tracer = None
        self._service = "service"

    # ------------------------------ binding ------------------------------

    def bind(self, registry, tracer, service: str) -> "CircuitBreaker":
        self._registry = registry
        self._tracer = tracer
        self._service = service
        if registry is not None:
            for k in self.kinds:
                registry.gauge("breaker_open", service=service,
                               kind=k).set(0.0)
        return self

    # ------------------------------ queries ------------------------------

    def state(self, kind: str) -> str:
        return self._state.get(kind, self.CLOSED)

    def allow_delta(self, kind: str) -> bool:
        """May this collect use its cached prior (the delta path)?

        Open breakers deny and count down the cooldown; the consult that
        exhausts it transitions to half-open and is allowed through as
        the probe."""
        st = self._state.get(kind)
        if st is None or st == self.CLOSED or st == self.HALF_OPEN:
            return True
        self._cool[kind] -= 1
        if self._cool[kind] > 0:
            return False
        self._state[kind] = self.HALF_OPEN
        self._probe_ok[kind] = 0
        return True

    # ----------------------------- reporting -----------------------------

    def record_failure(self, kind: str) -> None:
        """A collect raised while a usable prior was in play."""
        st = self._state.get(kind)
        if st == self.CLOSED:
            self._consec[kind] += 1
            if self._consec[kind] >= self.fail_threshold:
                self._trip(kind, probe_failed=False)
        elif st == self.HALF_OPEN:
            self._trip(kind, probe_failed=True)
        # open: the delta path never ran; the failure belongs to the
        # full path and says nothing about this breaker

    def record_success(self, kind: str) -> None:
        """A delta collect completed successfully."""
        st = self._state.get(kind)
        if st == self.CLOSED:
            self._consec[kind] = 0
        elif st == self.HALF_OPEN:
            self._probe_ok[kind] += 1
            if self._probe_ok[kind] >= self.probes:
                self._restore(kind)

    # ---------------------------- transitions ----------------------------

    def _trip(self, kind: str, *, probe_failed: bool) -> None:
        self._state[kind] = self.OPEN
        self._cool[kind] = self.cooldown
        self._consec[kind] = 0
        self.trips += 1
        if self._registry is not None:
            self._registry.gauge("breaker_open", service=self._service,
                                 kind=kind).set(1.0)
            self._registry.counter("breaker_trips", service=self._service,
                                   kind=kind).inc()
        if self._tracer is not None:
            with self._tracer.span("ladder_pinned", service=self._service,
                                   kind=kind) as sp:
                sp.set(failures=self.fail_threshold,
                       cooldown=self.cooldown,
                       probe_failed=bool(probe_failed))

    def _restore(self, kind: str) -> None:
        self._state[kind] = self.CLOSED
        self._consec[kind] = 0
        self.restores += 1
        if self._registry is not None:
            self._registry.gauge("breaker_open", service=self._service,
                                 kind=kind).set(0.0)
        if self._tracer is not None:
            with self._tracer.span("ladder_restored", service=self._service,
                                   kind=kind) as sp:
                sp.set(probes=self.probes)

    # ------------------------------- export ------------------------------

    def snapshot(self) -> dict:
        return {"states": dict(self._state), "trips": self.trips,
                "restores": self.restores,
                "consecutive_failures": dict(self._consec)}

    def __repr__(self):
        states = ", ".join(f"{k}={v}" for k, v in self._state.items())
        return (f"CircuitBreaker({states}, trips={self.trips}, "
                f"restores={self.restores})")
