"""Deterministic fault injection: every failure mode a replayable schedule.

The paper's non-blocking guarantee is about *failure*: a stalled or dead
operation must never corrupt shared state or block other readers.  The
functional analogue cannot test that guarantee with real crashes — so
this module makes failure a first-class, seeded input.  Hot paths call
:func:`inject` at **named fault points** (scheduler apply / ring commit,
collect dispatch, delta-ladder compute, ring eviction, result-cache
stores, journal barriers, telemetry sink IO); a :class:`FaultPlan`
activated via :func:`fault_scope` decides, deterministically, which hits
raise.  With no active plan, ``inject`` is one contextvar read — the
serving hot path pays nothing in production.

Two fault species:

  * :class:`InjectedFault` (``RuntimeError``) — a recoverable operation
    failure: the degrade ladder in ``resil.policy`` retries/demotes it,
    and schedulers/services must stay consistent around it;
  * :class:`InjectedCrash` (``BaseException``) — simulated process death
    for the journal's crash-consistency tests.  Deliberately NOT an
    ``Exception`` so retry ladders and cleanup handlers cannot swallow
    it: only the test harness (standing in for the next process
    incarnation) catches it.

Plans are either **scheduled** (``{point: [hit indices]}`` — fire on
exactly those invocations of the point) or **seeded-random** (per-point
Bernoulli streams derived from ``(seed, crc32(point))``, so the decision
sequence is independent of dict order and of PYTHONHASHSEED).  Every
decision lands in ``plan.log``; ``plan.to_schedule()`` converts whatever
a random plan fired into an explicit schedule that replays the identical
failure pattern — a chaos flake becomes a regression test in one call.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_POINTS", "FaultPlan", "InjectedCrash", "InjectedFault",
    "P_CACHE_STORE", "P_COLLECT_DELTA", "P_COLLECT_DISPATCH",
    "P_JOURNAL_BARRIER", "P_JOURNAL_TORN", "P_OBS_SINK", "P_RING_EVICT",
    "P_SCHED_APPLY", "P_SCHED_RING_COMMIT", "P_SERVE_DISPATCH",
    "active_plan", "fault_scope", "inject",
]

# ----------------------------- named points --------------------------------
#: mid-batch in the scheduler: before ``apply_ops`` runs the chunk.
P_SCHED_APPLY = "sched.apply_ops"
#: between a successful ``apply_ops`` and the ring append — the worst
#: possible commit boundary for atomicity.
P_SCHED_RING_COMMIT = "sched.ring_commit"
#: a collect's full compute dispatch (local ladder + sharded shard_map).
P_COLLECT_DISPATCH = "collect.dispatch"
#: the delta-ladder compute (a cached prior is about to be reused).
P_COLLECT_DELTA = "collect.delta"
#: ring eviction racing a query (a commit is about to rotate a version out).
P_RING_EVICT = "ring.evict"
#: result-cache slot write (a torn store must never corrupt a served slot).
P_CACHE_STORE = "cache.store"
#: journal commit barrier about to be written (crash point).
P_JOURNAL_BARRIER = "journal.barrier"
#: journal barrier torn mid-line (crash point; half the record reaches disk).
P_JOURNAL_TORN = "journal.torn"
#: telemetry JSONL sink IO.
P_OBS_SINK = "obs.sink"
#: the async front end's batched dispatch (a whole compatible-query batch
#: is about to run as one compiled call; a failure here must degrade to
#: the per-request resilient path, never lose a request).
P_SERVE_DISPATCH = "serve.dispatch"

#: every point the hot paths are wired with, for ``FaultPlan(points=...)``.
FAULT_POINTS: Tuple[str, ...] = (
    P_SCHED_APPLY, P_SCHED_RING_COMMIT, P_COLLECT_DISPATCH, P_COLLECT_DELTA,
    P_RING_EVICT, P_CACHE_STORE, P_JOURNAL_BARRIER, P_JOURNAL_TORN,
    P_OBS_SINK, P_SERVE_DISPATCH,
)

#: points that simulate process death by default (InjectedCrash).
DEFAULT_CRASH_POINTS: Tuple[str, ...] = (P_JOURNAL_BARRIER, P_JOURNAL_TORN)


class InjectedFault(RuntimeError):
    """A planned, recoverable operation failure."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected fault at {point!r} (hit {hit})")
        self.point = point
        self.hit = hit


class InjectedCrash(BaseException):
    """Simulated process death.  BaseException on purpose: recovery code
    under test must never 'handle' a crash — only the harness does."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected crash at {point!r} (hit {hit})")
        self.point = point
        self.hit = hit


class FaultPlan:
    """A deterministic schedule of which fault-point hits fail.

    ``schedule``: ``{point: iterable of 0-based hit indices}`` — those
    exact invocations fire.  ``seed``/``rate``: per-point Bernoulli
    streams over ``points`` (default: every non-crash point in
    :data:`FAULT_POINTS`).  Both can be combined; a hit fires if either
    says so.  ``max_faults`` caps total firings (chaos streams with
    retries always drain).  ``crash_points`` fire as
    :class:`InjectedCrash` instead of :class:`InjectedFault`.
    """

    def __init__(self, schedule: Optional[Dict[str, Iterable[int]]] = None,
                 *, seed: Optional[int] = None, rate: float = 0.0,
                 points: Optional[Sequence[str]] = None,
                 crash_points: Sequence[str] = DEFAULT_CRASH_POINTS,
                 max_faults: Optional[int] = None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.schedule = {p: frozenset(int(h) for h in hs)
                         for p, hs in (schedule or {}).items()}
        self.seed = seed
        self.rate = rate
        if points is None:
            points = tuple(p for p in FAULT_POINTS
                           if p not in DEFAULT_CRASH_POINTS)
        self.points = tuple(points)
        self.crash_points = frozenset(crash_points)
        self.max_faults = max_faults
        self.hits: Dict[str, int] = {}
        self.log: List[Tuple[str, int, bool]] = []
        self.fired = 0
        # One RNG stream per point, keyed by (seed, crc32(point)) so the
        # draw sequence never depends on cross-point interleaving or on
        # PYTHONHASHSEED.
        self._rngs: Dict[str, np.random.Generator] = {}
        # Concurrent serving threads share one plan (the async front end
        # runs its dispatcher in the activating thread's copied context);
        # the per-hit bookkeeping must not tear across them.
        self._lock = threading.Lock()

    def _rng(self, point: str) -> np.random.Generator:
        rng = self._rngs.get(point)
        if rng is None:
            rng = np.random.default_rng(
                [self.seed, zlib.crc32(point.encode())])
            self._rngs[point] = rng
        return rng

    def check(self, point: str) -> bool:
        """Consume one hit of ``point``; True when this hit must fail."""
        return self.consume(point) is not None

    def consume(self, point: str):
        """Consume one hit of ``point``; its hit index when it must fail,
        else ``None`` — the atomic form ``inject`` uses (the index must
        come from the same critical section that drew the decision)."""
        with self._lock:
            hit = self.hits.get(point, 0)
            self.hits[point] = hit + 1
            fire = hit in self.schedule.get(point, ())
            if (not fire and self.seed is not None and self.rate > 0.0
                    and point in self.points):
                # always draw, even past max_faults, so the stream position
                # of later hits is independent of how many already fired
                draw = float(self._rng(point).random()) < self.rate
                fire = fire or draw
            if fire and (self.max_faults is not None
                         and self.fired >= self.max_faults):
                fire = False
            self.log.append((point, hit, fire))
            if fire:
                self.fired += 1
            return hit if fire else None

    def to_schedule(self) -> Dict[str, List[int]]:
        """The explicit schedule of everything this plan fired so far —
        ``FaultPlan(plan.to_schedule())`` replays the identical pattern."""
        out: Dict[str, List[int]] = {}
        for point, hit, fired in self.log:
            if fired:
                out.setdefault(point, []).append(hit)
        return out

    def __repr__(self):
        return (f"FaultPlan(fired={self.fired}, "
                f"hits={sum(self.hits.values())}, seed={self.seed}, "
                f"rate={self.rate}, schedule={bool(self.schedule)})")


# ------------------------------ activation ---------------------------------

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_resil_fault_plan", default=None)


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE.get()


@contextlib.contextmanager
def fault_scope(plan: Optional[FaultPlan]):
    """Activate ``plan`` for the dynamic extent of the block (``None`` is
    allowed and a no-op, so callers can thread an optional plan)."""
    token = _ACTIVE.set(plan)
    try:
        yield plan
    finally:
        _ACTIVE.reset(token)


def inject(point: str) -> None:
    """Fault point: raise per the active plan; no-op (one contextvar read)
    when no plan is active."""
    plan = _ACTIVE.get()
    if plan is None:
        return
    hit = plan.consume(point)
    if hit is not None:
        if point in plan.crash_points:
            raise InjectedCrash(point, hit)
        raise InjectedFault(point, hit)
