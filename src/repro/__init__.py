"""repro: PANIGRAHAM-JAX — consistent non-blocking dynamic-graph operations
(Chatterjee, Peri, Sa — CS.DC 2020) rebuilt as a multi-pod JAX framework,
plus the assigned LM architecture zoo sharing the same distributed substrate.
"""

__version__ = "0.1.0"
