from .checkpointer import (  # noqa: F401
    Checkpointer, latest_step, read_manifest, save_checkpoint,
    restore_checkpoint,
)
