from .checkpointer import (  # noqa: F401
    Checkpointer, latest_step, save_checkpoint, restore_checkpoint,
)
