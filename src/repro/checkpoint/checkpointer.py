"""Versioned checkpoint store with PANIGRAHAM-style snapshot validation.

This is where the paper's technique genuinely generalizes to the LM stack:

  * every save commits a new **version** (monotonic counter) and writes the
    manifest LAST, atomically (tmp + rename) — a manifest is the analogue of
    a committed graph state; leaves written before the manifest rename are
    invisible, like nodes CAS-linked but not yet reachable;
  * a restore performs the paper's **double collect**: read manifest ->
    load leaves -> re-read manifest; if the version moved, a concurrent
    writer raced the read and the restore retries.  The loaded tree is thus
    a *validated consistent snapshot* even with an async writer — exactly
    SCAN/CMPTREE on files;
  * per-leaf checksums play the role of ``ecnt``: a leaf rewritten in place
    between the two manifest reads is detected even if the version check is
    defeated (e.g. clock-skewed writers on shared storage).

**Elastic resharding**: leaves are stored as full (unsharded) arrays keyed by
tree path; ``restore_checkpoint(..., mesh, specs)`` re-places them under ANY
mesh/sharding — restarting 512-chip training on 256 chips (or 2 pods on 1)
is a restore, not a migration.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import time
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out) or "_root"


def _leaf_files(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {(_path_str(path)): leaf for path, leaf in leaves}


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def save_checkpoint(ckpt_dir: str, step: int, tree, *, version: int,
                    verify: bool = False, extra: Optional[dict] = None) -> dict:
    """Write one checkpoint; returns the manifest.

    ``extra`` is an optional JSON-serializable dict stored verbatim in the
    manifest (and thus committed atomically with it) — side-car state that
    must travel with the snapshot, e.g. learned serving thresholds.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    manifest = {"step": step, "version": version, "leaves": {},
                "time": time.time()}
    if extra:
        manifest["extra"] = extra
    for name, leaf in _leaf_files(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", ".") + ".npy"
        np.save(os.path.join(d, fn), arr)
        entry = {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        if verify:
            entry["sha1"] = _checksum(arr)
        manifest["leaves"][name] = entry
    # manifest last + atomic rename = the commit point (linearization point)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(d, "manifest.json"))
    _update_index(ckpt_dir, step, version)
    return manifest


def _update_index(ckpt_dir: str, step: int, version: int) -> None:
    idx_path = os.path.join(ckpt_dir, "index.json")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump({"latest_step": step, "version": version}, f)
    os.replace(tmp, idx_path)


def latest_step(ckpt_dir: str) -> Optional[int]:
    idx_path = os.path.join(ckpt_dir, "index.json")
    if not os.path.exists(idx_path):
        return None
    with open(idx_path) as f:
        return json.load(f)["latest_step"]


def _read_manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The committed manifest of one step (the atomically-renamed file)."""
    return _read_manifest(ckpt_dir, step)


def restore_checkpoint(ckpt_dir: str, step: int, tree_like, *,
                       mesh: Optional[Mesh] = None, specs=None,
                       verify: bool = False, max_retries: int = 8):
    """Double-collect validated restore; reshards onto ``mesh``/``specs``.

    ``tree_like`` supplies the pytree structure (arrays or SDS).
    """
    for _ in range(max_retries):
        m1 = _read_manifest(ckpt_dir, step)
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        loaded = {}
        ok = True
        for name, entry in m1["leaves"].items():
            arr = np.load(os.path.join(d, entry["file"]))
            if verify and "sha1" in entry and _checksum(arr) != entry["sha1"]:
                ok = False          # leaf changed under us (ecnt mismatch)
                break
            loaded[name] = arr
        m2 = _read_manifest(ckpt_dir, step)
        if ok and m2["version"] == m1["version"]:
            break                    # CMPTREE matched: consistent snapshot
    else:
        raise RuntimeError("checkpoint kept changing during restore")

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    spec_leaves = None
    if specs is not None:
        spec_leaves = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda s: isinstance(s, P))[0]
    out = []
    for i, (path, like) in enumerate(flat):
        arr = loaded[_path_str(path)].astype(like.dtype)
        if mesh is not None and spec_leaves is not None:
            arr = jax.device_put(arr, NamedSharding(mesh, spec_leaves[i]))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class Checkpointer:
    """Async checkpointer: saves on a background thread so the train loop
    never blocks on disk (the non-blocking-update half of the paper's dial),
    with version counters shared with the restore-side validation."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.version = 0
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree, blocking: bool = False):
        self.version += 1
        version = self.version
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree, version=version)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_"))
        for s in steps[:-self.keep]:
            d = os.path.join(self.ckpt_dir, f"step_{s:08d}")
            for f in os.listdir(d):
                os.remove(os.path.join(d, f))
            os.rmdir(d)

    def restore_latest(self, tree_like, mesh=None, specs=None):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, None
        tree = restore_checkpoint(self.ckpt_dir, step, tree_like,
                                  mesh=mesh, specs=specs)
        return step, tree
