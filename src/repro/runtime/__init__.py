from .fault_tolerance import HeartbeatMonitor, RestartableLoop  # noqa: F401
