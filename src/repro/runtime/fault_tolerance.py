"""Fault tolerance: restartable step loop, heartbeat / straggler detection.

At 1000+ nodes the *expected* state is that something is failing.  The
posture here:

  * **Checkpoint/restart** — `RestartableLoop` wraps any step function with
    periodic async checkpoints and resume-from-latest; a crash (or SIGTERM
    preemption) anywhere re-enters at the last committed version with
    deterministic data (see data/pipeline.py).
  * **Straggler detection** — `HeartbeatMonitor` keeps a rolling window of
    latencies; a measurement slower than ``factor`` x the rolling median
    raises a straggler flag.  Its primary consumer is the serving stack:
    pass one as ``StreamScheduler(monitor=...)`` (or the ``monitor=``
    kwarg of either graph service) and it watches **commit latency** —
    a slow ``apply_ops``/ring append flags the commit, bumps the
    ``scheduler_stragglers`` counter, and annotates the commit's trace
    span with ``straggler=True``.  The training loop below wires the
    same monitor around its step function.  On a real fleet the flag
    feeds the cluster scheduler (recreate the slow host / shrink the
    mesh); the *elastic restart* path it would trigger is exactly the
    mesh-resharding restore in checkpoint/ (tested in tests/test_checkpoint).
  * **Elastic scaling** — nothing in the checkpoint format mentions the
    mesh: restore onto more/fewer chips = `restore_checkpoint(mesh=new)`.
"""
from __future__ import annotations

import signal
import statistics
import time
from collections import deque
from typing import Callable, Optional

from repro.checkpoint import Checkpointer


class HeartbeatMonitor:
    """Rolling-median latency watchdog (``start()``/``stop(step)`` around
    each unit of work).  ``stop`` returns the measured seconds and, once
    the window has >= 8 samples, counts/calls back on measurements over
    ``factor`` x the median."""

    def __init__(self, window: int = 32, factor: float = 3.0,
                 on_straggler: Optional[Callable] = None):
        self.window = deque(maxlen=window)
        self.factor = factor
        self.on_straggler = on_straggler
        self.stragglers = 0
        self._t0 = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int):
        dt = time.perf_counter() - self._t0
        if len(self.window) >= 8:
            med = statistics.median(self.window)
            if dt > self.factor * med:
                self.stragglers += 1
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.window.append(dt)
        return dt


class RestartableLoop:
    """Run ``state = step_fn(state, step_idx)`` with checkpoint/restart.

    ``state`` must be a pytree (params, opt, ...).  Preemption (SIGTERM) and
    injected failures checkpoint-and-raise; calling ``run`` again resumes.
    """

    def __init__(self, ckpt_dir: str, step_fn, state_like,
                 ckpt_every: int = 50, mesh=None, specs=None,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.ckpt = Checkpointer(ckpt_dir)
        self.step_fn = step_fn
        self.state_like = state_like
        self.ckpt_every = ckpt_every
        self.mesh = mesh
        self.specs = specs
        self.monitor = monitor or HeartbeatMonitor()
        self._preempted = False

    def _handle_sigterm(self, *_):
        self._preempted = True

    def run(self, state, total_steps: int, start_step: int = 0,
            fail_at: Optional[int] = None):
        """Returns (final_state, last_step_done). ``fail_at`` injects a crash
        (for tests / chaos drills)."""
        prev = signal.signal(signal.SIGTERM, self._handle_sigterm)
        try:
            resume_step, restored = self.ckpt.restore_latest(
                self.state_like, self.mesh, self.specs)
            if restored is not None and resume_step >= start_step:
                state, start_step = restored, resume_step
            for step in range(start_step, total_steps):
                if fail_at is not None and step == fail_at:
                    raise RuntimeError(f"injected failure at step {step}")
                self.monitor.start()
                state = self.step_fn(state, step)
                self.monitor.stop(step)
                if (step + 1) % self.ckpt_every == 0 or self._preempted:
                    self.ckpt.save(step + 1, state)
                if self._preempted:
                    self.ckpt.wait()
                    raise SystemExit("preempted; checkpointed at step "
                                     f"{step + 1}")
            self.ckpt.save(total_steps, state, blocking=True)
            return state, total_steps
        finally:
            # drain any in-flight async checkpoint so a crash/preemption
            # always leaves a consistent latest-step index behind
            self.ckpt.wait()
            signal.signal(signal.SIGTERM, prev)
