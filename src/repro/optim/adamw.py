"""AdamW, hand-rolled for sharding control.

Moments live in ``cfg.moment_dtype`` (fp32 default; bf16 for the 400B-class
MoE where fp32 moments would not fit 16 GB/chip) and inherit the parameter
shardings — with FSDP param specs this is ZeRO-sharded optimizer state for
free.  Global-norm clipping runs in fp32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def moment_specs(param_specs):
    """Moments are sharded exactly like their parameters."""
    return param_specs


def _sumsq(x: jax.Array) -> jax.Array:
    """Sum of squares in f32 WITHOUT materializing an f32 copy of the whole
    (possibly multi-GiB stacked) leaf: scan over leading-axis slices with an
    optimization barrier so XLA cannot hoist the f32 convert out of the
    loop."""
    if x.ndim >= 3 and x.shape[0] > 1:
        def body(acc, sl):
            sl = jax.lax.optimization_barrier(sl)
            return acc + jnp.sum(jnp.square(sl.astype(jnp.float32))), None
        acc, _ = jax.lax.scan(body, jnp.float32(0), x)
        return acc
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(_sumsq(x) for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_state)."""
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_slice(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    def upd(g, m, v, p):
        # Stacked (per-layer) leaves update one slice at a time: the f32
        # working copies of a [L, ...] MoE gradient would otherwise
        # materialize whole (3.75 GiB per leaf at llama4 scale).  The
        # barrier stops XLA hoisting convert(stack) back out of the loop.
        if g.ndim >= 3 and g.shape[0] > 1:
            def body(_, args):
                return None, upd_slice(*jax.lax.optimization_barrier(args))
            _, out = jax.lax.scan(body, None, (g, m, v, p))
            return out
        return upd_slice(g, m, v, p)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
