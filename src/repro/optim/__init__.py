from .adamw import AdamWState, adamw_init, adamw_update, moment_specs  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
from .compress import (  # noqa: F401
    CompressState, compress_init, compress_grads,
)
