"""Error-feedback int8 gradient compression (distributed-optimization trick).

At 1000-node scale the cross-pod gradient all-reduce rides the slow DCN
links; int8 quantization cuts that volume 4x (bf16) / 2x (vs fp16).  Error
feedback (Seide et al., 1-bit SGD lineage) accumulates the quantization
residual locally and re-injects it next step, preserving convergence.

Usage inside train_step, *before* the optimizer:

    grads_q, comp_state = compress_grads(grads, comp_state)

In a multi-pod deployment the quantize sits before the cross-pod psum and
the dequantize after it; here the transform is applied to the already
reduced gradients, which has identical numerics for the optimizer path (the
saving itself is a wire-level property we cannot measure on one host).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    residual: dict     # error-feedback accumulator, same tree as grads


def compress_init(params) -> CompressState:
    return CompressState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))


def _quant_dequant(x: jax.Array):
    """Symmetric per-tensor int8 fake-quant. Returns (dq, err)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    dq = q.astype(jnp.float32) * scale
    return dq, xf - dq


def compress_grads(grads, state: CompressState):
    """Returns (dequantized grads, new state). Fully jittable."""
    def one(g, r):
        dq, err = _quant_dequant(g.astype(jnp.float32) + r)
        return dq.astype(g.dtype), err

    out = jax.tree.map(one, grads, state.residual)
    dq = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return dq, CompressState(residual=res)
