"""Batched serving CLI: prefill + decode with a snapshot-consistent
parameter store.

The serving loop reads parameters through the versioned checkpoint store
with double-collect validation (checkpoint/checkpointer.py) — a trainer can
commit new versions concurrently and the server hot-swaps between batches
without ever serving a torn read: the paper's SCAN/CMPTREE applied to
parameters instead of vertices.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_moe_1b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.models import get_model
from repro.checkpoint import Checkpointer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_moe_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve weights from a (possibly live) checkpoint")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    model = get_model(cfg)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        state_like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params})
        step, restored = ck.restore_latest(state_like)
        if restored is not None:
            params = restored["params"]
            print(f"[serve] loaded validated snapshot @ step {step}")

    b, pl = args.batch, args.prompt_len
    max_len = pl + args.gen
    prompts = jax.random.randint(jax.random.fold_in(key, 1), (b, pl), 1,
                                 cfg.vocab_size)
    extra = {}
    if cfg.family in ("encdec", "audio"):
        extra["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (b, cfg.encoder_seq, cfg.d_model))

    prefill = jax.jit(lambda p, t, c, **kw: model.prefill(p, t, c, **kw))
    decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c))

    cache = model.init_cache(b, max_len, dtype=jnp.float32)
    t0 = time.time()
    logits, cache = prefill(params, prompts, cache, **extra)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, toks, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            toks = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None]
        else:
            toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"[serve] prefill {pl} toks x{b}: {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen - 1} steps: "
          f"{t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/tok")
    for i in range(min(b, 2)):
        print(f"  seq{i}: {gen[i][:12].tolist()} ...")


if __name__ == "__main__":
    main()
