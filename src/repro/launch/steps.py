"""jit-able step functions: train / prefill / decode (+ their shardings)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.optim.compress import compress_grads
from . import mesh as meshlib


def build_train_step(model: Model, *, peak_lr: float = 3e-4,
                     warmup_steps: int = 100, total_steps: int = 10_000,
                     weight_decay: float = 0.1, compress: bool = False):
    def train_step(params, opt, batch, comp_state=None):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        if compress:
            grads, comp_state = compress_grads(grads, comp_state)
        lr = warmup_cosine(opt.step, peak_lr=peak_lr,
                           warmup_steps=warmup_steps,
                           total_steps=total_steps)
        params, opt = adamw_update(grads, opt, params, lr=lr,
                                   weight_decay=weight_decay)
        metrics = {"loss": loss, "lr": lr}
        if compress:
            return params, opt, comp_state, metrics
        return params, opt, metrics

    return train_step


def build_prefill_step(model: Model):
    def prefill_step(params, cache, batch):
        kw = {k: batch[k] for k in ("positions", "frames") if k in batch}
        return model.prefill(params, batch["tokens"], cache, **kw)

    return prefill_step


def build_decode_step(model: Model):
    def decode_step(params, cache, batch):
        kw = {k: batch[k] for k in ("positions",) if k in batch}
        return model.decode_step(params, batch["tokens"], cache, **kw)

    return decode_step


def train_state_shardings(model: Model, mesh, params_sds, opt_sds):
    pspecs = model.specs()
    p_sh = meshlib.sanitize_shardings(pspecs, params_sds, mesh)
    o_sh = type(opt_sds)(
        step=NamedSharding(mesh, P()),
        m=meshlib.sanitize_shardings(pspecs, opt_sds.m, mesh),
        v=meshlib.sanitize_shardings(pspecs, opt_sds.v, mesh),
    )
    return p_sh, o_sh


def cache_shardings(model: Model, mesh, cache_sds):
    specs = model.cache_specs()
    return meshlib.sanitize_shardings(specs, cache_sds, mesh)
