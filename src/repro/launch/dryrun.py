"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The FIRST two lines (before any other import, including repro) create 512
placeholder host devices so ``jax.make_mesh`` can build the production mesh
on this CPU-only container.  Never set this flag globally — smoke tests and
benchmarks must see 1 device.

Per cell this driver can produce up to three compiles:
  * full depth           -> proves it compiles + memory_analysis (fits/chip)
  * depth d1=1, d2=2     -> (single-pod only) two-point depth extrapolation
    of FLOPs / bytes / collective-bytes, because XLA's HloCostAnalysis
    visits a ``lax.scan`` body ONCE regardless of trip count (verified in
    EXPERIMENTS.md §Dry-run) — per-layer deltas x true depth recover the
    real totals.  Inner chunk loops (attention q-blocks, chunked xent) are
    python-unrolled in the model code for exactly this reason.

Results are written incrementally to experiments/dryrun/*.json; the roofline
table (benchmarks/roofline.py, EXPERIMENTS.md §Roofline) reads them.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config, shapes_for  # noqa: E402
from repro.models import get_model, input_specs  # noqa: E402
from repro.models.sharding_ctx import sharding_context  # noqa: E402
from repro.optim import adamw_init  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import steps as steplib  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the per-device HLO."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
        out.setdefault("count", 0)
        out["count"] += 1
    return out


def scale_depth(cfg, d: int, unroll: bool = True):
    """Same-architecture config with depth = d 'units' (see unit_count).

    ``unroll=True`` additionally unrolls the layer scans so HloCostAnalysis
    counts every layer — required for the two-point depth extrapolation."""
    kw = {"scan_unroll": unroll}
    if cfg.family == "hybrid":
        rem = cfg.num_layers % cfg.attn_every
        kw["num_layers"] = d * cfg.attn_every + rem
    elif cfg.family in ("encdec", "audio"):
        kw["num_layers"] = d
        kw["encoder_layers"] = d
    else:
        kw["num_layers"] = d
    return dataclasses.replace(cfg, **kw)


def unit_count(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    return cfg.num_layers


def lower_cell(cfg, shape_name: str, mesh, donate: bool = True):
    """Build + lower the right step function for one cell. Returns lowered."""
    seq, gbatch, kind = SHAPES[shape_name]
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    full_batch = kind == "train"

    with mesh, sharding_context(mesh, full_batch=full_batch):
        params_sds = jax.eval_shape(model.init, key)
        batch_sds = input_specs(cfg, shape_name, gbatch, seq)
        b_sh = meshlib.batch_shardings(batch_sds, mesh,
                                       full_batch=full_batch)

        if kind == "train":
            opt_sds = jax.eval_shape(
                lambda p: adamw_init(p, cfg.moment_dtype), params_sds)
            p_sh, o_sh = steplib.train_state_shardings(
                model, mesh, params_sds, opt_sds)
            step = steplib.build_train_step(model)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1) if donate else ())
            return fn.lower(params_sds, opt_sds, batch_sds)

        cache_len = seq
        cache_sds = jax.eval_shape(lambda: model.init_cache(gbatch, cache_len))
        c_sh = steplib.cache_shardings(model, mesh, cache_sds)
        p_sh = meshlib.sanitize_shardings(model.specs(), params_sds, mesh)
        if kind == "prefill":
            step = steplib.build_prefill_step(model)
        else:
            step = steplib.build_decode_step(model)
        fn = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                     donate_argnums=(1,) if donate else ())
        return fn.lower(params_sds, cache_sds, batch_sds)


def analyze(compiled) -> dict:
    out = {}
    try:
        ms = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(ms.argument_size_in_bytes),
            "output_bytes": int(ms.output_size_in_bytes),
            "temp_bytes": int(ms.temp_size_in_bytes),
            "alias_bytes": int(ms.alias_size_in_bytes),
        }
        out["memory"]["peak_bytes"] = (
            out["memory"]["argument_bytes"] + out["memory"]["output_bytes"]
            + out["memory"]["temp_bytes"] - out["memory"]["alias_bytes"])
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        out["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        out["cost"] = {"error": str(e)}
    try:
        out["collectives"] = parse_collective_bytes(compiled.as_text())
    except Exception as e:  # pragma: no cover
        out["collectives"] = {"error": str(e)}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             roofline: bool = True, out_dir: str = OUT_DIR) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    if shape_name not in shapes_for(cfg):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": True,
                "reason": "long_500k needs sub-quadratic attention"}
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_devices": int(mesh.devices.size),
           "units": unit_count(cfg), "skipped": False}

    t0 = time.time()
    lowered = lower_cell(cfg, shape_name, mesh)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["full"] = analyze(compiled)
    del lowered, compiled

    if roofline and not multi_pod:
        for d in (1, 2):
            t0 = time.time()
            c = lower_cell(scale_depth(cfg, d), shape_name, mesh).compile()
            rec[f"depth{d}"] = analyze(c)
            rec[f"depth{d}"]["compile_s"] = round(time.time() - t0, 1)
            del c

    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}.{shape_name}.{mesh_name}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_graph_cell(multi_pod: bool, out_dir: str = OUT_DIR,
                   vcap: int = 131072, bc_vcap: int = 16384,
                   n_sources: int = 512) -> dict:
    """The paper's own workload on the production mesh: the sharded
    tile-grid engine's distributed BFS/SSSP/BC over a Table-1-scale graph
    (131072 vertices; the tile grid shards 512 rows of the 64 GiB padded
    weight matrix per chip).  Gather-mode BC all-gathers the row bands per
    shard, so its cell compiles at a smaller vcap; ring-mode BC
    (``bc_ring``, the SUMMA band rotation) keeps per-shard adjacency at
    O(Vp^2/n) and compiles at the FULL vcap like bfs/sssp — note the grid
    pads vcap up to a multiple of tile x n_devices (8 MiB-row granularity
    at 256+ devices), so each cell records the ``vp`` it actually compiled
    at and the per-device numbers must be read against vp, not vcap.
    Collective bytes per level (the O(S x vcap) frontier merges, and the
    ring's O(Vp^2/n) band permutes) land in the ``collectives`` section
    via the HLO parser."""
    from repro.core.partition import (
        make_distributed_query, distributed_query_specs)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mesh = meshlib.make_graph_mesh(meshlib.make_production_mesh(
        multi_pod=multi_pod))
    rec = {"arch": "graph_engine", "mesh": mesh_name,
           "vcap": vcap, "bc_vcap": bc_vcap, "n_sources": n_sources,
           "n_devices": int(mesh.devices.size)}
    for query in ("bfs", "sssp", "bc", "bc_ring"):
        v = bc_vcap if query == "bc" else vcap
        fn, in_sh, _ = make_distributed_query(mesh, query)
        sds = distributed_query_specs(v, mesh, n_sources=n_sources)
        t0 = time.time()
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*sds).compile()
        rec[query] = analyze(compiled)
        rec[query]["vcap"] = v
        rec[query]["vp"] = int(sds[0].shape[0])  # padding included
        rec[query]["compile_s"] = round(time.time() - t0, 1)
        del compiled
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"graph_engine.{mesh_name}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--graph", action="store_true",
                    help="also run the graph-engine cells")
    ap.add_argument("--no-roofline", action="store_true",
                    help="skip the depth-1/2 extrapolation compiles")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        if args.graph:
            try:
                rec = run_graph_cell(mp, args.out)
                print(f"[graph_engine {'2x16x16' if mp else '16x16'}] ok")
            except Exception as e:
                failures.append(("graph", mp, repr(e)))
                traceback.print_exc()
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                fn = os.path.join(args.out, f"{arch}.{shape}.{mesh_name}.json")
                if args.skip_existing and os.path.exists(fn):
                    print(f"[{arch} {shape} {mesh_name}] cached")
                    continue
                try:
                    rec = run_cell(arch, shape, mp,
                                   roofline=not args.no_roofline,
                                   out_dir=args.out)
                    if rec.get("skipped"):
                        print(f"[{arch} {shape} {mesh_name}] SKIP "
                              f"({rec['reason']})")
                    else:
                        mem = rec["full"].get("memory", {})
                        print(f"[{arch} {shape} {mesh_name}] ok "
                              f"compile={rec['compile_s']}s "
                              f"peak/dev={mem.get('peak_bytes', 0)/2**30:.2f}GiB")
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[{arch} {shape} {mesh_name}] FAIL: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
