"""Production mesh construction and sharding-spec sanitization.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 chips per pod; the multi-pod mesh adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes: batch shards over (pod, data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_graph_mesh(mesh: Optional[Mesh] = None) -> Mesh:
    """The 1-D logical ``graph`` axis the sharded tile-grid engine
    (``repro.shard``) partitions over: every device of ``mesh`` flattened
    (all local devices when ``None``).  The graph engine always sees one
    axis regardless of the production mesh's (pod, data, model) shape."""
    from repro.shard.tile_shard import as_graph_mesh
    return as_graph_mesh(mesh)


def sanitize_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop axes that are absent from the mesh or don't divide the dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in sizes)
        while names and dim % math.prod(sizes[n] for n in names) != 0:
            names = names[:-1]
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return P(*out)


def sanitize_shardings(specs, shapes, mesh: Mesh):
    """Tree of desired P -> tree of NamedSharding, validated against mesh.

    ``shapes`` is a matching tree of arrays / ShapeDtypeStructs.
    """
    def one(spec, like):
        if spec is None:
            spec = P()
        return NamedSharding(mesh, sanitize_spec(spec, like.shape, mesh))

    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda s: isinstance(s, P) or s is None)


def batch_shardings(batch_shapes: dict, mesh: Mesh,
                    full_batch: bool = False):
    """Input batches: leading dim over the DP axes; training shards the
    batch over EVERY axis (order data, model, pod — drop-from-end keeps
    (data, model) when the pod axis doesn't divide, giving hierarchical DP
    with pod-replicated batches).  M-RoPE positions carry a leading section
    axis, so the batch dim is axis 1 there."""
    if full_batch:
        dp = tuple(a for a in ("data", "model", "pod")
                   if a in mesh.axis_names)
    else:
        dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shapes.items():
        if k == "positions":
            spec = P(None, dp)
        elif v.ndim >= 1:
            spec = P(dp)
        else:
            spec = P()
        out[k] = NamedSharding(mesh, sanitize_spec(spec, v.shape, mesh))
    return out
