"""Trainer CLI: data pipeline + model + AdamW + checkpoint/restart.

On real hardware this runs under the production mesh (``--mesh single|multi``)
with the same sharding rules the dry-run proves out; on this CPU container
use ``--reduced`` for an end-to-end run of a small same-family model:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance: checkpoints every ``--ckpt-every`` steps (async), resumes
from the latest checkpoint automatically, straggler steps are flagged by
the heartbeat monitor, data is a pure function of the step index (restart
never replays or skips tokens).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced as make_reduced
from repro.data import SyntheticTokens, shard_batch
from repro.models import get_model
from repro.models.sharding_ctx import sharding_context
from repro.optim import adamw_init
from repro.runtime import HeartbeatMonitor
from repro.checkpoint import Checkpointer
from . import mesh as meshlib
from . import steps as steplib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
        cfg = dataclasses.replace(cfg, remat=True)
    model = get_model(cfg)

    mesh = None
    if args.mesh != "none":
        mesh = meshlib.make_production_mesh(multi_pod=args.mesh == "multi")

    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=0)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = adamw_init(params, cfg.moment_dtype)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params:,} params, "
          f"{jax.device_count()} device(s)")

    step_fn = steplib.build_train_step(
        model, peak_lr=args.lr, warmup_steps=max(2, args.steps // 10),
        total_steps=args.steps, compress=args.compress_grads)
    if args.compress_grads:
        from repro.optim import compress_init
        comp = compress_init(params)
    train_step = jax.jit(step_fn)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt:
        state_like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {"params": params, "opt": opt})
        s, restored = ckpt.restore_latest(state_like)
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = s
            print(f"[train] resumed from step {start}")

    mon = HeartbeatMonitor(on_straggler=lambda s, dt, med: print(
        f"[straggler] step {s}: {dt:.3f}s vs median {med:.3f}s"))

    ctx = sharding_context(mesh, full_batch=True) if mesh else _null()
    with ctx:
        t_start = time.time()
        for step in range(start, args.steps):
            batch = shard_batch(ds.batch_at(step), mesh)
            mon.start()
            if args.compress_grads:
                params, opt, comp, metrics = train_step(params, opt, batch,
                                                        comp)
            else:
                params, opt, metrics = train_step(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            dt = mon.stop(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):8.4f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt},
                      blocking=True)
    tok_s = (args.steps - start) * args.batch * args.seq \
        / max(time.time() - t_start, 1e-9)
    print(f"[train] done: {tok_s:,.0f} tokens/s, "
          f"stragglers={mon.stragglers}")


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
