"""GraphService: the streaming update/query front end over the engine.

Ties the three engine layers together into the serving API the ROADMAP's
north star asks for:

  * updates enter through :class:`~repro.engine.scheduler.StreamScheduler`
    (``submit``), which coalesces them into fixed-size batches and commits
    each batch as a new version in the
    :class:`~repro.engine.version_ring.VersionRing`;
  * queries (``query``) are answered from the ring.  Per ``(kind, src)``
    the service caches the last answer together with the ring version it
    was computed at; the next query ORs the per-commit dirty sets since
    that version (``ring.dirty_between``) and hands prior + dirty to
    ``engine.incremental`` — most queries cost an *unchanged* check or a
    few delta relax passes instead of a full fixed point.

Consistency modes (paper section 5, at batch granularity):

  * ``"icn"`` (PG-Icn): single collect against a pinned latest snapshot —
    best-effort, maximum throughput;
  * ``"cn"`` (PG-Cn): double collect — re-run the (incremental) query on
    consecutive ring versions until two answers ``cmp_tree``-match, while
    pending update batches keep committing between collects (the paper's
    interrupting updates).  Because commits are the only writers and each
    collect reads one committed version, a repeat on an unchanged version
    matches trivially; under churn the loop pays exactly the paper's
    retry cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import queries
from repro.core.graph_state import GraphState
from repro.core.snapshot import ScanStats
from repro.core.tiles import TileView, refresh_tile_view

from .incremental import (
    incremental_bc,
    incremental_bfs,
    incremental_sssp,
    results_equal,
)
from .scheduler import StreamScheduler
from .version_ring import PinnedSnapshot, VersionRing

_INCREMENTAL = {"bfs": incremental_bfs, "sssp": incremental_sssp,
                "bc": incremental_bc}
_FULL = {"bfs": queries.bfs, "sssp": queries.sssp,
         "bc": queries.bc_dependencies}


@dataclass
class ServiceStats:
    """Per-query mode tallies: unchanged + delta + full == queries (a cn
    query is counted once, by its final collect's mode)."""

    queries: int = 0
    unchanged: int = 0
    delta: int = 0
    full: int = 0
    collects: int = 0
    cn_retries: int = 0

    def count(self, mode: str) -> None:
        if mode == "unchanged":
            self.unchanged += 1
        elif mode == "delta":
            self.delta += 1
        else:
            self.full += 1


@dataclass
class _CacheSlot:
    version: int
    result: object  # BFSResult | SSSPResult


def prune_result_cache(cache: Dict, max_cached: int, floor: int) -> None:
    """Keep a per-``(kind, src)`` result cache bounded.

    Slots whose version fell below ``floor`` (out of the ring window) can
    never serve an unchanged/delta hit, so they go first; if the cache is
    still over budget, evict in insertion order — callers keep insertion
    order LRU by delete-then-insert on every hit.  Shared by
    :class:`GraphService` and the sharded service
    (``repro.shard.service``) so eviction semantics cannot drift.
    """
    if len(cache) <= max_cached:
        return
    for key in [k for k, s in cache.items() if s.version < floor]:
        del cache[key]
    while len(cache) > max_cached:
        cache.pop(next(iter(cache)))


@dataclass
class QueryReply:
    """What ``GraphService.query`` hands back."""

    result: object          # BFSResult | SSSPResult | BCResult
    version: int            # ring version the answer is valid at
    mode: str               # "unchanged" | "delta" | "full"
    validated: bool         # True for cn-mode answers that double-collected
    scan: ScanStats = field(default_factory=ScanStats)


class GraphService:
    """submit()/query() front end: streaming updates, incremental queries."""

    def __init__(self, initial_state: GraphState, *, ring_depth: int = 8,
                 batch_size: int = 32, dirty_threshold: float = 0.25,
                 strict_order: bool = False, coalesce: bool = False,
                 max_collects: int = 16, max_cached: int = 512):
        self.ring = VersionRing(initial_state, depth=ring_depth)
        self.scheduler = StreamScheduler(
            self.ring, batch_size=batch_size, strict_order=strict_order,
            coalesce=coalesce)
        self.dirty_threshold = dirty_threshold
        self.max_collects = max_collects
        self.max_cached = max_cached
        self.stats = ServiceStats()
        self._cache: Dict[Tuple[str, int], _CacheSlot] = {}
        self._tiles: Optional[TileView] = None
        self._tiles_version: int = -1
        self._bc_scores = None  # ((version, use_kernel), scores)

    # ------------------------------ updates ------------------------------

    def submit(self, op: Tuple) -> int:
        """Enqueue one mutation; full batches auto-commit into the ring."""
        return self.scheduler.submit(op)

    def submit_many(self, ops: Sequence[Tuple]) -> list:
        return self.scheduler.submit_many(ops)

    def flush(self):
        """Commit every pending update (the tail batch is padded)."""
        return self.scheduler.flush()

    @property
    def version(self) -> int:
        return self.ring.latest.version

    def pin(self, version: Optional[int] = None) -> PinnedSnapshot:
        return self.ring.pin(version)

    # ------------------------------ queries ------------------------------

    def _collect(self, kind: str, src: int):
        """One incremental collect against the current latest ring version."""
        entry = self.ring.latest
        slot = self._cache.get((kind, src))
        prior, dirty = None, None
        if slot is not None:
            prior = slot.result
            dirty = self.ring.dirty_between(slot.version, entry.version)
        res, inc = _INCREMENTAL[kind](
            entry.state, prior, dirty, src,
            dirty_threshold=self.dirty_threshold)
        # Delete-then-insert moves the key to the back of the dict so
        # _prune_cache's front-of-dict eviction is LRU, not FIFO.
        self._cache.pop((kind, src), None)
        self._cache[(kind, src)] = _CacheSlot(entry.version, res)
        self._prune_cache()
        return entry, res, inc

    def _prune_cache(self) -> None:
        # dirty_between still has a span for slots at oldest_version - 1
        # (the first in-window commit's dirty set covers that gap), so only
        # versions strictly below that are unservable.
        prune_result_cache(self._cache, self.max_cached,
                           self.ring.oldest_version - 1)

    def query(self, kind: str, src: int, mode: str = "icn") -> QueryReply:
        """Answer one analytics query.

        ``kind``: ``"bfs"`` | ``"sssp"`` (unchanged/delta/full) or ``"bc"``
        (unchanged/full — BC has no delta path yet, but caches per
        ``(kind, src)`` with the same snapshot semantics).
        ``mode``: ``"icn"`` or ``"cn"``.
        """
        if kind not in _FULL:
            raise KeyError(f"unknown query kind {kind!r}")
        if mode not in ("icn", "cn"):
            raise ValueError(f"unknown mode {mode!r}")
        self.stats.queries += 1
        if mode == "icn":
            entry, res, inc = self._collect(kind, src)
            self.stats.collects += 1
            self.stats.count(inc.mode)
            return QueryReply(res, entry.version, inc.mode, False,
                              ScanStats(collects=1, validated=False))
        return self._query_cn(kind, src)

    def _query_cn(self, kind: str, src: int) -> QueryReply:
        """PG-Cn: double-collect over ring versions until answers match.

        Between collects, one pending update batch commits (the stream's
        interrupting updates).  Two collects at the same ring version are
        equal by construction — the functional analogue of the paper's
        CMPTREE match — so the loop terminates as soon as the collect
        window sees no interleaved commit.
        """
        scan = ScanStats()
        v0 = self.ring.latest.version
        entry, prev_res, inc0 = self._collect(kind, src)
        scan.collects = 1
        mode = inc0.mode
        while scan.collects < self.max_collects:
            self.scheduler.commit_one()  # interrupting update, if any pending
            cur_entry, cur_res, inc = self._collect(kind, src)
            scan.collects += 1
            if cur_entry.version == entry.version or results_equal(
                    prev_res, cur_res):
                self.stats.collects += scan.collects
                self.stats.count(inc.mode)
                scan.interrupting_updates = cur_entry.version - v0
                return QueryReply(cur_res, cur_entry.version, inc.mode,
                                  True, scan)
            self.stats.cn_retries += 1
            entry, prev_res, mode = cur_entry, cur_res, inc.mode
        scan.validated = False
        scan.interrupting_updates = self.ring.latest.version - v0
        self.stats.collects += scan.collects
        self.stats.count(mode)
        return QueryReply(prev_res, entry.version, mode, False, scan)

    # --------------------------- batched analytics ------------------------

    def tile_view(self) -> TileView:
        """Blocked adjacency view of the latest version, kept fresh
        incrementally: each call re-derives only the tile rows the ring's
        dirty sets say moved since the last call (full rebuild when the
        span left the ring window or the vertex table grew)."""
        entry = self.ring.latest
        if self._tiles is not None and self._tiles_version == entry.version:
            return self._tiles
        dirty = None
        if self._tiles is not None:
            dirty = self.ring.dirty_between(self._tiles_version, entry.version)
        self._tiles = refresh_tile_view(entry.state, self._tiles, dirty)
        self._tiles_version = entry.version
        return self._tiles

    def bc_scores(self, use_kernel: bool = False,
                  src_chunk: Optional[int] = None):
        """Exact betweenness centrality of every vertex at the latest
        version, via the tile-sparse batched Brandes path (all sources at
        once as semiring matmuls; empty tiles skipped).  ``src_chunk``
        bounds the S x V scratch (chunked source axis — the vcap ~16k
        ceiling lifter, see ``bc_batched_dense``).  Returns
        ``(scores f32[vcap], version)``; cached per ring version."""
        entry = self.ring.latest
        key = (entry.version, use_kernel, src_chunk)
        if self._bc_scores is not None and self._bc_scores[0] == key:
            return self._bc_scores[1], entry.version
        state = entry.state
        view = self.tile_view()
        from repro.core.tiles import dense_views_from_tiles
        adj_mask, _, alive = dense_views_from_tiles(state, view)
        srcs = jnp.arange(state.vcap, dtype=jnp.int32)
        delta, _, _, ok = queries.bc_batched_dense(
            adj_mask, srcs, alive, use_kernel=use_kernel, amask=view.occ,
            src_chunk=src_chunk)
        scores = jnp.sum(jnp.where(ok[:, None], delta, 0.0), axis=0)
        scores = jnp.where(alive, scores, jnp.nan)
        self._bc_scores = (key, scores)
        return scores, entry.version
