"""GraphService: the streaming update/query front end over the engine.

Ties the three engine layers together into the serving API the ROADMAP's
north star asks for:

  * updates enter through :class:`~repro.engine.scheduler.StreamScheduler`
    (``submit``), which coalesces them into fixed-size batches and commits
    each batch as a new version in the
    :class:`~repro.engine.version_ring.VersionRing`;
  * queries (``query``) are answered from the ring.  Per ``(kind, src)``
    the service caches the last answer together with the ring version it
    was computed at; the next query ORs the per-commit dirty sets since
    that version (``ring.dirty_between``) and hands prior + dirty to
    ``engine.incremental`` — most queries cost an *unchanged* check or a
    few delta relax passes instead of a full fixed point.

Consistency modes (paper section 5, at batch granularity):

  * ``"icn"`` (PG-Icn): single collect against a pinned latest snapshot —
    best-effort, maximum throughput;
  * ``"cn"`` (PG-Cn): double collect — re-run the (incremental) query on
    consecutive ring versions until two answers ``cmp_tree``-match, while
    pending update batches keep committing between collects (the paper's
    interrupting updates).  Because commits are the only writers and each
    collect reads one committed version, a repeat on an unchanged version
    matches trivially; under churn the loop pays exactly the paper's
    retry cost.

:class:`BaseGraphService` carries everything that is not collect-specific
— the ring + scheduler, the per-key result cache with LRU pruning, the
mode counters, and the icn/cn query drivers — so the local service here
and the distributed one (``repro.shard.service.ShardedGraphService``)
share one copy of the unchanged → delta → full ladder plumbing and only
implement how a single collect is answered.

Resilience (``repro.resil``): with a :class:`~repro.resil.ResiliencePolicy`
attached, a raising collect walks the degrade ladder — retry as a full
recompute from a pinned snapshot, then (budget/deadline exhausted) serve
the last cached answer at its still-resident ring version, flagged
``degraded=True`` with ``stale_version`` on the reply and in the trace
record.  A degraded answer is still *correct at the version it claims*
(the cache is only ever written after a successful collect, atomically
from the caller's perspective), never a torn read.  Without a policy,
collect failures propagate — but stats stay conserved: ``queries`` (and
the per-mode tallies) count only successful collects, failures land in
``service_errors``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import queries
from repro.core.graph_state import GraphState
from repro.core.snapshot import ScanStats
from repro.core.tiles import TileView, refresh_tile_view
from repro.obs import AdaptiveThresholds, CounterStruct, ModeCounters, \
    Telemetry
from repro.obs.hlo import account_jit
from repro.obs.trace import maybe_span
from repro.resil.faults import (
    P_CACHE_STORE,
    P_COLLECT_DELTA,
    P_COLLECT_DISPATCH,
    InjectedCrash,
    inject,
)
from repro.resil.policy import CircuitBreaker, ResiliencePolicy

from .incremental import (
    _dirty_stats,
    incremental_bc,
    incremental_bfs,
    incremental_sssp,
    results_equal,
)
from .scheduler import SchedulerStats, StreamScheduler
from .version_ring import PinnedSnapshot, VersionRing

_INCREMENTAL = {"bfs": incremental_bfs, "sssp": incremental_sssp,
                "bc": incremental_bc}
_FULL = {"bfs": queries.bfs, "sssp": queries.sssp,
         "bc": queries.bc_dependencies}

#: per-query cost scratch template (reset at every traced query() entry).
_QUERY_COST_ZERO = {"coll_bytes": 0, "temp_bytes": 0, "flops": 0.0,
                    "device_us": 0.0}

#: static delta-vs-full crossover per query kind.  BFS/SSSP deltas are
#: frontier-local (cost tracks the dirty region), so a generous 25% bound
#: holds; BC's incremental path re-runs the FULL backward dependency
#: sweep no matter how small the cut, so its delta only wins when the
#: forward warm start saves real work — measured crossover sits near a
#: few percent dirty, and the old shared 0.25 default routed BC deltas
#: into guaranteed losses (the `engine_bc_incr` 0.91x regression).
DEFAULT_DIRTY_THRESHOLDS: Dict[str, float] = {
    "bfs": 0.25, "sssp": 0.25, "bc": 0.05}

#: a service's dirty_threshold= accepts one float for every kind or a
#: per-kind mapping (missing kinds fall back to the defaults above).
ThresholdSpec = Union[None, float, Mapping[str, float]]


def resolve_dirty_thresholds(spec: ThresholdSpec,
                             kinds: Sequence[str]) -> Dict[str, float]:
    """Normalize a ``dirty_threshold`` spec to a per-kind dict."""
    if spec is None:
        return {k: DEFAULT_DIRTY_THRESHOLDS.get(k, 0.25) for k in kinds}
    if isinstance(spec, (int, float)):
        return {k: float(spec) for k in kinds}
    return {k: float(spec.get(k, DEFAULT_DIRTY_THRESHOLDS.get(k, 0.25)))
            for k in kinds}


class ServiceStats(CounterStruct):
    """Per-query mode tallies: unchanged + delta + full == queries (a cn
    query is counted once, by its final collect's mode).

    ``queries`` and the mode tallies count only *successful* collects —
    a raising collect increments ``errors`` instead, so the conservation
    invariant survives failure.  ``degraded`` counts stale-serve replies
    (outside ``queries``: no collect succeeded for them), ``retries``
    counts demoted re-collect attempts the resilience ladder ran.

    Attribute names are the stable API (``svc.stats.delta`` etc.); since
    PR 6 the values live as ``service_*`` counters in a
    :class:`repro.obs.MetricsRegistry` — the service's telemetry registry
    when one is attached, a private registry otherwise.
    """

    _FIELDS = ("queries", "unchanged", "delta", "full", "collects",
               "cn_retries", "errors", "degraded", "retries")
    _PREFIX = "service_"

    def count(self, mode: str) -> None:
        if mode == "unchanged":
            self.unchanged += 1
        elif mode == "delta":
            self.delta += 1
        else:
            self.full += 1


@dataclass
class _CacheSlot:
    version: int
    result: object  # BFSResult | SSSPResult | BCResult | Sharded*Result


def prune_result_cache(cache: Dict, max_cached: int, floor: int,
                       pinned=()) -> None:
    """Keep a per-``(kind, src)`` result cache bounded.

    Slots whose version fell below ``floor`` (out of the ring window) can
    never serve an unchanged/delta hit, so they go first; if the cache is
    still over budget, evict in insertion order — callers keep insertion
    order LRU by delete-then-insert on every hit.  Shared by
    :class:`GraphService` and the sharded service
    (``repro.shard.service``) so eviction semantics cannot drift.

    ``pinned`` (the ring's pin table) exempts slots at those versions
    from BOTH sweeps: an admitted-but-undispatched query holds a pin on
    the version it will read, and evicting its slot would demote its
    unchanged/delta rung — or, worse, strip the stale-serve bottom rung —
    out from under it.  The cache may transiently exceed ``max_cached``
    when everything left is pinned; it shrinks again as pins release.
    """
    if len(cache) <= max_cached:
        return
    pinned = frozenset(pinned)
    for key in [k for k, s in cache.items()
                if s.version < floor and s.version not in pinned]:
        del cache[key]
    if len(cache) > max_cached:
        evictable = [k for k, s in cache.items() if s.version not in pinned]
        for key in evictable[:len(cache) - max_cached]:
            del cache[key]


@dataclass
class QueryReply:
    """What ``GraphService.query`` hands back.

    ``degraded`` replies carry the last cached answer at ``stale_version``
    (== ``version``, still resident in the ring) because every fresher
    rung of the resilience ladder failed; the answer is exact at that
    version, just not at the latest.  ``retries`` counts the demoted
    re-collect attempts the ladder ran before this reply.
    """

    result: object          # BFSResult | SSSPResult | BCResult
    version: int            # ring version the answer is valid at
    mode: str               # "unchanged" | "delta" | "full" | "degraded"
    validated: bool         # True for cn-mode answers that double-collected
    scan: ScanStats = field(default_factory=ScanStats)
    degraded: bool = False
    stale_version: Optional[int] = None
    retries: int = 0


class BaseGraphService:
    """Shared submit()/query() plumbing of the local and sharded services.

    Subclasses implement ``_collect(kind, srcs, key) -> (entry, result,
    mode)`` — one collect against the latest ring version, running their
    own unchanged → delta → full ladder — plus the small hooks below; the
    base drives the scheduler/ring, the LRU result cache, the mode
    counters, and the PG-Icn / PG-Cn collect loops identically for both.
    """

    #: query kinds this service answers (subclass attribute).
    _kinds: Tuple[str, ...] = ()
    #: ``service`` label on every metric / trace record (subclass attr).
    _service_name: str = "service"

    def _init_service(self, initial_state: GraphState, *, ring_depth: int,
                      batch_size: int, dirty_threshold: ThresholdSpec,
                      strict_order: bool, coalesce: bool, max_collects: int,
                      max_cached: int,
                      telemetry: Optional[Telemetry] = None,
                      policy: Optional[ResiliencePolicy] = None,
                      journal=None, monitor=None, adaptive=None,
                      breaker=None, compact_every: Optional[int] = None
                      ) -> None:
        self.telemetry = telemetry
        self.policy = policy
        registry = telemetry.registry if telemetry is not None else None
        self.dirty_thresholds = resolve_dirty_thresholds(
            dirty_threshold, self._kinds)
        # Adaptive dirty-threshold control (repro.obs.adaptive): pass an
        # AdaptiveThresholds (or True for defaults seeded from the static
        # per-kind thresholds) to have the ladder consult a self-tuned
        # per-kind crossover instead of the fixed dirty_threshold.  The
        # controller feeds on the traced wall times, so it requires
        # telemetry.
        if adaptive is True:
            adaptive = AdaptiveThresholds(base=self.dirty_thresholds)
        if adaptive is not None:
            if telemetry is None:
                raise ValueError("adaptive thresholds require telemetry= "
                                 "(the controller feeds on traced query "
                                 "wall times)")
            adaptive.bind(registry, telemetry.tracer, self._service_name)
        self.adaptive: Optional[AdaptiveThresholds] = adaptive
        # Circuit-breaker fault domains (repro.resil.policy): pass a
        # CircuitBreaker (or True for defaults) to quarantine a kind's
        # delta path after consecutive delta-collect failures — the
        # ladder pins at full until half-open probes succeed.  Works
        # without telemetry; with it, trips/restores are traced.
        if breaker is True:
            breaker = CircuitBreaker()
        if breaker is not None:
            breaker.bind(registry,
                         telemetry.tracer if telemetry is not None else None,
                         self._service_name)
        self.breaker: Optional[CircuitBreaker] = breaker
        self.ring = VersionRing(initial_state, depth=ring_depth)
        # The scheduler's counters carry this service's label: two services
        # sharing one telemetry registry (the differential harness does)
        # must not alias their scheduler_* tallies.
        sched_stats = (SchedulerStats(registry, service=self._service_name)
                       if registry is not None else None)
        self.scheduler = StreamScheduler(
            self.ring, batch_size=batch_size, strict_order=strict_order,
            coalesce=coalesce, telemetry=telemetry, journal=journal,
            monitor=monitor, compact_every=compact_every,
            compact_extra=self._wal_extra, stats=sched_stats)
        self.max_collects = max_collects
        self.max_cached = max_cached
        self.stats = ServiceStats(registry, service=self._service_name)
        self._cache: Dict[Tuple, _CacheSlot] = {}
        # The result cache is shared between the dispatcher's collect
        # path and the stale-serve bottom rung, which the async front end
        # may walk from a different thread; one re-entrant lock keeps
        # store + prune + stale-read atomic.
        self._cache_lock = threading.RLock()
        # Per-query observation scratch, reset at query() entry: the
        # HLO-attributed cost of the query's device programs summed over
        # its collects (local collects have no collectives, so they
        # report zero bytes but real flops), the attributed device time,
        # and the dirty fraction the ladder decision saw (fed to the
        # adaptive controller).  Thread-local so the query path is
        # re-entrant: concurrent callers (the async front end's
        # dispatcher vs. a direct caller) each see their own scratch.
        self._query_tls = threading.local()

    # ------------------------- per-thread scratch -------------------------

    @property
    def _query_cost(self) -> dict:
        cost = getattr(self._query_tls, "cost", None)
        if cost is None:
            cost = dict(_QUERY_COST_ZERO)
            self._query_tls.cost = cost
        return cost

    @_query_cost.setter
    def _query_cost(self, value: dict) -> None:
        self._query_tls.cost = value

    @property
    def _query_dirty_frac(self) -> Optional[float]:
        return getattr(self._query_tls, "dirty_frac", None)

    @_query_dirty_frac.setter
    def _query_dirty_frac(self, value: Optional[float]) -> None:
        self._query_tls.dirty_frac = value

    # ------------------------------ updates ------------------------------

    def submit(self, op: Tuple) -> int:
        """Enqueue one mutation; full batches auto-commit into the ring."""
        return self.scheduler.submit(op)

    def submit_many(self, ops: Sequence[Tuple]) -> list:
        return self.scheduler.submit_many(ops)

    def flush(self):
        """Commit every pending update (the tail batch is padded)."""
        return self.scheduler.flush()

    @property
    def version(self) -> int:
        return self.ring.latest.version

    def pin(self, version: Optional[int] = None) -> PinnedSnapshot:
        return self.ring.pin(version)

    # ---------------------------- WAL compaction --------------------------

    def _wal_extra(self) -> dict:
        """Side-car state a compaction snapshot must carry: the op ledger
        (so recovery can seed the scheduler stats) and, when the adaptive
        controller is bound, its learned per-kind thresholds — a recovered
        service resumes tuned, not cold."""
        extra = {"ops_committed": int(self.scheduler.stats.ops_committed)}
        if self.adaptive is not None:
            extra["adaptive_thresholds"] = self.adaptive.thresholds()
        return extra

    def compact_wal(self) -> dict:
        """Snapshot the latest committed state into the journal's
        checkpoint store and drop covered WAL segments (see
        :meth:`repro.resil.OpJournal.compact`); returns the report."""
        journal = self.scheduler.journal
        if journal is None:
            raise ValueError("compact_wal() requires a journal= on the "
                             "service")
        entry = self.ring.latest
        return journal.compact(entry.state, entry.version,
                               extra=self._wal_extra())

    # ------------------------------ breaker ------------------------------

    def _breaker_allows(self, kind: str) -> bool:
        """May this collect touch its cached prior (the delta path)?
        Consulted once per collect that HAS a usable prior — open
        breakers quarantine it and force the clean full path."""
        return self.breaker is None or self.breaker.allow_delta(kind)

    def _breaker_failure(self, kind: str) -> None:
        if self.breaker is not None:
            self.breaker.record_failure(kind)

    def _breaker_success(self, kind: str, mode: str) -> None:
        # only an actual delta collect says anything about the delta
        # path's health (an unchanged hit never ran it)
        if self.breaker is not None and mode == "delta":
            self.breaker.record_success(kind)

    # ------------------------------- cache -------------------------------

    def _cache_store(self, key, version: int, result) -> None:
        # A planned fault here models slot corruption racing the store;
        # firing BEFORE any mutation keeps the store atomic — the old
        # slot (still correct at ITS version) survives intact.
        inject(P_CACHE_STORE)
        # Delete-then-insert moves the key to the back of the dict so
        # _prune_cache's front-of-dict eviction is LRU, not FIFO.
        with self._cache_lock:
            self._cache.pop(key, None)
            self._cache[key] = _CacheSlot(version, result)
            self._prune_cache()

    def _prune_cache(self) -> None:
        # dirty_between still has a span for slots at oldest_version - 1
        # (the first in-window commit's dirty set covers that gap), so only
        # versions strictly below that are unservable.  The ring's pin
        # table exempts versions admitted queries still hold (pins are
        # taken at admission, before dispatch reads the slot).
        with self._cache_lock:
            prune_result_cache(self._cache, self.max_cached,
                               self.ring.oldest_version - 1,
                               pinned=self.ring.pinned_versions())

    # ------------------------------- hooks -------------------------------

    def _key(self, kind: str, srcs) -> Tuple:
        raise NotImplementedError

    def _check_srcs(self, kind: str, srcs) -> None:
        """Reject source specs this service cannot answer (ValueError)."""

    def _collect(self, kind: str, srcs, key, ladder: bool = True):
        """One collect at the latest ring version -> (entry, result, mode).

        ``ladder=False`` (a resilience-ladder retry) must bypass the
        cache/delta rungs and recompute fully from a pinned snapshot."""
        raise NotImplementedError

    def _icn_validated(self, result) -> bool:
        """The ``validated`` flag of a single-collect reply (the sharded
        service carries the psum cross-shard agreement here)."""
        return False

    # ----------------------------- telemetry -----------------------------

    def _charge_cost(self, cost: Optional[dict]) -> None:
        """Accumulate one collect's HLO-attributed cost into the current
        query's trace record (both services call this per dispatch)."""
        if cost:
            self._query_cost["coll_bytes"] += cost.get("collective_bytes",
                                                       0) or 0
            self._query_cost["temp_bytes"] = max(
                self._query_cost["temp_bytes"], cost.get("temp_bytes") or 0)
            self._query_cost["flops"] += cost.get("flops") or 0.0

    def _acct_begin(self):
        """The HLO cost accountant with its deposit slot cleared, or None.

        The query wrappers (``shard.queries`` sharded, ``account_jit`` in
        ``engine.incremental`` locally) deposit their compiled program's
        cost dict into ``accountant.last`` (``repro.obs.hlo``); the
        service picks it up right after the dispatch and charges it to
        the current query's trace record — wrapper return types stay
        exactly what they were."""
        tel = self.telemetry
        acct = tel.accountant if tel is not None else None
        if acct is not None:
            acct.last = None
        return acct

    def _acct_charge(self, acct) -> None:
        if acct is not None:
            self._charge_cost(acct.last)

    def _threshold(self, kind: str) -> float:
        """The ladder's delta-vs-full crossover for ``kind``: the adaptive
        controller's current (possibly probing) value when one is bound,
        else the static per-kind threshold."""
        if self.adaptive is not None:
            return self.adaptive.threshold(kind)
        return self.dirty_thresholds[kind]

    def _note_dirty_frac(self, frac) -> None:
        """Record the dirty fraction the ladder decision just saw, feeding
        the adaptive controller's crossover fit after the query closes."""
        if frac is not None:
            self._query_dirty_frac = float(frac)

    def _traced_collect(self, kind: str, srcs, key, ladder: bool = True):
        """``_collect`` wrapped in a child span when tracing is on; the
        device timer blocks the fresh result to attribute its dispatch
        gap (≈0 for an unchanged cache hit — nothing was dispatched)."""
        tel = self.telemetry
        if tel is None:
            return self._collect(kind, srcs, key, ladder=ladder)
        with tel.tracer.span("collect", kind=kind) as sp:
            entry, res, qmode = self._collect(kind, srcs, key, ladder=ladder)
            dev = tel.profiler.measure(res, name=f"collect:{kind}")
            self._query_cost["device_us"] += dev
            sp.set(version=entry.version, mode=qmode,
                   device_us=round(dev, 1))
        return entry, res, qmode

    # ------------------------------ queries ------------------------------

    def query(self, kind: str, srcs=None, mode: str = "icn") -> QueryReply:
        """Answer one analytics query.

        ``kind``: one of ``self._kinds``; ``srcs`` as the subclass defines
        (a vertex id for the local service; an id or sequence — ``None`` =
        all slots, BC only — for the sharded one).
        ``mode``: ``"icn"`` (single collect) or ``"cn"`` (double collect).

        With telemetry attached, every call emits one ``span == "query"``
        trace record carrying kind / ring version / ladder mode /
        wall+block time / collect count / HLO collective bytes, and
        observes the wall time into the ``query_wall_us`` histogram
        (labelled service/kind/mode) the latency benches read p50/p99
        from.
        """
        if kind not in self._kinds:
            raise KeyError(f"unknown query kind {kind!r}")
        if mode not in ("icn", "cn"):
            raise ValueError(f"unknown mode {mode!r}")
        self._check_srcs(kind, srcs)
        tel = self.telemetry
        if tel is None:
            return self._query_guarded(kind, srcs, mode)
        self._query_cost = dict(_QUERY_COST_ZERO)
        self._query_dirty_frac = None
        with tel.tracer.span("query", service=self._service_name,
                             kind=kind, cn=(mode == "cn")) as sp:
            try:
                reply = self._query_guarded(kind, srcs, mode)
            except BaseException as e:
                # The record stays parseable (report skips error records):
                # a failed query has no version/mode to claim.
                sp.set(error=type(e).__name__)
                raise
            block_us = 0.0
            if tel.block:
                t0 = time.perf_counter()
                jax.block_until_ready(reply.result)
                block_us = (time.perf_counter() - t0) * 1e6
            sp.set(version=reply.version, mode=reply.mode,
                   collects=reply.scan.collects,
                   cn_interrupts=reply.scan.interrupting_updates,
                   validated=reply.validated,
                   block_us=round(block_us, 1),
                   device_us=round(self._query_cost["device_us"], 1),
                   coll_bytes=self._query_cost["coll_bytes"],
                   temp_bytes=self._query_cost["temp_bytes"],
                   flops=self._query_cost["flops"],
                   degraded=reply.degraded,
                   stale_version=reply.stale_version,
                   retries=reply.retries)
        tel.registry.histogram(
            "query_wall_us", service=self._service_name, kind=kind,
            mode=reply.mode).observe(sp.wall_us)
        if self._query_cost["device_us"] > 0:
            tel.registry.histogram(
                "query_device_us", service=self._service_name, kind=kind,
                mode=reply.mode).observe(self._query_cost["device_us"])
        # Feed the controller after the span closed so any resulting
        # threshold_adjust span is a sibling, not a child, of the query.
        if self.adaptive is not None and not reply.degraded:
            self.adaptive.observe(kind, reply.mode, sp.wall_us,
                                  self._query_dirty_frac)
        return reply

    def _query_guarded(self, kind: str, srcs, mode: str) -> QueryReply:
        """One query under the failure policy (or bare stats accounting)."""
        if self.policy is None:
            try:
                return self._query_inner(kind, srcs, mode)
            except InjectedCrash:
                raise  # crashes are not an error path — they end the process
            except Exception:
                self.stats.errors += 1
                raise
        return self._query_resilient(kind, srcs, mode)

    def _query_resilient(self, kind: str, srcs, mode: str) -> QueryReply:
        """Walk the degrade ladder: attempt, retry-as-full, stale serve.

        The first attempt runs the normal unchanged → delta → full ladder;
        every retry forces a full recompute from a pinned snapshot
        (``force_full``), on the theory that the cheap rungs are what just
        failed.  The deadline bounds *retries*, never the first attempt.
        """
        pol = self.policy
        t0 = time.perf_counter()
        last_exc: Optional[Exception] = None
        for attempt in range(pol.max_retries + 1):
            if attempt:
                if pol.deadline_exceeded(t0):
                    break
                back = pol.backoff_s(attempt)
                if back > 0:
                    time.sleep(back)
                self.stats.retries += 1
            try:
                reply = self._query_inner(kind, srcs, mode,
                                          force_full=attempt > 0)
                reply.retries = attempt
                return reply
            except InjectedCrash:
                raise
            except Exception as e:
                self.stats.errors += 1
                last_exc = e
        if pol.allow_stale:
            reply = self._stale_reply(kind, srcs)
            if reply is not None:
                self.stats.degraded += 1
                return reply
        assert last_exc is not None
        raise last_exc

    def _stale_reply(self, kind: str, srcs) -> Optional[QueryReply]:
        """Bottom rung: last cached answer, iff its version is still
        resident in the ring (the answer is exact at that version — the
        cache is only written after a successful collect).

        The residency check and the reply assembly are atomic w.r.t.
        ring eviction: ``try_pin`` bumps the refcount in the same
        critical section that verifies residency, so a concurrent commit
        rotating the ring cannot evict the version between the check and
        the reply — a degraded reply never names a version that was
        already gone when it was built.
        """
        key = self._key(kind, srcs)
        with self._cache_lock:
            slot = self._cache.get(key)
            if slot is None:
                return None
            pin = self.ring.try_pin(slot.version)
        if pin is None:
            return None
        with pin:
            return QueryReply(slot.result, slot.version, "degraded", False,
                              ScanStats(), degraded=True,
                              stale_version=slot.version)

    def _query_inner(self, kind: str, srcs, mode: str,
                     force_full: bool = False) -> QueryReply:
        key = self._key(kind, srcs)
        if mode == "icn":
            entry, res, qmode = self._traced_collect(
                kind, srcs, key, ladder=not force_full)
            # Success accounting only: a raising collect must leave
            # queries (and the mode tallies) untouched so that
            # unchanged + delta + full == queries survives failure.
            self.stats.queries += 1
            self.stats.collects += 1
            self.stats.count(qmode)
            return QueryReply(res, entry.version, qmode,
                              self._icn_validated(res),
                              ScanStats(collects=1, validated=False))
        return self._query_cn(kind, srcs, key, force_full=force_full)

    def _query_cn(self, kind: str, srcs, key,
                  force_full: bool = False) -> QueryReply:
        """PG-Cn: double-collect over ring versions until answers match.

        Between collects, one pending update batch commits (the stream's
        interrupting updates).  Two collects at the same ring version are
        equal by construction — the functional analogue of the paper's
        CMPTREE match — so the loop terminates as soon as the collect
        window sees no interleaved commit.
        """
        ladder = not force_full
        scan = ScanStats()
        v0 = self.ring.latest.version
        entry, prev_res, qmode = self._traced_collect(kind, srcs, key,
                                                      ladder=ladder)
        scan.collects = 1
        while scan.collects < self.max_collects:
            self.scheduler.commit_one()  # interrupting update, if pending
            cur_entry, cur_res, cur_mode = self._traced_collect(
                kind, srcs, key, ladder=ladder)
            scan.collects += 1
            if cur_entry.version == entry.version or results_equal(
                    prev_res, cur_res):
                self.stats.queries += 1
                self.stats.collects += scan.collects
                self.stats.count(cur_mode)
                scan.interrupting_updates = cur_entry.version - v0
                scan.validated = True
                return QueryReply(cur_res, cur_entry.version, cur_mode,
                                  True, scan)
            self.stats.cn_retries += 1
            entry, prev_res, qmode = cur_entry, cur_res, cur_mode
        scan.validated = False
        scan.interrupting_updates = self.ring.latest.version - v0
        self.stats.queries += 1
        self.stats.collects += scan.collects
        self.stats.count(qmode)
        return QueryReply(prev_res, entry.version, qmode, False, scan)


class GraphService(BaseGraphService):
    """submit()/query() front end: streaming updates, incremental queries."""

    _kinds = ("bfs", "sssp", "bc")
    _service_name = "local"

    def __init__(self, initial_state: GraphState, *, ring_depth: int = 8,
                 batch_size: int = 32,
                 dirty_threshold: ThresholdSpec = None,
                 strict_order: bool = False, coalesce: bool = False,
                 max_collects: int = 16, max_cached: int = 512,
                 telemetry: Optional[Telemetry] = None,
                 policy: Optional[ResiliencePolicy] = None,
                 journal=None, monitor=None, adaptive=None, breaker=None,
                 compact_every: Optional[int] = None):
        self._init_service(
            initial_state, ring_depth=ring_depth, batch_size=batch_size,
            dirty_threshold=dirty_threshold, strict_order=strict_order,
            coalesce=coalesce, max_collects=max_collects,
            max_cached=max_cached, telemetry=telemetry, policy=policy,
            journal=journal, monitor=monitor, adaptive=adaptive,
            breaker=breaker, compact_every=compact_every)
        self._tiles: Optional[TileView] = None
        self._tiles_version: int = -1
        self._bc_scores: Optional[dict] = None
        self.bc_scores_stats = ModeCounters(
            self.stats.registry, "bc_scores_queries",
            service=self._service_name)

    # ------------------------------ queries ------------------------------

    def _key(self, kind: str, src) -> Tuple[str, int]:
        return kind, src

    def _check_srcs(self, kind: str, src) -> None:
        if src is None:
            raise ValueError(f"{kind!r} needs an explicit source vertex")

    def _collect(self, kind: str, src, key, ladder: bool = True):
        """One incremental collect against the current latest ring version:
        the unchanged → delta → full ladder lives in ``engine.incremental``.

        ``ladder=False`` (a resilience retry demoting past the cheap
        rungs) pins the latest version and recomputes from scratch — no
        cache read, no dirty-set math — so a corrupt delta path cannot
        poison the retry."""
        if not ladder:
            entry = self.ring.latest
            with self.ring.pin(entry.version):
                inject(P_COLLECT_DISPATCH)
                acct = self._acct_begin()
                res, inc = _INCREMENTAL[kind](
                    entry.state, None, None, src,
                    dirty_threshold=self.dirty_thresholds[kind],
                    accountant=acct)
                self._acct_charge(acct)
            self._cache_store(key, entry.version, res)
            return entry, res, inc.mode
        entry = self.ring.latest
        slot = self._cache.get(key)
        prior, dirty = None, None
        # A tripped breaker quarantines the cached prior entirely: the
        # collect below sees no prior, runs the clean full path, and
        # never executes the (possibly poisoned) delta rungs.
        use_prior = slot is not None and self._breaker_allows(kind)
        try:
            if use_prior:
                prior = slot.result
                dirty = self.ring.dirty_between(slot.version, entry.version)
                inject(P_COLLECT_DELTA)
            inject(P_COLLECT_DISPATCH)
            acct = self._acct_begin()
            res, inc = _INCREMENTAL[kind](
                entry.state, prior, dirty, src,
                dirty_threshold=self._threshold(kind), accountant=acct)
        except InjectedCrash:
            raise
        except Exception:
            # conservative attribution: any failure while a usable prior
            # was in play counts against the kind's delta path
            if use_prior:
                self._breaker_failure(kind)
            raise
        if use_prior:
            self._breaker_success(kind, inc.mode)
        self._acct_charge(acct)
        self._note_dirty_frac(inc.dirty_fraction)
        self._cache_store(key, entry.version, res)
        return entry, res, inc.mode

    # --------------------------- batched analytics ------------------------

    def tile_view(self) -> TileView:
        """Blocked adjacency view of the latest version, kept fresh
        incrementally: each call re-derives only the tile rows the ring's
        dirty sets say moved since the last call (full rebuild when the
        span left the ring window or the vertex table grew)."""
        entry = self.ring.latest
        if self._tiles is not None and self._tiles_version == entry.version:
            return self._tiles
        dirty = None
        if self._tiles is not None:
            dirty = self.ring.dirty_between(self._tiles_version, entry.version)
        tracer = self.telemetry.tracer if self.telemetry else None
        with maybe_span(tracer, "tile_refresh", service=self._service_name,
                        full=(self._tiles is None or dirty is None)):
            self._tiles = refresh_tile_view(entry.state, self._tiles, dirty)
        self._tiles_version = entry.version
        return self._tiles

    def bc_scores(self, use_kernel: bool = False,
                  src_chunk: Optional[int] = None):
        """Exact betweenness centrality of every vertex at the latest
        version, via the tile-sparse batched Brandes path (all sources at
        once as semiring matmuls; empty tiles skipped).  ``src_chunk``
        bounds the S x V scratch (chunked source axis — the vcap ~16k
        ceiling lifter, see ``bc_batched_dense``).  Returns
        ``(scores f32[vcap], version)``.

        Incremental across versions: the previous call's forward trees
        (level/sigma per source, cached alongside the scores) warm-start
        ``bc_batched_dense`` through the per-source level cut, so a
        localized commit re-runs only the forward work below each source's
        cut — bit-identical to the cold sweep.  Mode tallies land in
        ``bc_scores_stats``.
        """
        entry = self.ring.latest
        params = (use_kernel, src_chunk)
        slot = self._bc_scores
        if (slot is not None and slot["version"] == entry.version
                and slot["params"] == params):
            return slot["scores"], entry.version
        state = entry.state
        mode, dirty = "full", None
        if (slot is not None and slot["params"] == params
                and slot["level"].shape == (state.vcap, state.vcap)):
            dirty = self.ring.dirty_between(slot["version"], entry.version)
            if dirty is not None:
                n_dirty, touched = (int(x) for x in _dirty_stats(
                    (slot["level"] >= 0).any(axis=0), dirty))
                if not touched and bool((~slot["ok"] & state.alive).any()):
                    # A resurrected source's cached tree is empty: no dirty
                    # vertex can intersect it, but its row must recompute
                    # (the warm start restarts revived sources cold).
                    touched = True
                if not touched:
                    mode = "unchanged"
                elif n_dirty / state.vcap <= self._threshold("bc"):
                    mode = "delta"
        self.bc_scores_stats[mode] += 1
        if mode == "unchanged":
            # Churn never touched any source's forward region: every tree —
            # hence every score — stands as-is at the new version.
            slot["version"] = entry.version
            return slot["scores"], entry.version
        view = self.tile_view()
        from repro.core.tiles import dense_views_from_tiles
        adj_mask, _, alive = dense_views_from_tiles(state, view)
        srcs = jnp.arange(state.vcap, dtype=jnp.int32)
        warm = {}
        if mode == "delta":
            warm = dict(prior_level=slot["level"], prior_sigma=slot["sigma"],
                        cut=queries.bc_level_cut(slot["level"], dirty,
                                                 state.alive))
        delta, sigma, level, ok = queries.bc_batched_dense(
            adj_mask, srcs, alive, use_kernel=use_kernel, amask=view.occ,
            src_chunk=src_chunk, **warm)
        scores = jnp.sum(jnp.where(ok[:, None], delta, 0.0), axis=0)
        scores = jnp.where(alive, scores, jnp.nan)
        self._bc_scores = {"version": entry.version, "params": params,
                           "scores": scores, "level": level, "sigma": sigma,
                           "ok": ok}
        return scores, entry.version
