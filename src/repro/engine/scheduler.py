"""Streaming update scheduler: an op-log coalesced into fixed-size batches.

The core layer's unit of mutation is the fixed-size ``OpBatch`` (one jitted
``apply_batch`` per commit, one ``version`` bump = one linearization
boundary).  A serving system, however, receives *individual* PutV / RemV /
PutE / RemE requests.  The scheduler bridges the two:

  * ``submit`` appends a request to the op-log and returns its sequence
    number — the log is the total order of the stream;
  * full chunks of ``batch_size`` ops are committed through
    ``core.apply_ops`` (which handles compact/grow on overflow) into the
    :class:`~repro.engine.version_ring.VersionRing`; ``flush`` drains the
    partial tail (padded with NOPs, which ``apply_batch`` ignores).

Order guarantees
----------------
Batches commit in log order, so ops in different batches always linearize
in submission order.  *Within* a batch, ``apply_batch`` linearizes all
vertex ops (in submission order) before all edge ops (in submission order).
With ``strict_order=True`` the scheduler cuts a batch early whenever a
vertex op arrives after an edge op in the current chunk, which makes the
committed history equivalent to applying every op one at a time in
submission order (at the cost of shorter batches on adversarial streams).

Coalescing
----------
With ``coalesce=True``, consecutive edge ops on the same ``(u, v)`` key
within a chunk collapse to the last one.  The committed *state* is
unchanged (apply_batch already resolves intra-batch chains sequentially);
what is lost are the intermediate per-op return values and their ``ecnt``
bumps — safe, because no reader can observe the interior of a commit.
Vertex ops are never coalesced: RemV has side effects beyond its key
(incident-edge invalidation).

Failure semantics
-----------------
Commits are atomic: an exception anywhere inside ``_commit_chunk``
(``apply_ops`` mid-batch, the ring append, an injected fault at
``sched.apply_ops`` / ``sched.ring_commit`` / ``ring.evict``) leaves the
ring latest AND the pending op log exactly as before — the popped chunk
returns to the front of the log, so a retry replays the identical
prefix.  With a :class:`repro.resil.OpJournal` attached, every submit is
write-ahead logged and every successful commit writes a barrier;
``repro.resil.journal.recover`` replays the file into a bit-identical
ring latest.  An optional
:class:`~repro.runtime.fault_tolerance.HeartbeatMonitor` watches commit
latency: commits slower than ``factor`` x the rolling median raise the
straggler flag, surfacing as a ``scheduler_stragglers`` counter and a
``straggler=True`` annotation on the commit's trace span.

Concurrency
-----------
Many serving clients may submit concurrently (the async front end's
update path), so the op-log and the commit pipeline run under one
re-entrant scheduler lock: submits serialize, and whichever thread
fills a batch carries out its auto-commit while holding it.  Queries
never take this lock — in-flight reads on pinned ring versions overlap
every commit; the only cross-structure touch point is the version ring,
which has its own lock.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.updates import NOP, PUTE, PUTV, REME, REMV, apply_ops
from repro.obs import CounterStruct
from repro.obs.trace import maybe_span
from repro.resil.faults import P_SCHED_APPLY, P_SCHED_RING_COMMIT, inject

from .version_ring import RingEntry, VersionRing

_VERTEX_OPS = (PUTV, REMV)
_EDGE_OPS = (PUTE, REME)


class SchedulerStats(CounterStruct):
    """Op-log tallies, as ``scheduler_*`` registry counters since PR 6
    (attribute surface unchanged; see :class:`repro.obs.CounterStruct`)."""

    _FIELDS = ("ops_submitted", "ops_committed", "ops_coalesced",
               "batches_committed", "strict_cuts", "commit_failures",
               "stragglers", "compacts", "compact_failures")
    _PREFIX = "scheduler_"


@dataclass
class StreamScheduler:
    """Coalesce a stream of update requests into committed ``OpBatch``es."""

    ring: VersionRing
    batch_size: int = 32
    strict_order: bool = False
    coalesce: bool = False
    auto_commit: bool = True
    telemetry: object = None  # Optional[repro.obs.Telemetry]
    journal: object = None    # Optional[repro.resil.OpJournal]
    monitor: object = None    # Optional[repro.runtime.HeartbeatMonitor]
    compact_every: Optional[int] = None  # journal.compact cadence (batches)
    compact_extra: object = None  # Optional[Callable[[], dict]] manifest extra
    _log: List[Tuple] = field(default_factory=list)
    stats: SchedulerStats = None
    _lock: threading.RLock = field(default_factory=threading.RLock,
                                   repr=False)

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.stats is None:
            registry = (self.telemetry.registry
                        if self.telemetry is not None else None)
            self.stats = SchedulerStats(registry)

    # ------------------------------ intake -------------------------------

    def submit(self, op: Tuple) -> int:
        """Append one ``(kind, u[, v[, w]])`` request; returns its seq no.

        With a journal attached the op is write-ahead logged before it
        enters the in-memory log: an acknowledged submit survives a
        crash (as a pending op) even if its batch never committed.
        """
        if op[0] not in _VERTEX_OPS and op[0] not in _EDGE_OPS:
            raise ValueError(f"scheduler accepts mutations only, got {op!r}")
        with self._lock:
            seq = self.stats.ops_submitted
            if self.journal is not None:
                self.journal.append_op(seq, op)
            self._log.append(op)
            self.stats.ops_submitted += 1
            if self.auto_commit:
                self._commit_ready()
            return seq

    def submit_many(self, ops: Sequence[Tuple]) -> List[int]:
        return [self.submit(op) for op in ops]

    def pending(self) -> int:
        with self._lock:
            return len(self._log)

    # ------------------------------ commits ------------------------------

    def _next_chunk(self, limit: Optional[int]) -> List[Tuple]:
        """Pop the next committable chunk (respecting strict-order cuts)."""
        take = len(self._log) if limit is None else min(limit, len(self._log))
        if self.strict_order:
            seen_edge = False
            for i, op in enumerate(self._log[:take]):
                if op[0] in _EDGE_OPS:
                    seen_edge = True
                elif seen_edge:  # vertex op after an edge op: cut here
                    self.stats.strict_cuts += 1
                    take = i
                    break
        chunk, self._log = self._log[:take], self._log[take:]
        return chunk

    def _coalesce_chunk(self, chunk: List[Tuple]) -> List[Tuple]:
        out: List[Tuple] = []
        for op in chunk:
            if (self.coalesce and out
                    and op[0] in _EDGE_OPS and out[-1][0] in _EDGE_OPS
                    and op[1] == out[-1][1] and op[2] == out[-1][2]):
                out[-1] = op
                self.stats.ops_coalesced += 1
            else:
                out.append(op)
        return out

    def _commit_chunk(self, chunk: List[Tuple]) -> RingEntry:
        n_raw = len(chunk)
        ops = self._coalesce_chunk(list(chunk))
        tracer = self.telemetry.tracer if self.telemetry is not None else None
        mon = self.monitor
        stragglers0 = mon.stragglers if mon is not None else 0
        try:
            with maybe_span(tracer, "commit", batch_ops=n_raw,
                            coalesced=n_raw - len(ops)) as sp:
                if mon is not None:
                    mon.start()
                inject(P_SCHED_APPLY)
                state, _ = apply_ops(self.ring.latest.state, ops,
                                     batch_size=self.batch_size)
                inject(P_SCHED_RING_COMMIT)
                entry = self.ring.commit(state)
                if mon is not None:
                    mon.stop(entry.version)
                    if mon.stragglers > stragglers0:
                        self.stats.stragglers += 1
                        sp.set(straggler=True)
                sp.set(version=entry.version)
        except BaseException:
            # Atomic commit: a failure (incl. an injected crash) leaves
            # the ring latest and the pending log exactly as before —
            # the popped chunk returns to the FRONT of the log, so a
            # retry replays the identical prefix in submission order.
            self._log[:0] = chunk
            self.stats.commit_failures += 1
            raise
        if self.journal is not None:
            # barrier AFTER the ring append: the journal's durability
            # point; a crash in between rolls the batch back on recovery
            self.journal.commit_barrier(entry.version, n_raw)
        self.stats.ops_committed += n_raw
        self.stats.batches_committed += 1
        if (self.journal is not None and self.compact_every
                and self.stats.batches_committed % self.compact_every == 0):
            self._auto_compact(entry)
        return entry

    def _auto_compact(self, entry: RingEntry) -> None:
        """Best-effort journal compaction after a commit: a failed
        snapshot must never fail the (already durable) commit."""
        try:
            extra = self.compact_extra() if self.compact_extra else None
            self.journal.compact(entry.state, entry.version, extra=extra)
            self.stats.compacts += 1
        except Exception:
            self.stats.compact_failures += 1

    def _commit_ready(self) -> List[RingEntry]:
        """Commit every full batch currently in the log."""
        entries = []
        with self._lock:
            while len(self._log) >= self.batch_size:
                chunk = self._next_chunk(self.batch_size)
                if not chunk:  # strict cut at 0 cannot happen, but guard
                    break
                entries.append(self._commit_chunk(chunk))
        return entries

    def commit_one(self) -> Optional[RingEntry]:
        """Commit a single batch (possibly partial); None when log is empty."""
        with self._lock:
            if not self._log:
                return None
            # A strict cut lands after >= 1 op, so the chunk is non-empty.
            chunk = self._next_chunk(self.batch_size)
            return self._commit_chunk(chunk)

    def flush(self) -> List[RingEntry]:
        """Drain the whole log in batch-size chunks (tail is NOP-padded)."""
        entries = []
        with self._lock:
            while self._log:
                entry = self.commit_one()
                if entry is None:
                    break
                entries.append(entry)
        return entries

    # ------------------------------ recovery ------------------------------

    def replay_commit(self, chunk: Sequence[Tuple]) -> RingEntry:
        """Journal recovery: re-commit exactly this raw chunk.

        Bypasses batching/strict-cut decisions — the chunk IS a decision
        the original process already made (one barrier's worth of ops) —
        but runs the same coalesce + apply + ring pipeline, so the
        committed state and version are bit-identical.  When this
        scheduler journals, the replayed ops are re-logged first so the
        new journal is itself recoverable.
        """
        ops = [tuple(op) for op in chunk]
        with self._lock:
            if self.journal is not None:
                for i, op in enumerate(ops):
                    self.journal.append_op(self.stats.ops_submitted + i, op)
            self.stats.ops_submitted += len(ops)
            return self._commit_chunk(ops)

    def replay_pending(self, ops: Sequence[Tuple]) -> None:
        """Journal recovery: restore un-barriered tail ops as pending.

        Unlike ``submit``, never auto-commits — the original process had
        not committed these ops, and recovery must reproduce its state,
        not improve on it."""
        with self._lock:
            for op in ops:
                op = tuple(op)
                if self.journal is not None:
                    self.journal.append_op(self.stats.ops_submitted, op)
                self._log.append(op)
                self.stats.ops_submitted += 1
