"""Incremental analytics engine: version ring + delta queries + scheduler.

Layers (each usable on its own):

  * :mod:`repro.engine.version_ring` — MVCC ring of committed snapshots
    with per-commit dirty-vertex sets (pin / release / dirty_between);
  * :mod:`repro.engine.incremental` — delta-BFS / delta-SSSP that reuse a
    prior result and re-relax only the dirty region, with full-recompute
    fallback and cmp_tree-style validation;
  * :mod:`repro.engine.scheduler` — op-log coalescing the update stream
    into fixed-size committed batches;
  * :mod:`repro.engine.service` — the ``GraphService.submit()/query()``
    front end with PG-Icn / PG-Cn consistency modes.
"""
from .version_ring import PinnedSnapshot, RingEntry, VersionRing  # noqa: F401
from .incremental import (  # noqa: F401
    IncrementalStats,
    delta_bc,
    delta_bfs,
    delta_sssp,
    incremental_bc,
    incremental_bfs,
    incremental_sssp,
    results_equal,
    validate_incremental,
)
from .scheduler import SchedulerStats, StreamScheduler  # noqa: F401
from .service import GraphService, QueryReply, ServiceStats  # noqa: F401
