"""Delta-driven BFS / SSSP recomputation from a prior result + dirty set.

Static analytics recompute the whole fixed point on every change; the
paper's point (Figs 12/13) is that a versioned structure knows *what moved*
and should pay only for that.  Given a prior ``BFSResult``/``SSSPResult``
and the dirty-vertex set accumulated since it was computed (see
``engine.version_ring``), the delta queries here:

  1. **Poison** the stale region: a vertex's cached distance is invalid iff
     some edge on its cached shortest path may have changed.  Every edge
     mutation bumps ``ecnt`` at the edge's *source*, so the path through
     ``v`` is suspect exactly when some ancestor of ``v`` in the prior
     traversal tree has a dirty parent-edge source (or the vertex itself
     died).  Poison propagates down the parent tree by pointer doubling —
     ``ceil(log2 vcap)`` gathers, not a per-level walk.
  2. **Re-relax** from the surviving frontier: clean distances are genuine
     path lengths in the *new* graph (their whole parent chain is
     untouched), i.e. admissible upper bounds, so the standard
     label-correcting fixed point under ``lax.while_loop`` converges to the
     exact answer in ~(affected-region diameter) passes instead of
     ~(graph diameter).
  3. **Fall back** to full recompute when the dirty region is too large for
     the delta to win (``dirty_threshold``), when the cached result is
     unusable (dead source, grown vertex table, negative cycle), or when
     the caller has no dirty info at all.

The host wrappers also expose the cheap *unchanged* test — no dirty vertex
intersects the prior reached region — which returns the prior result with
zero relax passes; that selectivity is where most of the paper's win lives.

``validate_incremental`` is the ``cmp_tree``-style check that a delta answer
is bit-identical to a fresh collect on the same snapshot.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.graph_state import (
    INF,
    NOKEY,
    GraphState,
    find_edge_slots,
)
from repro.core.queries import (
    BCResult,
    BFSResult,
    SSSPResult,
    _bc_coo_sweep,
    _edge_views,
    bc_dependencies,
    bc_level_cut,
    bfs,
    bfs_tree_parents,
    relax_fixpoint,
    sssp,
    sssp_tree_parents,
)
from repro.obs.hlo import account_jit
from repro.obs.trace import annotate as _trace_annotate


@dataclass
class IncrementalStats:
    """How one incremental query was answered."""

    mode: str               # "unchanged" | "delta" | "full"
    dirty_count: int = 0
    dirty_fraction: float = 0.0


def _poison(state: GraphState, prior_parent: jax.Array,
            prior_reached: jax.Array, prior_distf: jax.Array,
            dirty: jax.Array, check_weight: bool) -> jax.Array:
    """bool[vcap]: vertices whose cached distance can no longer be trusted.

    Seeds: reached vertices that died, and vertices whose parent edge is
    actually gone.  A dirty parent only *suspects* the edge — ``ecnt`` says
    the parent's out-list changed, not which edge — so we re-probe the new
    state (one vectorized binary search): if edge ``(parent[v], v)`` is
    still live with the same weight (``prior.dist[v] - prior.dist[parent]``;
    weight ignored for BFS), the cached path survives and ``v`` stays
    clean.  Poison then closes downward over the prior tree by pointer
    doubling (after step k, a vertex is poisoned iff any of its 2^k nearest
    ancestors, itself included, is a seed).
    """
    vcap = prior_parent.shape[0]
    alive = state.alive
    parc = jnp.clip(prior_parent, 0, vcap - 1)
    has_par = (prior_parent != NOKEY) & prior_reached
    suspect = has_par & dirty[parc]
    self_id = jnp.arange(vcap, dtype=jnp.int32)
    qu = jnp.where(suspect, parc, NOKEY)
    qv = jnp.where(suspect, self_id, NOKEY)
    slot, _, edge_live = find_edge_slots(state, qu, qv)
    edge_ok = edge_live
    if check_weight:
        edge_ok = edge_ok & (state.ew[slot] == prior_distf - prior_distf[parc])
    seed = (prior_reached & ~alive) | (suspect & ~edge_ok)
    # Ancestor pointer: parent where one exists, else self (fixed point).
    anc = jnp.where(has_par, parc, self_id)
    steps = max(1, int(math.ceil(math.log2(max(vcap, 2)))))

    def body(_, carry):
        poison, anc = carry
        return poison | poison[anc], anc[anc]

    poison, anc_fin = lax.fori_loop(0, steps, body, (seed, anc))
    # With zero-weight edges the tight-edge parent "tree" can contain
    # cycles (dist does not strictly decrease along a zero-weight parent
    # link), and poison propagated along parents never escapes a cycle —
    # the entry edge that actually fed the cycle its distance is invisible
    # to the chain walk.  Such chains never reach a root: after >= vcap
    # doublings a tree vertex's ancestor is its (parentless) root, while a
    # cycle-bound chain lands on a vertex that still has a parent.  Their
    # cached distances are unverifiable, so poison them outright.
    return poison | has_par[anc_fin]


@jax.jit
def _dirty_stats(prior_reached: jax.Array, dirty: jax.Array):
    """(dirty count, query touched) in one device round trip.

    ``touched``: any dirty vertex intersects the prior reached region.
    Every mutation that can change the query's answer dirties a *reached*
    vertex: edge changes dirty the edge's source (irrelevant unless the
    source was reached), and liveness changes dirty the vertex itself
    (irrelevant unless it was reached — a vertex entering the region needs
    a new edge out of a reached, hence dirty, source).
    """
    return (jnp.sum(dirty.astype(jnp.int32)),
            (dirty & prior_reached).any())


# --------------------------------- BFS -----------------------------------

@jax.jit
def delta_bfs(state: GraphState, prior: BFSResult, dirty: jax.Array,
              src) -> BFSResult:
    """Recompute BFS on ``state`` reusing ``prior`` (computed <= dirty ago).

    Bit-identical to ``queries.bfs(state, src)`` for any dirty set that
    covers the actual changes (a too-large dirty set only costs time).
    """
    src = jnp.asarray(src, jnp.int32)
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ok = state.alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)

    priorf = prior.dist.astype(jnp.float32)
    poison = _poison(state, prior.parent, prior.reached, priorf, dirty,
                     check_weight=False)
    keep = prior.reached & ~poison
    dist0 = jnp.where(keep, priorf, INF)
    dist0 = dist0.at[src].set(jnp.where(ok, 0.0, INF), mode="drop")

    unit = jnp.ones((state.ecap,), jnp.float32)
    distf, _, _ = relax_fixpoint(dist0, live, srcc, dstc, unit, vcap)

    reached = distf < INF
    dist = jnp.where(reached, distf, -1.0).astype(jnp.int32)
    # Parent reconstruction matches queries.bfs exactly (see
    # bfs_tree_parents — shared with the sharded delta path).
    parent = bfs_tree_parents(state, dist[None], src[None])[0]
    return BFSResult(ok, reached, dist, parent)


# --------------------------------- SSSP ----------------------------------

@jax.jit
def delta_sssp(state: GraphState, prior: SSSPResult, dirty: jax.Array,
               src) -> SSSPResult:
    """Delta Bellman-Ford; bit-identical to ``queries.sssp`` absent negative
    cycles (on detection the wrapper re-runs the full query, whose
    partially-relaxed distances are iteration-order-dependent)."""
    src = jnp.asarray(src, jnp.int32)
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ew = jnp.where(live, state.ew, INF)
    ok_src = state.alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)

    prior_reached = prior.dist < INF
    poison = _poison(state, prior.parent, prior_reached, prior.dist, dirty,
                     check_weight=True)
    keep = prior_reached & ~poison
    dist0 = jnp.where(keep, prior.dist, INF)
    dist0 = dist0.at[src].set(jnp.where(ok_src, 0.0, INF), mode="drop")

    dist, changed, _ = relax_fixpoint(dist0, live, srcc, dstc, ew, vcap)

    # Same free CHECKNEGCYCLE as queries.sssp: from *any* admissible upper
    # bound, Bellman-Ford converges within vcap-1 passes absent a negative
    # cycle, so exiting the loop still-changed == negative cycle.
    negcycle = changed

    parent = sssp_tree_parents(state, dist[None], src[None])[0]
    return SSSPResult(ok_src & ~negcycle, negcycle, dist, parent)


# ---------------------------------- BC -----------------------------------

def delta_bc(state: GraphState, prior: BCResult, dirty: jax.Array,
             src) -> BCResult:
    """Level-cut delta Brandes: recompute BC dependencies reusing ``prior``.

    BC needs a different poison than BFS/SSSP: ``sigma`` counts *all*
    shortest paths, so even an edge insertion that moves no distance (a new
    tight edge into an existing level) changes downstream counts — per-edge
    chain probing cannot clear it.  But level sets are built level-by-level
    from the out-edge lists of the previous level's (clean) vertices, so
    everything strictly above the shallowest dirty level is untouched
    (``bc_level_cut``): reuse the cached forward levels/sigma there, resume
    the forward sweep from the cut's frontier, and re-run the backward
    sweep in full (dependency flow crosses the cut upward, so it cannot be
    truncated).  Bit-identical to ``bc_dependencies(state, src)`` — the
    warm forward state at the resume pass equals the cold run's.

    Callers gate on ``cut >= 1`` (a cut of 0 means the source itself is
    suspect; ``incremental_bc`` falls back to the full query there — the
    same gate, via ``prior.ok``, excludes priors whose source was dead).
    """
    cut = bc_level_cut(prior.level, dirty, state.alive)
    return _delta_bc_at_cut(state, prior, cut, src)


@jax.jit
def _delta_bc_at_cut(state: GraphState, prior: BCResult, cut,
                     src) -> BCResult:
    """``delta_bc`` with the cut already computed (``incremental_bc``
    evaluates it once for its host-side gate and passes the device scalar
    through rather than re-deriving it under jit)."""
    src = jnp.asarray(src, jnp.int32)
    vcap = state.vcap
    live, srcc, dstc = _edge_views(state)
    ok = state.alive[jnp.clip(src, 0, vcap - 1)] & (src >= 0) & (src < vcap)

    cut = jnp.asarray(cut, jnp.int32)
    keep = (prior.level >= 0) & (prior.level < cut)
    level0 = jnp.where(keep, prior.level, -1)
    sigma0 = jnp.where(keep, prior.sigma, 0.0)
    front0 = level0 == cut - 1

    level, sigma, delta = _bc_coo_sweep(
        live, srcc, dstc, vcap, level0, sigma0, front0, cut - 1)
    return BCResult(ok, delta, sigma, level)


# ----------------------------- host wrappers ------------------------------

def _prior_usable(state: GraphState, prior, prior_ok) -> bool:
    return (prior is not None
            and bool(prior_ok)
            and prior.dist.shape[0] == state.vcap)


def _acct_key(kind: str, state: GraphState) -> tuple:
    """Program signature of a local jitted query: ``src`` is a traced
    scalar, so the compiled program depends only on the table capacities."""
    return ("local", kind, state.vcap, state.ecap)


def incremental_bfs(state: GraphState, prior: Optional[BFSResult],
                    dirty: Optional[jax.Array], src, *,
                    dirty_threshold: float = 0.25, accountant=None):
    """BFS on ``state`` reusing ``prior`` where possible.

    Returns ``(BFSResult, IncrementalStats)``; the result is always exactly
    what ``queries.bfs(state, src)`` would return.  With an ``accountant``
    (``repro.obs.hlo``), the cost dict of whichever compiled program
    produced the answer is deposited in ``accountant.last`` — the
    *unchanged* shortcut runs no program and deposits nothing.
    """
    if dirty is None or not _prior_usable(state, prior, prior.ok if prior else False):
        account_jit(accountant, _acct_key("bfs", state), bfs, state, src)
        return bfs(state, src), IncrementalStats("full")
    n_dirty, touched = (int(x) for x in _dirty_stats(prior.reached, dirty))
    frac = n_dirty / state.vcap
    _trace_annotate(dirty=n_dirty, dirty_frac=round(frac, 6))
    stats = IncrementalStats("delta", n_dirty, frac)
    # Unchanged beats the threshold check: churn confined outside the
    # query's reached region leaves the cached answer valid no matter how
    # large the dirty set is.
    if not touched:
        stats.mode = "unchanged"
        return prior, stats
    if frac > dirty_threshold:
        stats.mode = "full"
        account_jit(accountant, _acct_key("bfs", state), bfs, state, src)
        return bfs(state, src), stats
    account_jit(accountant, _acct_key("bfs_delta", state), delta_bfs,
                state, prior, dirty, src)
    return delta_bfs(state, prior, dirty, src), stats


def incremental_sssp(state: GraphState, prior: Optional[SSSPResult],
                     dirty: Optional[jax.Array], src, *,
                     dirty_threshold: float = 0.25, accountant=None):
    """SSSP analogue of ``incremental_bfs``."""
    if dirty is None or not _prior_usable(state, prior, prior.ok if prior else False):
        account_jit(accountant, _acct_key("sssp", state), sssp, state, src)
        return sssp(state, src), IncrementalStats("full")
    n_dirty, touched = (int(x) for x in _dirty_stats(prior.dist < jnp.inf,
                                                     dirty))
    frac = n_dirty / state.vcap
    _trace_annotate(dirty=n_dirty, dirty_frac=round(frac, 6))
    stats = IncrementalStats("delta", n_dirty, frac)
    if not touched:
        stats.mode = "unchanged"
        return prior, stats
    if frac > dirty_threshold:
        stats.mode = "full"
        account_jit(accountant, _acct_key("sssp", state), sssp, state, src)
        return sssp(state, src), stats
    res = delta_sssp(state, prior, dirty, src)
    if bool(res.negcycle):
        # Negative cycle: the full query's non-converged distances depend on
        # relaxation order; rerun it so callers see the canonical answer.
        stats.mode = "full"
        account_jit(accountant, _acct_key("sssp", state), sssp, state, src)
        return sssp(state, src), stats
    account_jit(accountant, _acct_key("sssp_delta", state), delta_sssp,
                state, prior, dirty, src)
    return res, stats


def incremental_bc(state: GraphState, prior: Optional[BCResult],
                   dirty: Optional[jax.Array], src, *,
                   dirty_threshold: float = 0.25, accountant=None):
    """BC dependencies with the engine's unchanged → delta → full ladder.

    Same *unchanged* shortcut as BFS/SSSP — churn that never touches the
    prior forward-traversal region (``level >= 0``) cannot move any
    shortest path from ``src``, so the cached dependencies stand.  A
    touched region runs the level-cut delta (``delta_bc``) when the
    shallowest suspect level is below the source (``cut >= 1``) and the
    dirty fraction is within ``dirty_threshold``; otherwise full recompute.
    """
    usable = (prior is not None and bool(prior.ok)
              and prior.level.shape[0] == state.vcap)
    if dirty is None or not usable:
        account_jit(accountant, _acct_key("bc", state), bc_dependencies,
                    state, src)
        return bc_dependencies(state, src), IncrementalStats("full")
    n_dirty, touched = (int(x) for x in _dirty_stats(prior.level >= 0, dirty))
    frac = n_dirty / state.vcap
    _trace_annotate(dirty=n_dirty, dirty_frac=round(frac, 6))
    stats = IncrementalStats("delta", n_dirty, frac)
    if not touched:
        stats.mode = "unchanged"
        return prior, stats
    if frac > dirty_threshold:
        stats.mode = "full"
        account_jit(accountant, _acct_key("bc", state), bc_dependencies,
                    state, src)
        return bc_dependencies(state, src), stats
    cut = bc_level_cut(prior.level, dirty, state.alive)
    if int(cut) < 1:
        stats.mode = "full"
        account_jit(accountant, _acct_key("bc", state), bc_dependencies,
                    state, src)
        return bc_dependencies(state, src), stats
    account_jit(accountant, _acct_key("bc_delta", state), _delta_bc_at_cut,
                state, prior, cut, src)
    return _delta_bc_at_cut(state, prior, cut, src), stats


# ------------------------------ validation --------------------------------

def results_equal(a, b) -> bool:
    """CMPTREE over result tuples: region, tree, and payload all bit-equal."""
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def validate_incremental(state: GraphState, src, result, kind: str) -> bool:
    """``cmp_tree``-style check: does ``result`` match a fresh collect?

    Compares the reached region, the traversal tree, and the payload of the
    incremental answer against ``queries.bfs``/``sssp``/``bc_dependencies``
    run from scratch on the same snapshot — the engine's analogue of the
    paper's CMPTREE validation of a SCAN.
    """
    fresh = {"bfs": bfs, "sssp": sssp, "bc": bc_dependencies}[kind](state, src)
    return results_equal(result, fresh)
