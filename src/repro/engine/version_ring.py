"""Fixed-depth MVCC version ring with per-commit dirty-vertex sets.

The core layer already gives every committed batch a new immutable
``GraphState`` (a value commit is the functional analogue of the paper's
CAS-committed heap mutation).  The ring makes that history *addressable*:

  * the last ``depth`` commits stay resident, so a reader can pin any of
    them and keep querying a stable snapshot while writers race ahead
    (the wait-free-snapshot idea of Bhardwaj et al., at batch granularity);
  * every commit records the **dirty-vertex set** it disturbed, derived
    from the ``ecnt``/``alive`` deltas (``core.updates.dirty_vertices``).
    ``dirty_between(a, b)`` ORs the per-commit sets into the exact region
    a delta query must re-examine — the paper's SNode/ecnt selectivity
    turned into a first-class index that ``engine.incremental`` consumes.

Pinning semantics: ``pin`` holds a version beyond ring rotation (the entry
moves to a side table instead of being evicted); ``release`` drops it once
the last pin is gone.  Dirty-set history, however, lives only in the ring
window — ``dirty_between`` returns ``None`` when the window no longer
covers the span, which callers treat as "fall back to full recompute".

Concurrency: the ring is shared between the async serving front end's
admission path (pin), its dispatcher (read + release), and the update
scheduler (commit/evict), so every mutation and every read that feeds a
decision runs under one re-entrant lock.  Pins are refcounted —
concurrent queries at the same version share one table entry — and a
:class:`PinnedSnapshot` handle releases exactly once no matter how many
threads call ``release()`` on it (the released flag flips under the ring
lock, not in racy Python-attribute space).  ``try_pin`` exists for
check-then-use sites (e.g. stale-reply assembly): residency check and
refcount bump happen in one critical section, so the caller either holds
the version or learns it is gone — never a reply naming an evicted
version.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.graph_state import GraphState
from repro.core.updates import dirty_vertices_padded
from repro.resil.faults import P_RING_EVICT, inject


class RingEntry(NamedTuple):
    """One committed version: ring-assigned id, state, dirty set vs parent."""

    version: int
    state: GraphState
    dirty: jax.Array  # bool[vcap] — vertices disturbed by THIS commit


@dataclass
class PinnedSnapshot:
    """A pin handle; use as a context manager or call ``release()``.

    ``release()`` is idempotent under concurrency: the first caller to
    flip ``_released`` (inside the ring lock) decrements the refcount,
    every later or racing caller is a no-op.  Double-release therefore
    can never steal a pin another in-flight query still holds.
    """

    ring: "VersionRing"
    version: int
    _released: bool = False

    @property
    def state(self) -> GraphState:
        entry = self.ring.get_entry(self.version)
        if entry is None:
            raise RuntimeError(f"pinned version {self.version} vanished")
        return entry.state

    def release(self) -> None:
        self.ring._release_handle(self)

    def __enter__(self) -> "PinnedSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class VersionRing:
    """Ring of the last ``depth`` committed ``GraphState`` versions."""

    def __init__(self, initial_state: GraphState, depth: int = 8):
        if depth < 1:
            raise ValueError("ring depth must be >= 1")
        self.depth = depth
        first = RingEntry(
            version=0,
            state=initial_state,
            dirty=jnp.zeros((initial_state.vcap,), jnp.bool_),
        )
        self._window: deque[RingEntry] = deque([first])
        self._pins: dict[int, int] = {}          # version -> pin count
        self._parked: dict[int, RingEntry] = {}  # pinned but rotated out
        self.evictions = 0
        # One re-entrant lock covers window rotation, the pin table, and
        # the parked side table: commit/evict, pin/release, and the
        # residency reads that feed decisions all serialize here.  The
        # lock is held only around bookkeeping (dict/deque ops), never
        # around device compute, so it is not a dispatch bottleneck.
        self._lock = threading.RLock()

    # ------------------------------ commits ------------------------------

    @property
    def latest(self) -> RingEntry:
        with self._lock:
            return self._window[-1]

    @property
    def oldest_version(self) -> int:
        with self._lock:
            return self._window[0].version

    def commit(self, state: GraphState) -> RingEntry:
        """Append a new version; dirty set is derived vs the previous latest.

        The commit is atomic: the ``ring.evict`` fault point (an eviction
        racing an in-flight query) fires BEFORE the append, so a planned
        eviction failure leaves the ring exactly as it was — callers
        (the scheduler's atomic-commit path) rely on that.  The dirty-set
        derivation (device work) runs outside the lock; only the window
        rotation itself is serialized against pin/release.
        """
        with self._lock:
            if len(self._window) >= self.depth:
                inject(P_RING_EVICT)
            prev = self._window[-1]
        dirty = dirty_vertices_padded(prev.state, state)
        with self._lock:
            if self._window[-1].version != prev.version:
                raise RuntimeError(
                    "concurrent VersionRing.commit: commits must be "
                    "serialized by the scheduler")
            entry = RingEntry(
                version=prev.version + 1, state=state, dirty=dirty)
            self._window.append(entry)
            while len(self._window) > self.depth:
                old = self._window.popleft()
                if self._pins.get(old.version, 0) > 0:
                    self._parked[old.version] = old
                else:
                    self.evictions += 1
            return entry

    # ------------------------------ reads --------------------------------

    def get_entry(self, version: int) -> Optional[RingEntry]:
        with self._lock:
            for e in self._window:
                if e.version == version:
                    return e
            return self._parked.get(version)

    def get(self, version: int) -> Optional[GraphState]:
        e = self.get_entry(version)
        return None if e is None else e.state

    def dirty_between(self, v_from: int, v_to: int) -> Optional[jax.Array]:
        """OR of dirty sets over commits ``v_from+1 .. v_to`` (inclusive).

        ``None`` when the ring window no longer covers the whole span (the
        caller must recompute from scratch).  ``v_from == v_to`` yields the
        all-False mask (nothing moved), sized to that version's ``vcap`` —
        like the general path sizes to ``v_to``'s — so it requires the
        version to still be resident.
        """
        if v_from > v_to:
            raise ValueError(f"dirty_between({v_from}, {v_to}): reversed span")
        with self._lock:
            if v_to > self._window[-1].version:
                return None
            if v_from == v_to:
                entry = self.get_entry(v_to)
                if entry is None:
                    return None
                return jnp.zeros((entry.state.vcap,), jnp.bool_)
            if v_from + 1 < self._window[0].version:
                return None  # span starts before window: dirty info evicted
            masks = [e.dirty for e in self._window
                     if v_from < e.version <= v_to]
        if len(masks) != v_to - v_from:
            return None
        vcap = masks[-1].shape[0]
        acc = jnp.zeros((vcap,), jnp.bool_)
        for m in masks:
            if m.shape[0] != vcap:  # vertex table grew inside the span
                m = jnp.concatenate(
                    [m, jnp.zeros((vcap - m.shape[0],), jnp.bool_)])
            acc = acc | m
        return acc

    # ------------------------------ pinning ------------------------------

    def pin(self, version: Optional[int] = None) -> PinnedSnapshot:
        """Pin a resident version (default: latest) against eviction.

        Residency check and refcount bump are one critical section, so a
        returned handle always holds the version it names.
        """
        with self._lock:
            if version is None:
                version = self._window[-1].version
            if self.get_entry(version) is None:
                raise KeyError(
                    f"version {version} is not resident in the ring")
            self._pins[version] = self._pins.get(version, 0) + 1
            return PinnedSnapshot(self, version)

    def try_pin(self, version: Optional[int] = None
                ) -> Optional[PinnedSnapshot]:
        """Like :meth:`pin` but returns ``None`` for a non-resident
        version instead of raising — the atomic form of the
        check-then-pin pattern callers would otherwise race."""
        with self._lock:
            try:
                return self.pin(version)
            except KeyError:
                return None

    def release(self, version: int) -> None:
        """Drop one pin on ``version``; extra releases are no-ops.

        Refcounted: the parked entry is evicted only when the LAST pin
        goes, so concurrent queries sharing a version never unpin each
        other.
        """
        with self._lock:
            count = self._pins.get(version, 0)
            if count <= 0:
                return  # already fully released: idempotent
            if count == 1:
                self._pins.pop(version, None)
                if self._parked.pop(version, None) is not None:
                    self.evictions += 1
            else:
                self._pins[version] = count - 1

    def _release_handle(self, handle: PinnedSnapshot) -> None:
        """Release a :class:`PinnedSnapshot` exactly once (see its
        docstring); the released flag flips under the ring lock."""
        with self._lock:
            if handle._released:
                return
            handle._released = True
            self.release(handle.version)

    def pin_count(self, version: int) -> int:
        with self._lock:
            return self._pins.get(version, 0)

    def pinned_versions(self) -> list[int]:
        with self._lock:
            return sorted(self._pins)
