"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) ff=21504 vocab=262144,
5 local(window 1024) : 1 global, 128k ctx. [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    window=1024, local_global=5, rope_theta=1e6,
)
