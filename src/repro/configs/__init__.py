"""Assigned architecture configs (+ the paper's own graph configs).

``get_config(name)`` returns the full published config; ``reduced(cfg)``
shrinks it for CPU smoke tests (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "mamba2_780m",
    "qwen3_32b",
    "codeqwen15_7b",
    "gemma3_27b",
    "mistral_nemo_12b",
    "llama4_maverick_400b",
    "granite_moe_1b",
    "qwen2_vl_72b",
    "whisper_large_v3",
    "zamba2_12b",
]

# shape grid assigned to the LM family (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: SSM / hybrid only (DESIGN.md §4).
LONG_OK_FAMILIES = ("ssm", "hybrid")


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def shapes_for(cfg: ModelConfig):
    """The live (shape) cells for an architecture (skips documented)."""
    out = {}
    for shape, (s, b, kind) in SHAPES.items():
        if shape == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
            continue
        out[shape] = (s, b, kind)
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family miniature for CPU smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        xent_chunk=32,
        attn_chunk=32,
        remat=False,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.attn_every:
        kw.update(attn_every=2, num_layers=5)   # 2 super-blocks + tail of 1
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=24)
    if cfg.window:
        kw.update(window=16)
    import jax.numpy as jnp
    kw.update(dtype=jnp.float32)
    return dataclasses.replace(cfg, **kw)
