"""mamba2-780m [ssm]: 48L d_model=1536, attn-free, SSD state=128.
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
    vocab_size=50432,  # 50280 padded to 256x (Megatron-style) so vocab shards over TP=16
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
    tie_embeddings=True,
)
