"""zamba2-1.2b [hybrid]: 38L Mamba2 (d=2048, state=64) + weight-shared
attention block (32H, kv=32) every 6 layers, shared-MLP ff=8192, vocab=32000.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
    attn_every=6,
)
