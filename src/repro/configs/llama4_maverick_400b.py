"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) expert ff=8192
vocab=202048, 128 experts top-1. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

moment_dtype=float8_e5m2: at this scale (~600B params as configured: all 48
layers MoE x 128 experts x ff 8192) even bf16 AdamW moments do not fit a
single 16 GB/chip pod alongside params+grads; 1-byte moments (per-leaf f32
math, cast on store) are the documented deliberate trade — the alternative
is requiring >= 2 pods for training this arch."""
import jax.numpy as jnp
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=128, top_k=1, capacity_factor=1.25,
    rope_theta=5e5, moment_dtype=jnp.float8_e5m2,
)
