"""whisper-large-v3 [audio]: enc-dec 32L+32L d=1280 20H (MHA) ff=5120
vocab=51866, conv frontend STUB (input_specs supplies frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=52224,  # 51866 padded to 256x so vocab shards over TP=16
    encoder_layers=32, encoder_seq=1500,
)
