"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) ff=29568 vocab=152064,
M-RoPE (t/h/w sections 16/24/24), vision frontend stubbed.
[arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    mrope_sections=(16, 24, 24), rope_theta=1e6,
)
