"""Compatible-query batching: N pinned queries, one compiled dispatch.

The dispatcher groups admitted requests by ``(kind, version)`` and this
module turns each group into at most two compiled calls:

  * the **full** rung is ``jax.vmap`` of the single-source query
    (``queries.bfs`` / ``sssp`` / ``bc_dependencies``) over the stacked
    source axis — N concurrent BFS queries at version ``v`` cost one
    compiled program instead of N dispatches;
  * the **delta** rung is ``jax.vmap`` of the engine's delta kernels
    (``delta_bfs`` / ``delta_sssp`` / ``_delta_bc_at_cut``) over stacked
    ``(prior, dirty, src)`` lanes — each lane carries its own prior and
    its own accumulated dirty mask, so requests cached at *different*
    earlier versions still share the dispatch.

Per-lane answers are bit-identical to the sequential single-source
calls: ``jax.vmap`` batches ``lax.while_loop`` by running the body while
*any* lane is active and ``select``-ing each finished lane's carry
unchanged, so a lane that converged early keeps exactly the value the
unbatched loop would have produced.  The concurrent differential suite
(`tests/stream_differential.py`) holds this as its oracle.

Classification (which rung a request rides) reuses the ladder's own
pieces — ``ring.dirty_between``, ``_dirty_stats``, the per-kind
threshold consult, ``bc_level_cut`` — so the batched ladder demotes on
exactly the same evidence as ``engine.incremental``'s sequential one.

Lane stacks are padded up to the next power of two (replicating lane 0,
whose extra output rows are discarded) so the number of compiled batch
variants stays logarithmic in ``max_batch`` instead of linear.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import queries
from repro.engine.incremental import _delta_bc_at_cut, _dirty_stats, \
    delta_bfs, delta_sssp

__all__ = ["Lane", "classify_local", "dispatch_local_group", "pad_pow2"]

#: vmapped full rungs: state broadcast, source axis stacked.
_VFULL = {
    "bfs": jax.jit(jax.vmap(queries.bfs, in_axes=(None, 0))),
    "sssp": jax.jit(jax.vmap(queries.sssp, in_axes=(None, 0))),
    "bc": jax.jit(jax.vmap(queries.bc_dependencies, in_axes=(None, 0))),
}

#: vmapped delta rungs: state broadcast; prior / dirty-or-cut / source
#: stacked per lane.
_VDELTA = {
    "bfs": jax.jit(jax.vmap(delta_bfs, in_axes=(None, 0, 0, 0))),
    "sssp": jax.jit(jax.vmap(delta_sssp, in_axes=(None, 0, 0, 0))),
    "bc": jax.jit(jax.vmap(_delta_bc_at_cut, in_axes=(None, 0, 0, 0))),
}

#: reached-region mask of a cached local result, per kind (the unchanged
#: test: dirty ∩ reached == ∅ ⇒ the cached answer stands).
_REACHED = {
    "bfs": lambda r: r.reached,
    "sssp": lambda r: r.dist < jnp.inf,
    "bc": lambda r: r.level >= 0,
}


def pad_pow2(n: int) -> int:
    """Smallest power of two >= n (compile-variant bucketing)."""
    size = 1
    while size < n:
        size *= 2
    return size


@dataclass
class Lane:
    """One request's slice of a batched dispatch."""

    index: int              # position in the dispatcher's group
    src: int
    mode: str               # "unchanged" | "delta" | "full"
    prior: object = None    # cached result (unchanged/delta lanes)
    dirty: object = None    # accumulated dirty mask (delta bfs/sssp)
    cut: object = None      # warm-start level cut (delta bc)
    dirty_frac: Optional[float] = None


def classify_local(service, kind: str, src: int, version: int,
                   state) -> Lane:
    """Which rung does this request ride?  Mirrors the gates of
    ``engine.incremental.incremental_*`` exactly (prior usability, the
    unchanged shortcut, the threshold consult, BC's level-cut floor), so
    a batched query demotes on the same evidence as a sequential one.
    """
    with service._cache_lock:
        slot = service._cache.get((kind, src))
    if slot is None or not service._breaker_allows(kind):
        return Lane(0, src, "full")
    prior = slot.result
    usable = bool(prior.ok) and (
        prior.level.shape[0] == state.vcap if kind == "bc"
        else prior.dist.shape[0] == state.vcap)
    if not usable:
        return Lane(0, src, "full")
    if slot.version == version:
        return Lane(0, src, "unchanged", prior=prior)
    dirty = service.ring.dirty_between(slot.version, version)
    if dirty is None:
        return Lane(0, src, "full")
    reached = _REACHED[kind](prior)
    n_dirty, touched = (int(x) for x in _dirty_stats(reached, dirty))
    frac = n_dirty / state.vcap
    if not touched:
        return Lane(0, src, "unchanged", prior=prior, dirty_frac=frac)
    if frac > service._threshold(kind):
        return Lane(0, src, "full", dirty_frac=frac)
    if kind == "bc":
        cut = queries.bc_level_cut(prior.level, dirty, state.alive)
        if int(cut) < 1:
            return Lane(0, src, "full", dirty_frac=frac)
        return Lane(0, src, "delta", prior=prior, cut=cut, dirty_frac=frac)
    return Lane(0, src, "delta", prior=prior, dirty=dirty, dirty_frac=frac)


def _stack_pad(trees: List, pad: int):
    """Stack pytrees along a new leading lane axis, replicating lane 0
    ``pad`` more times (padding lanes are discarded by the caller)."""
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)
    if pad:
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.concatenate(
                [x, jnp.repeat(x[:1], pad, axis=0)], axis=0), stacked)
    return stacked


def _unstack(batched, n: int) -> List:
    """Lane ``i``'s result tree, for the first ``n`` (unpadded) lanes."""
    return [jax.tree_util.tree_map(lambda x: x[i], batched)
            for i in range(n)]


def dispatch_local_group(service, kind: str, state,
                         lanes: List[Lane]) -> Tuple[List, Dict[str, int]]:
    """Run one ``(kind, version)`` group's device work.

    Returns ``(results, dispatch_sizes)`` where ``results[i]`` answers
    ``lanes[i]`` and ``dispatch_sizes`` maps rung name -> lane count for
    each compiled call that actually ran.  Lanes may be *reclassified*
    ``delta -> full`` on the way (a delta SSSP that surfaced a negative
    cycle re-runs full for the canonical answer, exactly the
    ``incremental_sssp`` contract) — callers must read ``lane.mode``
    after this returns.
    """
    results: List = [None] * len(lanes)
    sizes: Dict[str, int] = {}
    full_lanes = [ln for ln in lanes if ln.mode == "full"]
    delta_lanes = [ln for ln in lanes if ln.mode == "delta"]
    for ln in lanes:
        if ln.mode == "unchanged":
            results[ln.index] = ln.prior

    if delta_lanes:
        n = len(delta_lanes)
        pad = pad_pow2(n) - n
        srcs = jnp.asarray([ln.src for ln in delta_lanes], jnp.int32)
        if pad:
            srcs = jnp.concatenate([srcs, jnp.repeat(srcs[:1], pad)])
        priors = _stack_pad([ln.prior for ln in delta_lanes], pad)
        if kind == "bc":
            cuts = jnp.asarray([ln.cut for ln in delta_lanes], jnp.int32)
            if pad:
                cuts = jnp.concatenate([cuts, jnp.repeat(cuts[:1], pad)])
            out = _VDELTA[kind](state, priors, cuts, srcs)
        else:
            dirt = _stack_pad([ln.dirty for ln in delta_lanes], pad)
            out = _VDELTA[kind](state, priors, dirt, srcs)
        per_lane = _unstack(out, n)
        sizes["delta"] = n
        for ln, res in zip(delta_lanes, per_lane):
            if kind == "sssp" and bool(res.negcycle):
                # Born-since-prior negative cycle: the full query's
                # partially-relaxed distances are the canonical answer.
                ln.mode = "full"
                full_lanes.append(ln)
            else:
                results[ln.index] = res

    if full_lanes:
        n = len(full_lanes)
        pad = pad_pow2(n) - n
        srcs = jnp.asarray([ln.src for ln in full_lanes], jnp.int32)
        if pad:
            srcs = jnp.concatenate([srcs, jnp.repeat(srcs[:1], pad)])
        out = _VFULL[kind](state, srcs)
        per_lane = _unstack(out, n)
        sizes["full"] = n
        for ln, res in zip(full_lanes, per_lane):
            results[ln.index] = res

    return results, sizes
