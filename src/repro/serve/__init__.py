"""Non-blocking async serving front end.

``AsyncGraphService`` wraps a :class:`repro.engine.service.GraphService`
(or the sharded service) with concurrent admission: queries pin a ring
version at arrival and resolve as Futures; a dispatcher batches
compatible queries (same kind, same pinned version) into single vmapped
compiled calls; updates commit through the (thread-safe) scheduler
without ever blocking in-flight reads on older versions.  See
``serve.async_service`` for the admission → pin → batch → dispatch
lifecycle and ``serve.batch`` for the bit-identity argument.
"""
from .async_service import AsyncGraphService, ServeStats
from .batch import Lane, classify_local, dispatch_local_group, pad_pow2

__all__ = [
    "AsyncGraphService", "Lane", "ServeStats", "classify_local",
    "dispatch_local_group", "pad_pow2",
]
