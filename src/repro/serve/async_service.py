"""AsyncGraphService: non-blocking serving front end over the engine.

Many clients submit updates and queries concurrently; the paper's
property — a writer never blocks a reader — becomes the serving
lifecycle **admission → pin → batch → dispatch**:

  * **admission** (any client thread): ``query_async`` atomically reads
    the latest ring version and takes a refcounted pin on it
    (``VersionRing.pin`` — one critical section, so the version cannot
    evict between read and pin), stamps the request's deadline from the
    resilience policy, and enqueues it.  The caller gets a
    ``concurrent.futures.Future`` immediately.
  * **pin**: the pin holds the version resident (parked past ring
    rotation if needed) and shields the request's cache slot from LRU
    pruning (``prune_result_cache`` respects the pin table), while
    updates keep committing through the scheduler — in-flight reads on
    older versions never block a commit, and vice versa.
  * **batch** (dispatcher thread): queued requests are drained and
    grouped by ``(kind, version)``; each group is classified onto the
    unchanged / delta / full rungs with the sequential ladder's own
    gates (``serve.batch.classify_local``).
  * **dispatch**: each rung that has lanes runs as ONE compiled call —
    ``jax.vmap`` over the stacked source axis (full) or stacked
    ``(prior, dirty, src)`` lanes (delta) — then per-request results are
    sliced out, cached, counted, traced, and the futures resolved.  A
    dispatch failure (including the ``serve.dispatch`` fault point)
    degrades to the per-request resilient path (``service.query``), so a
    poisoned batch loses throughput, never a request.

Updates flow through ``submit``/``submit_many`` from any thread — the
scheduler's lock serializes the op-log and whichever client fills a
batch carries out the commit, overlapping the dispatcher's query
compute (the ring has its own lock; neither path holds both).

Consistency: every reply is exact at the ring version it claims — the
batched lanes are bit-identical to sequential single-source collects
(see ``serve.batch``) — and each request linearizes at its admission
point (local service) or at dispatch (fallback path, which answers at
the then-latest version and says so in ``reply.version``).

Telemetry (when the wrapped service carries it): ``serve_queue_depth``
gauge, ``serve_batch_size`` histogram (lanes per compiled dispatch),
``serve_request_us`` histogram (admission -> reply), per-batch
``dispatch`` spans and per-request ``query`` records, and
``serve_batched_dispatches`` / ``serve_fallbacks`` counters — the
conservation invariant ``unchanged + delta + full == queries == clean
query trace records`` holds for batched queries exactly as for
sequential ones.
"""
from __future__ import annotations

import contextvars
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional

from repro.core.snapshot import ScanStats
from repro.engine.service import GraphService, QueryReply
from repro.obs.trace import maybe_span
from repro.resil.faults import P_SERVE_DISPATCH, InjectedCrash, \
    InjectedFault, inject

from .batch import classify_local, dispatch_local_group

__all__ = ["AsyncGraphService"]


@dataclass
class _Request:
    kind: str
    src: object
    version: int
    pin: object                      # PinnedSnapshot (refcounted handle)
    future: Future
    t_admit: float
    deadline_at: Optional[float]     # absolute perf_counter bound, or None
    lane: object = None

    def expired(self) -> bool:
        return (self.deadline_at is not None
                and time.perf_counter() >= self.deadline_at)


@dataclass
class ServeStats:
    """Host-side tallies of the front end itself (the per-query ladder
    tallies stay on the wrapped service's ``ServiceStats``)."""

    admitted: int = 0
    batched_dispatches: int = 0      # compiled calls serving >= 2 lanes
    dispatches: int = 0              # compiled calls, any width
    fallbacks: int = 0               # requests served by the resilient path
    deadline_expired: int = 0
    max_batch_seen: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)


class AsyncGraphService:
    """Threaded serving front end over a :class:`GraphService` (or the
    sharded service, batching by request dedup — see ``_dispatch_group``).

    Use as a context manager (``with AsyncGraphService(svc) as srv:``) or
    call ``start()``/``stop()``.  ``query_async`` returns a Future;
    ``query`` blocks on it.  ``submit``/``flush`` pass through to the
    (thread-safe) scheduler from any thread.
    """

    def __init__(self, service, *, max_batch: int = 32,
                 poll_ms: float = 2.0, max_queue: int = 4096):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.service = service
        self.max_batch = max_batch
        self.poll_s = max(poll_ms, 0.1) / 1e3
        self._queue: queue_mod.Queue = queue_mod.Queue(maxsize=max_queue)
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._inflight = 0               # admitted, not yet resolved
        self._inflight_lock = threading.Lock()
        self._drained = threading.Condition(self._inflight_lock)
        self.stats = ServeStats()
        #: local services get the vmapped compatible-query fast path;
        #: anything else (the sharded service) batches by dedup.
        self._local = isinstance(service, GraphService)

    # ----------------------------- lifecycle -----------------------------

    def start(self) -> "AsyncGraphService":
        if self._thread is not None:
            raise RuntimeError("front end already started")
        self._running = True
        # The dispatcher runs in a copy of the STARTING thread's context:
        # contextvars (the active fault plan, tracing nesting defaults)
        # propagate into dispatch, so a chaos scope wrapped around
        # start() exercises batched dispatch too.
        ctx = contextvars.copy_context()
        self._thread = threading.Thread(
            target=lambda: ctx.run(self._loop), name="serve-dispatcher",
            daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        if self._thread is None:
            return
        if drain:
            self.drain()
        self._running = False
        self._thread.join()
        self._thread = None
        # Anything still queued (stop(drain=False)) must not leak pins.
        while True:
            try:
                req = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            self._fail(req, RuntimeError("front end stopped"))

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request has resolved (an in-flight
        count, not a queue peek — a request popped by the dispatcher but
        not yet answered still holds the drain)."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        with self._drained:
            while self._inflight > 0:
                rem = (None if deadline is None
                       else deadline - time.perf_counter())
                if rem is not None and rem <= 0:
                    return False
                self._drained.wait(timeout=rem)
        return True

    def __enter__(self) -> "AsyncGraphService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc[0] is None)

    # ------------------------------ updates ------------------------------

    def submit(self, op) -> int:
        """Thread-safe update intake: the scheduler lock serializes the
        op-log; a filled batch commits on THIS caller's thread, fully
        overlapped with the dispatcher's pinned-version query compute."""
        return self.service.submit(op)

    def submit_many(self, ops) -> list:
        return self.service.submit_many(ops)

    def flush(self):
        return self.service.flush()

    # ------------------------------ queries ------------------------------

    def query_async(self, kind: str, src, mode: str = "icn") -> Future:
        """Admit one query: pin the latest version, enqueue, return a
        Future resolving to a :class:`QueryReply` exact at that version
        (or at the fallback path's dispatch version, which the reply
        names).  Only PG-Icn admission is served here; PG-Cn's
        double-collect loop needs the sequential path."""
        if self._thread is None:
            raise RuntimeError("front end not started")
        if mode != "icn":
            raise ValueError("async admission serves icn queries; use "
                             "service.query(..., mode='cn') directly")
        if kind not in self.service._kinds:
            raise KeyError(f"unknown query kind {kind!r}")
        self.service._check_srcs(kind, src)
        pol = self.service.policy
        pin = self.service.ring.pin()        # atomic read-latest + pin
        now = time.perf_counter()
        deadline = (now + pol.deadline_ms / 1e3
                    if pol is not None and pol.deadline_ms != float("inf")
                    else None)
        req = _Request(kind, src, pin.version, pin, Future(), now, deadline)
        with self._inflight_lock:
            self._inflight += 1
        try:
            self._queue.put(req, timeout=5.0)
        except queue_mod.Full:
            self._done()
            pin.release()
            raise RuntimeError("admission queue full") from None
        with self.stats._lock:
            self.stats.admitted += 1
        self._observe_queue_depth()
        return req.future

    def _done(self) -> None:
        with self._drained:
            self._inflight -= 1
            if self._inflight <= 0:
                self._drained.notify_all()

    def query(self, kind: str, src, mode: str = "icn",
              timeout: Optional[float] = None) -> QueryReply:
        return self.query_async(kind, src, mode).result(timeout=timeout)

    # ----------------------------- dispatcher ----------------------------

    def _telemetry(self):
        return self.service.telemetry

    def _observe_queue_depth(self) -> None:
        tel = self._telemetry()
        if tel is not None:
            tel.registry.gauge(
                "serve_queue_depth",
                service=self.service._service_name).set(self._queue.qsize())

    def _loop(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=self.poll_s)
            except queue_mod.Empty:
                if not self._running:
                    return
                continue
            batch = [first]
            while len(batch) < self.max_batch:
                try:
                    batch.append(self._queue.get_nowait())
                except queue_mod.Empty:
                    break
            self._observe_queue_depth()
            try:
                self._dispatch(batch)
            except InjectedCrash:
                # simulated process death: the dispatcher dies like the
                # process would; unresolved futures stay pending, exactly
                # as a crashed server leaves its clients
                raise
            except Exception as exc:  # pragma: no cover - defensive
                # _dispatch_group degrades per-request; anything that
                # still escapes must not kill the dispatcher silently.
                for req in batch:
                    if not req.future.done():
                        self._fail(req, exc)

    def _dispatch(self, batch) -> None:
        groups = {}
        for req in batch:
            groups.setdefault((req.kind, req.version), []).append(req)
        # Ascending version order: a group's cache stores must never be
        # overwritten by an older group dispatched after it.
        for (kind, version), reqs in sorted(groups.items(),
                                            key=lambda kv: kv[0][1]):
            live = []
            for req in reqs:
                if req.expired():
                    self._finish_expired(req)
                else:
                    live.append(req)
            if live:
                self._dispatch_group(kind, version, live)

    def _dispatch_group(self, kind: str, version: int, reqs) -> None:
        svc = self.service
        tel = self._telemetry()
        tracer = tel.tracer if tel is not None else None
        entry = svc.ring.get_entry(version)  # pinned => resident
        try:
            with maybe_span(tracer, "dispatch",
                            service=svc._service_name, kind=kind,
                            version=version, batch=len(reqs)) as sp:
                inject(P_SERVE_DISPATCH)
                if entry is None:
                    raise RuntimeError(
                        f"pinned version {version} vanished")
                if self._local:
                    sizes = self._dispatch_local(kind, version, entry,
                                                 reqs)
                else:
                    sizes = self._dispatch_dedup(kind, version, entry,
                                                 reqs)
                sp.set(**{f"lanes_{k}": v for k, v in sizes.items()})
        except InjectedCrash:
            raise
        except (InjectedFault, Exception):
            # The batch is poisoned, the requests are not: each one NOT
            # yet answered (a failure can land mid-batch, after some
            # futures resolved) retries on the per-request resilient
            # ladder.
            for req in reqs:
                if not req.future.done():
                    self._fallback(req)

    def _dispatch_local(self, kind: str, version: int, entry, reqs):
        """The vmapped fast path (local service): classify, batch, slice."""
        svc = self.service
        tel = self._telemetry()
        state = entry.state
        for i, req in enumerate(reqs):
            req.lane = classify_local(svc, kind, req.src, version, state)
            req.lane.index = i
        lanes = [req.lane for req in reqs]
        results, sizes = dispatch_local_group(svc, kind, state, lanes)
        self._note_dispatch(kind, sizes)
        for req, res in zip(reqs, results):
            svc._cache_store((kind, req.src), version, res)
            self._finish(req, res, req.lane.mode, version,
                         validated=False)
        return sizes

    def _dispatch_dedup(self, kind: str, version: int, entry, reqs):
        """Sharded (or any non-local) service: identical ``(kind, src)``
        requests at one version share a single collect — the sharded
        query's source axis is already batched per collect, so the win
        here is collapsing duplicate request keys; everything else rides
        the service's own ladder at the latest version."""
        svc = self.service
        by_key = {}
        for req in reqs:
            by_key.setdefault(svc._key(kind, req.src), []).append(req)
        sizes = {"dedup": 0}
        for key, shared in by_key.items():
            if version == svc.ring.latest.version:
                entry2, res, mode = svc._traced_collect(
                    kind, shared[0].src, key)
                self._note_dispatch(kind, {"dedup": len(shared)})
                sizes["dedup"] += len(shared)
                for req in shared:
                    self._finish(req, res, mode, entry2.version,
                                 validated=svc._icn_validated(res))
            else:
                # The mesh view tracks the latest version only; a group
                # pinned behind it answers per-request at latest (the
                # reply names its version) via the resilient path.
                for req in shared:
                    self._fallback(req)
        return sizes

    # ----------------------------- completion ----------------------------

    def _note_dispatch(self, kind: str, sizes) -> None:
        tel = self._telemetry()
        for rung, n in sizes.items():
            with self.stats._lock:
                self.stats.dispatches += 1
                self.stats.max_batch_seen = max(self.stats.max_batch_seen,
                                                n)
                if n >= 2:
                    self.stats.batched_dispatches += 1
            if tel is not None:
                tel.registry.histogram(
                    "serve_batch_size", service=self.service._service_name,
                    kind=kind, rung=rung).observe(n)
                if n >= 2:
                    tel.registry.counter(
                        "serve_batched_dispatches",
                        service=self.service._service_name,
                        kind=kind).inc()

    def _finish(self, req: _Request, result, mode: str, version: int,
                validated: bool) -> None:
        """Resolve one request from the batched path: stats, trace
        record, latency observation, future, pin release — the same
        bookkeeping contract as ``BaseGraphService.query``."""
        svc = self.service
        svc.stats.queries += 1
        svc.stats.collects += 1
        svc.stats.count(mode)
        reply = QueryReply(result, version, mode, validated,
                           ScanStats(collects=1))
        tel = self._telemetry()
        if tel is not None:
            with tel.tracer.span("query", service=svc._service_name,
                                 kind=req.kind, cn=False) as sp:
                sp.set(version=version, mode=mode, collects=1,
                       batched=True, validated=validated,
                       wait_us=round(
                           (time.perf_counter() - req.t_admit) * 1e6, 1))
        self._resolve(req, reply)

    def _fallback(self, req: _Request) -> None:
        """Serve one request on the sequential resilient path (counts,
        traces, and degrades exactly as a direct ``service.query``)."""
        with self.stats._lock:
            self.stats.fallbacks += 1
        try:
            reply = self.service.query(req.kind, req.src)
        except InjectedCrash:
            raise
        except Exception as exc:
            self._fail(req, exc)
            return
        self._resolve(req, reply)

    def _finish_expired(self, req: _Request) -> None:
        """Deadline passed while queued: stale-serve if the policy
        allows (degraded, exact at the version it names), else a
        TimeoutError — never silent, never a torn read."""
        svc = self.service
        with self.stats._lock:
            self.stats.deadline_expired += 1
        reply = (svc._stale_reply(req.kind, req.src)
                 if svc.policy is not None and svc.policy.allow_stale
                 else None)
        if reply is not None:
            svc.stats.degraded += 1
            tel = self._telemetry()
            if tel is not None:
                # same record shape as a sync degraded reply, so the
                # trace/stats reconciliation survives expiry
                with tel.tracer.span("query", service=svc._service_name,
                                     kind=req.kind, cn=False) as sp:
                    sp.set(version=reply.version, mode=reply.mode,
                           collects=0, batched=True, degraded=True,
                           stale_version=reply.stale_version,
                           validated=False)
            self._resolve(req, reply)
            return
        self._fail(req, TimeoutError(
            f"query ({req.kind}, {req.src}) missed its deadline before "
            f"dispatch"))

    def _fail(self, req: _Request, exc: BaseException) -> None:
        req.pin.release()
        req.future.set_exception(exc)
        self._done()

    def _resolve(self, req: _Request, reply: QueryReply) -> None:
        tel = self._telemetry()
        if tel is not None:
            tel.registry.histogram(
                "serve_request_us",
                service=self.service._service_name,
                kind=req.kind).observe(
                    (time.perf_counter() - req.t_admit) * 1e6)
        req.pin.release()
        req.future.set_result(reply)
        self._done()
