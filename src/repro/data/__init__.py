from .pipeline import SyntheticTokens, shard_batch  # noqa: F401
from .rmat import rmat_edges, load_rmat_graph  # noqa: F401
