"""R-MAT graph generator (Chakrabarti et al.) — the paper's benchmark input.

Defaults follow the paper exactly: a=0.5, b=0.1, c=0.1, d=0.3, edge count
10x vertices unless stated, integer weights uniform in [1, log2(N)].
"""
from __future__ import annotations

import numpy as np

from repro.core.graph_state import GraphState, from_edge_list


def rmat_edges(n_vertices: int, n_edges: int, a=0.5, b=0.1, c=0.1, d=0.3,
               seed: int = 0, weighted: bool = True):
    """Returns (src, dst, w) int32/float32 arrays. n_vertices must be 2^k."""
    scale = int(np.log2(n_vertices))
    assert 2 ** scale == n_vertices, "R-MAT needs a power-of-two vertex count"
    rng = np.random.default_rng(seed)
    src = np.zeros(n_edges, np.int64)
    dst = np.zeros(n_edges, np.int64)
    probs = np.array([a, b, c, d]).cumsum()
    for level in range(scale):
        r = rng.random(n_edges)
        quad = np.searchsorted(probs, r)
        half = n_vertices >> (level + 1)
        src += np.where((quad == 2) | (quad == 3), half, 0)
        dst += np.where((quad == 1) | (quad == 3), half, 0)
    if weighted:
        w = rng.integers(1, max(2, scale + 1), size=n_edges).astype(np.float32)
    else:
        w = np.ones(n_edges, np.float32)
    # drop self loops (paper graphs are simple directed graphs)
    keep = src != dst
    return src[keep].astype(np.int32), dst[keep].astype(np.int32), w[keep]


def load_rmat_graph(n_vertices: int, n_edges: int, slack: float = 1.5,
                    seed: int = 0, weighted: bool = True) -> GraphState:
    """Paper Table-1 style initial graph, with edge-capacity slack for the
    dynamic-update workload."""
    src, dst, w = rmat_edges(n_vertices, n_edges, seed=seed, weighted=weighted)
    ecap = int(n_edges * slack)
    return from_edge_list(n_vertices, ecap, src, dst, w)
