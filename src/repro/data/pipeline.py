"""Deterministic synthetic data pipeline (host-side, shardable).

Batches are a pure function of (seed, step) so a restarted trainer resumes
on exactly the data it would have seen — checkpoint/restart never replays or
skips tokens.  Per-host sharding takes the host's slice of the global batch
(multi-host ready; a single-process run owns the whole batch).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Zipfian token distribution: more realistic logit/loss dynamics than
    # uniform (and exercises the chunked-xent gather path unevenly).
    zipf_a: float = 1.3

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        z = rng.zipf(self.zipf_a, size=(self.global_batch, self.seq_len + 1))
        tokens = (z % (self.vocab_size - 1)).astype(np.int32) + 1
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def shard_batch(batch: dict, mesh: Optional[Mesh], dp_axes=("data",)):
    """Place a host batch onto the mesh: batch dim over the DP axes."""
    if mesh is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    out = {}
    for k, v in batch.items():
        spec = P(axes) if v.ndim >= 1 else P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
