"""Mixture-of-Experts with REAL expert parallelism (shard_map + all-to-all).

Two implementations behind one ``moe()`` entry point:

  * ``_moe_shard_map`` — the production path, used whenever a sharding
    context with (data, model) axes is active.  Experts are sharded over
    ``data`` (EP) and each expert's FFN over ``model`` (TP).  Tokens travel
    to their expert's owner row via an explicit ``lax.all_to_all`` with
    per-destination capacity buckets, run through the owner's experts, and
    return via the reverse all-to-all; the TP partial outputs merge with one
    psum.  This is the canonical MoE dance — under plain GSPMD the
    data-dependent scatter/gather dispatch is unpartitionable and silently
    replicates the full global token buffer on every chip (measured: 160
    GiB/chip on llama4-maverick train_4k).
  * ``_moe_dense`` — pure-jnp capacity dispatch (scatter into [E, C, d]),
    used on single-device runs (unit tests, CPU examples) and as the oracle
    the shard_map path is tested against.

Both drop tokens beyond capacity (standard capacity-factor semantics) and
add a Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import FSDP, TP, _init
from . import sharding_ctx


def init_moe(key, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _init(ks[0], (d, e), jnp.float32),
        "wi": _init(ks[1], (e, d, ff), cfg.dtype),
        "wg": _init(ks[2], (e, d, ff), cfg.dtype),
        "wo": _init(ks[3], (e, ff, d), cfg.dtype, scale=ff ** -0.5),
    }


def moe_specs(cfg: ModelConfig):
    # Experts over the data axis (EP), expert-FFN hidden over model (TP).
    return {
        "router": P(None, None),
        "wi": P(FSDP, None, TP),
        "wg": P(FSDP, None, TP),
        "wo": P(FSDP, TP, None),
    }


def _route(xt, router, e, k):
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return gate, idx, aux


def _positions_in_bucket(bucket_ids, n_buckets):
    """Rank of each element within its bucket (exclusive cumsum of one-hot)."""
    oh = (bucket_ids[:, None] == jnp.arange(n_buckets)[None, :]) \
        .astype(jnp.int32)
    return (jnp.cumsum(oh, axis=0) - oh)[
        jnp.arange(bucket_ids.shape[0]), jnp.clip(bucket_ids, 0, n_buckets - 1)]


@jax.custom_vjp
def take_rows(x, idx, inv):
    """``x[idx]`` with out-of-range -> 0, whose TRANSPOSE IS ALSO A GATHER.

    ``inv [N, K]``: for each row of x, the (up to K) output rows sourcing it
    (out-of-range = none).  The standard gather VJP is a scatter-add, whose
    XLA lowering materializes payload-sized f32/u32 helper buffers (~16
    GiB/layer for the MoE dispatch); with the inverse map supplied both
    directions are fill-gathers.
    """
    return x.at[idx].get(mode="fill", fill_value=0)


def _take_rows_fwd(x, idx, inv):
    return take_rows(x, idx, inv), (inv, jnp.zeros((), x.dtype))


def _take_rows_bwd(res, g):
    inv, probe = res
    dx = sum(g.at[inv[:, j]].get(mode="fill", fill_value=0)
             for j in range(inv.shape[1]))
    return dx.astype(probe.dtype), None, None


take_rows.defvjp(_take_rows_fwd, _take_rows_bwd)


def _moe_dense(p, x, cfg: ModelConfig):
    """Single-device capacity dispatch (also the shard_map oracle)."""
    b, s, d = x.shape
    t, k, e = b * s, cfg.top_k, cfg.num_experts
    cap = max(1, int(t * k * cfg.capacity_factor / e))

    xt = x.reshape(t, d)
    gate, idx, aux = _route(xt, p["router"], e, k)
    flat_e = idx.reshape(t * k)
    pos = _positions_in_bucket(flat_e, e)
    keep = pos < cap
    posc = jnp.where(keep, pos, cap)
    tok = jnp.arange(t * k, dtype=jnp.int32) // k

    buf = jnp.zeros((e, cap, d), x.dtype).at[
        jnp.where(keep, flat_e, e), posc].set(xt[tok], mode="drop")
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    eout = jnp.einsum("ecf,efd->ecd", hidden, p["wo"])

    gathered = eout[jnp.where(keep, flat_e, 0), jnp.where(keep, posc, 0)]
    wts = (gate.reshape(t * k) * keep).astype(x.dtype)
    out = ((gathered * wts[:, None]).reshape(t, k, d)).sum(axis=1)
    return out.reshape(b, s, d), aux


def _moe_shard_map(p, x, cfg: ModelConfig, mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nd, nm = sizes["data"], sizes["model"]
    e, d, k = cfg.num_experts, cfg.d_model, cfg.top_k
    e_row = e // nd              # experts owned per data row
    b, s, _ = x.shape
    # Training shards the batch over (data, model); every model column of a
    # data row must see the row's full token set (the TP psum merges their
    # ff shards), so the body all-gathers over 'model' INSIDE the manual
    # region and is checkpointed there: the only saved residual per layer is
    # the (data, model)-sharded input slice, not the gathered buffers —
    # shard_map internals are opaque to the outer scan-level remat.
    gather_model = (b % (nd * nm) == 0)

    def body(xin, router, wi, wg, wo):
        if gather_model:
            xl = lax.all_gather(xin, "model", axis=0, tiled=True)
        else:
            xl = xin
        bl = xl.shape[0]
        tl = bl * s
        xt = xl.reshape(tl, d)
        gate, idx, aux = _route(xt, router, e, k)
        flat_e = idx.reshape(tl * k)
        row = flat_e // e_row                       # owner data-row
        le = flat_e % e_row                         # expert id within owner

        # ---- outbound: per-destination-row capacity buckets --------------
        # All payload movement uses take_rows (gather both ways); the only
        # scatters are tiny int32 inverse-map builds.
        cap = max(1, -(-tl * k * int(cfg.capacity_factor * 100) // 100 // nd))
        tk = tl * k
        pos = _positions_in_bucket(row, nd)
        keep = pos < cap
        slot_of = jnp.where(keep, row * cap + pos, nd * cap)   # [tk]
        tr = nd * cap
        slot_src = jnp.full((tr,), tk, jnp.int32).at[slot_of].set(
            jnp.arange(tk, dtype=jnp.int32), mode="drop", unique_indices=True)

        send_x = take_rows(
            xt, jnp.where(slot_src < tk, slot_src // k, tl),
            slot_of.reshape(tl, k))
        send_le = jnp.full((tr,), -1, jnp.int32).at[slot_of].set(
            le, mode="drop", unique_indices=True)

        recv_x = lax.all_to_all(send_x, "data", 0, 0, tiled=True)
        recv_le = lax.all_to_all(send_le, "data", 0, 0, tiled=True)

        # ---- owner side: per-expert capacity buffers ----------------------
        valid = recv_le >= 0
        c2 = max(1, -(-tr * 13 // (10 * e_row)))    # 1.3x local slack
        lec = jnp.where(valid, recv_le, e_row)
        pos2 = _positions_in_bucket(lec, e_row)
        keep2 = valid & (pos2 < c2)
        eslot_of = jnp.where(keep2, lec * c2 + pos2, e_row * c2)  # [tr]
        slot_tok = jnp.full((e_row * c2,), tr, jnp.int32).at[eslot_of].set(
            jnp.arange(tr, dtype=jnp.int32), mode="drop", unique_indices=True)
        buf = take_rows(recv_x, slot_tok, eslot_of[:, None]) \
            .reshape(e_row, c2, d)

        hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
            * jnp.einsum("ecd,edf->ecf", buf, wi)
        part = jnp.einsum("ecf,efd->ecd", hidden, wo)
        part = lax.psum(part, "model")              # merge TP ff shards

        y_recv = take_rows(part.reshape(e_row * c2, d), eslot_of,
                           slot_tok[:, None])

        # ---- return trip + combine ---------------------------------------
        y_send = lax.all_to_all(y_recv, "data", 0, 0, tiled=True)
        y_slot = take_rows(y_send, slot_of, slot_src[:, None])   # [tk, d]
        wts = (gate * keep.reshape(tl, k).astype(gate.dtype)).astype(x.dtype)
        y_tok = (y_slot.reshape(tl, k, d) * wts[:, :, None]).sum(axis=1)
        aux = lax.pmean(aux, "data")
        y = y_tok.reshape(bl, s, d)
        if gather_model:
            c = lax.axis_index("model")
            own = bl // nm
            y = lax.dynamic_slice_in_dim(y, c * own, own, axis=0)
        return y, aux

    body = jax.checkpoint(body)
    # ALL mesh axes are manual (an auto 'pod' axis trips an XLA partitioner
    # crash - "Invalid binary instruction opcode copy").  The pod axis is
    # simply unused inside: experts replicate across pods (hierarchical EP,
    # all-to-all stays inside a pod's ICI domain - exactly what you want on
    # real hardware, DCN never sees dispatch traffic).
    if gather_model:
        xspec = P(("data", "model"), None, None)
    elif "pod" in mesh.axis_names and b % (
            sizes["pod"] * nd) == 0:
        xspec = P(("pod", "data"), None, None)
    else:
        xspec = P("data", None, None)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None),
                  P("data", None, "model"), P("data", None, "model"),
                  P("data", "model", None)),
        out_specs=(xspec, P()),
        axis_names=set(mesh.axis_names), check_vma=False)
    return fn(x, p["router"], p["wi"], p["wg"], p["wo"])


def moe(p, x, cfg: ModelConfig):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    mesh = sharding_ctx._CTX.get("mesh")
    if (mesh is not None and sharding_ctx._CTX.get("active")
            and {"data", "model"} <= set(mesh.axis_names)):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if (cfg.num_experts % sizes["data"] == 0
                and cfg.d_ff % sizes["model"] == 0
                and x.shape[0] % sizes["data"] == 0):
            return _moe_shard_map(p, x, cfg, mesh)
    return _moe_dense(p, x, cfg)
