"""Ambient sharding context for activation constraints.

The launcher installs the mesh before tracing; layer code calls
``constrain(x, "dp", None, "tp")`` with *logical* axis tags which resolve to
the physical mesh axes ("dp" -> ("pod","data") when present, "tp" ->
("model",)).  Outside a context (unit tests, CPU smoke runs) ``constrain``
is a no-op, so model code never depends on a mesh being present.  Dims not
divisible by the resolved axis product are left unconstrained.
"""
from __future__ import annotations

import contextlib
import math
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_CTX: dict = {"active": False, "dp": (), "tp": (), "sizes": {}}


@contextlib.contextmanager
def sharding_context(mesh, full_batch: bool = False):
    """``full_batch=True`` (training): the batch dim shards over EVERY mesh
    axis (ZeRO-3 posture; per-device batch of ~1 sequence bounds the remat
    carries).  Axis order ("data","model","pod") matters: non-divisible dims
    drop axes from the END, so a 256-seq batch on the 512-chip mesh keeps
    (data, model) and replicates over pod (hierarchical DP)."""
    names = tuple(mesh.axis_names)
    old = dict(_CTX)
    dp_order = ("data", "model", "pod") if full_batch else ("pod", "data")
    _CTX.update(
        active=True,
        dp=tuple(a for a in dp_order if a in names),
        tp=tuple(a for a in ("model",) if a in names),
        sizes=dict(zip(names, mesh.devices.shape)),
        mesh=mesh,
    )
    try:
        yield
    finally:
        _CTX.clear()
        _CTX.update(old)


def _resolve(tag: Optional[str]):
    if tag is None:
        return None
    if tag == "dp":
        return _CTX["dp"] or None
    if tag == "tp":
        return _CTX["tp"] or None
    if tag == "xb":
        # batch axes excluding the model axis (frees it for vocab/TP use in
        # the same tensor, e.g. chunked-xent logits [b, s, vocab])
        xb = tuple(a for a in _CTX["dp"] if a != "model")
        return xb or None
    return tag


def constrain(x: jax.Array, *tags):
    if not _CTX["active"]:
        return x
    spec = []
    used: set = set()
    for dim, tag in zip(x.shape, tags):
        r = _resolve(tag)
        if r is None:
            spec.append(None)
            continue
        axes = tuple(a for a in (r if isinstance(r, tuple) else (r,))
                     if a not in used)
        # drop axes from the end until the dim divides evenly
        while axes and dim % math.prod(_CTX["sizes"].get(a, 1)
                                       for a in axes) != 0:
            axes = axes[:-1]
        if not axes:
            spec.append(None)
            continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else axes)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
