"""Zamba2-style hybrid: Mamba2 backbone + a weight-SHARED attention block.

Structure: the layer stack is organised as super-blocks of ``attn_every``
Mamba2 layers followed by one invocation of a single shared transformer
block (same weights at every invocation point, as in Zamba2).  Remaining
``L % attn_every`` Mamba2 layers run after the scan.

Simplification vs the released Zamba2 (documented in DESIGN.md): the shared
block attends over the hidden stream only (Zamba2 concatenates the original
embedding and uses 2x-width attention + LoRA adapters per invocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers as L
from . import ssm as S


def _n_super(cfg: ModelConfig):
    return cfg.num_layers // cfg.attn_every, cfg.num_layers % cfg.attn_every


def init(key, cfg: ModelConfig):
    ke, km, ka, kt = jax.random.split(key, 4)
    ns, rem = _n_super(cfg)

    def one(k):
        p = S.init_ssm_block(k, cfg)
        n1, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
        return {"mixer": p, "ln": n1}

    stack = jax.vmap(one)(jax.random.split(km, ns * cfg.attn_every))
    stack = jax.tree.map(
        lambda x: x.reshape((ns, cfg.attn_every) + x.shape[1:]), stack)
    tail = jax.vmap(one)(jax.random.split(kt, rem)) if rem else None

    shared_attn = L.init_attention(ka, cfg)
    shared_mlp = L.init_mlp(jax.random.fold_in(ka, 1), cfg)
    n1, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    n2, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    fn, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    params = {
        "embed": L.init_embed(ke, cfg),
        "lm_head": L.init_unembed(jax.random.fold_in(ke, 7), cfg),
        "blocks": stack,
        "shared": {"attn": shared_attn, "mlp": shared_mlp,
                   "ln1": n1, "ln2": n2},
        "final_norm": fn,
    }
    if tail is not None:
        params["tail"] = tail
    return params


def specs(cfg: ModelConfig):
    ns, rem = _n_super(cfg)
    one = {"mixer": S.ssm_block_specs(cfg), "ln": P(None)}
    stack = jax.tree.map(lambda s: P(*((None, None) + tuple(s))), one,
                         is_leaf=lambda s: isinstance(s, P))
    out = {
        "embed": L.embed_specs(cfg),
        "lm_head": L.unembed_specs(cfg),
        "blocks": stack,
        "shared": {"attn": L.attention_specs(cfg), "mlp": L.mlp_specs(cfg),
                   "ln1": P(None), "ln2": P(None)},
        "final_norm": P(None),
    }
    if rem:
        out["tail"] = jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                                   is_leaf=lambda s: isinstance(s, P))
    return out


def _shared_block(sp, h, cfg, cache, positions):
    a, nc = L.attention(sp["attn"], L.rms_norm(h, sp["ln1"], cfg.norm_eps),
                        cfg, positions=positions, cache=cache)
    h = h + a
    h = h + L.mlp(sp["mlp"], L.rms_norm(h, sp["ln2"], cfg.norm_eps))
    return h, nc


def forward(params, tokens, cfg: ModelConfig, caches=None, positions=None):
    """caches: None or dict(ssm=[ns,ae,...], attn={k,v,idx}[ns], tail=[rem,...])."""
    from .sharding_ctx import constrain
    h = constrain(L.embed(params["embed"], tokens), "dp", None, None)
    ns, rem = _n_super(cfg)
    sp = params["shared"]

    def mamba_sub(hh, lp, cache):
        o, nc = S.mamba_block(lp["mixer"],
                              L.rms_norm(hh, lp["ln"], cfg.norm_eps), cfg,
                              cache)
        return hh + o, nc

    if caches is None:
        def super_body(hh, bp):
            hh = lax.optimization_barrier(hh)
            def inner(h2, lp):
                h2, _ = mamba_sub(h2, lp, None)
                return h2, None
            hh, _ = lax.scan(inner, hh, bp, unroll=cfg.scan_unroll)
            hh, _ = _shared_block(sp, hh, cfg, None, positions)
            return hh, None

        super_body = jax.checkpoint(super_body) if cfg.remat else super_body
        h, _ = lax.scan(super_body, h, params["blocks"],
                        unroll=cfg.scan_unroll)
        if rem:
            def inner(h2, lp):
                h2, _ = mamba_sub(h2, lp, None)
                return h2, None
            h, _ = lax.scan(inner, h, params["tail"],
                            unroll=cfg.scan_unroll)
        new_caches = None
    else:
        def super_body(hh, xs):
            bp, ssm_c, attn_c = xs
            def inner(h2, x2):
                lp, cc = x2
                return mamba_sub(h2, lp, cc)
            hh, ssm_nc = lax.scan(inner, hh, (bp, ssm_c),
                                  unroll=cfg.scan_unroll)
            hh, attn_nc = _shared_block(sp, hh, cfg, attn_c, positions)
            return hh, (ssm_nc, attn_nc)

        h, (ssm_nc, attn_nc) = lax.scan(
            super_body, h, (params["blocks"], caches["ssm"], caches["attn"]),
            unroll=cfg.scan_unroll)
        tail_nc = None
        if rem:
            def inner(h2, x2):
                lp, cc = x2
                return mamba_sub(h2, lp, cc)
            h, tail_nc = lax.scan(inner, h, (params["tail"], caches["tail"]),
                                  unroll=cfg.scan_unroll)
        new_caches = {"ssm": ssm_nc, "attn": attn_nc, "tail": tail_nc}
    return L.rms_norm(h, params["final_norm"], cfg.norm_eps), new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    h, _ = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    mask = (targets != 0).astype(jnp.float32)
    nll, cnt = L.unembed_chunked_xent(params["lm_head"], h, targets, mask,
                                      cfg.xent_chunk)
    return nll / jnp.maximum(cnt, 1.0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    ns, rem = _n_super(cfg)
    ssm_one = S.init_ssm_cache(cfg, batch, dtype)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    out = {
        "ssm": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None, None],
                                       (ns, cfg.attn_every) + x.shape),
            ssm_one),
        "attn": {
            "k": jnp.zeros((ns, batch, kv, max_len, hd), dtype),
            "v": jnp.zeros((ns, batch, kv, max_len, hd), dtype),
            "idx": jnp.zeros((ns,), jnp.int32),
        },
        "tail": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (rem,) + x.shape), ssm_one)
        if rem else None,
    }
    return out


def cache_specs(cfg: ModelConfig):
    ns, rem = _n_super(cfg)
    sone = S.ssm_cache_specs(cfg)
    out = {
        "ssm": jax.tree.map(lambda s: P(*((None, None) + tuple(s))), sone,
                            is_leaf=lambda s: isinstance(s, P)),
        "attn": {
            "k": P(None, L.FSDP, None, L.TP, None),
            "v": P(None, L.FSDP, None, L.TP, None),
            "idx": P(None),
        },
        "tail": jax.tree.map(lambda s: P(*((None,) + tuple(s))), sone,
                             is_leaf=lambda s: isinstance(s, P))
        if rem else None,
    }
    return out


def prefill(params, tokens, cfg: ModelConfig, cache, positions=None):
    h, nc = forward(params, tokens, cfg, caches=cache, positions=positions)
    return L.unembed_logits(params["lm_head"], h[:, -1:, :]), nc


def decode_step(params, tokens, cfg: ModelConfig, cache, positions=None):
    return prefill(params, tokens, cfg, cache, positions)
