"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: Optional[int] = None       # sliding window for local layers
    local_global: int = 0              # N => N local layers : 1 global layer
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE (VLM)

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # hybrid (Zamba2): a weight-shared attention block every `attn_every` layers
    attn_every: int = 0

    # encoder-decoder (Whisper): encoder depth and fixed frame count
    encoder_layers: int = 0
    encoder_seq: int = 0

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    dtype: object = jnp.bfloat16
    moment_dtype: object = jnp.float32  # optimizer moments (bf16 for 400B-class)

    # training-time knobs (perf hillclimb surface)
    xent_chunk: int = 512              # chunked cross-entropy block
    attn_chunk: int = 512              # q-block for the XLA chunked attention
    remat: bool = True
    # Attention implementation: "xla" (chunked einsum path — lowers on any
    # backend, used by the CPU dry-run) or "flash" (the Pallas kernel in
    # kernels/flash_attention.py — the real-TPU path; runs in interpret
    # mode on CPU).
    attn_impl: str = "xla"
    # Dry-run cost-measurement mode: unroll the layer scans so XLA's cost
    # analysis (which visits a scan body once) counts every layer.  Used by
    # the depth-1/2 extrapolation compiles only — never at full depth.
    scan_unroll: bool = False

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def params_dense(self) -> int:
        """Approximate parameter count (for 6ND roofline math)."""
        d, ff, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        h = self.num_heads * self.head_dim
        kv = self.num_kv_heads * self.head_dim
        attn = d * h + 2 * d * kv + h * d
        if self.family == "ssm":
            blk = self._ssm_block_params()
        elif self.family == "moe":
            blk = attn + 3 * d * ff * self.num_experts
        elif self.family == "hybrid":
            blk = self._ssm_block_params()
        else:
            blk = attn + 3 * d * ff
        total = L * blk + V * d
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * d * self.d_ff     # one shared block
        if self.family == "encdec":
            enc_blk = attn + 3 * d * ff
            total += self.encoder_layers * enc_blk + L * attn  # cross-attn
        return total

    def params_active(self) -> int:
        """Active parameters per token (MoE-aware)."""
        if self.family != "moe":
            return self.params_dense()
        d, ff, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        h = self.num_heads * self.head_dim
        kv = self.num_kv_heads * self.head_dim
        attn = d * h + 2 * d * kv + h * d
        blk = attn + 3 * d * ff * max(1, self.top_k)
        return L * blk + V * d

    def _ssm_block_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_p = d * (2 * di + 2 * n * h // self.ssm_heads * self.ssm_heads + h)
        in_p = d * (2 * di + 2 * n + h)  # zx + B,C + dt heads (grouped B/C)
        return in_p + di * d + di * self.conv_kernel
