"""Config -> model dispatch: one uniform API over every architecture family.

    model = get_model(cfg)
    params = model.init(key)                  # or jax.eval_shape(model.init, key)
    loss   = model.loss_fn(params, batch)
    logits, cache = model.prefill(params, tokens, cache)
    logits, cache = model.decode_step(params, tokens, cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import transformer, ssm, hybrid, encdec


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    specs: Callable          # () -> param PartitionSpec tree
    loss_fn: Callable        # (params, batch) -> scalar
    prefill: Callable        # (params, tokens, cache, **kw) -> (logits, cache)
    decode_step: Callable    # (params, tokens, cache, **kw) -> (logits, cache)
    init_cache: Callable     # (batch, max_len) -> cache
    cache_specs: Callable    # () -> cache PartitionSpec tree


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        mod = transformer
    elif cfg.family == "ssm":
        mod = ssm
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family in ("encdec", "audio"):
        mod = encdec
    else:
        raise ValueError(f"unknown family {cfg.family}")

    return Model(
        cfg=cfg,
        init=lambda key: mod.init(key, cfg),
        specs=lambda: mod.specs(cfg),
        loss_fn=lambda params, batch: mod.loss_fn(params, batch, cfg),
        prefill=lambda params, tokens, cache, **kw: mod.prefill(
            params, tokens, cfg, cache, **kw),
        decode_step=lambda params, tokens, cache, **kw: mod.decode_step(
            params, tokens, cfg, cache, **kw),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: mod.init_cache(
            cfg, batch, max_len, dtype),
        cache_specs=lambda: mod.cache_specs(cfg),
    )


def input_specs(cfg: ModelConfig, shape: str, global_batch: int,
                seq_len: int) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    Modality frontends are stubs per the assignment: the VLM gets M-RoPE
    position streams, the audio model gets precomputed frame embeddings.
    """
    sds = jax.ShapeDtypeStruct
    b, s = global_batch, seq_len
    if shape.startswith("train"):
        batch = {"tokens": sds((b, s), jnp.int32)}
        if cfg.mrope_sections:
            batch["positions"] = sds((3, b, s - 1), jnp.int32)
        if cfg.family in ("encdec", "audio"):
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model),
                                  jnp.float32)
        return batch
    if shape.startswith("prefill"):
        out = {"tokens": sds((b, s), jnp.int32)}
        if cfg.mrope_sections:
            out["positions"] = sds((3, b, s), jnp.int32)
        if cfg.family in ("encdec", "audio"):
            out["frames"] = sds((b, cfg.encoder_seq, cfg.d_model),
                                jnp.float32)
        return out
    # decode shapes: one new token against a cache of length seq_len
    out = {"tokens": sds((b, 1), jnp.int32)}
    if cfg.mrope_sections:
        out["positions"] = sds((3, b, 1), jnp.int32)
    return out
