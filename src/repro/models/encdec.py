"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings [B, encoder_seq, d_model] (what the two conv
layers would emit).  The transformer backbone is complete: bidirectional
encoder, causal decoder with cross-attention, KV caches for both.

Deviation (documented in DESIGN.md): positions use RoPE instead of Whisper's
learned absolute embeddings so the assigned decode_32k / prefill_32k shapes
(far beyond Whisper's 448-token decoder window) remain well-defined.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers as L


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    n1, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    n2, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    return {"attn": L.init_attention(k1, cfg), "mlp": L.init_mlp(k2, cfg),
            "ln1": n1, "ln2": n2}


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    n1, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    n2, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    n3, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    return {"self": L.init_attention(k1, cfg),
            "cross": L.init_attention(k2, cfg),
            "mlp": L.init_mlp(k3, cfg), "ln1": n1, "ln2": n2, "ln3": n3}


def init(key, cfg: ModelConfig):
    ke, kenc, kdec = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(kenc, cfg.encoder_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(kdec, cfg.num_layers))
    fe, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    fd, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    return {"embed": L.init_embed(ke, cfg), "encoder": enc, "decoder": dec,
            "enc_norm": fe, "final_norm": fd,
            "lm_head": L.init_unembed(jax.random.fold_in(ke, 7), cfg)}


def specs(cfg: ModelConfig):
    a, m = L.attention_specs(cfg), L.mlp_specs(cfg)
    enc_one = {"attn": a, "mlp": m, "ln1": P(None), "ln2": P(None)}
    dec_one = {"self": a, "cross": a, "mlp": m,
               "ln1": P(None), "ln2": P(None), "ln3": P(None)}
    lift = lambda t: jax.tree.map(lambda s: P(*((None,) + tuple(s))), t,
                                  is_leaf=lambda s: isinstance(s, P))
    return {"embed": L.embed_specs(cfg), "encoder": lift(enc_one),
            "decoder": lift(dec_one), "enc_norm": P(None),
            "final_norm": P(None), "lm_head": L.unembed_specs(cfg)}


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, encoder_seq, d] (stubbed frontend output)."""
    def body(h, lp):
        h = lax.optimization_barrier(h)
        a, _ = L.attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                           cfg, causal=False, use_rope=True)
        h = h + a
        h = h + L.mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, None

    body = jax.checkpoint(body) if cfg.remat else body
    h, _ = lax.scan(body, frames.astype(cfg.dtype), params["encoder"],
                    unroll=cfg.scan_unroll)
    return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def decode(params, tokens, enc_out, cfg: ModelConfig, caches=None):
    """caches: None or dict(self={k,v,idx}[L], cross={k,v}[L])."""
    from .sharding_ctx import constrain
    h = constrain(L.embed(params["embed"], tokens), "dp", None, None)

    if caches is None:
        def body(hh, lp):
            hh = lax.optimization_barrier(hh)
            a, _ = L.attention(lp["self"],
                               L.rms_norm(hh, lp["ln1"], cfg.norm_eps), cfg)
            hh = hh + a
            c, _ = L.attention(lp["cross"],
                               L.rms_norm(hh, lp["ln2"], cfg.norm_eps), cfg,
                               kv_x=enc_out, causal=False, use_rope=False)
            hh = hh + c
            hh = hh + L.mlp(lp["mlp"], L.rms_norm(hh, lp["ln3"], cfg.norm_eps))
            return hh, None

        body = jax.checkpoint(body) if cfg.remat else body
        h, _ = lax.scan(body, h, params["decoder"],
                        unroll=cfg.scan_unroll)
        new_caches = None
    else:
        def body(hh, xs):
            lp, sc, cc = xs
            a, snc = L.attention(lp["self"],
                                 L.rms_norm(hh, lp["ln1"], cfg.norm_eps),
                                 cfg, cache=sc)
            hh = hh + a
            c, _ = L.attention(lp["cross"],
                               L.rms_norm(hh, lp["ln2"], cfg.norm_eps), cfg,
                               kv_x="cached", cache=cc, causal=False,
                               use_rope=False)
            hh = hh + c
            hh = hh + L.mlp(lp["mlp"], L.rms_norm(hh, lp["ln3"], cfg.norm_eps))
            return hh, snc

        h, self_nc = lax.scan(body, h, (params["decoder"], caches["self"],
                                        caches["cross"]),
                              unroll=cfg.scan_unroll)
        new_caches = {"self": self_nc, "cross": caches["cross"]}
    return L.rms_norm(h, params["final_norm"], cfg.norm_eps), new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    enc_out = encode(params, batch["frames"], cfg)
    h, _ = decode(params, tokens[:, :-1], enc_out, cfg)
    targets = tokens[:, 1:]
    mask = (targets != 0).astype(jnp.float32)
    nll, cnt = L.unembed_chunked_xent(params["lm_head"], h, targets, mask,
                                      cfg.xent_chunk)
    return nll / jnp.maximum(cnt, 1.0)


def build_cross_cache(params, enc_out, cfg: ModelConfig):
    def one(lp):
        return L.init_cross_kv(lp["cross"], cfg, enc_out)
    return lax.map(one, params["decoder"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    kv, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    return {
        "self": {
            "k": jnp.zeros((nl, batch, kv, max_len, hd), dtype),
            "v": jnp.zeros((nl, batch, kv, max_len, hd), dtype),
            "idx": jnp.zeros((nl,), jnp.int32),
        },
        "cross": {
            "k": jnp.zeros((nl, batch, kv, cfg.encoder_seq, hd), dtype),
            "v": jnp.zeros((nl, batch, kv, cfg.encoder_seq, hd), dtype),
        },
    }


def cache_specs(cfg: ModelConfig):
    kvspec = P(None, L.FSDP, None, L.TP, None)
    return {
        "self": {"k": kvspec, "v": kvspec, "idx": P(None)},
        "cross": {"k": kvspec, "v": kvspec},
    }


def prefill(params, tokens, cfg: ModelConfig, cache, frames=None,
            positions=None):
    """Prompt pass. If ``frames`` given, (re)build the cross cache from the
    encoder; otherwise the provided cross cache is used as-is."""
    if frames is not None:
        enc_out = encode(params, frames, cfg)
        cache = dict(cache, cross=build_cross_cache(params, enc_out, cfg))
    h, nc = decode(params, tokens, None, cfg, caches=cache)
    return L.unembed_logits(params["lm_head"], h[:, -1:, :]), nc


def decode_step(params, tokens, cfg: ModelConfig, cache, positions=None):
    h, nc = decode(params, tokens, None, cfg, caches=cache)
    return L.unembed_logits(params["lm_head"], h[:, -1:, :]), nc
