"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Layers are stacked along a leading L axis and executed with
``lax.scan`` (+ ``jax.checkpoint`` remat), so the HLO contains ONE layer body
regardless of depth — essential for compiling 80-layer models against a
512-device mesh in reasonable time, and for bounding activation memory.

Per-layer heterogeneity (gemma3's 5 local : 1 global pattern) is threaded as
a scanned ``window`` array: local layers carry the sliding-window size,
global layers carry a huge value — one homogeneous body, per-layer masks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers as L
from .moe import init_moe, moe, moe_specs
from .sharding_ctx import constrain


BIG_WINDOW = jnp.int32(2**30)   # "global" attention == window larger than S


def _init_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    attn_p = L.init_attention(k1, cfg)
    if cfg.num_experts:
        ffn_p = init_moe(k2, cfg)
    else:
        ffn_p = L.init_mlp(k2, cfg)
    n1, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    n2, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    return {"attn": attn_p, "ffn": ffn_p, "ln1": n1, "ln2": n2}


def _layer_specs(cfg: ModelConfig):
    return {
        "attn": L.attention_specs(cfg),
        "ffn": moe_specs(cfg) if cfg.num_experts else L.mlp_specs(cfg),
        "ln1": P(None), "ln2": P(None),
    }


def layer_windows(cfg: ModelConfig) -> jax.Array:
    """Per-layer window sizes implementing the local:global pattern."""
    idx = jnp.arange(cfg.num_layers)
    if cfg.local_global and cfg.window:
        is_global = (idx % (cfg.local_global + 1)) == cfg.local_global
        return jnp.where(is_global, BIG_WINDOW, cfg.window).astype(jnp.int32)
    if cfg.window:
        return jnp.full((cfg.num_layers,), cfg.window, jnp.int32)
    return jnp.full((cfg.num_layers,), BIG_WINDOW, jnp.int32)


def init(key, cfg: ModelConfig):
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.num_layers)
    stack_p = jax.vmap(lambda k: _init_layer(k, cfg))(lkeys)
    fn, _ = L.init_rmsnorm(cfg.d_model, cfg.dtype)
    return {"embed": L.init_embed(ke, cfg), "layers": stack_p,
            "final_norm": fn,
            "lm_head": L.init_unembed(jax.random.fold_in(ke, 7), cfg)}


def specs(cfg: ModelConfig):
    stack_s = jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                           _layer_specs(cfg),
                           is_leaf=lambda s: isinstance(s, P))
    return {"embed": L.embed_specs(cfg), "layers": stack_s,
            "final_norm": P(None), "lm_head": L.unembed_specs(cfg)}


def _layer_apply(lp, h, cfg, window, cache, positions):
    # NOTE (Perf iters 1-2, EXPERIMENTS.md): barriers / explicit replicate
    # constraints here do NOT stop the CPU backend from shipping weight
    # all-gathers in f32 (its dots convert operands to f32 and the
    # partitioner orders convert-before-gather) — both refuted; the roofline
    # applies a documented dtype correction instead (TPU MXU consumes bf16
    # natively, so real gathers move half the bytes).
    a, new_cache = L.attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, positions=positions, cache=cache,
                               window=window)
    h = h + a
    hn = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        f, aux = moe(lp["ffn"], hn, cfg)
    else:
        f, aux = L.mlp(lp["ffn"], hn), jnp.float32(0)
    return h + f, new_cache, aux


def forward(params, tokens, cfg: ModelConfig, *, caches=None, positions=None,
            h: Optional[jax.Array] = None):
    """Returns (hidden [B,S,d], new_caches, aux_loss)."""
    if h is None:
        h = L.embed(params["embed"], tokens)
    h = constrain(h, "dp", None, None)
    windows = layer_windows(cfg)

    if caches is None:
        def body(carry, xs):
            hh, aux = carry
            lp, win = xs
            # barrier: stops XLA from hoisting the backward's f32 cast of
            # the whole saved-carry stack out of the loop (4 GiB at 48L)
            hh = lax.optimization_barrier(hh)
            hh, _, a = _layer_apply(lp, hh, cfg, win, None, positions)
            return (hh, aux + a), None

        body = jax.checkpoint(body) if cfg.remat else body
        (h, aux), _ = lax.scan(body, (h, jnp.float32(0)),
                               (params["layers"], windows),
                               unroll=cfg.scan_unroll)
        new_caches = None
    else:
        def body(carry, xs):
            hh, aux = carry
            lp, win, cache = xs
            hh, nc, a = _layer_apply(lp, hh, cfg, win, cache, positions)
            return (hh, aux + a), nc

        (h, aux), new_caches = lax.scan(body, (h, jnp.float32(0)),
                                        (params["layers"], windows, caches),
                                        unroll=cfg.scan_unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, new_caches, aux


def loss_fn(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    positions = batch.get("positions")
    h, _, aux = forward(params, tokens[:, :-1], cfg, positions=positions)
    targets = tokens[:, 1:]
    mask = (targets != 0).astype(jnp.float32)
    nll, cnt = L.unembed_chunked_xent(params["lm_head"], h, targets, mask,
                                      cfg.xent_chunk)
    return nll / jnp.maximum(cnt, 1.0) + 0.01 * aux


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16):
    kv, hd, nl = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    return {
        "k": jnp.zeros((nl, batch_size, kv, max_len, hd), dtype),
        "v": jnp.zeros((nl, batch_size, kv, max_len, hd), dtype),
        "idx": jnp.zeros((nl,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig):
    """Desired shardings for the KV cache: batch over data, seq over model
    (sequence parallelism — enables 500k contexts at batch 1)."""
    return {
        "k": P(None, L.FSDP, None, L.TP, None),
        "v": P(None, L.FSDP, None, L.TP, None),
        "idx": P(None),
    }


def prefill(params, tokens, cfg: ModelConfig, cache, positions=None):
    """Run the prompt through the model, filling the cache.
    Returns (last-token logits, cache)."""
    h, new_caches, _ = forward(params, tokens, cfg, caches=cache,
                               positions=positions)
    logits = L.unembed_logits(params["lm_head"], h[:, -1:, :])
    return logits, new_caches


def decode_step(params, tokens, cfg: ModelConfig, cache, positions=None):
    """One incremental token: tokens [B, 1] -> (logits [B,1,V], cache)."""
    return prefill(params, tokens, cfg, cache, positions=positions)
