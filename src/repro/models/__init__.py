"""Assigned-architecture model zoo sharing one functional layer library."""
from .config import ModelConfig  # noqa: F401
from .registry import Model, get_model, input_specs  # noqa: F401
