"""Mamba2 (state-space duality / SSD) blocks, chunked for TPU.

The SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence into
chunks: an intra-chunk quadratic term (batched matmuls -> MXU-friendly) plus
an inter-chunk linear state recurrence (a short ``lax.scan`` over chunks).
Decode is the O(1)-per-token state recurrence — this is why ``long_500k``
runs for the SSM/hybrid architectures while quadratic-attention models skip
it.

Simplifications vs the reference CUDA implementation (documented in
DESIGN.md): n_groups=1 (B/C shared across heads), no bias terms, gated
RMSNorm before out-projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import FSDP, TP, _init, rms_norm, init_rmsnorm


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm_block(key, cfg: ModelConfig):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 6)
    return {
        "in_z": _init(ks[0], (d, di), cfg.dtype),
        "in_xbc": _init(ks[1], (d, conv_dim(cfg)), cfg.dtype),
        "in_dt": _init(ks[2], (d, h), cfg.dtype),
        "conv_w": _init(ks[3], (conv_dim(cfg), cfg.conv_kernel), cfg.dtype,
                        scale=cfg.conv_kernel ** -0.5),
        "conv_b": jnp.zeros((conv_dim(cfg),), cfg.dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), cfg.dtype),
        "out": _init(ks[4], (di, d), cfg.dtype, scale=di ** -0.5),
    }


def ssm_block_specs(cfg: ModelConfig):
    return {
        "in_z": P(FSDP, TP), "in_xbc": P(FSDP, TP), "in_dt": P(FSDP, None),
        "conv_w": P(TP, None), "conv_b": P(TP),
        "A_log": P(None), "D": P(None), "dt_bias": P(None),
        "norm": P(TP), "out": P(TP, FSDP),
    }


def _causal_conv(xbc, w, b, cache=None):
    """Depthwise causal conv1d. xbc: [B, S, C]; w: [C, K].

    Training: left-pad K-1. Decode: cache [B, K-1, C] carries history.
    Returns (out [B, S, C], new_cache).
    """
    k = w.shape[1]
    if cache is None:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    else:
        pad = jnp.concatenate([cache.astype(xbc.dtype), xbc], axis=1)
        new_cache = pad[:, -(k - 1):, :]
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[:, i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :]), new_cache


def ssd_chunked(x, dt, a, bm, cm, chunk: int, init_state=None):
    """SSD forward. x [B,S,H,Pd]; dt [B,S,H] (softplus applied);
    a [H] (negative); bm, cm [B,S,N].  Returns (y, final_state [B,H,Pd,N])."""
    b, s, h, pd = x.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        # dt=0 padding is exactly state-neutral: decay=exp(0)=1, update=0.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, chunk, h, pd)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    bc = bm.reshape(b, nc, chunk, n)
    cc = cm.reshape(b, nc, chunk, n)

    da = dtc * a[None, None, None, :]                     # [b,nc,l,h]
    cum = jnp.cumsum(da, axis=2)

    # intra-chunk quadratic term (the "attention-like" dual form)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [b,nc,i,j,h]
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], seg, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    att = cb[..., None] * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(x.dtype), xc)

    # per-chunk boundary states
    right = jnp.exp(cum[:, :, -1:, :] - cum)              # [b,nc,l,h]
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bc,
                        (dtc * right).astype(x.dtype), xc)
    total = jnp.exp(cum[:, :, -1, :])                     # [b,nc,h]

    def scan_fn(hprev, xs):
        tot, st = xs
        hnew = tot[:, :, None, None].astype(hprev.dtype) * hprev + st
        return hnew, hprev

    h0 = init_state if init_state is not None else \
        jnp.zeros((b, h, pd, n), x.dtype)
    final, hprevs = lax.scan(scan_fn, h0,
                             (total.transpose(1, 0, 2),
                              states.transpose(1, 0, 2, 3, 4)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)              # [b,nc,h,pd,n]

    left = jnp.exp(cum)                                   # [b,nc,l,h]
    y_inter = jnp.einsum("bcln,bchpn->bclhp", cc, hprevs) \
        * left[..., None].astype(x.dtype)
    y = (y_intra + y_inter).reshape(b, nc * chunk, h, pd)
    return y[:, :s], final


def mamba_block(p, x, cfg: ModelConfig, cache=None):
    """One Mamba2 block. cache: None or dict(conv=[B,K-1,C], ssd=[B,H,Pd,N]).
    Returns (out [B,S,d], new_cache)."""
    b, s, _ = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim

    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xbc = jnp.einsum("bsd,de->bse", x, p["in_xbc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :])

    conv_cache = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xs = xbc[..., :di].reshape(b, s, h, pd)
    bm = xbc[..., di:di + n]
    cm = xbc[..., di + n:]

    a = -jnp.exp(p["A_log"])

    if cache is None or s > 1:
        init_state = cache["ssd"] if cache is not None else None
        y, final = ssd_chunked(xs, dt, a, bm, cm, cfg.ssm_chunk, init_state)
    else:
        # decode: one-step recurrence
        da = jnp.exp(dt[:, 0] * a[None, :])               # [b,h]
        upd = jnp.einsum("bn,bh,bhp->bhpn", bm[:, 0],
                         dt[:, 0].astype(x.dtype), xs[:, 0])
        final = da[:, :, None, None].astype(x.dtype) * cache["ssd"] + upd
        y = jnp.einsum("bn,bhpn->bhp", cm[:, 0], final)[:, None]
        y = y.reshape(b, 1, h, pd)

    y = y + xs * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssd": final}
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim(cfg)), dtype),
        "ssd": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                          cfg.ssm_state), dtype),
    }


def ssm_cache_specs(cfg: ModelConfig):
    return {"conv": P(FSDP, None, TP), "ssd": P(FSDP, TP, None, None)}


# ------------------------- full Mamba2 LM --------------------------------

def init(key, cfg: ModelConfig):
    from .layers import init_embed
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.num_layers)

    def one(k):
        p = init_ssm_block(k, cfg)
        n1, _ = init_rmsnorm(cfg.d_model, cfg.dtype)
        return {"mixer": p, "ln": n1}

    stack = jax.vmap(one)(lkeys)
    fn, _ = init_rmsnorm(cfg.d_model, cfg.dtype)
    from .layers import init_unembed
    return {"embed": init_embed(ke, cfg), "layers": stack, "final_norm": fn,
            "lm_head": init_unembed(jax.random.fold_in(ke, 7), cfg)}


def specs(cfg: ModelConfig):
    from .layers import embed_specs
    one = {"mixer": ssm_block_specs(cfg), "ln": P(None)}
    stack = jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                         is_leaf=lambda s: isinstance(s, P))
    from .layers import unembed_specs
    return {"embed": embed_specs(cfg), "layers": stack,
            "final_norm": P(None), "lm_head": unembed_specs(cfg)}


def forward(params, tokens, cfg: ModelConfig, caches=None):
    from .layers import embed, rms_norm as rn
    from .sharding_ctx import constrain
    h = constrain(embed(params["embed"], tokens), "dp", None, None)

    if caches is None:
        def body(hh, lp):
            hh = lax.optimization_barrier(hh)
            o, _ = mamba_block(lp["mixer"], rn(hh, lp["ln"], cfg.norm_eps), cfg)
            return hh + o, None

        body = jax.checkpoint(body) if cfg.remat else body
        h, _ = lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll)
        new_caches = None
    else:
        def body(hh, xs):
            lp, cache = xs
            o, nc = mamba_block(lp["mixer"], rn(hh, lp["ln"], cfg.norm_eps),
                                cfg, cache)
            return hh + o, nc

        h, new_caches = lax.scan(body, h, (params["layers"], caches),
                                 unroll=cfg.scan_unroll)
    return rn(h, params["final_norm"], cfg.norm_eps), new_caches


def loss_fn(params, batch, cfg: ModelConfig):
    from .layers import unembed_chunked_xent
    tokens = batch["tokens"]
    h, _ = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    mask = (targets != 0).astype(jnp.float32)
    nll, cnt = unembed_chunked_xent(params["lm_head"], h, targets, mask,
                                    cfg.xent_chunk)
    return nll / jnp.maximum(cnt, 1.0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    one = init_ssm_cache(cfg, batch, dtype)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one)


def cache_specs(cfg: ModelConfig):
    one = ssm_cache_specs(cfg)
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), one,
                        is_leaf=lambda s: isinstance(s, P))


def prefill(params, tokens, cfg: ModelConfig, cache, positions=None):
    from .layers import unembed_logits
    h, new_caches = forward(params, tokens, cfg, caches=cache)
    return unembed_logits(params["lm_head"], h[:, -1:, :]), new_caches


def decode_step(params, tokens, cfg: ModelConfig, cache, positions=None):
    return prefill(params, tokens, cfg, cache, positions)
