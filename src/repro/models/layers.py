"""Functional layer library: norms, linears, RoPE/M-RoPE, GQA attention, MLP.

Every ``init_*`` returns ``(params, specs)`` — a pytree of arrays and a
parallel pytree of ``PartitionSpec`` giving the *desired* sharding; the
launcher sanitizes specs against the actual mesh (dropping axes whose size
does not divide the dimension) so one codebase serves every mesh.

Sharding philosophy (MaxText-style FSDP+TP):
  * weight matrices: input-feature dim over ``data`` (FSDP storage; XLA
    inserts the per-layer all-gather / reduce-scatter), output-feature /
    head dim over ``model`` (tensor parallelism);
  * activations: batch over ``data`` (and ``pod``), features unconstrained
    (inferred by GSPMD from the weight shardings).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .sharding_ctx import constrain

# Mesh-axis names used in desired specs (sanitized against the real mesh).
FSDP = "data"
TP = "model"


def _init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------- norms -----------------------------------

def init_rmsnorm(d: int, dtype):
    return jnp.ones((d,), dtype), P(None)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)).astype(x.dtype) * w).astype(x.dtype)


# ------------------------------ RoPE / M-RoPE ----------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: Optional[tuple] = None) -> jax.Array:
    """x: [B, H, S, D]. positions: [B, S] or [3, B, S] for M-RoPE.

    M-RoPE (Qwen2-VL): the rotary half-dim splits into (t, h, w) sections,
    each rotated by its own position stream.  Identical streams recover
    standard RoPE exactly (the text-only case).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # [D/2]
    if positions.ndim == 2:
        positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [3, B, S, D/2]
    if sections is None:
        ang = ang[0]
    else:
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            parts.append(ang[i, ..., start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)         # [B, S, D/2]
    cos = jnp.cos(ang)[:, None, :, :].astype(x.dtype)  # [B, 1, S, D/2]
    sin = jnp.sin(ang)[:, None, :, :].astype(x.dtype)
    x1, x2 = x[..., ::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


# ------------------------------ attention --------------------------------

def init_attention(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": _init(ks[0], (d, h * hd), cfg.dtype),
        "wk": _init(ks[1], (d, kv * hd), cfg.dtype),
        "wv": _init(ks[2], (d, kv * hd), cfg.dtype),
        "wo": _init(ks[3], (h * hd, d), cfg.dtype, scale=(h * hd) ** -0.5),
    }
    if cfg.qk_norm:
        params["q_norm"], _ = init_rmsnorm(hd, cfg.dtype)
        params["k_norm"], _ = init_rmsnorm(hd, cfg.dtype)
    return params


def attention_specs(cfg: ModelConfig):
    specs = {
        "wq": P(FSDP, TP), "wk": P(FSDP, TP), "wv": P(FSDP, TP),
        "wo": P(TP, FSDP),
    }
    if cfg.qk_norm:
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return specs


def _sdpa_chunked(q, k, v, *, causal: bool, q_offset, chunk: int,
                  window: Optional[int], softcap: float = 0.0):
    """Memory-bounded full-head attention in plain XLA ops.

    q, k, v: [B, H, S, D] with K/V already expanded to the full head count
    (a sharded repeat — each model shard holds only its own heads' copies,
    so the expansion is local and GSPMD keeps the score tensor head-sharded;
    the grouped-einsum alternative defeats SPMD propagation through the
    (kv, group) reshape and silently replicates heads).  Unrolled python
    loop over query blocks (NOT lax.scan: XLA cost analysis visits a scan
    body once, which would hide (nchunk-1)/nchunk of the attention FLOPs
    from the dry-run roofline); buffer liveness still bounds peak memory to
    ~one block's scores.  On real TPU the Pallas flash kernel
    (kernels/flash_attention.py) replaces this path.
    """
    b, h, sq, d0 = q.shape
    skv = k.shape[2]
    scale = d0 ** -0.5
    kpos = jnp.arange(skv, dtype=jnp.int32)

    def block(qc, kk, vv, qpos):
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, kk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones((qpos.shape[0], skv), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)

    if sq <= chunk:
        return block(q, k, v, q_offset + jnp.arange(sq, dtype=jnp.int32))

    # lax.scan over query blocks with a rematerialized body: backward
    # liveness is ONE block's score matrix (an unrolled loop keeps every
    # block's [B,H,chunk,Skv] f32 scores simultaneously live through the
    # gradient pass — ~full S^2 scores/device).  The flip side: XLA cost
    # analysis sees the body once, so the dry-run roofline adds the
    # analytic (nchunk-1) x per-block attention FLOPs correction
    # (benchmarks/roofline.py, documented in EXPERIMENTS.md).
    nchunk = -(-sq // chunk)
    pad = nchunk * chunk - sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qs = qp.reshape(b, h, nchunk, chunk, d0).transpose(2, 0, 1, 3, 4)
    block = jax.checkpoint(block)

    def body(i, qc):
        qpos = q_offset + i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        return i + 1, block(qc, k, v, qpos)

    _, outs = lax.scan(body, jnp.int32(0), qs)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, nchunk * chunk, d0)
    return out[:, :, :sq, :]


def attention(p, x, cfg: ModelConfig, *, positions=None, cache=None,
              window: Optional[int] = None, kv_x: Optional[jax.Array] = None,
              causal: bool = True, use_rope: bool = True):
    """GQA attention. Returns ``(out, new_cache)``.

    cache (self-attn): dict(k=[B,KV,Smax,D], v=..., idx=int32[]) — keys are
    stored rotated; fresh slices are written at ``idx``.
    cache (cross-attn, kv_x='cached'): dict(k=..., v=...) precomputed.
    """
    b, sq, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, sq, h, hd)

    cross_cached = isinstance(kv_x, str) and kv_x == "cached"
    if cross_cached:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        src = x if kv_x is None else kv_x
        skv_in = src.shape[1]
        k = jnp.einsum("bsd,dh->bsh", src, p["wk"]).reshape(b, skv_in, kv, hd)
        v = jnp.einsum("bsd,dh->bsh", src, p["wv"]).reshape(b, skv_in, kv, hd)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        new_cache = None

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if not cross_cached:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)  # [B,KV,S,D], D last
    q = q.transpose(0, 2, 1, 3)   # [B, H, Sq, D]

    is_self = kv_x is None
    q_offset = cache["idx"] if (cache is not None and is_self) else jnp.int32(0)
    if use_rope and is_self:
        pos = positions if positions is not None else jnp.broadcast_to(
            (q_offset + jnp.arange(sq, dtype=jnp.int32))[None], (b, sq))
        q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.mrope_sections)

    if cache is not None and is_self:
        ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, 0, q_offset, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, 0, q_offset, 0))
        new_cache = {"k": ck, "v": cv, "idx": q_offset + sq}
        k, v = ck, cv

    if (cfg.attn_impl == "flash" and sq > 1 and cfg.logit_softcap == 0
            and (window is None or not hasattr(window, "dtype"))):
        # Pallas flash kernel: GQA handled in its index map (never repeats
        # K/V), online softmax keeps scores in VMEM.  Traced per-layer
        # windows (gemma3's scanned local:global pattern) fall through to
        # the XLA path — the kernel needs a static window for block skips.
        from repro.kernels import ops as kops
        win = int(window) if window is not None else None
        out = kops.flash_attention(q, k, v, causal=causal and is_self,
                                   window=win)
        out = out.transpose(0, 2, 1, 3).reshape(b, sq, h * hd)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"]), new_cache

    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    if sq > 1:
        # prefill/train: keep heads model-sharded through the expansion
        # (each shard repeats only its own kv heads — a local op).  When the
        # head count does not divide TP (llama4: 40 heads on 16-way model),
        # the second "tp" tag falls through to the QUERY-SEQUENCE dim —
        # sequence parallelism for the score matrix instead of 16x
        # replicated attention compute (Perf §llama4 iter 2).
        q = constrain(q, "dp", "tp", "tp", None)
        k = constrain(k, "dp", "tp", None, None)
        v = constrain(v, "dp", "tp", None, None)
    out = _sdpa_chunked(q, k, v, causal=causal and is_self,
                        q_offset=q_offset, chunk=cfg.attn_chunk,
                        window=window, softcap=cfg.logit_softcap)
    out = out.transpose(0, 2, 1, 3).reshape(b, sq, h * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), new_cache


def init_cross_kv(p, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (decode cache)."""
    b, se, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(b, se, kv, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(b, se, kv, hd)
    return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}


# -------------------------------- MLP ------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi": _init(ks[0], (d, ff), cfg.dtype),
        "wg": _init(ks[1], (d, ff), cfg.dtype),
        "wo": _init(ks[2], (ff, d), cfg.dtype, scale=ff ** -0.5),
    }


def mlp_specs(cfg: ModelConfig):
    return {"wi": P(FSDP, TP), "wg": P(FSDP, TP), "wo": P(TP, FSDP)}


def mlp(p, x):
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    up = jnp.einsum("bsd,df->bsf", x, p["wi"])
    return jnp.einsum("bsf,fd->bsd", gate * up, p["wo"])


# ----------------------------- embeddings --------------------------------

def init_embed(key, cfg: ModelConfig):
    return _init(key, (cfg.vocab_size, cfg.d_model), cfg.dtype, scale=1.0)


def embed_specs(cfg: ModelConfig):
    # vocab over model, d replicated: the token gather stays shard-local and
    # the scatter-grad stays vocab-sharded (no axis conflict with the batch).
    return P(TP, None)


def init_unembed(key, cfg: ModelConfig):
    """Untied output head [d, vocab].  Untying keeps the unembed matmul's
    weight gradient sharded — a tied table is used by a gather AND a matmul
    whose GSPMD shardings conflict, which materializes the full f32 table
    (and its gradient, and its all-reduce) on every device."""
    return _init(key, (cfg.d_model, cfg.vocab_size), cfg.dtype)


def unembed_specs(cfg: ModelConfig):
    return P(None, TP)


@jax.custom_vjp
def embed(table, tokens):
    return table[tokens]


def _embed_fwd(table, tokens):
    probe = jnp.zeros((), table.dtype)  # dtype/shape carrier for the bwd
    return table[tokens], (tokens, table.shape[0], table.shape[1], probe)


def _embed_bwd(res, g):
    """Embedding gradient as chunked one-hot matmuls.

    The naive scatter-add gradient cannot be partitioned by GSPMD when the
    batch is sharded (data-dependent indices) — it replicates the FULL f32
    [vocab, d] gradient (plus its all-reduce) on every device.  The one-hot
    matmul form is the classic TPU embedding gradient: each chunk's
    [b, chunk, vocab] one-hot is vocab-sharded over the model axis, so the
    accumulated gradient lives sharded end-to-end.
    """
    tokens, vocab, d, probe = res
    b, s = tokens.shape
    chunk = min(512, s)
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    tp = jnp.pad(tokens, ((0, 0), (0, pad)), constant_values=-1)
    gp = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
    viota = jnp.arange(vocab, dtype=jnp.int32)

    def body(acc, i):
        tc = constrain(
            lax.dynamic_slice_in_dim(tp, i * chunk, chunk, axis=1),
            "xb", None)
        gc = constrain(
            lax.dynamic_slice_in_dim(gp, i * chunk, chunk, axis=1),
            "xb", None, None)
        oh = (tc[..., None] == viota[None, None, :]).astype(gc.dtype)
        oh = constrain(oh, "xb", None, "tp")
        upd = jnp.einsum("bcv,bcd->vd", oh, gc,
                         preferred_element_type=jnp.float32)
        # constrain the partial-sum too: the (b,c) contraction's cross-shard
        # reduce must happen on vocab-sharded pieces, not the full table
        return acc + constrain(upd, "tp", None), None

    acc0 = constrain(jnp.zeros((vocab, d), jnp.float32), "tp", None)
    acc, _ = lax.scan(body, acc0, jnp.arange(nchunk, dtype=jnp.int32))
    return acc.astype(probe.dtype), None


embed.defvjp(_embed_fwd, _embed_bwd)


def unembed_chunked_xent(head, h, targets, mask, chunk: int):
    """Cross-entropy without materializing [B, S, vocab] logits.

    Unrolled python loop over sequence chunks (not lax.scan — see
    ``_sdpa_chunked`` for why); per-step peak = [B, chunk, vocab/TP] f32:
    the logits are constrained vocab-sharded over the model axis, and the
    gold logit is extracted with an iota-mask reduction (SPMD-friendly,
    unlike a cross-shard take_along_axis gather).  Returns (sum_nll, sum_mask).
    """
    from .sharding_ctx import constrain

    b, s, d = h.shape
    chunk = min(chunk, s)
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))
    vocab = head.shape[1]
    viota = jnp.arange(vocab, dtype=jnp.int32)

    # lax.scan over chunks: bounds fwd+bwd liveness to ONE chunk's logits
    # (unrolled, every chunk's f32 [b, chunk, V] logits + grads co-live in
    # the backward).  XLA cost analysis sees the body once; the dry-run
    # roofline adds the analytic (nchunk-1)x per-chunk correction.
    def body(carry, i):
        nll, cnt = carry
        hc = lax.dynamic_slice_in_dim(hp, i * chunk, chunk, axis=1)
        tc = lax.dynamic_slice_in_dim(tp, i * chunk, chunk, axis=1)
        mc = lax.dynamic_slice_in_dim(mp, i * chunk, chunk, axis=1)
        # Reshard the chunk off the model axis so vocab can use it: avoids
        # GSPMD's "involuntary full rematerialization" of [B,S,d] when the
        # batch and vocab shardings collide on the same axis.
        hc = constrain(hc, "xb", None, None)
        tc = constrain(tc, "xb", None)
        mc = constrain(mc, "xb", None)
        logits = jnp.einsum("bsd,dv->bsv", hc, head,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "xb", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.sum(jnp.where(viota[None, None, :] == tc[..., None],
                                 logits, 0.0), axis=-1)
        return (nll + jnp.sum((lse - gold) * mc), cnt + jnp.sum(mc)), None

    body = jax.checkpoint(body)
    (nll, cnt), _ = lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                             jnp.arange(nchunk, dtype=jnp.int32))
    return nll, cnt


def unembed_logits(head, h):
    """Full logits (decode-time: S is tiny)."""
    return jnp.einsum("bsd,dv->bsv", h, head,
                      preferred_element_type=jnp.float32)
