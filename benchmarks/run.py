"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Sizes are scaled to this
CPU container (the paper used 56-core Xeons and 10^4-op runs; we keep the
shapes of the curves, not the absolute scale — EXPERIMENTS.md maps each
run back to its figure).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np
import jax.numpy as jnp


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ----- Figures 6/7/8: query latency x graph size x mode -------------------

def fig678_query_latency(sizes=(256, 1024), n_ops=60):
    from workload import load_graph, make_ops, run_mix
    rng = np.random.default_rng(0)
    for query, fig in (("bfs", "fig6"), ("sssp", "fig7"), ("bc", "fig8")):
        for n in sizes:
            g = load_graph(n)
            ops = make_ops(rng, n_ops, n, (0.4, 0.1, 0.5))
            for mode in ("pgcn", "pgicn", "static"):
                r = run_mix(g, ops, query, mode)
                us = r.seconds / max(r.queries, 1) * 1e6
                _row(f"{fig}_{query}_v{n}_{mode}", us,
                     f"queries={r.queries}")


# ----- Figures 9/10/11: workload distributions at fixed size --------------

def fig91011_distributions(n=512, n_ops=80):
    from workload import load_graph, make_ops, run_mix
    rng = np.random.default_rng(1)
    dists = {"40_10_50": (0.4, 0.1, 0.5), "60_10_30": (0.6, 0.1, 0.3),
             "80_10_10": (0.8, 0.1, 0.1)}
    for query, fig in (("bfs", "fig9"), ("sssp", "fig10"), ("bc", "fig11")):
        g = load_graph(n)
        for label, dist in dists.items():
            ops = make_ops(rng, n_ops, n, dist)
            for mode in ("pgcn", "pgicn"):
                r = run_mix(g, ops, query, mode)
                _row(f"{fig}_{query}_{label}_{mode}",
                     r.seconds / max(len(ops), 1) * 1e6,
                     f"total_s={r.seconds:.2f}")


# ----- Figures 12/13: collects per scan + interrupting updates ------------

def fig1213_scan_stats(n=512, n_ops=60):
    from workload import load_graph, make_ops, run_mix
    rng = np.random.default_rng(2)
    for query in ("bfs", "sssp"):
        for label, dist in (("25u", (0.25, 0.25, 0.5)),
                            ("45u", (0.45, 0.05, 0.5))):
            g = load_graph(n)
            ops = make_ops(rng, n_ops, n, dist)
            r = run_mix(g, ops, query, "pgcn")
            per_scan = r.collects / max(r.queries, 1)
            per_q_int = r.interrupts / max(r.queries, 1)
            _row(f"fig12_13_{query}_{label}",
                 r.seconds / max(r.queries, 1) * 1e6,
                 f"collects_per_scan={per_scan:.2f};"
                 f"interrupts_per_query={per_q_int:.2f}")


# ----- Update-throughput microbench (Table-1-scale graphs) ----------------

def bench_update_throughput(n=4096, batch=256, iters=6):
    from repro.core import PUTE, REME, apply_ops
    from workload import load_graph
    rng = np.random.default_rng(3)
    g = load_graph(n)
    ops = [(PUTE, int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(rng.integers(1, 9))) if i % 2 == 0 else
           (REME, int(rng.integers(0, n)), int(rng.integers(0, n)))
           for i in range(batch)]
    g, _ = apply_ops(g, ops, batch_size=batch)       # warm the jit
    t0 = time.perf_counter()
    for _ in range(iters):
        g, _ = apply_ops(g, ops, batch_size=batch)
    g.esrc.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    _row("update_batch256_v4096", dt * 1e6,
         f"ops_per_s={batch / dt:.0f}")


# ----- Kernel sanity timings (jnp oracle path on CPU) ----------------------

def bench_semiring_dense(n=512):
    from repro.core import semiring
    f = jnp.asarray((np.random.default_rng(0).random((n, n)) < 0.01),
                    jnp.float32)
    a = f
    semiring.bool_mm(f, a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        semiring.bool_mm(f, a).block_until_ready()
    _row(f"bool_semiring_mm_{n}", (time.perf_counter() - t0) / 5 * 1e6,
         "jnp_path")
    d = jnp.where(f > 0, 1.0, jnp.inf)
    semiring.minplus_mm(d, d).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        semiring.minplus_mm(d, d).block_until_ready()
    _row(f"minplus_mm_{n}", (time.perf_counter() - t0) / 3 * 1e6,
         "jnp_path")


# ----- Roofline summary (reads dry-run artifacts when present) -------------

def roofline_summary():
    import roofline
    try:
        rows = roofline.table()
    except Exception as e:
        _row("roofline", 0.0, f"unavailable:{e}")
        return
    for r in rows:
        _row(f"roofline_{r['arch']}_{r['shape']}",
             max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
             f"dom={r['dominant']};mfu_bound={r['mfu_bound']:.3f};"
             f"useful={r['useful_ratio']:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    fig678_query_latency()
    fig91011_distributions()
    fig1213_scan_stats()
    bench_update_throughput()
    bench_semiring_dense()
    roofline_summary()


if __name__ == "__main__":
    main()
