"""Sharded tile-grid engine benchmark: multi-device queries + view upkeep.

Runs on host-platform placeholder devices (``--devices``, default 4 — set
BEFORE jax imports, like ``launch/dryrun.py``), so the numbers measure the
sharded *program structure* (collective volume, tile-skip rates, refresh
locality) rather than real accelerator parallelism: all shards share one
CPU, so ``speedup_sharded_vs_local`` is an overhead ratio here and a
scaling ratio only on a real mesh.  What it reports:

  * **view upkeep** — ``build_sharded_view`` from scratch vs
    ``refresh_sharded_view`` re-deriving only the dirty tile rows of a
    localized commit (the headline: refresh must beat rebuild at n=2048);
  * **queries** — distributed bfs/sssp/bc wall time vs the single-device
    ``core.queries`` batched path on the same snapshot, with results
    cross-checked (dist/level/sigma bit-identical, delta/scores allclose);
  * **per-shard tile-skip hit rate** — what fraction of its band each
    shard's masked kernels elide;
  * **collective bytes per level** — measured from the compiled HLO
    (``launch.dryrun.parse_collective_bytes`` on the while-loop body) next
    to the formula value S x Vp x (1B bfs | 4B sssp).

Prints the usual ``name,us_per_call,derived`` CSV rows and always writes
``BENCH_shard.json``.

    PYTHONPATH=src python benchmarks/bench_shard.py [--n 2048] \
        [--devices 4] [--sources 16] [--json BENCH_shard.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _parse_args(argv):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=2048,
                   help="live vertex count (power of two for R-MAT)")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--devices", type=int, default=4,
                   help="host-platform placeholder device count")
    p.add_argument("--sources", type=int, default=16,
                   help="bfs/sssp/bc source batch (multiple of --devices)")
    p.add_argument("--hot-frac", type=float, default=0.02,
                   help="fraction of vertices a refresh commit touches")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--bc-chunk", type=int, default=None)
    p.add_argument("--json", default="BENCH_shard.json")
    return p.parse_args(argv)


ARGS = _parse_args(sys.argv[1:])
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={ARGS.devices} "
    + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import PUTE, REME, apply_ops, queries  # noqa: E402
from repro.core.updates import dirty_vertices  # noqa: E402
from repro.data import load_rmat_graph  # noqa: E402
from repro.shard import (  # noqa: E402
    as_graph_mesh,
    bc_batched,
    bfs,
    build_sharded_view,
    query_fn,
    refresh_sharded_view,
    sharded_occupancy_stats,
    sssp,
)

ROWS: list[dict] = []


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})


def _block(res):
    if hasattr(res, "w") and hasattr(res, "occ"):  # ShardedTileView
        res.w.block_until_ready()
        res.occ.block_until_ready()
        return res
    jax.tree.map(lambda x: x.block_until_ready(), res)
    return res


def _time(fn, *args, **kw):
    _block(fn(*args, **kw))  # warm compilation
    t0 = time.perf_counter()
    out = _block(fn(*args, **kw))
    return time.perf_counter() - t0, out


def hot_commit(rng, g, n, hot_frac):
    """One localized commit: edge churn confined to a contiguous hot range."""
    size = max(2, int(n * hot_frac))
    base = int(rng.integers(0, max(1, n - size)))
    ops = []
    for _ in range(size):
        u = base + int(rng.integers(0, size))
        v = int(rng.integers(0, n))
        if rng.random() < 0.3:
            ops.append((REME, u, v))
        else:
            ops.append((PUTE, u, v, float(rng.integers(1, 9))))
    g2, _ = apply_ops(g, ops)
    return g2


def bench_view(mesh, g, n, hot_frac, seed):
    rng = np.random.default_rng(seed)
    t_build, view = _time(build_sharded_view, g, mesh)
    occ = sharded_occupancy_stats(view)
    _row("shard_view_build", t_build * 1e6,
         f"vp={view.vp};shards={view.n_shards};"
         f"tile_skip_rate={occ['tile_skip_rate']:.4f}")

    g2 = hot_commit(rng, g, n, hot_frac)
    dirty = dirty_vertices(g, g2)
    n_rows = int(np.unique(np.flatnonzero(np.asarray(jax.device_get(dirty)))
                           // view.tile).size)
    # warm the row-refresh compile cache, then take best-of-3 (host-forced
    # placeholder devices share one CPU, so single-shot timings are noisy);
    # each refresh CONSUMES its input view (donated buffers), so a fresh
    # base view is built outside the timed region per repeat.
    refresh_sharded_view(g2, build_sharded_view(g, mesh), dirty)
    t_refresh = float("inf")
    for _ in range(3):
        base_view = _block(build_sharded_view(g, mesh))
        t0 = time.perf_counter()
        view2 = _block(refresh_sharded_view(g2, base_view, dirty))
        t_refresh = min(t_refresh, time.perf_counter() - t0)
    t_rebuild = min(_time(build_sharded_view, g2, mesh)[0] for _ in range(3))
    speedup = t_rebuild / t_refresh
    _row("shard_view_refresh", t_refresh * 1e6,
         f"dirty_tile_rows={n_rows};vs_rebuild={speedup:.2f}x")
    return view2, g2, {
        "t_build_s": round(t_build, 4),
        "t_refresh_s": round(t_refresh, 4),
        "t_rebuild_s": round(t_rebuild, 4),
        "dirty_tile_rows": n_rows,
        "refresh_vs_rebuild": round(speedup, 2),
        "occupancy": occ,
    }


def _collective_bytes(mesh, view, g, kind, srcs):
    """Per-level collective bytes off the compiled HLO (the while body's
    all-reduce appears once in the program text)."""
    # Deferred import: dryrun prepends its own 512-device XLA flag on
    # import, which must never race this benchmark's --devices flag.
    from repro.launch.dryrun import parse_collective_bytes
    fn = query_fn(mesh, kind, view.tile)
    txt = fn.lower(view.w, view.occ, g.alive, g.ecnt, srcs,
                   g.version).compile().as_text()
    return parse_collective_bytes(txt)


def bench_queries(mesh, view, g, n_sources, bc_chunk):
    srcs = jnp.arange(n_sources, dtype=jnp.int32)
    am, wd, alive = queries.dense_views(g)
    out = {}

    t_s, r = _time(bfs, view, g, srcs)
    t_l, ref = _time(queries.bfs_batched_dense, am, srcs, alive)
    assert np.array_equal(np.asarray(r.dist), np.asarray(ref)), "bfs drift"
    coll = _collective_bytes(mesh, view, g, "bfs", srcs)
    _row("shard_bfs", t_s * 1e6,
         f"local={t_l * 1e6:.1f}us;ratio={t_l / t_s:.2f}x;"
         f"coll_bytes_level={coll.get('all-reduce', 0)}")
    out["bfs"] = {"t_sharded_s": round(t_s, 4), "t_local_s": round(t_l, 4),
                  "speedup_sharded_vs_local": round(t_l / t_s, 2),
                  "collective_bytes_per_level": coll.get("all-reduce", 0),
                  "formula_bytes_per_level": n_sources * view.vp}

    t_s, r = _time(sssp, view, g, srcs)
    t_l, (dref, negref) = _time(queries.sssp_batched_dense, wd, srcs, alive)
    assert np.array_equal(np.asarray(r.dist), np.asarray(dref)), "sssp drift"
    assert np.array_equal(np.asarray(r.negcycle), np.asarray(negref))
    coll = _collective_bytes(mesh, view, g, "sssp", srcs)
    _row("shard_sssp", t_s * 1e6,
         f"local={t_l * 1e6:.1f}us;ratio={t_l / t_s:.2f}x;"
         f"coll_bytes_level={coll.get('all-reduce', 0)}")
    out["sssp"] = {"t_sharded_s": round(t_s, 4), "t_local_s": round(t_l, 4),
                   "speedup_sharded_vs_local": round(t_l / t_s, 2),
                   "collective_bytes_per_level": coll.get("all-reduce", 0),
                   "formula_bytes_per_level": 4 * n_sources * view.vp}

    t_s, r = _time(bc_batched, view, g, srcs, src_chunk=bc_chunk)
    t_l, (d, s, lv, ok) = _time(queries.bc_batched_dense, am, srcs, alive,
                                src_chunk=bc_chunk)
    assert np.array_equal(np.asarray(r.level), np.asarray(lv)), "bc drift"
    assert np.array_equal(np.asarray(r.sigma), np.asarray(s))
    assert np.allclose(np.asarray(r.delta), np.asarray(d),
                       rtol=1e-5, atol=1e-5)
    _row("shard_bc", t_s * 1e6,
         f"local={t_l * 1e6:.1f}us;ratio={t_l / t_s:.2f}x;"
         f"src_chunk={bc_chunk}")
    out["bc"] = {"t_sharded_s": round(t_s, 4), "t_local_s": round(t_l, 4),
                 "speedup_sharded_vs_local": round(t_l / t_s, 2),
                 "src_chunk": bc_chunk}
    return out


def main(a):
    ROWS.clear()
    print("name,us_per_call,derived", flush=True)
    mesh = as_graph_mesh()
    n_dev = int(mesh.devices.size)
    g = load_rmat_graph(a.n, a.n * a.edge_factor, seed=a.seed)

    view, g2, view_stats = bench_view(mesh, g, a.n, a.hot_frac, a.seed)
    n_sources = max(n_dev, a.sources - a.sources % n_dev)
    q = bench_queries(mesh, view, g2, n_sources, a.bc_chunk)

    print(f"\nSharded tile grid on {n_dev} devices at n={a.n}: refresh "
          f"{view_stats['refresh_vs_rebuild']:.2f}x over rebuild "
          f"({view_stats['dirty_tile_rows']} dirty tile rows); bfs "
          f"collective {q['bfs']['collective_bytes_per_level']} B/level "
          f"(formula {q['bfs']['formula_bytes_per_level']} B)", flush=True)

    payload = {
        "bench": "shard",
        "backend": jax.default_backend(),
        "devices": n_dev,
        "params": {"n": a.n, "edge_factor": a.edge_factor,
                   "sources": n_sources, "hot_frac": a.hot_frac,
                   "seed": a.seed, "bc_chunk": a.bc_chunk},
        "rows": ROWS,
        "view": view_stats,
        "per_shard_tile_skip_rate":
            view_stats["occupancy"]["per_shard_tile_skip_rate"],
        "queries": q,
        "speedups": {
            "shardedview_refresh_vs_rebuild":
                view_stats["refresh_vs_rebuild"],
            "sharded_vs_local": {k: v["speedup_sharded_vs_local"]
                                 for k, v in q.items()},
        },
        "verified": True,  # every timed query is cross-checked above
    }
    if a.json:
        with open(a.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {a.json}", flush=True)
    return payload


if __name__ == "__main__":
    main(ARGS)
