"""Sharded tile-grid engine benchmark: multi-device queries + view upkeep.

Runs on host-platform placeholder devices (``--devices``, default 4 — set
BEFORE jax imports, like ``launch/dryrun.py``), so the numbers measure the
sharded *program structure* (collective volume, tile-skip rates, refresh
locality) rather than real accelerator parallelism: all shards share one
CPU, so ``speedup_sharded_vs_local`` is an overhead ratio here and a
scaling ratio only on a real mesh.  What it reports:

  * **view upkeep** — ``build_sharded_view`` from scratch vs
    ``refresh_sharded_view`` re-deriving only the dirty tile rows of a
    localized commit (the headline: refresh must beat rebuild at n=2048);
  * **queries** — distributed bfs/sssp/bc wall time vs the single-device
    ``core.queries`` batched path on the same snapshot, with results
    cross-checked (dist/level/sigma bit-identical, delta/scores allclose);
  * **per-shard tile-skip hit rate** — what fraction of its band each
    shard's masked kernels elide;
  * **collective bytes per level** — measured from the compiled HLO
    (``launch.dryrun.parse_collective_bytes`` on the while-loop body) next
    to the formula value S x Vp x (1B bfs | 4B sssp);
  * **ring vs gather BC** — the SUMMA band-rotation BC (``bc_mode="ring"``)
    against the all-gather oracle: wall time, per-device temp bytes off
    ``memory_analysis`` (gather materialises the O(Vp^2) grid, ring holds
    O(Vp^2/n)), and the measured ``collective-permute`` bytes next to the
    O(Vp^2/n)-per-rotation formula.

Prints the usual ``name,us_per_call,derived`` CSV rows and always writes
``BENCH_shard.json``.

    PYTHONPATH=src python benchmarks/bench_shard.py [--n 2048] \
        [--devices 4] [--sources 16] [--json BENCH_shard.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _parse_args(argv):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=2048,
                   help="live vertex count (power of two for R-MAT)")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--devices", type=int, default=4,
                   help="host-platform placeholder device count")
    p.add_argument("--sources", type=int, default=16,
                   help="bfs/sssp/bc source batch (multiple of --devices)")
    p.add_argument("--hot-frac", type=float, default=0.02,
                   help="fraction of vertices a refresh commit touches")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--bc-chunk", type=int, default=None)
    p.add_argument("--json", default="BENCH_shard.json")
    return p.parse_args(argv)


ARGS = _parse_args(sys.argv[1:])
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={ARGS.devices} "
    + os.environ.get("XLA_FLAGS", ""))

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import PUTE, REME, apply_ops, queries  # noqa: E402
from repro.core.updates import dirty_vertices  # noqa: E402
from repro.data import load_rmat_graph  # noqa: E402
from repro.shard import (  # noqa: E402
    ShardedGraphService,
    as_graph_mesh,
    bc_batched,
    bfs,
    build_sharded_view,
    delta_bc_sharded,
    delta_bfs_sharded,
    delta_sssp_sharded,
    query_fn,
    refresh_sharded_view,
    refresh_stats,
    sharded_occupancy_stats,
    sssp,
)

ROWS: list[dict] = []


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})


def _block(res):
    if hasattr(res, "w") and hasattr(res, "occ"):  # ShardedTileView
        res.w.block_until_ready()
        res.occ.block_until_ready()
        return res
    jax.tree.map(lambda x: x.block_until_ready(), res)
    return res


def _time(fn, *args, **kw):
    _block(fn(*args, **kw))  # warm compilation
    best = float("inf")
    # best-of-3: host-platform placeholder devices share one CPU, so
    # single-shot timings swing by tens of percent
    for _ in range(3):
        t0 = time.perf_counter()
        out = _block(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best, out


def hot_commit(rng, g, n, hot_frac):
    """One localized commit: edge churn confined to a contiguous hot range."""
    size = max(2, int(n * hot_frac))
    base = int(rng.integers(0, max(1, n - size)))
    ops = []
    for _ in range(size):
        u = base + int(rng.integers(0, size))
        v = int(rng.integers(0, n))
        if rng.random() < 0.3:
            ops.append((REME, u, v))
        else:
            ops.append((PUTE, u, v, float(rng.integers(1, 9))))
    g2, _ = apply_ops(g, ops)
    return g2


def bench_view(mesh, g, n, hot_frac, seed):
    rng = np.random.default_rng(seed)
    t_build, view = _time(build_sharded_view, g, mesh)
    occ = sharded_occupancy_stats(view)
    _row("shard_view_build", t_build * 1e6,
         f"vp={view.vp};shards={view.n_shards};"
         f"tile_skip_rate={occ['tile_skip_rate']:.4f}")

    g2 = hot_commit(rng, g, n, hot_frac)
    dirty = dirty_vertices(g, g2)
    n_rows = int(np.unique(np.flatnonzero(np.asarray(jax.device_get(dirty)))
                           // view.tile).size)
    # warm the row-refresh compile cache, then take best-of-3 (host-forced
    # placeholder devices share one CPU, so single-shot timings are noisy);
    # each refresh CONSUMES its input view (donated buffers), so a fresh
    # base view is built outside the timed region per repeat.
    refresh_sharded_view(g2, build_sharded_view(g, mesh), dirty)
    t_refresh = float("inf")
    rows0, disp0 = refresh_stats.rows, refresh_stats.dispatches
    for _ in range(3):
        base_view = _block(build_sharded_view(g, mesh))
        t0 = time.perf_counter()
        view2 = _block(refresh_sharded_view(g2, base_view, dirty))
        t_refresh = min(t_refresh, time.perf_counter() - t0)
    # dispatch accounting per refresh: pre-batching cost was one shard_map
    # launch per dirty row; same-width batching fuses them.
    n_refresh = 3
    rows_per = (refresh_stats.rows - rows0) // n_refresh
    disp_per = (refresh_stats.dispatches - disp0) // n_refresh
    t_rebuild = min(_time(build_sharded_view, g2, mesh)[0] for _ in range(3))
    speedup = t_rebuild / t_refresh
    _row("shard_view_refresh", t_refresh * 1e6,
         f"dirty_tile_rows={n_rows};vs_rebuild={speedup:.2f}x;"
         f"dispatches={disp_per}/{rows_per}rows")
    return view2, g2, {
        "t_build_s": round(t_build, 4),
        "t_refresh_s": round(t_refresh, 4),
        "t_rebuild_s": round(t_rebuild, 4),
        "dirty_tile_rows": n_rows,
        "refresh_vs_rebuild": round(speedup, 2),
        "dispatches_unbatched": rows_per,  # one per dirty row before batching
        "dispatches_batched": disp_per,
        "occupancy": occ,
    }


def _compiled(mesh, view, g, kind, srcs, src_chunk=None):
    fn = query_fn(mesh, kind, view.tile, False, src_chunk)
    return fn.lower(view.w, view.occ, g.alive, g.ecnt, srcs,
                    g.version).compile()


def _collective_bytes(mesh, view, g, kind, srcs, src_chunk=None):
    """Per-level collective bytes off the compiled HLO (the while body's
    all-reduce — and the ring's band permutes — appear once per loop in
    the program text)."""
    # Deferred import: dryrun prepends its own 512-device XLA flag on
    # import, which must never race this benchmark's --devices flag.
    from repro.launch.dryrun import parse_collective_bytes
    txt = _compiled(mesh, view, g, kind, srcs, src_chunk).as_text()
    return parse_collective_bytes(txt)


def _temp_bytes(mesh, view, g, kind, srcs, src_chunk=None):
    """Per-device temp (scratch) bytes of the compiled program — where the
    gather path's materialised Vp^2 grid vs the ring path's O(Vp^2/n) band
    shows up."""
    try:
        ma = _compiled(mesh, view, g, kind, srcs, src_chunk).memory_analysis()
        return int(ma.temp_size_in_bytes)
    except Exception:  # pragma: no cover - backend without memory stats
        return None


def bench_queries(mesh, view, g, n_sources, bc_chunk):
    srcs = jnp.arange(n_sources, dtype=jnp.int32)
    am, wd, alive = queries.dense_views(g)
    out = {}

    t_s, r = _time(bfs, view, g, srcs)
    t_l, ref = _time(queries.bfs_batched_dense, am, srcs, alive)
    assert np.array_equal(np.asarray(r.dist), np.asarray(ref)), "bfs drift"
    coll = _collective_bytes(mesh, view, g, "bfs", srcs)
    _row("shard_bfs", t_s * 1e6,
         f"local={t_l * 1e6:.1f}us;ratio={t_l / t_s:.2f}x;"
         f"coll_bytes_level={coll.get('all-reduce', 0)}")
    out["bfs"] = {"t_sharded_s": round(t_s, 4), "t_local_s": round(t_l, 4),
                  "speedup_sharded_vs_local": round(t_l / t_s, 2),
                  "collective_bytes_per_level": coll.get("all-reduce", 0),
                  "formula_bytes_per_level": n_sources * view.vp}

    t_s, r = _time(sssp, view, g, srcs)
    t_l, (dref, negref) = _time(queries.sssp_batched_dense, wd, srcs, alive)
    assert np.array_equal(np.asarray(r.dist), np.asarray(dref)), "sssp drift"
    assert np.array_equal(np.asarray(r.negcycle), np.asarray(negref))
    coll = _collective_bytes(mesh, view, g, "sssp", srcs)
    _row("shard_sssp", t_s * 1e6,
         f"local={t_l * 1e6:.1f}us;ratio={t_l / t_s:.2f}x;"
         f"coll_bytes_level={coll.get('all-reduce', 0)}")
    out["sssp"] = {"t_sharded_s": round(t_s, 4), "t_local_s": round(t_l, 4),
                   "speedup_sharded_vs_local": round(t_l / t_s, 2),
                   "collective_bytes_per_level": coll.get("all-reduce", 0),
                   "formula_bytes_per_level": 4 * n_sources * view.vp}

    t_s, r = _time(bc_batched, view, g, srcs, src_chunk=bc_chunk)
    t_l, (d, s, lv, ok) = _time(queries.bc_batched_dense, am, srcs, alive,
                                src_chunk=bc_chunk)
    assert np.array_equal(np.asarray(r.level), np.asarray(lv)), "bc drift"
    assert np.array_equal(np.asarray(r.sigma), np.asarray(s))
    assert np.allclose(np.asarray(r.delta), np.asarray(d),
                       rtol=1e-5, atol=1e-5)
    _row("shard_bc", t_s * 1e6,
         f"local={t_l * 1e6:.1f}us;ratio={t_l / t_s:.2f}x;"
         f"src_chunk={bc_chunk}")
    out["bc"] = {"t_sharded_s": round(t_s, 4), "t_local_s": round(t_l, 4),
                 "speedup_sharded_vs_local": round(t_l / t_s, 2),
                 "src_chunk": bc_chunk}

    # ---- ring-mode BC: SUMMA band rotation vs the gathered oracle -----
    # Crossover economics: gather pays O(Vp^2/n x (n-1)) all-gather bytes
    # ONCE per query plus O(Vp^2) per-shard memory; ring pays O(Vp^2/n)
    # permute bytes per rotation, (levels x n) rotations per sweep, but
    # holds per-shard memory at O(Vp^2/n).  On host-platform placeholder
    # devices the timing ratio is pure program overhead — the memory and
    # byte columns are the hardware-independent facts.
    t_r, rr = _time(bc_batched, view, g, srcs, src_chunk=bc_chunk,
                    bc_mode="ring")
    assert np.array_equal(np.asarray(rr.level), np.asarray(r.level)), \
        "ring level drift"
    assert np.array_equal(np.asarray(rr.sigma), np.asarray(r.sigma)), \
        "ring sigma drift"
    assert np.allclose(np.asarray(rr.scores), np.asarray(r.scores),
                       rtol=1e-4, atol=1e-4), "ring score drift"
    coll = _collective_bytes(mesh, view, g, "bc_ring", srcs,
                             src_chunk=bc_chunk)
    permute = coll.get("collective-permute", 0)
    n_dev = view.n_shards
    per_rot = (view.band * view.vp * 4
               + view.rows_per_shard * view.n_tiles * 4)  # O(Vp^2/n)
    mem_g = _temp_bytes(mesh, view, g, "bc", srcs, src_chunk=bc_chunk)
    mem_r = _temp_bytes(mesh, view, g, "bc_ring", srcs, src_chunk=bc_chunk)
    _row("shard_bc_ring", t_r * 1e6,
         f"gather={t_s * 1e6:.1f}us;ring_vs_gather={t_s / t_r:.2f}x;"
         f"permute_bytes={permute};per_rot_formula={per_rot};"
         f"temp_bytes={mem_r}vs{mem_g}")
    out["bc"]["ring"] = {
        "t_ring_s": round(t_r, 4),
        "ring_vs_gather": round(t_s / t_r, 2),
        "rotations_per_product": n_dev,
        "permute_bytes_hlo": permute,
        "permute_bytes_per_rotation_formula": per_rot,
        "temp_bytes_gather": mem_g,
        "temp_bytes_ring": mem_r,
        "temp_bytes_ratio": (round(mem_g / mem_r, 2)
                             if mem_g and mem_r else None),
    }
    return out


def dirty_commit(rng, g, n, frac):
    """One commit dirtying ~frac of the vertices (contiguous hot range)."""
    return hot_commit(rng, g, n, frac)


def _deep_hot_set(prior_dist, n, max_size):
    """The deepest vertices below the median level of EVERY source's tree.

    The delta cuts are per source, and the warm loops run to the max over
    sources — one shallow cut serializes the whole batch — so the
    deep-churn regime needs vertices that are deep (or unreached) from
    every source at once; among those, the deepest bind the cuts least,
    so they are taken deepest-first (by each vertex's shallowest reached
    level, the quantity ``bc_level_cut`` minimizes over).
    """
    lv = np.asarray(jax.device_get(prior_dist))
    depth = lv.max()
    big = np.iinfo(np.int32).max
    lvm = np.where(lv >= 0, lv, big).min(axis=0)  # binding level per vertex
    cand = np.flatnonzero((lvm > depth // 2) & (lvm < big))
    cand = cand[cand < n]
    return cand[np.argsort(-lvm[cand], kind="stable")][:max(2, max_size)]


def bench_incremental(mesh, view, g, n, n_sources, bc_chunk, seed,
                      fracs=(0.05, 0.2, 0.5)):
    """Sharded delta vs full recompute, and the crossover as dirt grows.

    Two regimes, both ≤ the smallest fraction of dirty vertices:

      * the **headline rows** (``shard_*_incr``) churn a hot set below the
        median level of every source's forward tree — the regime the level
        cut targets (deep churn ⇒ deep cuts ⇒ the warm loops skip the
        shallow passes; the SSSP poison keeps almost everything);
      * the **crossover table** places the hot range uniformly at random
        (the local engine benchmark's regime) and grows the dirty fraction
        (5%, 20%, 50%) — delta shrinks toward 1x as more of the graph
        moves, which is exactly why the service ladder has a threshold.

    Every delta result is cross-checked bit-identical to its full
    counterpart before being timed.
    """
    rng = np.random.default_rng(seed + 1)
    srcs = jnp.arange(n_sources, dtype=jnp.int32)
    prior_b = _block(bfs(view, g, srcs))
    prior_s = _block(sssp(view, g, srcs))
    prior_c = _block(bc_batched(view, g, srcs, src_chunk=bc_chunk))

    # ---- headline: deep churn at <= fracs[0] dirty --------------------
    deep = _deep_hot_set(prior_b.dist, n, int(n * fracs[0]) // 2)
    ops = [(PUTE, int(u), int(rng.integers(0, n)), float(rng.integers(1, 9)))
           for u in deep]
    g3, _ = apply_ops(g, ops)
    dirty3 = dirty_vertices(g, g3)
    frac3 = float(np.asarray(jax.device_get(dirty3)).mean())
    view3 = build_sharded_view(g3, mesh)
    out = {}
    for kind, delta_fn, full_fn, prior in (
            ("bfs", delta_bfs_sharded, bfs, prior_b),
            ("sssp", delta_sssp_sharded, sssp, prior_s)):
        t_d, d = _time(delta_fn, view3, g3, prior, dirty3, srcs)
        t_f, f = _time(full_fn, view3, g3, srcs)
        assert np.array_equal(np.asarray(d.dist), np.asarray(f.dist)), kind
        assert np.array_equal(np.asarray(d.parent), np.asarray(f.parent))
        _row(f"shard_{kind}_incr", t_d * 1e6,
             f"full_us={t_f * 1e6:.1f};speedup={t_f / t_d:.2f}x;"
             f"dirty_frac={frac3:.3f};deep_hot={deep.size}")
        out[kind] = {"t_delta_s": round(t_d, 4), "t_full_s": round(t_f, 4),
                     "speedup_delta_vs_full": round(t_f / t_d, 2),
                     "dirty_frac": round(frac3, 4)}
    t_dc, dc = _time(delta_bc_sharded, view3, g3, prior_c, dirty3, srcs,
                     src_chunk=bc_chunk)
    t_fc, fc = _time(bc_batched, view3, g3, srcs, src_chunk=bc_chunk)
    assert np.array_equal(np.asarray(dc.level), np.asarray(fc.level))
    assert np.array_equal(np.asarray(dc.sigma), np.asarray(fc.sigma))
    assert np.array_equal(np.asarray(dc.scores), np.asarray(fc.scores))
    _row("shard_bc_incr", t_dc * 1e6,
         f"full_us={t_fc * 1e6:.1f};speedup={t_fc / t_dc:.2f}x;"
         f"dirty_frac={frac3:.3f};deep_hot={deep.size}")
    out["bc"] = {"t_delta_s": round(t_dc, 4), "t_full_s": round(t_fc, 4),
                 "speedup_delta_vs_full": round(t_fc / t_dc, 2),
                 "dirty_frac": round(frac3, 4),
                 "deep_dirty_vertices": int(deep.size)}

    # Ring-mode delta BC: the prior's forward trees are mode-independent
    # (level/sigma bit-identical), so the gather prior warm-starts the
    # ring sweep directly; the cuts and per-source resume counters agree
    # by construction.
    t_dr, dr = _time(delta_bc_sharded, view3, g3, prior_c, dirty3, srcs,
                     src_chunk=bc_chunk, bc_mode="ring")
    t_fr, fr = _time(bc_batched, view3, g3, srcs, src_chunk=bc_chunk,
                     bc_mode="ring")
    assert np.array_equal(np.asarray(dr.level), np.asarray(fc.level))
    assert np.array_equal(np.asarray(dr.sigma), np.asarray(fc.sigma))
    assert np.array_equal(np.asarray(dr.scores), np.asarray(fr.scores))
    _row("shard_bc_incr_ring", t_dr * 1e6,
         f"full_ring_us={t_fr * 1e6:.1f};speedup={t_fr / t_dr:.2f}x;"
         f"dirty_frac={frac3:.3f}")
    out["bc"]["ring"] = {"t_delta_s": round(t_dr, 4),
                         "t_full_s": round(t_fr, 4),
                         "speedup_delta_vs_full": round(t_fr / t_dr, 2)}

    # ---- crossover: uniform hot range, growing dirty fraction ---------
    crossover = []
    for frac in fracs:
        g2 = dirty_commit(rng, g, n, frac)
        dirty = dirty_vertices(g, g2)
        view2 = build_sharded_view(g2, mesh)
        t_db, db = _time(delta_bfs_sharded, view2, g2, prior_b, dirty, srcs)
        t_fb, fb = _time(bfs, view2, g2, srcs)
        assert np.array_equal(np.asarray(db.dist), np.asarray(fb.dist))
        t_ds, ds = _time(delta_sssp_sharded, view2, g2, prior_s, dirty, srcs)
        t_fs, fs = _time(sssp, view2, g2, srcs)
        assert np.array_equal(np.asarray(ds.dist), np.asarray(fs.dist))
        crossover.append({
            "dirty_frac": frac,
            "bfs": {"t_delta_s": round(t_db, 4), "t_full_s": round(t_fb, 4),
                    "speedup_delta_vs_full": round(t_fb / t_db, 2)},
            "sssp": {"t_delta_s": round(t_ds, 4), "t_full_s": round(t_fs, 4),
                     "speedup_delta_vs_full": round(t_fs / t_ds, 2)},
        })
    out["crossover"] = crossover
    return out


def bench_service_modes(mesh, g, n, hot_frac, seed, n_commits=6):
    """Mode counters of the sharded service ladder over a commit stream:
    localized hot-range churn submitted through the scheduler, one bfs +
    one sssp query per commit."""
    rng = np.random.default_rng(seed + 2)
    svc = ShardedGraphService(g, mesh, ring_depth=n_commits + 2,
                              batch_size=4096)
    srcs = [0, 1]
    svc.query("bfs", srcs)
    svc.query("sssp", srcs)
    size = max(2, int(n * hot_frac))
    t0 = time.perf_counter()
    for _ in range(n_commits):
        base = int(rng.integers(0, max(1, n - size)))
        ops = []
        for _ in range(size):
            u = base + int(rng.integers(0, size))
            v = int(rng.integers(0, n))
            ops.append((REME, u, v) if rng.random() < 0.3
                       else (PUTE, u, v, float(rng.integers(1, 9))))
        svc.submit_many(ops)
        svc.flush()
        _block(svc.query("bfs", srcs).result)
        _block(svc.query("sssp", srcs).result)
    dt = time.perf_counter() - t0
    st = svc.stats
    modes = {"unchanged": st.unchanged, "delta": st.delta, "full": st.full}
    _row("shard_service_stream", dt / n_commits * 1e6,
         f"unchanged={st.unchanged};delta={st.delta};full={st.full}")
    return modes


def main(a):
    ROWS.clear()
    print("name,us_per_call,derived", flush=True)
    mesh = as_graph_mesh()
    n_dev = int(mesh.devices.size)
    g = load_rmat_graph(a.n, a.n * a.edge_factor, seed=a.seed)

    view, g2, view_stats = bench_view(mesh, g, a.n, a.hot_frac, a.seed)
    n_sources = max(n_dev, a.sources - a.sources % n_dev)
    q = bench_queries(mesh, view, g2, n_sources, a.bc_chunk)
    incr = bench_incremental(mesh, view, g2, a.n, n_sources, a.bc_chunk,
                             a.seed)
    incr["service_modes"] = bench_service_modes(mesh, g2, a.n, a.hot_frac,
                                                a.seed)

    print(f"\nSharded tile grid on {n_dev} devices at n={a.n}: refresh "
          f"{view_stats['refresh_vs_rebuild']:.2f}x over rebuild "
          f"({view_stats['dirty_tile_rows']} dirty tile rows, "
          f"{view_stats['dispatches_batched']} dispatches for "
          f"{view_stats['dispatches_unbatched']} rows); delta at "
          f"{incr['crossover'][0]['dirty_frac'] * 100:.0f}% dirty: bfs "
          f"{incr['bfs']['speedup_delta_vs_full']:.2f}x, sssp "
          f"{incr['sssp']['speedup_delta_vs_full']:.2f}x, bc "
          f"{incr['bc']['speedup_delta_vs_full']:.2f}x over full",
          flush=True)

    from report import bench_metadata
    payload = {
        "bench": "shard",
        "meta": bench_metadata(),
        "backend": jax.default_backend(),
        "devices": n_dev,
        "params": {"n": a.n, "edge_factor": a.edge_factor,
                   "sources": n_sources, "hot_frac": a.hot_frac,
                   "seed": a.seed, "bc_chunk": a.bc_chunk},
        "rows": ROWS,
        "view": view_stats,
        "per_shard_tile_skip_rate":
            view_stats["occupancy"]["per_shard_tile_skip_rate"],
        "queries": q,
        "incremental": incr,
        "speedups": {
            "shardedview_refresh_vs_rebuild":
                view_stats["refresh_vs_rebuild"],
            "sharded_vs_local": {k: v["speedup_sharded_vs_local"]
                                 for k, v in q.items()},
            "sharded_delta_vs_full": {
                k: incr[k]["speedup_delta_vs_full"]
                for k in ("bfs", "sssp", "bc")},
            "bc_ring_vs_gather": q["bc"]["ring"]["ring_vs_gather"],
        },
        "verified": True,  # every timed query is cross-checked above
    }
    if a.json:
        with open(a.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {a.json}", flush=True)
    return payload


if __name__ == "__main__":
    main(ARGS)
