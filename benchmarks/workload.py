"""Paper-style workload runner: mixed update/search/query streams.

Reproduces the experimental protocol of Section 5: load an R-MAT graph,
run N operations drawn from a {Update, Search, Op} distribution, measure
end-to-end time.  "Concurrency" manifests at batch granularity: while a
query SCANs, pending updates from the stream commit between collects (the
``on_read`` hook), producing the paper's interrupting-update dynamics.

Modes: pgcn (linearizable), pgicn (single collect), static (Ligra-style
dense semiring analytics over a frozen snapshot).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core import (
    GETE, GETV, PUTE, PUTV, REME, REMV, StateRef, apply_ops,
    bfs_batched_dense, dense_views, op_inconsistent, op_linearizable,
    sssp_batched_dense,
)
from repro.core.snapshot import COLLECTORS
from repro.data import load_rmat_graph


@dataclass
class MixResult:
    seconds: float
    queries: int = 0
    collects: int = 0
    interrupts: int = 0
    retries_hist: list = field(default_factory=list)


def make_ops(rng, n_ops, n_vertices, dist):
    """dist = (update%, search%, query%) as in the paper's labels."""
    upd, srch, qry = dist
    kinds = rng.choice(3, size=n_ops, p=[upd, srch, qry])
    ops = []
    for k in kinds:
        u = int(rng.integers(0, n_vertices))
        v = int(rng.integers(0, n_vertices))
        if k == 0:
            op = rng.choice([PUTV, REMV, PUTE, REME])
            if op == PUTV:
                ops.append((PUTV, u))
            elif op == REMV:
                ops.append((REMV, u))
            elif op == PUTE:
                ops.append((PUTE, u, v, float(rng.integers(1, 9))))
            else:
                ops.append((REME, u, v))
        elif k == 1:
            ops.append((rng.choice([GETV, GETE]), u, v))
        else:
            ops.append(("QUERY", u))
    return ops


def run_mix(graph, ops, query: str, mode: str, update_batch: int = 8,
            seed: int = 0) -> MixResult:
    ref = StateRef(graph)
    pending = [op for op in ops if op[0] != "QUERY"]
    queries = [op for op in ops if op[0] == "QUERY"]
    pos = {"i": 0}

    def interrupt(r):
        i = pos["i"]
        if i < len(pending):
            batch = pending[i:i + update_batch]
            pos["i"] = i + len(batch)
            ns, _ = apply_ops(r.state, batch, batch_size=update_batch)
            r.commit(ns)

    ref.on_read.append(interrupt)
    res = MixResult(0.0)
    t0 = time.perf_counter()
    for q in queries:
        src = q[1]
        if mode == "pgcn":
            out, stats = op_linearizable(ref, query, src)
            res.collects += stats.collects
            res.interrupts += stats.interrupting_updates
            res.retries_hist.append(stats.collects)
        elif mode == "pgicn":
            out, stats = op_inconsistent(ref, query, src)
            res.collects += stats.collects
        elif mode == "static":
            # Ligra-style: freeze a snapshot, run the parallel dense query
            interrupt(ref)
            am, wd, alive = dense_views(ref.state)
            if query == "bfs":
                bfs_batched_dense(am, jnp.array([src]), alive
                                  ).block_until_ready()
            elif query == "sssp":
                sssp_batched_dense(wd, jnp.array([src]), alive
                                   )[0].block_until_ready()
            else:  # bc via one dense source pass
                COLLECTORS["bc"](ref.state, src)
        res.queries += 1
    # drain the remaining update stream (all modes do the same total work)
    while pos["i"] < len(pending):
        interrupt(ref)
    res.seconds = time.perf_counter() - t0
    return res


def load_graph(n_vertices: int, edge_factor: int = 10, seed: int = 0):
    return load_rmat_graph(n_vertices, n_vertices * edge_factor,
                           slack=2.0, seed=seed)
