"""ScanStats across update rates — the paper's Fig 12/13 microbenchmark.

For each update share in the op mix, run the PG-Cn workload and report how
many TREECOLLECTs each SCAN needed and how many update batches interrupted
it (plus the fraction of scans that validated within the collect budget).

    PYTHONPATH=src python benchmarks/bench_scan_stats.py

Prints ``name,us_per_call,derived`` CSV rows like the other benchmarks.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np

from workload import load_graph, make_ops, run_mix


def scan_stats_vs_update_rate(n: int = 256, n_ops: int = 60,
                              rates=(0.1, 0.25, 0.4, 0.55, 0.7),
                              query: str = "bfs", seed: int = 0):
    rng = np.random.default_rng(seed)
    graph = load_graph(n)
    print("name,us_per_call,derived", flush=True)
    for rate in rates:
        search = 0.1
        dist = (rate, search, 1.0 - rate - search)
        ops = make_ops(rng, n_ops, n, dist)
        r = run_mix(graph, ops, query, "pgcn")
        q = max(r.queries, 1)
        us = r.seconds / q * 1e6
        print(f"fig1213_{query}_v{n}_upd{int(rate * 100)},{us:.1f},"
              f"collects/scan={r.collects / q:.2f};"
              f"interrupts/query={r.interrupts / q:.2f};"
              f"queries={r.queries}", flush=True)


if __name__ == "__main__":
    scan_stats_vs_update_rate()
