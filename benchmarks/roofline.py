"""Three-term roofline from the dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
    memory term     = HLO_bytes / HBM_bw                (819 GB/s)
    collective term = collective_bytes / link_bw        (50 GB/s ICI)

All quantities are PER DEVICE (the compiled module is the per-device SPMD
program).  FLOPs/bytes at full depth are recovered by the two-point depth
extrapolation (HloCostAnalysis visits scan bodies once), plus analytic
corrections for the three inner chunk-scans the models use to bound
activation memory (attention q-blocks, chunked cross-entropy, one-hot
embedding gradient) — their bodies are likewise visited once, and their
per-chunk cost is exactly computable from the config.
"""
from __future__ import annotations

import glob
import json
import math
import os

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12      # TPU v5e-class chip, bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link (conservative single-link)

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def _axis_sizes(mesh_name: str) -> dict:
    if mesh_name == "pod2x16x16":
        return {"pod": 2, "data": 16, "model": 16}
    return {"data": 16, "model": 16}


def analytic_corrections(arch: str, shape: str, mesh_name: str) -> dict:
    """Missing (nchunk-1)x per-chunk costs of the inner scans, per device."""
    cfg = get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    ax = _axis_sizes(mesh_name)
    nd, nm = ax["data"], ax["model"]
    ntot = nd * nm * ax.get("pod", 1)

    flops = bytes_ = coll = 0.0
    d = cfg.d_model

    # ---- attention q-block scan -------------------------------------
    if cfg.num_heads:
        sq = seq - 1 if kind == "train" else (seq if kind == "prefill" else 1)
        if sq > cfg.attn_chunk:
            chunk = cfg.attn_chunk
            nchunk = math.ceil(sq / chunk)
            if kind == "train":
                bloc = gbatch / ntot if gbatch % ntot == 0 else gbatch / nd
                hloc = cfg.num_heads        # model axis consumed by batch
                passes = 4                   # fwd + remat refwd + bwd(2)
                n_attn = (cfg.num_layers if cfg.family != "hybrid"
                          else cfg.num_layers // cfg.attn_every)
            else:
                bloc = gbatch / nd if gbatch % nd == 0 else gbatch
                # heads shard over model when divisible; otherwise the
                # q-sequence dim does (sequence-parallel fallback) — either
                # way the per-device block shrinks by nm.
                if cfg.num_heads % nm == 0 or cfg.attn_chunk % nm == 0:
                    hloc = cfg.num_heads / nm
                else:
                    hloc = cfg.num_heads
                passes = 1
                n_attn = (cfg.num_layers if cfg.family != "hybrid"
                          else cfg.num_layers // cfg.attn_every)
            skv = sq
            one_block = 4 * bloc * hloc * chunk * skv * cfg.head_dim
            flops += (nchunk - 1) * one_block * passes * n_attn
            bytes_ += (nchunk - 1) * bloc * hloc * chunk * skv * 4 \
                * 4 * min(passes, 2) * n_attn
        # whisper: encoder + cross attention blocks (seq 1500)
        if cfg.family in ("encdec", "audio") and kind == "train":
            es = cfg.encoder_seq
            if es > cfg.attn_chunk:
                nch = math.ceil(es / cfg.attn_chunk)
                bloc = gbatch / nd if gbatch % nd == 0 else gbatch
                one = 4 * bloc * cfg.num_heads * cfg.attn_chunk * es \
                    * cfg.head_dim
                flops += (nch - 1) * one * 4 * cfg.encoder_layers

    # ---- chunked xent + embedding-grad one-hot (train only) ----------
    if kind == "train":
        s = seq - 1
        chunk = min(cfg.xent_chunk, s)
        nchunk = math.ceil(s / chunk)
        nxb = nd * ax.get("pod", 1) if gbatch % (nd * ax.get("pod", 1)) == 0 \
            else nd
        tloc = (gbatch / nxb) * chunk
        vloc = (cfg.vocab_size / nm if cfg.vocab_size % nm == 0
                else cfg.vocab_size)
        per_chunk = 2 * tloc * d * vloc
        flops += (nchunk - 1) * per_chunk * 3          # xent fwd + 2 bwd
        flops += (nchunk - 1) * per_chunk              # embed one-hot bwd
        bytes_ += (nchunk - 1) * tloc * vloc * (4 * 4 + 2 * 2)
        coll += (nchunk - 1) * tloc * d * 2 * 2 * 2    # chunk reshard gathers

    return {"flops": flops, "bytes": bytes_, "coll": coll}


def extrapolate(rec: dict) -> dict | None:
    """Two-point depth extrapolation + corrections -> per-device totals."""
    if "depth1" not in rec or "depth2" not in rec:
        return None
    u = rec["units"]
    out = {}
    for key, path in (("flops", ("cost", "flops")),
                      ("bytes", ("cost", "bytes accessed")),
                      ("coll", ("collectives", "total"))):
        x1 = rec["depth1"].get(path[0], {}).get(path[1], 0.0) or 0.0
        x2 = rec["depth2"].get(path[0], {}).get(path[1], 0.0) or 0.0
        out[key] = x1 + (u - 1) * (x2 - x1)
    corr = analytic_corrections(rec["arch"], rec["shape"], rec["mesh"])
    for k in out:
        out[k] += corr[k]
    out["corrections"] = corr
    return out


def model_flops_per_chip(arch: str, shape: str, n_chips: int) -> float:
    cfg = get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    n_active = cfg.params_active()
    if kind == "train":
        return 6.0 * n_active * gbatch * (seq - 1) / n_chips
    if kind == "prefill":
        return 2.0 * n_active * gbatch * seq / n_chips
    # decode: one token per sequence + KV attention reads
    attn = 4.0 * gbatch * seq * cfg.num_heads * cfg.head_dim \
        * (cfg.num_layers if cfg.num_heads else 0)
    return (2.0 * n_active * gbatch + attn) / n_chips


def analyze_cell(rec: dict) -> dict | None:
    ext = extrapolate(rec)
    if ext is None:
        return None
    t_c = ext["flops"] / PEAK_FLOPS
    t_m = ext["bytes"] / HBM_BW
    t_x = ext["coll"] / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_chip(rec["arch"], rec["shape"], rec["n_devices"])
    t_total = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops": ext["flops"],
        "useful_ratio": mf / ext["flops"] if ext["flops"] else 0.0,
        "mfu_bound": (mf / PEAK_FLOPS) / t_total if t_total else 0.0,
        "peak_gib": rec["full"].get("memory", {}).get("peak_bytes", 0) / 2**30,
        "corrections": ext["corrections"],
    }


_ADVICE = {
    "compute": "compute-bound: raise MFU via larger per-chip batch/fusion; "
               "already the healthy regime",
    "memory": "HBM-bound: fuse/loop-tile the dominant bandwidth op "
              "(attention scores or vocab logits), keep bf16 end-to-end",
    "collective": "ICI-bound: overlap collectives with compute, shrink "
                  "gather volume (reduce-scatter weights, a2a capacity)",
}


def advice(dom: str) -> str:
    return _ADVICE[dom]


def load_all(dirname: str = DEFAULT_DIR):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("skipped") or "full" not in rec:
            continue
        recs.append(rec)
    return recs


def table(dirname: str = DEFAULT_DIR, mesh: str = "pod16x16"):
    rows = []
    for rec in load_all(dirname):
        if rec["mesh"] != mesh or rec["arch"] == "graph_engine":
            continue
        cell = analyze_cell(rec)
        if cell:
            rows.append(cell)
    return rows


def markdown(rows) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | 6ND/HLO | MFU bound | peak GiB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']:.3f} | {r['peak_gib']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = table()
    print(markdown(rows))
    for r in rows:
        print(f"{r['arch']}.{r['shape']}: {advice(r['dominant'])}")
