"""Incremental-engine throughput/latency benchmark (paper-style micro).

Workload: an R-MAT graph takes a stream of localized edge-update commits
(each commit dirties at most ``hot_frac`` of the vertices — the paper's
"only part of the graph moved" regime) interleaved with BFS/SSSP queries
from a fixed source.  We compare:

  * **full**     — the static baseline: fresh ``queries.bfs``/``sssp``
                   fixed point on every committed snapshot;
  * **incr**     — the engine path: ``engine.incremental`` delta queries
                   driven by the version ring's per-commit dirty sets.

plus the end-to-end ``GraphService`` streaming path (update ops/sec with
queries riding along), and query latency as the update rate per query
grows.  Prints ``name,us_per_call,derived`` CSV rows like the other
benchmarks, then a speedup summary.

    PYTHONPATH=src python benchmarks/bench_engine.py [--verify]
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np
import jax

from repro.core import PUTE, REME, queries
from repro.data import load_rmat_graph
from repro.engine import (
    GraphService,
    VersionRing,
    incremental_bfs,
    incremental_sssp,
    validate_incremental,
)

_INCR = {"bfs": incremental_bfs, "sssp": incremental_sssp}
_FULL = {"bfs": queries.bfs, "sssp": queries.sssp}


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _block(res):
    jax.tree.map(lambda x: x.block_until_ready(), res)
    return res


def make_commit_stream(rng, n, n_commits, ops_per_commit, hot_frac):
    """Edge churn confined to a hot vertex set of ``hot_frac * n`` sources."""
    hot = rng.choice(n, size=max(2, int(n * hot_frac)), replace=False)
    stream = []
    for _ in range(n_commits):
        ops = []
        for _ in range(ops_per_commit):
            u = int(rng.choice(hot))
            v = int(rng.integers(0, n))
            if rng.random() < 0.6:
                ops.append((PUTE, u, v, float(rng.integers(1, 9))))
            else:
                ops.append((REME, u, v))
        stream.append(ops)
    return stream


def build_versions(graph, stream, depth):
    """Commit the stream through a VersionRing; return [(state, dirty)]."""
    ring = VersionRing(graph, depth=depth)
    out = []
    for ops in stream:
        from repro.core import apply_ops
        state, _ = apply_ops(ring.latest.state, ops, batch_size=len(ops))
        entry = ring.commit(state)
        out.append((entry.state, entry.dirty))
    return out


def bench_query_paths(graph, versions, src, kind, verify=False):
    """Per-commit query latency: full fixed point vs engine delta path."""
    full_fn, incr_fn = _FULL[kind], _INCR[kind]
    # Warm up compilation on both paths.
    _block(full_fn(versions[0][0], src))
    prior, _ = incr_fn(versions[0][0], None, None, src)
    _block(incr_fn(versions[0][0], prior, versions[0][1], src)[0])

    t0 = time.perf_counter()
    for state, _ in versions:
        _block(full_fn(state, src))
    t_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    prior = None
    dirty = None
    modes = {"unchanged": 0, "delta": 0, "full": 0}
    for state, d in versions:
        res, stats = incr_fn(state, prior, d if prior is not None else None,
                             src)
        _block(res)
        modes[stats.mode] += 1
        prior = res
    t_incr = time.perf_counter() - t0

    if verify:
        prior = None
        for state, d in versions:
            res, _ = incr_fn(state, prior, d if prior is not None else None,
                             src)
            assert validate_incremental(state, src, res, kind), kind
            prior = res

    n = len(versions)
    us_full = t_full / n * 1e6
    us_incr = t_incr / n * 1e6
    speedup = t_full / t_incr
    _row(f"engine_{kind}_full", us_full, f"commits={n}")
    _row(f"engine_{kind}_incr", us_incr,
         f"speedup={speedup:.2f}x;unchanged={modes['unchanged']};"
         f"delta={modes['delta']};full={modes['full']}")
    return speedup


def bench_service_stream(graph, stream, src, batch_size=32):
    """End-to-end GraphService: ops/sec with a query after every commit."""
    svc = GraphService(graph, ring_depth=max(8, len(stream) + 2),
                       batch_size=batch_size)
    # warmup
    svc.query("bfs", src)
    n_ops = 0
    t0 = time.perf_counter()
    for ops in stream:
        svc.submit_many(ops)
        svc.flush()
        n_ops += len(ops)
        _block(svc.query("bfs", src).result)
    dt = time.perf_counter() - t0
    _row("engine_service_stream", dt / max(len(stream), 1) * 1e6,
         f"update_ops_per_s={n_ops / dt:.0f};"
         f"queries_per_s={len(stream) / dt:.1f};"
         f"unchanged={svc.stats.unchanged};delta={svc.stats.delta};"
         f"full={svc.stats.full}")


def bench_latency_vs_update_rate(graph, rng, n, src, hot_frac,
                                 rates=(8, 32, 128), n_commits=24):
    """Query latency as more update ops land between consecutive queries."""
    for rate in rates:
        stream = make_commit_stream(rng, n, n_commits, rate, hot_frac)
        versions = build_versions(graph, stream, depth=n_commits + 2)
        for kind in ("bfs", "sssp"):
            full_fn, incr_fn = _FULL[kind], _INCR[kind]
            _block(full_fn(versions[0][0], src))
            prior = None
            t0 = time.perf_counter()
            for state, d in versions:
                res, _ = incr_fn(state, prior,
                                 d if prior is not None else None, src)
                _block(res)
                prior = res
            t_incr = time.perf_counter() - t0
            t0 = time.perf_counter()
            for state, _ in versions:
                _block(full_fn(state, src))
            t_full = time.perf_counter() - t0
            _row(f"engine_{kind}_rate{rate}",
                 t_incr / n_commits * 1e6,
                 f"full_us={t_full / n_commits * 1e6:.1f};"
                 f"speedup={t_full / t_incr:.2f}x")


def main(n=2048, edge_factor=8, n_commits=32, ops_per_commit=24,
         hot_frac=0.05, seed=0, verify=False):
    rng = np.random.default_rng(seed)
    graph = load_rmat_graph(n, n * edge_factor, slack=2.0, seed=seed)
    deg = np.bincount(np.asarray(graph.esrc)[np.asarray(graph.esrc) < n],
                      minlength=n)
    src = int(np.argmax(deg))  # well-connected source: large reached region

    print("name,us_per_call,derived", flush=True)
    stream = make_commit_stream(rng, n, n_commits, ops_per_commit, hot_frac)
    versions = build_versions(graph, stream, depth=n_commits + 2)

    speedups = {}
    for kind in ("bfs", "sssp"):
        speedups[kind] = bench_query_paths(graph, versions, src, kind,
                                           verify=verify)
    bench_service_stream(graph, stream, src)
    bench_latency_vs_update_rate(graph, rng, n, src, hot_frac)

    print(f"\nIncremental speedup at <={hot_frac * 100:.0f}% dirty/commit: "
          f"BFS {speedups['bfs']:.2f}x, SSSP {speedups['sssp']:.2f}x "
          f"over full recompute", flush=True)
    return speedups


if __name__ == "__main__":
    main(verify="--verify" in sys.argv)
