"""Incremental-engine throughput/latency benchmark (paper-style micro).

Workload: an R-MAT graph takes a stream of localized edge-update commits
(each commit dirties at most ``hot_frac`` of the vertices — the paper's
"only part of the graph moved" regime) interleaved with BFS/SSSP queries
from a fixed source.  We compare:

  * **full**     — the static baseline: fresh ``queries.bfs``/``sssp``
                   fixed point on every committed snapshot;
  * **incr**     — the engine path: ``engine.incremental`` delta queries
                   driven by the version ring's per-commit dirty sets.

plus the end-to-end ``GraphService`` streaming path (update ops/sec with
queries riding along), query latency as the update rate per query grows,
and the tile-view maintenance path (full ``build_tile_view`` vs
dirty-set-driven ``refresh_tile_view``, with the occupancy the tile-skipping
kernels consume).  Prints ``name,us_per_call,derived`` CSV rows like the
other benchmarks, then a speedup summary, and always writes the whole run
as machine-readable JSON (default ``BENCH_engine.json``) so the perf
trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_engine.py [--verify] \
        [--n 2048] [--commits 32] [--ops 24] [--json BENCH_engine.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np
import jax

from repro.core import PUTE, REME, queries
from repro.core.tiles import build_tile_view, occupancy_stats, refresh_tile_view
from repro.data import load_rmat_graph
from repro.engine import (
    GraphService,
    VersionRing,
    incremental_bc,
    incremental_bfs,
    incremental_sssp,
    validate_incremental,
)

_INCR = {"bfs": incremental_bfs, "sssp": incremental_sssp,
         "bc": incremental_bc}
_FULL = {"bfs": queries.bfs, "sssp": queries.sssp,
         "bc": queries.bc_dependencies}

ROWS: list[dict] = []


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})


def _block(res):
    jax.tree.map(lambda x: x.block_until_ready(), res)
    return res


def make_commit_stream(rng, n, n_commits, ops_per_commit, hot_frac):
    """Edge churn confined to a hot vertex set of ``hot_frac * n`` sources.

    The hot set is a *contiguous* id range: localized churn (recently
    inserted vertices, one shard's id block) is the regime the paper's
    dynamic workloads model, and it keeps the dirty tile rows few — which
    is what the tile-view refresh path exploits.
    """
    size = max(2, int(n * hot_frac))
    base = int(rng.integers(0, max(1, n - size)))
    hot = np.arange(base, base + size)
    stream = []
    for _ in range(n_commits):
        ops = []
        for _ in range(ops_per_commit):
            u = int(rng.choice(hot))
            v = int(rng.integers(0, n))
            if rng.random() < 0.6:
                ops.append((PUTE, u, v, float(rng.integers(1, 9))))
            else:
                ops.append((REME, u, v))
        stream.append(ops)
    return stream


def build_versions(graph, stream, depth):
    """Commit the stream through a VersionRing; return [(state, dirty)]."""
    ring = VersionRing(graph, depth=depth)
    out = []
    for ops in stream:
        from repro.core import apply_ops
        state, _ = apply_ops(ring.latest.state, ops, batch_size=len(ops))
        entry = ring.commit(state)
        out.append((entry.state, entry.dirty))
    return out


def bench_query_paths(graph, versions, src, kind, verify=False, reps=3):
    """Per-commit query latency: full fixed point vs engine delta path.

    Best-of-``reps`` on each path (the bench_shard convention): single
    sub-second chain timings swing with CPU contention, and the
    ``speedup >= 1.0`` structural gate on the committed artifact needs
    the noise floor below the bc ladder's margin."""
    full_fn, incr_fn = _FULL[kind], _INCR[kind]
    # Warm up compilation on both paths.
    _block(full_fn(versions[0][0], src))
    prior, _ = incr_fn(versions[0][0], None, None, src)
    _block(incr_fn(versions[0][0], prior, versions[0][1], src)[0])

    t_full = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for state, _ in versions:
            _block(full_fn(state, src))
        t_full = min(t_full, time.perf_counter() - t0)

    t_incr = float("inf")
    modes = {"unchanged": 0, "delta": 0, "full": 0}
    for rep in range(reps):
        t0 = time.perf_counter()
        prior = None
        rep_modes = {"unchanged": 0, "delta": 0, "full": 0}
        for state, d in versions:
            res, stats = incr_fn(state, prior,
                                 d if prior is not None else None, src)
            _block(res)
            rep_modes[stats.mode] += 1
            prior = res
        t_incr = min(t_incr, time.perf_counter() - t0)
        if rep == 0:
            modes = rep_modes  # deterministic: identical across reps

    if verify:
        prior = None
        for state, d in versions:
            res, _ = incr_fn(state, prior, d if prior is not None else None,
                             src)
            assert validate_incremental(state, src, res, kind), kind
            prior = res

    n = len(versions)
    us_full = t_full / n * 1e6
    us_incr = t_incr / n * 1e6
    speedup = t_full / t_incr
    _row(f"engine_{kind}_full", us_full, f"commits={n}")
    _row(f"engine_{kind}_incr", us_incr,
         f"speedup={speedup:.2f}x;unchanged={modes['unchanged']};"
         f"delta={modes['delta']};full={modes['full']}")
    return speedup


def _run_service_stream(graph, stream, src, batch_size, telemetry=None):
    """One timed pass of the GraphService streaming loop; (dt, n_ops, svc)."""
    svc = GraphService(graph, ring_depth=max(8, len(stream) + 2),
                       batch_size=batch_size, telemetry=telemetry)
    # warmup
    svc.query("bfs", src)
    n_ops = 0
    t0 = time.perf_counter()
    for ops in stream:
        svc.submit_many(ops)
        svc.flush()
        n_ops += len(ops)
        _block(svc.query("bfs", src).result)
    dt = time.perf_counter() - t0
    return dt, n_ops, svc


def bench_service_stream(graph, stream, src, batch_size=32):
    """End-to-end GraphService: ops/sec with a query after every commit.

    Runs the same deterministic stream repeatedly: an UNTIMED warm pass
    (all commit/query program shapes compile here, so no timed pass pays
    them), then best-of-3 telemetry off (the plain timing, unchanged
    from earlier PRs) vs best-of-3 telemetry on (tracing + the
    ``query_wall_us`` histograms the p50/p99 fields come from — pooled
    across the reps).  Best-of-k because single ~0.5 s stream timings
    swing with CPU contention (the bench_shard convention); the on/off
    overhead ratio is the telemetry acceptance gate (<= 5%).
    """
    from repro.obs import Telemetry

    reps = 3
    _run_service_stream(graph, stream, src, batch_size)  # warm compiles
    offs = [_run_service_stream(graph, stream, src, batch_size)
            for _ in range(reps)]
    dt = min(r[0] for r in offs)
    n_ops, svc = offs[0][1], offs[0][2]
    ops_per_s = n_ops / dt
    _row("engine_service_stream", dt / max(len(stream), 1) * 1e6,
         f"update_ops_per_s={ops_per_s:.0f};"
         f"queries_per_s={len(stream) / dt:.1f};"
         f"unchanged={svc.stats.unchanged};delta={svc.stats.delta};"
         f"full={svc.stats.full}")

    tel = Telemetry.make(hlo=False)
    ons = [_run_service_stream(graph, stream, src, batch_size, telemetry=tel)
           for _ in range(reps)]
    dt_tel, svc_tel = min(r[0] for r in ons), ons[-1][2]
    qs = tel.registry.merged_quantiles("query_wall_us", (0.5, 0.99),
                                       service="local", kind="bfs")
    p50_ms = qs[0.5] / 1e3 if qs[0.5] is not None else None
    p99_ms = qs[0.99] / 1e3 if qs[0.99] is not None else None
    overhead = dt_tel / dt
    _row("engine_service_stream_telemetry",
         dt_tel / max(len(stream), 1) * 1e6,
         f"overhead={overhead:.3f}x;p50_ms={p50_ms:.2f};p99_ms={p99_ms:.2f};"
         f"unchanged={svc_tel.stats.unchanged};delta={svc_tel.stats.delta};"
         f"full={svc_tel.stats.full}")
    tel.close()
    # A healthy (fault-free) bench stream must finish with zero resilience
    # events; CI pins both at 0 so a ladder regression that silently
    # degrades answers (or swallows query errors) shows up in the bench.
    return {"update_ops_per_s": round(ops_per_s, 1),
            "p50_ms": round(p50_ms, 3), "p99_ms": round(p99_ms, 3),
            "telemetry_overhead": round(overhead, 4),
            "errors": svc.stats.errors + svc_tel.stats.errors,
            "degraded": svc.stats.degraded + svc_tel.stats.degraded}


def _run_concurrent_stream(graph, stream, srcs, batch_size, clients,
                           per_client, burst, telemetry=None):
    """One pass of the multi-client concurrent workload; timing + stats.

    One updater thread drives the commit stream through the scheduler
    while ``clients`` query threads fire pipelined bursts of BFS queries
    (a burst admits together, so compatible requests land in the same
    dispatcher drain and batch into one compiled call).  Every future is
    awaited inside the timed region — the queries/s number is
    end-to-end, admission to resolved reply.
    """
    import threading

    from repro.serve import AsyncGraphService

    svc = GraphService(graph, ring_depth=max(8, len(stream) + 2),
                       batch_size=batch_size, telemetry=telemetry)
    errs = []
    with AsyncGraphService(svc, max_batch=32) as srv:
        # Warm burst at v0: compiles the pow2 batched-dispatch variants.
        for f in [srv.query_async("bfs", s) for s in (srcs * 3)[:16]]:
            f.result(timeout=300)

        def updater():
            try:
                for ops in stream:
                    srv.submit_many(ops)
                    srv.flush()
            except Exception as e:  # pragma: no cover - harness guard
                errs.append(e)

        def querier(i):
            try:
                for q in range(0, per_client, burst):
                    futs = [srv.query_async(
                        "bfs", srcs[(i * 7 + q + j) % len(srcs)])
                        for j in range(min(burst, per_client - q))]
                    for f in futs:
                        f.result(timeout=300)
            except Exception as e:  # pragma: no cover - harness guard
                errs.append(e)

        threads = [threading.Thread(target=updater)]
        threads += [threading.Thread(target=querier, args=(i,))
                    for i in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert srv.drain(timeout=300), "drain timed out"
        dt = time.perf_counter() - t0
        assert not errs, errs
        stats = srv.stats
    return dt, clients * per_client, svc, stats


def bench_service_concurrent(graph, stream, src, batch_size=32, clients=4,
                             per_client=96, burst=4):
    """Concurrent serving front end: sustained queries/s vs the
    single-caller baseline.

    The mixed multi-client stream the tentpole exists for: updates
    commit through the scheduler while ``clients`` threads query
    concurrently on snapshot-pinned admissions; compatible queries
    (same version + kind) batch into one compiled dispatch.  An untimed
    rehearsal pass absorbs every batched-variant compile (the jit
    caches are module-level, so they survive the fresh timed service),
    then the timed pass reports end-to-end queries/s, request p50/p99
    from ``serve_request_us``, and the batch-size histogram median —
    the CI gate pins ``batch_p50 > 1`` (batching observable), batched
    dispatch count > 0, and errors/degraded == 0.
    """
    from repro.obs import Telemetry

    srcs = [(src + i) % graph.vcap for i in range(8)]
    _run_concurrent_stream(graph, stream, srcs, batch_size, clients,
                           per_client, burst)  # rehearsal: warm compiles
    tel = Telemetry.make(hlo=False)
    dt, n_q, svc, stats = _run_concurrent_stream(
        graph, stream, srcs, batch_size, clients, per_client, burst,
        telemetry=tel)
    qps = n_q / dt
    lat = tel.registry.merged_quantiles("serve_request_us", (0.5, 0.99))
    p50_ms, p99_ms = lat[0.5] / 1e3, lat[0.99] / 1e3
    bq = tel.registry.merged_quantiles("serve_batch_size", (0.5, 1.0))
    tel.close()
    _row("engine_service_concurrent", dt / max(n_q, 1) * 1e6,
         f"clients={clients};queries_per_s={qps:.0f};"
         f"p50_ms={p50_ms:.2f};p99_ms={p99_ms:.2f};"
         f"batch_p50={bq[0.5]:.0f};batch_max={bq[1.0]:.0f};"
         f"batched_dispatches={stats.batched_dispatches}")
    return {"clients": clients, "queries": n_q,
            "queries_per_s": round(qps, 1),
            "p50_ms": round(p50_ms, 3), "p99_ms": round(p99_ms, 3),
            "batch_p50": bq[0.5], "batch_max": bq[1.0],
            "batched_dispatches": int(stats.batched_dispatches),
            "dispatches": int(stats.dispatches),
            "fallbacks": int(stats.fallbacks),
            "deadline_expired": int(stats.deadline_expired),
            "max_batch_seen": int(stats.max_batch_seen),
            "errors": svc.stats.errors, "degraded": svc.stats.degraded}


def bench_service_adaptive(graph, stream, src, batch_size=32,
                           base_stats=None):
    """The self-tuning ladder on a live stream (``repro.obs.adaptive``).

    Same deterministic commit stream, but the service consults an
    aggressive :class:`AdaptiveThresholds` controller (short period,
    frequent probes — a bench-scale stream must actually move the
    thresholds) and queries all three kinds per commit so every per-kind
    controller sees samples.  Emits the before/after thresholds, the
    controller's adjustment/probe counts, and the bfs p50/p99 deltas
    against the static-threshold telemetry run (``base_stats``) — the
    number that says what self-tuning bought (or cost) on this workload.
    """
    from repro.engine.service import DEFAULT_DIRTY_THRESHOLDS
    from repro.obs import AdaptiveThresholds, Telemetry

    tel = Telemetry.make(hlo=False)
    ctl = AdaptiveThresholds(base=DEFAULT_DIRTY_THRESHOLDS, period=8,
                             min_full=1, min_delta=4, probe_every=8)
    before = ctl.thresholds()
    svc = GraphService(graph, ring_depth=max(8, len(stream) + 2),
                       batch_size=batch_size, telemetry=tel, adaptive=ctl)
    kinds = ("bfs", "sssp", "bc")
    for kind in kinds:
        _block(svc.query(kind, src).result)  # warm compiles
    t0 = time.perf_counter()
    for ops in stream:
        svc.submit_many(ops)
        svc.flush()
        for kind in kinds:
            _block(svc.query(kind, src).result)
    dt = time.perf_counter() - t0

    snap = ctl.snapshot()
    qs = tel.registry.merged_quantiles("query_wall_us", (0.5, 0.99),
                                       service="local", kind="bfs")
    p50_ms, p99_ms = qs[0.5] / 1e3, qs[0.99] / 1e3
    d50 = d99 = None
    if base_stats:
        d50 = round(p50_ms - base_stats["p50_ms"], 3)
        d99 = round(p99_ms - base_stats["p99_ms"], 3)
    thr = ";".join(f"{k}={snap['thresholds'][k]:.3f}" for k in kinds)
    _row("engine_service_stream_adaptive",
         dt / max(len(stream), 1) * 1e6,
         f"adjustments={snap['adjustments']};probes={snap['probes']};{thr};"
         f"p50_ms={p50_ms:.2f};p99_ms={p99_ms:.2f}")
    tel.close()
    return {"thresholds_before": before,
            "thresholds_after": snap["thresholds"],
            "clamps": snap["clamps"],
            "adjustments": snap["adjustments"],
            "probes": snap["probes"],
            "samples": snap["samples"],
            "p50_ms": round(p50_ms, 3), "p99_ms": round(p99_ms, 3),
            "p50_delta_ms": d50, "p99_delta_ms": d99,
            "errors": svc.stats.errors, "degraded": svc.stats.degraded}


def bench_service_recovery(graph, stream, src, batch_size=32):
    """Durable-recovery path: WAL replay throughput + compaction payoff.

    Runs the deterministic commit stream twice through journaled
    services: once against a plain single-file WAL, where recovery is a
    full-history replay (the ``replay_ops_per_s`` number), and once with
    segment rotation + periodic snapshot compaction, where recovery is
    snapshot-restore + replay-of-tail (the ``cold_recover_wall_ms``
    number, plus the snapshot size and how many sealed segments the
    compactions truncated).  Both recoveries are asserted bit-identical
    to their survivor's ring latest before any number is reported.
    """
    import shutil
    import tempfile

    from repro.resil import OpJournal, journal_meta, recover

    def _same_state(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b)))

    d = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        # ---- plain WAL: recovery == full-history replay ----
        p1 = os.path.join(d, "plain.jsonl")
        svc1 = GraphService(graph, batch_size=batch_size,
                            journal=OpJournal(p1, meta=journal_meta(
                                graph, {"batch_size": batch_size})))
        n_ops = 0
        for ops in stream:
            svc1.submit_many(ops)
            svc1.flush()
            n_ops += len(ops)
        t0 = time.perf_counter()
        rec1 = recover(p1, graph, batch_size=batch_size)
        dt_replay = time.perf_counter() - t0
        assert rec1.version == svc1.version
        assert _same_state(rec1.ring.latest.state, svc1.ring.latest.state)
        replay_ops_per_s = n_ops / dt_replay

        # ---- rotated + compacted WAL: recovery == snapshot + tail ----
        p2 = os.path.join(d, "compacted.jsonl")
        j2 = OpJournal(p2, meta=journal_meta(
            graph, {"batch_size": batch_size}), segment_bytes=2048)
        svc2 = GraphService(graph, batch_size=batch_size, journal=j2,
                            compact_every=max(1, len(stream) // 4))
        for ops in stream:
            svc2.submit_many(ops)
            svc2.flush()
        report = svc2.compact_wal()
        t0 = time.perf_counter()
        rec2 = recover(p2, batch_size=batch_size)  # snapshot: no g0 needed
        dt_cold = time.perf_counter() - t0
        assert rec2.version == svc2.version
        assert _same_state(rec2.ring.latest.state, svc2.ring.latest.state)

        n = max(len(stream), 1)
        _row("engine_service_recovery_replay", dt_replay / n * 1e6,
             f"replay_ops_per_s={replay_ops_per_s:.0f};ops={n_ops}")
        _row("engine_service_recovery_cold", dt_cold / n * 1e6,
             f"cold_recover_ms={dt_cold * 1e3:.1f};"
             f"snapshot_bytes={report['snapshot_bytes']};"
             f"segments_truncated={j2.segments_dropped}")
        return {"replay_ops_per_s": round(replay_ops_per_s, 1),
                "replay_wall_ms": round(dt_replay * 1e3, 2),
                "cold_recover_wall_ms": round(dt_cold * 1e3, 2),
                "snapshot_bytes": int(report["snapshot_bytes"]),
                "segments_truncated": int(j2.segments_dropped),
                "rotations": int(j2.rotations),
                "compactions": int(j2.compactions),
                "recovered_version": int(rec2.version),
                "recovered_matches": True}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def bench_latency_vs_update_rate(graph, rng, n, src, hot_frac,
                                 rates=(8, 32, 128), n_commits=24):
    """Query latency as more update ops land between consecutive queries."""
    for rate in rates:
        stream = make_commit_stream(rng, n, n_commits, rate, hot_frac)
        versions = build_versions(graph, stream, depth=n_commits + 2)
        for kind in ("bfs", "sssp"):
            full_fn, incr_fn = _FULL[kind], _INCR[kind]
            _block(full_fn(versions[0][0], src))
            prior = None
            t0 = time.perf_counter()
            for state, d in versions:
                res, _ = incr_fn(state, prior,
                                 d if prior is not None else None, src)
                _block(res)
                prior = res
            t_incr = time.perf_counter() - t0
            t0 = time.perf_counter()
            for state, _ in versions:
                _block(full_fn(state, src))
            t_full = time.perf_counter() - t0
            _row(f"engine_{kind}_rate{rate}",
                 t_incr / n_commits * 1e6,
                 f"full_us={t_full / n_commits * 1e6:.1f};"
                 f"speedup={t_full / t_incr:.2f}x")


def bench_tile_view(graph, versions):
    """Tile-view maintenance: full rebuild vs dirty-driven refresh."""
    _block(build_tile_view(graph))  # warm
    t0 = time.perf_counter()
    for state, _ in versions:
        _block(build_tile_view(state))
    t_full = time.perf_counter() - t0

    # Warm the refresh traces on a throwaway chain — refresh compiles one
    # program per row-window width bucket, so every commit must run once
    # untimed (and refresh *consumes* its input: the row updates donate the
    # buffers, hence the fresh build for the timed chain).
    warm = _block(build_tile_view(graph))
    for state, d in versions:
        warm = _block(refresh_tile_view(state, warm, d))
    view = _block(build_tile_view(graph))
    t0 = time.perf_counter()
    for state, d in versions:
        view = _block(refresh_tile_view(state, view, d))
    t_incr = time.perf_counter() - t0

    n = len(versions)
    stats = occupancy_stats(view)
    speedup = t_full / t_incr
    _row("engine_tileview_full", t_full / n * 1e6, f"commits={n}")
    _row("engine_tileview_refresh", t_incr / n * 1e6,
         f"speedup={speedup:.2f}x;"
         f"tile_skip_rate={stats['tile_skip_rate']:.4f};"
         f"tiles_active={stats['tiles_active']}/{stats['tiles_total']}")
    return speedup, stats


def main(n=2048, edge_factor=8, n_commits=32, ops_per_commit=24,
         hot_frac=0.05, seed=0, verify=False, json_path="BENCH_engine.json"):
    ROWS.clear()
    rng = np.random.default_rng(seed)
    graph = load_rmat_graph(n, n * edge_factor, slack=2.0, seed=seed)
    deg = np.bincount(np.asarray(graph.esrc)[np.asarray(graph.esrc) < n],
                      minlength=n)
    src = int(np.argmax(deg))  # well-connected source: large reached region

    print("name,us_per_call,derived", flush=True)
    stream = make_commit_stream(rng, n, n_commits, ops_per_commit, hot_frac)
    versions = build_versions(graph, stream, depth=n_commits + 2)

    speedups = {}
    for kind in ("bfs", "sssp", "bc"):
        speedups[kind] = bench_query_paths(graph, versions, src, kind,
                                           verify=verify)
    service_stats = bench_service_stream(graph, stream, src)
    service_stats["concurrent"] = bench_service_concurrent(graph, stream,
                                                           src)
    service_stats["adaptive"] = bench_service_adaptive(
        graph, stream, src, base_stats=service_stats)
    service_stats["recovery"] = bench_service_recovery(graph, stream, src)
    bench_latency_vs_update_rate(graph, rng, n, src, hot_frac)
    tile_speedup, tile_stats = bench_tile_view(graph, versions)

    print(f"\nIncremental speedup at <={hot_frac * 100:.0f}% dirty/commit: "
          f"BFS {speedups['bfs']:.2f}x, SSSP {speedups['sssp']:.2f}x, "
          f"BC {speedups['bc']:.2f}x over full recompute; tile refresh "
          f"{tile_speedup:.2f}x over rebuild", flush=True)

    from report import bench_metadata
    payload = {
        "bench": "engine",
        "meta": bench_metadata(),
        "backend": jax.default_backend(),
        "params": {"n": n, "edge_factor": edge_factor,
                   "n_commits": n_commits, "ops_per_commit": ops_per_commit,
                   "hot_frac": hot_frac, "seed": seed},
        "rows": ROWS,
        "speedups": {"bfs_incr_vs_full": round(speedups["bfs"], 3),
                     "sssp_incr_vs_full": round(speedups["sssp"], 3),
                     "bc_incr_vs_full": round(speedups["bc"], 3),
                     "tileview_refresh_vs_rebuild": round(tile_speedup, 3)},
        "service": service_stats,
        "tile_occupancy": tile_stats,
        "verified": bool(verify),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {json_path}", flush=True)
    return payload


def _parse_args(argv):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=2048,
                   help="vertex count (power of two for R-MAT)")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--commits", type=int, default=32)
    p.add_argument("--ops", type=int, default=24,
                   help="update ops per commit")
    p.add_argument("--hot-frac", type=float, default=0.05)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--verify", action="store_true")
    p.add_argument("--json", default="BENCH_engine.json",
                   help="output path for the machine-readable results")
    return p.parse_args(argv)


if __name__ == "__main__":
    a = _parse_args(sys.argv[1:])
    main(n=a.n, edge_factor=a.edge_factor, n_commits=a.commits,
         ops_per_commit=a.ops, hot_frac=a.hot_frac, seed=a.seed,
         verify=a.verify, json_path=a.json)
