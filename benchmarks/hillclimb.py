"""Perf-iteration driver (EXPERIMENTS.md §Perf).

Recompiles a dry-run cell with config/code overrides and reports the three
roofline terms, so each hypothesis->change->measure cycle is one command:

    PYTHONPATH=src:benchmarks python benchmarks/hillclimb.py \
        --arch qwen2_vl_72b --shape train_4k --set attn_chunk=1024

Results append to experiments/hillclimb.jsonl.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch.dryrun import analyze, lower_cell, scale_depth, unit_count  # noqa: E402

import roofline  # noqa: E402


def measure(arch: str, shape: str, overrides: dict, label: str,
            full_memory: bool = False) -> dict:
    mesh = meshlib.make_production_mesh()
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    u = unit_count(cfg)
    rec = {"arch": arch, "shape": shape, "mesh": "pod16x16", "units": u,
           "label": label, "overrides": overrides, "full": {}}
    for d in (1, 2):
        t0 = time.time()
        c = lower_cell(scale_depth(cfg, d), shape, mesh).compile()
        rec[f"depth{d}"] = analyze(c)
        rec[f"depth{d}"]["compile_s"] = round(time.time() - t0, 1)
        del c
    if full_memory:
        c = lower_cell(cfg, shape, mesh).compile()
        rec["full"] = analyze(c)
        del c
    cell = roofline.analyze_cell({**rec, "n_devices": 256})
    out = {"label": label, "arch": arch, "shape": shape,
           "overrides": {k: str(v) for k, v in overrides.items()},
           **{k: cell[k] for k in ("t_compute", "t_memory", "t_collective",
                                   "dominant", "mfu_bound", "useful_ratio")}}
    if full_memory:
        out["peak_gib"] = rec["full"]["memory"]["peak_bytes"] / 2**30
    with open("experiments/hillclimb.jsonl", "a") as f:
        f.write(json.dumps(out) + "\n")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--label", default="iter")
    ap.add_argument("--full-memory", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (int/float parsed)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v
    out = measure(args.arch, args.shape, overrides, args.label,
                  args.full_memory)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
