"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts,
plus the shared ``bench_metadata()`` header every BENCH_*.json emitter
stamps into its payload (schema version, git sha, device inventory)."""
import datetime
import glob
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

BENCH_SCHEMA_VERSION = 1


def _git_sha():
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except Exception:
        return None


def bench_metadata():
    """The provenance header shared by every BENCH_*.json payload.

    One place defines the schema, so the CI gates (and any diffing of
    bench artifacts across commits) can rely on every emitter carrying
    the same ``meta`` block.
    """
    import jax
    return {
        "bench_schema": BENCH_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def dryrun_table():
    import roofline
    rows = []
    for fn in sorted(glob.glob(os.path.join(roofline.DEFAULT_DIR, "*.json"))):
        rec = json.load(open(fn))
        if rec.get("arch") == "graph_engine":
            continue
        if rec.get("skipped"):
            rows.append((rec["arch"], rec["shape"], rec["mesh"], "SKIP",
                         rec["reason"], ""))
            continue
        mem = rec["full"].get("memory", {})
        coll = rec["full"].get("collectives", {})
        rows.append((
            rec["arch"], rec["shape"], rec["mesh"],
            f"ok ({rec['compile_s']}s)",
            f"{mem.get('peak_bytes', 0)/2**30:.1f} GiB",
            f"{coll.get('total', 0)/2**30:.2f} GiB/{coll.get('count', 0)}"))
    out = ["| arch | shape | mesh | compile | peak/dev | HLO coll bytes/ops |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def graph_table():
    import roofline
    out = ["| cell | mesh | query | per-level coll | flops(body) | compile |",
           "|---|---|---|---|---|---|"]
    for fn in sorted(glob.glob(os.path.join(roofline.DEFAULT_DIR,
                                            "graph_engine*.json"))):
        rec = json.load(open(fn))
        name = os.path.basename(fn).replace(".json", "")
        for q in ("bfs", "sssp"):
            if q not in rec:
                continue
            c = rec[q]["collectives"]
            out.append(
                f"| {name} | {rec['mesh']} | {q} | "
                f"{c.get('total', 0)/1024:.0f} KiB/{c.get('count')} ops | "
                f"{rec[q]['cost'].get('flops', 0):.2e} | "
                f"{rec[q].get('compile_s', '?')}s |")
    return "\n".join(out)


if __name__ == "__main__":
    import roofline
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print(dryrun_table())
        print()
    if which in ("all", "graph"):
        print(graph_table())
        print()
    if which in ("all", "roofline"):
        print(roofline.markdown(roofline.table()))
