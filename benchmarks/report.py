"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import roofline  # noqa: E402


def dryrun_table():
    rows = []
    for fn in sorted(glob.glob(os.path.join(roofline.DEFAULT_DIR, "*.json"))):
        rec = json.load(open(fn))
        if rec.get("arch") == "graph_engine":
            continue
        if rec.get("skipped"):
            rows.append((rec["arch"], rec["shape"], rec["mesh"], "SKIP",
                         rec["reason"], ""))
            continue
        mem = rec["full"].get("memory", {})
        coll = rec["full"].get("collectives", {})
        rows.append((
            rec["arch"], rec["shape"], rec["mesh"],
            f"ok ({rec['compile_s']}s)",
            f"{mem.get('peak_bytes', 0)/2**30:.1f} GiB",
            f"{coll.get('total', 0)/2**30:.2f} GiB/{coll.get('count', 0)}"))
    out = ["| arch | shape | mesh | compile | peak/dev | HLO coll bytes/ops |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def graph_table():
    out = ["| cell | mesh | query | per-level coll | flops(body) | compile |",
           "|---|---|---|---|---|---|"]
    for fn in sorted(glob.glob(os.path.join(roofline.DEFAULT_DIR,
                                            "graph_engine*.json"))):
        rec = json.load(open(fn))
        name = os.path.basename(fn).replace(".json", "")
        for q in ("bfs", "sssp"):
            if q not in rec:
                continue
            c = rec[q]["collectives"]
            out.append(
                f"| {name} | {rec['mesh']} | {q} | "
                f"{c.get('total', 0)/1024:.0f} KiB/{c.get('count')} ops | "
                f"{rec[q]['cost'].get('flops', 0):.2e} | "
                f"{rec[q].get('compile_s', '?')}s |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print(dryrun_table())
        print()
    if which in ("all", "graph"):
        print(graph_table())
        print()
    if which in ("all", "roofline"):
        print(roofline.markdown(roofline.table()))
