"""Batched Brandes BC benchmark: semiring matmuls vs the lax.map baseline.

Two regimes, both on R-MAT inputs:

  * **compact**  — vcap == n (every tile row populated): measures the
    headline win of ``bc_batched_dense`` (all sources at once as
    bool/count semiring matmuls) over the per-source ``lax.map`` of
    ``bc_dependencies`` that ``bc()`` used to run.  The baseline is timed
    over a source subsample (``--baseline-sources``) and extrapolated —
    running all n sources through lax.map takes minutes by design.
  * **slack**    — vcap == slack_factor * n with the live graph in the low
    ids (the paper's dynamic regime: capacity preallocated for growth):
    most tile rows are empty, and the tile-skipping path
    (``amask=TileView.occ``) shows its win over the dense sweep.  The
    reported ``tile_skip_rate`` is the fraction of weight tiles with no
    live edge — exactly what the masked kernels elide.

Forward-sweep frontier-slab occupancy (the *dynamic* skip the kernels also
exploit: one-hot frontiers touch almost no k slabs early on) is measured by
replaying the level loop eagerly.  Prints CSV rows, verifies the batched
results against per-source Brandes on a subsample, and always writes
``BENCH_bc.json``.

    PYTHONPATH=src python benchmarks/bench_bc.py [--n 1024] \
        [--baseline-sources 64] [--bc-chunk 256] [--json BENCH_bc.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import from_edge_list, queries
from repro.core.tiles import TILE, build_tile_view, occupancy_stats
from repro.data import rmat_edges

ROWS: list[dict] = []


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})


def _block(res):
    jax.tree.map(lambda x: x.block_until_ready(), res)
    return res


def _time(fn, *args, **kw):
    _block(fn(*args, **kw))  # warm compilation
    t0 = time.perf_counter()
    out = _block(fn(*args, **kw))
    return time.perf_counter() - t0, out


def frontier_slab_occupancy(adj, alive, srcs, bm=128, bk=512):
    """Replay the forward sweep eagerly, measuring the fraction of
    (source-slab, k-slab) frontier blocks that are non-identity per level —
    the dynamic skip rate of the masked kernels' left operand.  Defaults
    match the bool/count kernel block sizes (bm=128, bk=512) so the rate is
    what those kernels can actually elide, not an optimistic finer grid."""
    V = adj.shape[0]
    a = (adj & alive[:, None] & alive[None, :]).astype(jnp.float32)
    front = jax.nn.one_hot(srcs, V, dtype=jnp.float32) \
        * alive[jnp.clip(srcs, 0, V - 1)][:, None]
    dist = jnp.where(front > 0, 0, -1).astype(jnp.int32)
    rates, lvl = [], 0
    while bool((front > 0).any()) and lvl < V:
        fp = np.asarray(front)
        S, K = fp.shape
        sp = -(-S // bm) * bm
        kp = -(-K // bk) * bk
        padded = np.zeros((sp, kp), np.float32)
        padded[:S, :K] = fp
        blocks = padded.reshape(sp // bm, bm, kp // bk, bk).any(axis=(1, 3))
        rates.append(float(blocks.mean()))
        nxt = queries.semiring.bool_mm(front, a)
        newly = (np.asarray(nxt) > 0) & (np.asarray(dist) < 0)
        dist = jnp.where(jnp.asarray(newly), lvl + 1, dist)
        front = jnp.asarray(newly.astype(np.float32))
        lvl += 1
    return rates


def bench_compact(n, edge_factor, seed, baseline_sources, verify,
                  bc_chunk=None):
    """vcap == n: batched semiring BC vs the per-source lax.map baseline."""
    src, dst, w = rmat_edges(n, n * edge_factor, seed=seed, weighted=False)
    g = from_edge_list(n, int(len(src) * 1.5), src, dst, w)
    view = build_tile_view(g)
    occ = occupancy_stats(view)
    am, _, alive = queries.dense_views(g)
    srcs = jnp.arange(n, dtype=jnp.int32)

    t_batched, out = _time(queries.bc_batched_dense, am, srcs, alive)
    _row("bc_batched_all_sources", t_batched * 1e6,
         f"n={n};sources={n};tile_skip_rate={occ['tile_skip_rate']:.4f}")

    t_chunked = None
    if bc_chunk:
        # Source-axis chunking: 4 x chunk x V scratch instead of 4 x S x V
        # (the vcap ~ 16k ceiling), one forward+backward sweep per chunk.
        t_chunked, out_c = _time(queries.bc_batched_dense, am, srcs, alive,
                                 src_chunk=bc_chunk)
        _row("bc_batched_chunked", t_chunked * 1e6,
             f"src_chunk={bc_chunk};vs_unchunked="
             f"{t_batched / t_chunked:.2f}x")
        if verify:
            for a, b in zip(out, out_c):
                assert np.allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
            print("verify: chunked == unchunked batched BC", flush=True)

    sub = jnp.arange(min(baseline_sources, n), dtype=jnp.int32)
    t_map, _ = _time(queries.bc_map, g, 0, sub)
    us_map_per_src = t_map / int(sub.shape[0]) * 1e6
    t_map_full_est = us_map_per_src * n / 1e6
    speedup = t_map_full_est / t_batched
    _row("bc_laxmap_baseline", us_map_per_src,
         f"sampled={int(sub.shape[0])};est_full_s={t_map_full_est:.2f};"
         f"speedup={speedup:.2f}x")

    if verify:
        delta, sigma, level, ok = out
        for s in np.linspace(0, n - 1, 8, dtype=int):
            r = queries.bc_dependencies(g, int(s))
            assert np.array_equal(np.asarray(level[s]), np.asarray(r.level))
            assert np.array_equal(np.asarray(sigma[s]), np.asarray(r.sigma))
            assert np.allclose(np.asarray(delta[s]), np.asarray(r.delta),
                               rtol=1e-5, atol=1e-5)
        print("verify: batched == per-source on 8 sampled sources",
              flush=True)

    slabs = frontier_slab_occupancy(am, alive, srcs)
    return {
        "t_batched_s": round(t_batched, 4),
        "src_chunk": bc_chunk,
        "t_chunked_s": round(t_chunked, 4) if t_chunked else None,
        "laxmap_us_per_source": round(us_map_per_src, 1),
        "laxmap_est_full_s": round(t_map_full_est, 3),
        "speedup_vs_laxmap": round(speedup, 2),
        "tile_occupancy": occ,
        "frontier_slab_block": [128, 512],  # (bm, bk) of bool/count kernels
        "frontier_slab_occupancy_per_level": [round(r, 4) for r in slabs],
    }


def bench_slack(n, edge_factor, slack_factor, seed):
    """vcap >> live vertices: tile skipping vs the dense sweep."""
    vcap = n * slack_factor
    src, dst, w = rmat_edges(n, n * edge_factor, seed=seed, weighted=False)
    g = from_edge_list(vcap, int(len(src) * 1.5), src, dst, w)
    view = build_tile_view(g)
    occ = occupancy_stats(view)
    am, _, alive = queries.dense_views(g)
    srcs = jnp.arange(n, dtype=jnp.int32)  # live sources only

    t_dense, _ = _time(queries.bc_batched_dense, am, srcs, alive)
    t_masked, _ = _time(queries.bc_batched_dense, am, srcs, alive,
                        amask=view.occ)
    speedup = t_dense / t_masked
    _row("bc_batched_slack_dense", t_dense * 1e6, f"vcap={vcap};sources={n}")
    _row("bc_batched_slack_masked", t_masked * 1e6,
         f"speedup={speedup:.2f}x;"
         f"tile_skip_rate={occ['tile_skip_rate']:.4f}")
    return {
        "vcap": vcap,
        "t_dense_s": round(t_dense, 4),
        "t_masked_s": round(t_masked, 4),
        "speedup_masked_vs_dense": round(speedup, 2),
        "tile_occupancy": occ,
    }


def main(n=1024, edge_factor=8, slack_factor=4, seed=0, baseline_sources=64,
         verify=False, json_path="BENCH_bc.json", bc_chunk=None):
    ROWS.clear()
    print("name,us_per_call,derived", flush=True)
    compact = bench_compact(n, edge_factor, seed, baseline_sources, verify,
                            bc_chunk=bc_chunk)
    slack = bench_slack(n, edge_factor, slack_factor, seed)

    print(f"\nBatched BC at n={n}: {compact['speedup_vs_laxmap']:.1f}x over "
          f"the lax.map baseline; tile skipping at "
          f"{slack['tile_occupancy']['tile_skip_rate']*100:.1f}% empty tiles "
          f"(slack regime): {slack['speedup_masked_vs_dense']:.2f}x over the "
          f"dense sweep", flush=True)

    from report import bench_metadata
    payload = {
        "bench": "bc",
        "meta": bench_metadata(),
        "backend": jax.default_backend(),
        "params": {"n": n, "edge_factor": edge_factor,
                   "slack_factor": slack_factor, "seed": seed,
                   "baseline_sources": baseline_sources,
                   "bc_chunk": bc_chunk},
        "rows": ROWS,
        "compact": compact,
        "slack": slack,
        "verified": bool(verify),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {json_path}", flush=True)
    return payload


def _parse_args(argv):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=1024,
                   help="live vertex count (power of two for R-MAT)")
    p.add_argument("--edge-factor", type=int, default=8)
    p.add_argument("--slack-factor", type=int, default=4,
                   help="vcap multiplier for the tile-skip regime")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--baseline-sources", type=int, default=64,
                   help="lax.map baseline sample size (extrapolated)")
    p.add_argument("--bc-chunk", type=int, default=None,
                   help="source-axis chunk for the batched path (bounds "
                        "the S x V scratch; see bc_batched_dense)")
    p.add_argument("--verify", action="store_true")
    p.add_argument("--json", default="BENCH_bc.json",
                   help="output path for the machine-readable results")
    return p.parse_args(argv)


if __name__ == "__main__":
    a = _parse_args(sys.argv[1:])
    main(n=a.n, edge_factor=a.edge_factor, slack_factor=a.slack_factor,
         seed=a.seed, baseline_sources=a.baseline_sources, verify=a.verify,
         json_path=a.json, bc_chunk=a.bc_chunk)
