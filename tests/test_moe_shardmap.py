"""The shard_map expert-parallel MoE vs the dense oracle, on a real
(2 data x 2 model) mesh — spawned in a subprocess so the 4 placeholder
devices never leak into the other tests."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.configs import get_config, reduced
from repro.models.moe import _moe_dense, _moe_shard_map, init_moe
from repro.models.sharding_ctx import sharding_context

cfg = dataclasses.replace(
    reduced(get_config("granite_moe_1b")),
    num_experts=4, top_k=2, d_ff=64, d_model=32,
    capacity_factor=8.0)   # no drops -> exact equality expected

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
key = jax.random.PRNGKey(0)
p = init_moe(key, cfg)
p = jax.tree.map(lambda x: x.astype(jnp.float32), p)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 32), jnp.float32)

dense_out, dense_aux = _moe_dense(p, x, cfg)

with mesh, sharding_context(mesh, full_batch=True):
    sm_out, sm_aux = jax.jit(
        lambda p, x: _moe_shard_map(p, x, cfg, mesh))(p, x)

err = float(jnp.max(jnp.abs(dense_out - sm_out)))
print("max err:", err)
assert err < 1e-4, err

# gradients agree too
def loss_d(p, x):
    o, a = _moe_dense(p, x, cfg)
    return jnp.sum(o ** 2) + a

def loss_s(p, x):
    o, a = _moe_shard_map(p, x, cfg, mesh)
    return jnp.sum(o ** 2) + a

gd = jax.grad(loss_d)(p, x)
with mesh, sharding_context(mesh, full_batch=True):
    gs = jax.jit(jax.grad(loss_s))(p, x)
for k in ("router", "wi", "wg", "wo"):
    e = float(jnp.max(jnp.abs(gd[k] - gs[k])))
    m = float(jnp.max(jnp.abs(gd[k]))) + 1e-9
    assert e / m < 1e-3, (k, e, m)
print("GRADS OK")
"""


def test_shard_map_moe_matches_dense_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "GRADS OK" in out.stdout
