"""Pure-python reference model of the paper's ADT (the sequential oracle)."""
from __future__ import annotations

import math
from collections import deque

INF = float("inf")


class GraphOracle:
    """Sequential directed graph with the exact ADT semantics of Section 2."""

    def __init__(self):
        self.vertices: set[int] = set()
        self.edges: dict[tuple[int, int], float] = {}

    # --- updates -----------------------------------------------------
    def put_v(self, v):
        if v in self.vertices:
            return False
        self.vertices.add(v)
        return True

    def rem_v(self, v):
        if v not in self.vertices:
            return False
        self.vertices.discard(v)
        self.edges = {(a, b): w for (a, b), w in self.edges.items()
                      if a != v and b != v}
        return True

    def get_v(self, v):
        return v in self.vertices

    def put_e(self, u, v, w):
        if u not in self.vertices or v not in self.vertices:
            return False, INF
        if (u, v) in self.edges:
            old = self.edges[(u, v)]
            if old == w:
                return False, old
            self.edges[(u, v)] = w
            return True, old
        self.edges[(u, v)] = w
        return True, INF

    def rem_e(self, u, v):
        if (u, v) in self.edges and u in self.vertices and v in self.vertices:
            return True, self.edges.pop((u, v))
        return False, INF

    def get_e(self, u, v):
        if (u, v) in self.edges and u in self.vertices and v in self.vertices:
            return True, self.edges[(u, v)]
        return False, INF

    # --- queries -----------------------------------------------------
    def adj(self):
        out = {}
        for (u, v), w in self.edges.items():
            if u in self.vertices and v in self.vertices:
                out.setdefault(u, []).append((v, w))
        return out

    def bfs(self, src):
        if src not in self.vertices:
            return None
        adj = self.adj()
        dist = {src: 0}
        q = deque([src])
        while q:
            u = q.popleft()
            for v, _ in sorted(adj.get(u, [])):
                if v not in dist:
                    dist[v] = dist[u] + 1
                    q.append(v)
        return dist

    def sssp(self, src):
        """Bellman-Ford. Returns (dist dict, negcycle flag)."""
        if src not in self.vertices:
            return None, False
        adj = self.adj()
        dist = {v: INF for v in self.vertices}
        dist[src] = 0.0
        for _ in range(max(1, len(self.vertices) - 1)):
            changed = False
            for u, nbrs in adj.items():
                if dist.get(u, INF) == INF:
                    continue
                for v, w in nbrs:
                    if dist[u] + w < dist[v] - 1e-9:
                        dist[v] = dist[u] + w
                        changed = True
            if not changed:
                break
        neg = False
        for u, nbrs in adj.items():
            if dist.get(u, INF) == INF:
                continue
            for v, w in nbrs:
                if dist[u] + w < dist[v] - 1e-6:
                    neg = True
        return dist, neg

    def bc_dependencies(self, src):
        """Brandes single-source dependencies delta(src | v)."""
        if src not in self.vertices:
            return None
        adj = self.adj()
        # forward
        dist = {src: 0}
        sigma = {src: 1.0}
        order = []
        q = deque([src])
        while q:
            u = q.popleft()
            order.append(u)
            for v, _ in adj.get(u, []):
                if v not in dist:
                    dist[v] = dist[u] + 1
                    sigma[v] = 0.0
                    q.append(v)
                if dist[v] == dist[u] + 1:
                    sigma[v] += sigma[u]
        delta = {v: 0.0 for v in dist}
        for u in reversed(order):
            for v, _ in adj.get(u, []):
                if dist.get(v, -9) == dist[u] + 1:
                    delta[u] += sigma[u] / sigma[v] * (1 + delta[v])
        delta[src] = 0.0
        return delta

    def bc_scores(self):
        """Exact all-sources betweenness: BC(v) = sum_s delta(s | v)."""
        scores = {v: 0.0 for v in self.vertices}
        for s in self.vertices:
            for v, d in self.bc_dependencies(s).items():
                scores[v] += d
        return scores
