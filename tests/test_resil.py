"""Resilience subsystem: deterministic fault plans, the degrade ladder,
scheduler commit atomicity under failure, crash-consistent journal
recovery, and the post-fault invariant checker.

Every scenario is driven by an explicit :class:`repro.resil.FaultPlan`
schedule (or a seeded-random plan whose ``to_schedule()`` replay is
itself asserted), so each failure mode here is a regression test, not a
flake.  The randomized end-to-end chaos runs live in
``test_stream_differential``; this file pins the mechanisms one at a
time.
"""
import json

import numpy as np
import pytest

from repro.core import PUTE, PUTV, REMV, apply_ops, make_graph
from repro.engine import GraphService
from repro.resil import (
    FAULT_POINTS,
    P_CACHE_STORE,
    P_COLLECT_DELTA,
    P_COLLECT_DISPATCH,
    P_JOURNAL_BARRIER,
    P_JOURNAL_TORN,
    P_OBS_SINK,
    P_RING_EVICT,
    P_SCHED_APPLY,
    P_SCHED_RING_COMMIT,
    CircuitBreaker,
    FaultPlan,
    InjectedCrash,
    InjectedFault,
    JournalError,
    OpJournal,
    ResiliencePolicy,
    assert_service_ok,
    fault_scope,
    inject,
    journal_meta,
    read_journal,
    read_journal_versions,
    recover,
    segment_files,
    snapshot_dir,
    verify_service,
)

VCAP, ECAP = 64, 256


def _seed_graph(rng, n=24, m=96):
    g = make_graph(VCAP, ECAP)
    ops = [(PUTV, i) for i in range(n)]
    for _ in range(m):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        ops.append((PUTE, u, v, float(rng.integers(1, 9))))
    g, _ = apply_ops(g, ops)
    return g


def _stream_ops(rng, n=24, count=40):
    ops = []
    for _ in range(count):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        r = float(rng.random())
        if r < 0.1:
            ops.append((PUTV, u))
        elif r < 0.2:
            ops.append((REMV, u))
        else:
            ops.append((PUTE, u, v, float(rng.integers(1, 9))))
    return ops


def _assert_same_state(a, b):
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ------------------------------- fault plans --------------------------------

def test_inject_noop_without_plan():
    for p in FAULT_POINTS:
        inject(p)  # no active plan: must be free and silent


def test_scheduled_plan_fires_exact_hits():
    plan = FaultPlan({P_COLLECT_DISPATCH: [1, 3]})
    with fault_scope(plan):
        inject(P_COLLECT_DISPATCH)  # hit 0: pass
        with pytest.raises(InjectedFault) as ei:
            inject(P_COLLECT_DISPATCH)  # hit 1: fire
        assert ei.value.point == P_COLLECT_DISPATCH and ei.value.hit == 1
        inject(P_COLLECT_DISPATCH)  # hit 2: pass
        with pytest.raises(InjectedFault):
            inject(P_COLLECT_DISPATCH)  # hit 3: fire
        inject(P_SCHED_APPLY)  # other points untouched
    assert plan.fired == 2
    assert plan.to_schedule() == {P_COLLECT_DISPATCH: [1, 3]}


def test_crash_points_raise_base_exception():
    plan = FaultPlan({P_JOURNAL_BARRIER: [0]})
    with fault_scope(plan):
        with pytest.raises(InjectedCrash) as ei:
            inject(P_JOURNAL_BARRIER)
    assert not isinstance(ei.value, Exception)  # unswallowable by ladders


def test_random_plan_replays_identically():
    def drive(plan):
        fired = []
        with fault_scope(plan):
            for i in range(200):
                point = FAULT_POINTS[i % len(FAULT_POINTS)]
                try:
                    inject(point)
                except (InjectedFault, InjectedCrash):
                    fired.append((point, i))
        return fired

    p1 = FaultPlan(seed=5, rate=0.2)
    fired1 = drive(p1)
    assert fired1, "rate 0.2 over 200 hits must fire"
    # identical seeded plan -> identical decisions
    assert drive(FaultPlan(seed=5, rate=0.2)) == fired1
    # to_schedule() replays the exact pattern without the RNG
    assert drive(FaultPlan(p1.to_schedule())) == fired1


def test_max_faults_caps_firing_without_shifting_streams():
    p_uncapped = FaultPlan(seed=9, rate=0.5)
    p_capped = FaultPlan(seed=9, rate=0.5, max_faults=3)

    def decisions(plan):
        with fault_scope(plan):
            out = []
            for _ in range(100):
                try:
                    inject(P_COLLECT_DELTA)
                    out.append(False)
                except InjectedFault:
                    out.append(True)
        return out

    d_un, d_cap = decisions(p_uncapped), decisions(p_capped)
    assert p_capped.fired == 3
    assert d_cap == [d and i < [j for j, x in enumerate(d_un) if x][2] + 1
                     for i, d in enumerate(d_un)]


def test_fault_scope_nests_and_allows_none():
    with fault_scope(None):
        inject(P_SCHED_APPLY)
        plan = FaultPlan({P_SCHED_APPLY: [0]})
        with fault_scope(plan):
            with pytest.raises(InjectedFault):
                inject(P_SCHED_APPLY)
        inject(P_SCHED_APPLY)  # outer scope restored: no plan


# --------------------------------- policy -----------------------------------

def test_policy_validation_and_backoff():
    with pytest.raises(ValueError):
        ResiliencePolicy(max_retries=-1)
    with pytest.raises(ValueError):
        ResiliencePolicy(backoff_ms=-1.0)
    pol = ResiliencePolicy(backoff_ms=10.0, backoff_factor=2.0)
    assert pol.backoff_s(1) == 0.010 and pol.backoff_s(3) == 0.040
    assert ResiliencePolicy().backoff_s(5) == 0.0
    assert not ResiliencePolicy().deadline_exceeded(0.0)  # inf deadline
    assert ResiliencePolicy(deadline_ms=0.0).deadline_exceeded(0.0)


# ----------------------- service stats exception-safety ---------------------

def test_stats_conserved_when_collect_raises_no_policy():
    """Satellite regression: a raising collect must NOT count as a query —
    it lands in ``service_errors`` and conservation still holds."""
    rng = np.random.default_rng(0)
    svc = GraphService(_seed_graph(rng), batch_size=4)
    svc.query("bfs", 0)
    base = svc.stats.queries
    with fault_scope(FaultPlan({P_COLLECT_DISPATCH: [0]})):
        with pytest.raises(InjectedFault):
            svc.query("bfs", 1)
    st = svc.stats
    assert st.queries == base and st.errors == 1
    assert st.unchanged + st.delta + st.full == st.queries
    assert_service_ok(svc)
    # the service keeps serving afterwards
    assert svc.query("bfs", 1).version == svc.version


def test_cache_store_fault_preserves_old_slot():
    """A fault racing the result-cache store leaves the previously cached
    answer intact and servable (no torn slot)."""
    rng = np.random.default_rng(1)
    svc = GraphService(_seed_graph(rng), batch_size=4)
    r0 = svc.query("bfs", 0)
    slot_before = svc._cache[("bfs", 0)]
    svc.submit_many(_stream_ops(rng, count=8))
    svc.flush()
    with fault_scope(FaultPlan({P_CACHE_STORE: [0]})):
        with pytest.raises(InjectedFault):
            svc.query("bfs", 0)
    assert svc._cache[("bfs", 0)] is slot_before
    assert svc._cache[("bfs", 0)].version == r0.version
    assert_service_ok(svc)


# --------------------------- degrade ladder ---------------------------------

def test_retry_demotes_to_full_from_pinned_snapshot():
    """First attempt faults in the delta rung; the retry recomputes full
    and the answer matches a never-faulted twin bit-for-bit."""
    rng = np.random.default_rng(2)
    g0 = _seed_graph(rng)
    ops = _stream_ops(rng, count=8)
    pol = ResiliencePolicy(max_retries=1)
    svc = GraphService(g0, batch_size=4, policy=pol)
    twin = GraphService(g0, batch_size=4)
    for s in (svc, twin):
        s.query("bfs", 0)
        s.submit_many(ops)
        s.flush()
    with fault_scope(FaultPlan({P_COLLECT_DELTA: [0]})):
        reply = svc.query("bfs", 0)
    assert reply.mode == "full" and reply.retries == 1
    assert not reply.degraded
    assert svc.stats.retries == 1 and svc.stats.errors == 1
    _assert_same_state(reply.result, twin.query("bfs", 0).result)
    assert_service_ok(svc)


def test_ladder_exhausted_serves_stale_flagged_degraded():
    rng = np.random.default_rng(3)
    pol = ResiliencePolicy(max_retries=1)
    svc = GraphService(_seed_graph(rng), batch_size=4, policy=pol)
    r0 = svc.query("bfs", 0)
    svc.submit_many(_stream_ops(rng, count=8))
    svc.flush()
    assert svc.version > r0.version
    # attempt (delta rung) + retry (full rung) both fail
    with fault_scope(FaultPlan({P_COLLECT_DELTA: [0],
                                P_COLLECT_DISPATCH: [0]})):
        reply = svc.query("bfs", 0)
    assert reply.degraded and reply.mode == "degraded"
    assert reply.stale_version == reply.version == r0.version
    assert svc.ring.get_entry(reply.stale_version) is not None
    _assert_same_state(reply.result, r0.result)  # exact at its version
    assert svc.stats.degraded == 1 and svc.stats.errors == 2
    assert svc.stats.retries == 1
    assert_service_ok(svc)


def test_ladder_exhausted_nothing_cached_raises():
    """No resident cached answer -> a loud error, never a silent lie."""
    rng = np.random.default_rng(4)
    pol = ResiliencePolicy(max_retries=1)
    svc = GraphService(_seed_graph(rng), batch_size=4, policy=pol)
    with fault_scope(FaultPlan({P_COLLECT_DISPATCH: [0, 1]})):
        with pytest.raises(InjectedFault):
            svc.query("bfs", 0)
    assert svc.stats.degraded == 0 and svc.stats.errors == 2
    assert_service_ok(svc)


def test_allow_stale_off_reraises():
    rng = np.random.default_rng(5)
    pol = ResiliencePolicy(max_retries=0, allow_stale=False)
    svc = GraphService(_seed_graph(rng), batch_size=4, policy=pol)
    svc.query("bfs", 0)
    svc.submit_many(_stream_ops(rng, count=8))
    svc.flush()
    with fault_scope(FaultPlan({P_COLLECT_DELTA: [0]})):
        with pytest.raises(InjectedFault):
            svc.query("bfs", 0)
    assert svc.stats.degraded == 0
    assert_service_ok(svc)


def test_zero_deadline_skips_retries_straight_to_stale():
    rng = np.random.default_rng(6)
    pol = ResiliencePolicy(deadline_ms=0.0, max_retries=5)
    svc = GraphService(_seed_graph(rng), batch_size=4, policy=pol)
    svc.query("bfs", 0)
    with fault_scope(FaultPlan({P_COLLECT_DELTA: [0],
                                P_COLLECT_DISPATCH: [0]})):
        reply = svc.query("bfs", 0)
    assert reply.degraded
    assert svc.stats.retries == 0  # deadline spent before any retry
    assert_service_ok(svc)


# ------------------------ scheduler commit atomicity ------------------------

@pytest.mark.parametrize("point", [P_SCHED_APPLY, P_SCHED_RING_COMMIT])
def test_commit_atomic_under_fault(point):
    """A fault mid-commit (before apply, or between apply and the ring
    append) leaves ring latest AND pending log untouched; the retry then
    commits the identical prefix — bit-identical to a never-faulted twin."""
    rng = np.random.default_rng(7)
    g0 = _seed_graph(rng)
    ops = _stream_ops(rng, count=10)
    svc = GraphService(g0, batch_size=4)
    twin = GraphService(g0, batch_size=4)
    twin.submit_many(ops)
    twin.flush()

    with fault_scope(FaultPlan({point: [1]})):  # second batch's commit
        with pytest.raises(InjectedFault):
            svc.submit_many(ops)
        v = svc.version
        assert svc.scheduler.stats.commit_failures == 1
        # atomicity: the whole second chunk went back, in order (the
        # raising submit had already logged its own op)
        assert list(svc.scheduler._log) == ops[4:8]
        assert svc.scheduler.stats.ops_submitted == 8
        # resume the stream: the ops the raising submit_many never reached
        svc.submit_many(ops[8:])
        svc.flush()
    assert svc.version > v
    assert svc.scheduler.pending() == 0
    assert svc.version == twin.version
    _assert_same_state(svc.ring.latest.state, twin.ring.latest.state)
    assert_service_ok(svc)
    assert_service_ok(twin)


def test_ring_evict_fault_keeps_ring_consistent():
    """An eviction fault racing a commit aborts the commit atomically —
    the window, pins and latest stay exactly as before."""
    rng = np.random.default_rng(8)
    svc = GraphService(_seed_graph(rng), ring_depth=2, batch_size=4)
    svc.submit_many(_stream_ops(rng, count=16))
    svc.flush()  # window now full: next commit must evict
    v = svc.version
    window = list(svc.ring._window)
    with fault_scope(FaultPlan({P_RING_EVICT: [0]})):
        with pytest.raises(InjectedFault):
            svc.submit_many(_stream_ops(rng, count=4))
        assert svc.version == v and list(svc.ring._window) == window
        svc.flush()
    assert svc.version == v + 1
    assert_service_ok(svc)


# ------------------------------- journal ------------------------------------

def _journaled_service(tmp_path, g0, name="wal.jsonl", **kw):
    kw.setdefault("batch_size", 4)
    meta = journal_meta(g0, kw)
    journal = OpJournal(str(tmp_path / name), meta=meta)
    return GraphService(g0, journal=journal, **kw), journal


def test_journal_roundtrip_bit_identical(tmp_path):
    rng = np.random.default_rng(9)
    g0 = _seed_graph(rng)
    svc, journal = _journaled_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=11))  # 2 commits + 3 pending
    assert svc.scheduler.pending() == 3
    journal.close()

    rec = recover(str(tmp_path / "wal.jsonl"), g0, batch_size=4)
    assert rec.version == svc.version
    _assert_same_state(rec.ring.latest.state, svc.ring.latest.state)
    assert rec.scheduler.pending() == 3
    assert list(rec.scheduler._log) == list(svc.scheduler._log)
    assert_service_ok(rec)
    # the recovered service keeps going exactly like the original
    # (whose WAL is closed, so detach it before driving it further)
    svc.scheduler.journal = None
    svc.flush()
    rec.flush()
    _assert_same_state(rec.ring.latest.state, svc.ring.latest.state)


def test_journal_recover_resumes_journaling(tmp_path):
    rng = np.random.default_rng(10)
    g0 = _seed_graph(rng)
    svc, journal = _journaled_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=9))
    journal.close()
    rec = recover(str(tmp_path / "wal.jsonl"), g0, batch_size=4,
                  journal=OpJournal(str(tmp_path / "wal2.jsonl"),
                                    meta=journal_meta(g0, {"batch_size": 4})))
    rec.scheduler.journal.close()
    # the new journal recovers to the same place as the old one
    rec2 = recover(str(tmp_path / "wal2.jsonl"), g0, batch_size=4)
    assert rec2.version == rec.version == svc.version
    _assert_same_state(rec2.ring.latest.state, svc.ring.latest.state)


@pytest.mark.parametrize("crash_point", [P_JOURNAL_BARRIER, P_JOURNAL_TORN])
def test_crash_at_barrier_rolls_batch_back_atomically(tmp_path, crash_point):
    """Crash between the ring append and the barrier (or mid-barrier-write):
    recovery yields the ring WITHOUT the batch and the pending log WITH all
    of its ops — all-or-nothing, no torn prefix."""
    rng = np.random.default_rng(11)
    g0 = _seed_graph(rng)
    svc, journal = _journaled_service(tmp_path, g0)
    first = _stream_ops(rng, count=4)
    svc.submit_many(first)  # one clean committed batch (plan not active)
    v_before = svc.version
    doomed = _stream_ops(rng, count=4)
    # inside the scope the doomed batch's barrier is the first hit of
    # either crash point
    with fault_scope(FaultPlan({crash_point: [0]})):
        with pytest.raises(InjectedCrash):
            svc.submit_many(doomed)
    journal.close()

    rec = recover(str(tmp_path / "wal.jsonl"), g0, batch_size=4)
    assert rec.version == v_before  # the doomed batch rolled back...
    assert rec.scheduler.pending() == len(doomed)  # ...into pending, whole
    assert list(rec.scheduler._log) == [tuple(op) for op in doomed]
    assert_service_ok(rec)
    # replaying the pending ops reconverges with the pre-crash intent
    rec.flush()
    twin = GraphService(g0, batch_size=4)
    twin.submit_many(first)
    twin.submit_many(doomed)
    twin.flush()
    _assert_same_state(rec.ring.latest.state, twin.ring.latest.state)


def test_torn_final_line_tolerated_interior_raises(tmp_path):
    path = tmp_path / "wal.jsonl"
    g0 = _seed_graph(np.random.default_rng(12))
    svc, journal = _journaled_service(tmp_path, g0)
    svc.submit_many(_stream_ops(np.random.default_rng(12), count=6))
    journal.close()
    raw = path.read_text()
    # torn FINAL line: parse up to the last complete record
    path.write_text(raw + '{"t": "op", "se')
    meta, batches, pending = read_journal(str(path))
    assert meta["batch_size"] == 4 and len(batches) == 1
    assert recover(str(path), g0, batch_size=4).version == svc.version
    # torn INTERIOR line: real corruption, loud failure
    lines = raw.strip().split("\n")
    lines[2] = lines[2][: len(lines[2]) // 2]
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalError):
        read_journal(str(path))


def test_journal_meta_mismatch_and_overcounting_barrier(tmp_path):
    path = tmp_path / "wal.jsonl"
    g0 = _seed_graph(np.random.default_rng(13))
    svc, journal = _journaled_service(tmp_path, g0)
    svc.submit_many(_stream_ops(np.random.default_rng(13), count=4))
    journal.close()
    with pytest.raises(JournalError, match="batch_size"):
        recover(str(path), g0, batch_size=8)
    with open(path, "a") as f:  # a barrier claiming ops never journaled
        f.write(json.dumps({"t": "commit", "version": 99, "ops": 7}) + "\n")
    with pytest.raises(JournalError, match="barrier covers"):
        read_journal(str(path))


# ------------------------------ invariants ----------------------------------

def test_verify_service_flags_planted_violations():
    rng = np.random.default_rng(14)
    svc = GraphService(_seed_graph(rng), batch_size=4)
    svc.query("bfs", 0)
    assert verify_service(svc) == []
    svc.stats.queries += 1  # break mode conservation
    assert any("conservation" in p for p in verify_service(svc))
    svc.stats.queries -= 1
    svc.scheduler.stats.ops_submitted += 2  # break the op ledger
    assert any("ledger" in p for p in verify_service(svc))
    svc.scheduler.stats.ops_submitted -= 2
    assert verify_service(svc) == []
    with pytest.raises(AssertionError):
        svc._cache[("bfs", 0)].version = svc.version + 5
        assert_service_ok(svc)


# ------------------------- telemetry sink faults ----------------------------

def test_tracer_sink_fault_never_raises(tmp_path):
    from repro.obs import Tracer
    path = tmp_path / "t.jsonl"
    tr = Tracer(str(path))
    with fault_scope(FaultPlan({P_OBS_SINK: [1]})):
        with tr.span("query", kind="bfs"):
            pass
        with tr.span("query", kind="sssp"):  # sink write faults; span OK
            pass
        with tr.span("query", kind="bc"):
            pass
    tr.close()
    assert tr.sink_errors == 1
    assert [r["kind"] for r in tr.records] == ["bfs", "sssp", "bc"]
    on_disk = [json.loads(x) for x in path.read_text().splitlines()]
    assert [r["kind"] for r in on_disk] == ["bfs", "bc"]  # one line lost


def test_service_stream_with_failing_sink_stays_correct(tmp_path):
    """Telemetry IO faults mid-stream: queries keep answering, counters
    keep conserving, only sink lines are lost."""
    from repro.obs import Telemetry
    rng = np.random.default_rng(15)
    tel = Telemetry.make(str(tmp_path / "t.jsonl"))
    svc = GraphService(_seed_graph(rng), batch_size=4, telemetry=tel)
    with fault_scope(FaultPlan(seed=1, rate=0.3,
                               points=(P_OBS_SINK,))):
        for step in range(4):
            svc.submit_many(_stream_ops(rng, count=6))
            svc.flush()
            for kind in ("bfs", "sssp", "bc"):
                svc.query(kind, 0)
    assert tel.tracer.sink_errors > 0
    assert len([r for r in tel.tracer.records if r["span"] == "query"]) == 12
    assert svc.stats.queries == 12
    assert_service_ok(svc)
    tel.close()


# --------------------- segment rotation + compaction ------------------------

def _segmented_service(tmp_path, g0, *, name="wal.jsonl", segment_bytes=700,
                       **kw):
    kw.setdefault("batch_size", 4)
    meta = journal_meta(g0, kw)
    journal = OpJournal(str(tmp_path / name), meta=meta,
                        segment_bytes=segment_bytes)
    return GraphService(g0, journal=journal, **kw), journal


def test_segment_rotation_replays_bit_identical(tmp_path):
    """Rotation seals segments only at barrier boundaries; the multi-file
    reader stitches them back into the exact batch sequence."""
    rng = np.random.default_rng(21)
    g0 = _seed_graph(rng)
    svc, journal = _segmented_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=42))
    svc.flush()
    assert journal.rotations >= 3
    assert len(segment_files(journal.path)) == journal.rotations
    meta, vbatches, pending = read_journal_versions(journal.path)
    assert [v for v, _ in vbatches] == list(
        range(1, svc.ring.latest.version + 1))
    assert pending == []
    journal.close()
    rec = recover(journal.path, g0, batch_size=4)
    assert rec.ring.latest.version == svc.ring.latest.version
    _assert_same_state(svc.ring.latest.state, rec.ring.latest.state)
    assert_service_ok(rec)


def test_compaction_bounds_disk_and_recovers_without_initial_state(tmp_path):
    """>= 3 sealed segments, then compact: every covered segment is
    deleted, on-disk WAL = snapshot + (fresh) active file, and recovery
    restores from the snapshot alone — no initial state, bit-identical
    answers."""
    rng = np.random.default_rng(22)
    g0 = _seed_graph(rng)
    svc, journal = _segmented_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=44))
    svc.flush()
    sealed = len(segment_files(journal.path))
    assert sealed >= 3
    report = svc.compact_wal()
    # compact seals the active history first, so every segment is covered
    assert report["segments_dropped"] == sealed + 1
    assert report["segments_kept"] == 0
    assert report["snapshot_bytes"] > 0
    assert segment_files(journal.path) == []
    # bounded disk: exactly the active WAL (one meta header) + snapshot
    assert sorted(p.name for p in tmp_path.iterdir()) == [
        "wal.jsonl", "wal.jsonl.ckpt"]
    meta, batches, pending = read_journal_versions(journal.path)
    assert batches == [] and pending == []

    expected = {k: svc.query(k, 0) for k in ("bfs", "sssp", "bc")}
    journal.close()
    rec = recover(journal.path, batch_size=4)  # no initial_state
    assert rec.ring.latest.version == svc.ring.latest.version
    _assert_same_state(svc.ring.latest.state, rec.ring.latest.state)
    for k, want in expected.items():
        got = rec.query(k, 0)
        assert got.version == want.version
        for x, y in zip(want.result, got.result):
            assert np.array_equal(np.asarray(x), np.asarray(y))
    assert_service_ok(rec)


def test_recovery_replays_tail_after_compaction(tmp_path):
    """Post-compaction commits land in fresh segments; recovery is
    snapshot + tail replay (never the full history)."""
    rng = np.random.default_rng(23)
    g0 = _seed_graph(rng)
    svc, journal = _segmented_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=24))
    svc.flush()
    svc.compact_wal()
    snap_version = svc.ring.latest.version
    svc.submit_many(_stream_ops(rng, count=14))  # 3 commits + 2 pending
    svc.flush()
    journal.close()
    rec = recover(journal.path, batch_size=4)
    assert rec.ring.latest.version == svc.ring.latest.version
    _assert_same_state(svc.ring.latest.state, rec.ring.latest.state)
    # the rebased ring starts at the snapshot version: elided history
    # is truly elided, not replayed
    assert rec.ring.oldest_version >= snap_version
    assert_service_ok(rec)


def test_compacted_journal_recovers_from_any_crash_point(tmp_path):
    """Chaos stream with auto-compaction: crash at EVERY barrier in turn,
    recover, and the ring latest must equal the uninterrupted oracle's
    state at that version — all-or-nothing batches, snapshot + tail."""
    rng = np.random.default_rng(24)
    g0 = _seed_graph(rng)
    ops = _stream_ops(rng, count=36)  # 9 full batches at batch_size=4
    twin, tj = _segmented_service(tmp_path, g0, name="twin.jsonl")
    twin.submit_many(ops)
    twin.flush()
    n_barriers = twin.scheduler.stats.batches_committed
    tj.close()
    _, twin_batches, _ = read_journal(str(tmp_path / "twin.jsonl"))

    for hit in range(n_barriers):
        name = f"wal{hit}.jsonl"
        svc, journal = _segmented_service(tmp_path, g0, name=name,
                                          compact_every=3)
        with fault_scope(FaultPlan({P_JOURNAL_BARRIER: [hit]})):
            with pytest.raises(InjectedCrash):
                svc.submit_many(ops)
                svc.flush()
        journal.close()
        rec = recover(str(tmp_path / name), g0, batch_size=4)
        assert rec.ring.latest.version == hit
        expected = g0
        for chunk in twin_batches[:hit]:
            expected, _ = apply_ops(expected, list(chunk), batch_size=4)
        _assert_same_state(expected, rec.ring.latest.state)
        # the crashed batch's ops are back in the pending log, uncommitted
        assert rec.scheduler.pending() == 4
        assert_service_ok(rec)


def test_recover_detects_missing_segment(tmp_path):
    """A deleted (uncovered) segment is a replay gap, not silent skew."""
    rng = np.random.default_rng(25)
    g0 = _seed_graph(rng)
    svc, journal = _segmented_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=40))
    svc.flush()
    segs = segment_files(journal.path)
    assert len(segs) >= 3
    journal.close()
    (tmp_path / segs[1][1].split("/")[-1]).unlink()  # drop a middle segment
    with pytest.raises(JournalError, match="replay gap"):
        recover(journal.path, g0, batch_size=4)


def test_adaptive_thresholds_ride_the_snapshot(tmp_path):
    """Learned dirty thresholds persist through compact + recover: the
    recovered service resumes tuned, not at cold defaults."""
    from repro.obs import Telemetry
    rng = np.random.default_rng(26)
    g0 = _seed_graph(rng)
    tel = Telemetry.make(str(tmp_path / "t.jsonl"), hlo=False, profile=False)
    kw = dict(batch_size=4)
    journal = OpJournal(str(tmp_path / "wal.jsonl"),
                        meta=journal_meta(g0, kw))
    svc = GraphService(g0, journal=journal, telemetry=tel, adaptive=True,
                       **kw)
    svc.submit_many(_stream_ops(rng, count=12))
    svc.flush()
    learned = {"bfs": 0.11, "sssp": 0.62, "bc": 0.33}
    svc.adaptive.restore(learned)
    report = svc.compact_wal()
    assert report["version"] == svc.ring.latest.version
    journal.close()

    tel2 = Telemetry.make(str(tmp_path / "t2.jsonl"), hlo=False,
                          profile=False)
    rec = recover(str(tmp_path / "wal.jsonl"), batch_size=4,
                  telemetry=tel2, adaptive=True)
    got = rec.adaptive.thresholds()
    for k, v in learned.items():
        assert got[k] == pytest.approx(v)
    # the op ledger rode along too: conservation invariants hold
    assert_service_ok(rec)
    tel.close()
    tel2.close()


def test_recover_resumed_journal_is_self_contained(tmp_path):
    """recover(journal=new) after compaction re-compacts the restored
    base into the new journal, so the new WAL alone can recover."""
    rng = np.random.default_rng(27)
    g0 = _seed_graph(rng)
    svc, journal = _segmented_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=24))
    svc.flush()
    svc.compact_wal()
    journal.close()
    kw = dict(batch_size=4)
    rec = recover(journal.path, batch_size=4,
                  journal=OpJournal(str(tmp_path / "wal2.jsonl"),
                                    meta=journal_meta(g0, kw)))
    rec.submit_many(_stream_ops(rng, count=8))
    rec.flush()
    rec.scheduler.journal.close()
    rec2 = recover(str(tmp_path / "wal2.jsonl"), batch_size=4)
    assert rec2.ring.latest.version == rec.ring.latest.version
    _assert_same_state(rec.ring.latest.state, rec2.ring.latest.state)
    assert_service_ok(rec2)


# --------------------------- circuit breaker --------------------------------

def test_breaker_state_machine():
    br = CircuitBreaker(fail_threshold=2, cooldown=3, probes=2)
    assert br.state("bfs") == br.CLOSED
    assert br.allow_delta("bfs")
    br.record_failure("bfs")
    br.record_success("bfs")  # success resets the consecutive count
    br.record_failure("bfs")
    assert br.state("bfs") == br.CLOSED
    br.record_failure("bfs")
    assert br.state("bfs") == br.OPEN and br.trips == 1
    assert br.state("sssp") == br.CLOSED  # fault domains are per kind
    # cooldown: two denials, the third consult is the half-open probe
    assert not br.allow_delta("bfs")
    assert not br.allow_delta("bfs")
    assert br.allow_delta("bfs")
    assert br.state("bfs") == br.HALF_OPEN
    br.record_success("bfs")  # probe 1 of 2
    assert br.state("bfs") == br.HALF_OPEN
    br.record_success("bfs")
    assert br.state("bfs") == br.CLOSED and br.restores == 1
    # a half-open probe failure re-opens with a fresh cooldown
    br.record_failure("bfs")
    br.record_failure("bfs")
    assert br.state("bfs") == br.OPEN
    for _ in range(3):
        br.allow_delta("bfs")
    assert br.state("bfs") == br.HALF_OPEN
    br.record_failure("bfs")
    assert br.state("bfs") == br.OPEN and br.trips == 3


def _churn(rng, *svcs, n=24):
    """One random edge insert, applied identically to every service."""
    u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
    op = (PUTE, u, v, float(rng.integers(1, 9)))
    for svc in svcs:
        svc.submit(op)
        svc.flush()


def test_breaker_trips_pins_full_and_half_open_restores(tmp_path):
    """Acceptance: forced consecutive delta failures trip the breaker —
    queries keep succeeding via full with zero wrong answers, a
    ladder_pinned span + breaker_open gauge are emitted — and half-open
    probes restore delta serving once the fault plan clears."""
    from repro.obs import Telemetry
    rng = np.random.default_rng(31)
    g0 = _seed_graph(rng)
    tel = Telemetry.make(str(tmp_path / "t.jsonl"), hlo=False, profile=False)
    oracle = GraphService(g0, batch_size=4)  # fault-free twin
    svc = GraphService(g0, batch_size=4, telemetry=tel,
                       policy=ResiliencePolicy(max_retries=1),
                       breaker=CircuitBreaker(fail_threshold=3, cooldown=2,
                                              probes=1))
    ops = _stream_ops(rng, count=8)
    for s in (svc, oracle):
        s.submit_many(ops)
        s.flush()
        s.query("bfs", 0)  # seed the delta path's cached prior

    def check(reply):
        with fault_scope(FaultPlan({})):  # shield the oracle from the plan
            want = oracle.query("bfs", 0)
        assert reply.version == want.version and not reply.degraded
        assert np.array_equal(np.asarray(reply.result.dist),
                              np.asarray(want.result.dist))

    with fault_scope(FaultPlan({P_COLLECT_DELTA: list(range(64))})):
        for i in range(3):  # every delta attempt fails -> retried as full
            _churn(rng, svc, oracle)
            reply = svc.query("bfs", 0)
            assert reply.retries == 1
            check(reply)
        assert svc.breaker.state("bfs") == "open"
        # tripped: the delta point is still armed, but the quarantined
        # ladder never reaches it — clean full answers, zero retries
        _churn(rng, svc, oracle)
        reply = svc.query("bfs", 0)
        assert reply.mode == "full" and reply.retries == 0
        check(reply)
    # plan cleared: next consult exhausts the cooldown and probes
    _churn(rng, svc, oracle)
    reply = svc.query("bfs", 0)
    assert reply.mode == "delta" and svc.breaker.state("bfs") == "closed"
    check(reply)
    assert svc.breaker.trips == 1 and svc.breaker.restores == 1
    assert svc.stats.errors == 3 and svc.stats.degraded == 0
    assert_service_ok(svc)
    tel.close()
    recs = [json.loads(x) for x in
            (tmp_path / "t.jsonl").read_text().splitlines()]
    pinned = [r for r in recs if r.get("span") == "ladder_pinned"]
    restored = [r for r in recs if r.get("span") == "ladder_restored"]
    assert len(pinned) == 1 and pinned[0]["kind"] == "bfs"
    assert len(restored) == 1
    open_gauges = tel.registry.find("breaker_open", kind="bfs")
    assert open_gauges and open_gauges[0].value == 0.0  # restored: back to 0


def test_breaker_quarantines_sharded_delta_path(tmp_path):
    """Sharded service: a tripped breaker pins the ladder at full; the
    full-path answers stay bit-identical to the local oracle."""
    from repro.shard import ShardedGraphService, as_graph_mesh
    rng = np.random.default_rng(32)
    g0 = _seed_graph(rng)
    oracle = GraphService(g0, batch_size=4)
    svc = ShardedGraphService(
        g0, as_graph_mesh(), batch_size=4, src_chunk=2,
        policy=ResiliencePolicy(max_retries=1),
        breaker=CircuitBreaker(fail_threshold=2, cooldown=2, probes=1))
    ops = _stream_ops(rng, count=8)
    for s in (svc, oracle):
        s.submit_many(ops)
        s.flush()
        s.query("bfs", [0] if s is svc else 0)
    with fault_scope(FaultPlan({P_COLLECT_DELTA: list(range(64))})):
        for i in range(2):
            _churn(rng, svc, oracle)
            reply = svc.query("bfs", [0])
            assert reply.retries == 1
    assert svc.breaker.state("bfs") == "open"
    _churn(rng, svc, oracle)
    reply = svc.query("bfs", [0])
    want = oracle.query("bfs", 0)
    assert reply.mode == "full" and reply.retries == 0
    assert np.array_equal(np.asarray(reply.result.dist[0]),
                          np.asarray(want.result.dist))
    assert_service_ok(svc)


def test_verify_service_flags_journal_ledger_skew(tmp_path):
    rng = np.random.default_rng(33)
    g0 = _seed_graph(rng)
    svc, journal = _journaled_service(tmp_path, g0)
    svc.submit_many(_stream_ops(rng, count=6))
    assert verify_service(svc) == []
    journal.ops_logged += 2  # fake write-ahead records with no pending ops
    problems = verify_service(svc)
    assert any("journal depth" in p for p in problems)
