import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(__file__))


def run_multidevice(script: str, n_devices: int = 4,
                    timeout: int = 900) -> str:
    """Run ``script`` in a subprocess with ``n_devices`` host-platform
    placeholder devices forced BEFORE jax imports (the elastic-rescale
    pattern of ``test_checkpoint.py``), so the placeholder devices never
    leak into other tests.  PYTHONPATH carries ``src`` plus this tests
    directory (for ``oracle`` / ``stream_differential`` imports); any
    inherited XLA_FLAGS are scrubbed.  Shared by ``test_shard.py`` and
    ``test_stream_differential.py``.
    """
    env = dict(os.environ)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here])
    env.pop("XLA_FLAGS", None)
    prelude = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={n_devices}"\n')
    r = subprocess.run([sys.executable, "-c", prelude + script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout
