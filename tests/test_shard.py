"""Sharded tile-grid engine: sharded-vs-local equivalence.

The multi-device tests run through ``conftest.run_multidevice`` (a
subprocess sets ``--xla_force_host_platform_device_count=4`` BEFORE
importing jax, so the placeholder devices never leak into other tests).
Equivalence bar (the PR's acceptance): distributed bfs/sssp dist
and bc level/sigma are BIT-identical to the single-device ``core.queries``
batched path on the same snapshot — including tombstones and dead vertices
— while bc delta/scores match to f32 summation order (the same caveat
``bc_batched_dense`` documents vs per-source Brandes).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import run_multidevice as _run_multidevice

from repro.core import (
    PUTE, REME, REMV, apply_ops, dense_views, queries,
)
from repro.core.partition import (
    SUPPORTED_KINDS, build_query_inputs, make_distributed_query,
)
from repro.core.updates import dirty_vertices
from repro.data import load_rmat_graph
from repro.shard import (
    as_graph_mesh,
    bc_batched,
    bfs,
    build_sharded_view,
    delta_bc_sharded,
    delta_bfs_sharded,
    delta_sssp_sharded,
    gather_view,
    refresh_sharded_view,
    refresh_stats,
    sharded_occupancy_stats,
    sssp,
    validate_incremental_sharded,
)


def _tombstoned_graph(n=64, edges=400, seed=3):
    g = load_rmat_graph(n, edges, seed=seed)
    return apply_ops(g, [(REME, int(g.esrc[5]), int(g.edst[5])),
                         (REME, int(g.esrc[40]), int(g.edst[40])),
                         (REMV, 7), (REMV, 33)])[0]


# ------------------------ in-process (1-device mesh) -----------------------

def test_make_distributed_query_rejects_unknown_kind():
    mesh = as_graph_mesh()
    with pytest.raises(ValueError) as ei:
        make_distributed_query(mesh, "pagerank")
    msg = str(ei.value)
    assert "pagerank" in msg
    for kind in SUPPORTED_KINDS:
        assert kind in msg


def test_sharded_matches_local_single_device():
    """The shard_map programs are mesh-size-agnostic: on a 1-device mesh
    they must already be bit-identical to the local batched path."""
    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    am, wd, alive = dense_views(g)
    srcs = jnp.asarray([0, 1, 7, 33, 63], jnp.int32)  # incl. dead sources

    r = bfs(view, g, srcs)
    assert np.array_equal(np.asarray(r.dist),
                          np.asarray(queries.bfs_batched_dense(am, srcs,
                                                               alive)))
    assert bool(r.agree)
    r2 = sssp(view, g, srcs)
    dref, negref = queries.sssp_batched_dense(wd, srcs, alive)
    assert np.array_equal(np.asarray(r2.dist), np.asarray(dref))
    assert np.array_equal(np.asarray(r2.negcycle), np.asarray(negref))

    r3 = bc_batched(view, g, srcs, src_chunk=2)
    d, s, lv, ok = queries.bc_batched_dense(am, srcs, alive, src_chunk=2)
    assert np.array_equal(np.asarray(r3.level), np.asarray(lv))
    assert np.array_equal(np.asarray(r3.sigma), np.asarray(s))
    assert np.array_equal(np.asarray(r3.ok), np.asarray(ok))
    assert np.allclose(np.asarray(r3.delta), np.asarray(d),
                       rtol=1e-5, atol=1e-5)


def test_bc_source_padding_and_default_sources():
    """Source counts that don't divide the mesh are padded with -1 and the
    padding sliced back off; ``srcs=None`` means every vertex slot."""
    g = _tombstoned_graph(n=32, edges=120)
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    r = bc_batched(view, g, jnp.asarray([0, 5, 9], jnp.int32))
    assert r.delta.shape == (3, 32) and r.ok.shape == (3,)
    r_all = bc_batched(view, g, None)
    am, _, alive = dense_views(g)
    d, s, lv, ok = queries.bc_batched_dense(
        am, jnp.arange(32, dtype=jnp.int32), alive)
    scores = jnp.sum(jnp.where(ok[:, None], d, 0.0), axis=0)
    assert np.allclose(np.asarray(r_all.scores), np.asarray(scores),
                       rtol=1e-5, atol=1e-5)


def test_bc_ring_matches_gather_single_device():
    """Ring-mode BC on a 1-device mesh (a ring of one: no permutes) must
    already match the gathered oracle bit-for-bit on level/sigma."""
    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    srcs = jnp.asarray([0, 1, 7, 33, 63], jnp.int32)
    rg = bc_batched(view, g, srcs, src_chunk=2)
    rr = bc_batched(view, g, srcs, src_chunk=2, bc_mode="ring")
    assert np.array_equal(np.asarray(rr.level), np.asarray(rg.level))
    assert np.array_equal(np.asarray(rr.sigma), np.asarray(rg.sigma))
    assert np.array_equal(np.asarray(rr.ok), np.asarray(rg.ok))
    assert np.allclose(np.asarray(rr.delta), np.asarray(rg.delta),
                       rtol=1e-5, atol=1e-5)
    assert np.allclose(np.asarray(rr.scores), np.asarray(rg.scores),
                       rtol=1e-5, atol=1e-5)


def test_bc_mode_validation():
    """Unknown bc_mode raises with the supported modes listed, at both the
    query and the service layer."""
    g = _tombstoned_graph(n=32, edges=120)
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    with pytest.raises(ValueError) as ei:
        bc_batched(view, g, jnp.asarray([0], jnp.int32), bc_mode="bogus")
    assert "gather" in str(ei.value) and "ring" in str(ei.value)
    from repro.shard import ShardedGraphService
    with pytest.raises(ValueError) as ei2:
        ShardedGraphService(g, mesh, tile=16, bc_mode="bogus")
    assert "ring" in str(ei2.value)


def test_sharded_sssp_negcycle_delta_fallback():
    """A negative cycle born since the cached answer: the delta re-relax
    surfaces it (exit-changed flag) and the service falls back to the full
    distributed collect for the canonical answer — under both bc_mode
    values (the knob must not disturb the sssp ladder)."""
    from repro.shard import ShardedGraphService

    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    srcs = jnp.asarray([0], jnp.int32)

    # direct delta path: the new cycle flips the negcycle flag
    view = build_sharded_view(g, mesh, tile=16)
    prior = sssp(view, g, srcs)
    assert not bool(prior.negcycle.any())
    reached = np.flatnonzero(np.asarray(prior.dist[0]) < np.inf)
    a, b = (int(v) for v in reached[1:3])
    ops = [(PUTE, a, b, 1.0), (PUTE, b, a, -5.0)]
    g2, _ = apply_ops(g, ops)
    dirty = dirty_vertices(g, g2)
    view2 = refresh_sharded_view(g2, view, dirty)
    ds = delta_sssp_sharded(view2, g2, prior, dirty, srcs)
    assert bool(ds.negcycle[0]) and not bool(ds.ok[0])

    for bc_mode in ("gather", "ring"):
        svc = ShardedGraphService(g, mesh, tile=16, batch_size=4,
                                  bc_mode=bc_mode)
        rep0 = svc.query("sssp", [0])
        assert rep0.mode == "full" and not bool(rep0.result.negcycle[0])
        svc.submit_many(ops)
        svc.flush()
        # the ladder attempts delta (tiny touched dirty set, usable prior)
        # and its negcycle detection returns None = fall back to full
        ring_dirty = svc.ring.dirty_between(rep0.version, svc.version)
        state = svc.ring.latest.state
        assert svc._delta_collect("sssp", rep0.result, ring_dirty, [0],
                                  state) is None
        rep = svc.query("sssp", [0])
        assert rep.mode == "full" and bool(rep.result.negcycle[0])
        fresh = sssp(svc.view(), state, srcs)
        assert np.array_equal(np.asarray(rep.result.dist),
                              np.asarray(fresh.dist))
        # the canonical negcycle answer is cached; the NEXT query cannot
        # ride delta off it (negcycle prior is unusable) — localized churn
        # forces a fresh full collect, not a poisoned warm start
        svc.submit_many([(PUTE, a, int(reached[3]), 1.0)])
        svc.flush()
        rep2 = svc.query("sssp", [0])
        assert rep2.mode == "full" and bool(rep2.result.negcycle[0])


def test_sharded_parents_match_local_queries():
    """Full sharded bfs/sssp carry traversal-tree parents identical to the
    per-source COO queries (the arrays the delta poison step walks)."""
    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    srcs = jnp.asarray([0, 1, 7, 33, 63], jnp.int32)
    r, r2 = bfs(view, g, srcs), sssp(view, g, srcs)
    for i, s in enumerate([0, 1, 7, 33, 63]):
        assert np.array_equal(np.asarray(r.parent[i]),
                              np.asarray(queries.bfs(g, s).parent)), s
        assert np.array_equal(np.asarray(r2.parent[i]),
                              np.asarray(queries.sssp(g, s).parent)), s


def test_sharded_delta_queries_single_device():
    """Delta bfs/sssp/bc on a 1-device mesh: bit-identical to (a) a full
    sharded recompute and (b) the local engine's per-source delta path."""
    from repro.engine import delta_bfs, delta_sssp

    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    srcs = jnp.asarray([0, 1, 7, 33, 63], jnp.int32)
    pb, ps = bfs(view, g, srcs), sssp(view, g, srcs)
    pc = bc_batched(view, g, srcs, src_chunk=2)
    g2, _ = apply_ops(g, [(PUTE, 0, 40, 2.0), (REME, 1, int(g.edst[20])),
                          (PUTE, 20, 55, 1.0), (REMV, 12)])
    dirty = dirty_vertices(g, g2)
    view2 = refresh_sharded_view(g2, view, dirty)
    db = delta_bfs_sharded(view2, g2, pb, dirty, srcs)
    ds = delta_sssp_sharded(view2, g2, ps, dirty, srcs)
    dc = delta_bc_sharded(view2, g2, pc, dirty, srcs, src_chunk=2)
    assert validate_incremental_sharded(view2, g2, srcs, db, "bfs")
    assert validate_incremental_sharded(view2, g2, srcs, ds, "sssp")
    assert validate_incremental_sharded(view2, g2, srcs, dc, "bc",
                                        src_chunk=2)
    for i, s in enumerate([0, 1, 7, 33, 63]):
        lb = delta_bfs(g2, queries.bfs(g, s), dirty, s)
        assert np.array_equal(np.asarray(db.dist[i]), np.asarray(lb.dist)), s
        assert np.array_equal(np.asarray(db.parent[i]),
                              np.asarray(lb.parent)), s
        ls = delta_sssp(g2, queries.sssp(g, s), dirty, s)
        assert np.array_equal(np.asarray(ds.dist[i]), np.asarray(ls.dist)), s
        assert np.array_equal(np.asarray(ds.parent[i]),
                              np.asarray(ls.parent)), s


def test_sharded_delta_revived_source_restarts_cold():
    """A source that was dead when the prior was cached and resurrected
    since has an EMPTY prior row — invisible to the level cut and to the
    unchanged test — and must be recomputed from scratch, in BOTH bc_mode
    values (the ring warm start shares the gather path's cut/revive logic
    but runs a different program)."""
    from repro.core import PUTV
    from repro.engine import GraphService
    from repro.shard import ShardedGraphService

    g = _tombstoned_graph()  # vertices 7 and 33 are dead
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    srcs = jnp.asarray([0, 7], jnp.int32)
    pb = bfs(view, g, srcs)
    pc = bc_batched(view, g, srcs, src_chunk=2)
    assert not bool(pb.ok[1])
    g2, _ = apply_ops(g, [(PUTV, 7), (PUTE, 7, 20, 1.0), (PUTE, 0, 40, 2.0)])
    dirty = dirty_vertices(g, g2)
    view2 = refresh_sharded_view(g2, view, dirty)
    db = delta_bfs_sharded(view2, g2, pb, dirty, srcs)
    assert validate_incremental_sharded(view2, g2, srcs, db, "bfs")
    assert bool(db.ok[1]) and int(db.dist[1, 7]) == 0
    for bc_mode in ("gather", "ring"):
        dc = delta_bc_sharded(view2, g2, pc, dirty, srcs, src_chunk=2,
                              bc_mode=bc_mode)
        assert validate_incremental_sharded(view2, g2, srcs, dc, "bc",
                                            src_chunk=2, bc_mode=bc_mode), \
            bc_mode
        assert bool(dc.ok[1]) and int(dc.level[1, 7]) == 0, bc_mode
    # the service ladder must not answer "unchanged" when the ONLY churn
    # is the resurrection (no prior-reached vertex is dirty)
    svc = ShardedGraphService(g, mesh, tile=16, batch_size=4)
    local = GraphService(g, batch_size=4)
    svc.query("bfs", [7])
    ops = [(PUTV, 7), (PUTE, 7, 20, 1.0)]
    svc.submit_many(ops); local.submit_many(ops)
    svc.flush(); local.flush()
    rep = svc.query("bfs", [7])
    assert rep.mode != "unchanged"
    lrep = local.query("bfs", 7)
    assert np.array_equal(np.asarray(rep.result.dist[0]),
                          np.asarray(lrep.result.dist))


def test_batched_refresh_dispatch_counts():
    """Same-width dirty rows fuse into one shard_map dispatch each batch:
    strictly fewer dispatches than rows, result identical to a rebuild."""
    rng = np.random.default_rng(5)
    g = load_rmat_graph(256, 2000, seed=2)
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    ops = [(PUTE, int(rng.integers(0, 96)), int(rng.integers(0, 256)), 2.0)
           for _ in range(40)]
    g2, _ = apply_ops(g, ops)
    dirty = dirty_vertices(g, g2)
    r0, d0 = refresh_stats.rows, refresh_stats.dispatches
    view2 = refresh_sharded_view(g2, view, dirty)
    rows = refresh_stats.rows - r0
    dispatches = refresh_stats.dispatches - d0
    assert rows > 1 and dispatches < rows
    full, ref = gather_view(view2), gather_view(
        build_sharded_view(g2, mesh, tile=16))
    assert np.array_equal(np.asarray(full.w), np.asarray(ref.w))
    assert np.array_equal(np.asarray(full.occ), np.asarray(ref.occ))


def test_refresh_sharded_view_strategies():
    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    # empty dirty set: the very same view comes back
    same = refresh_sharded_view(g, view, jnp.zeros((64,), jnp.bool_))
    assert same is view
    # tile-size mismatch: falls back to a rebuild at the new grid
    g2, _ = apply_ops(g, [(PUTE, 3, 9, 2.0)])
    view2 = refresh_sharded_view(g2, view, dirty_vertices(g, g2), tile=32)
    assert view2.tile == 32
    full = gather_view(view2)
    ref = gather_view(build_sharded_view(g2, mesh, tile=32))
    assert np.array_equal(np.asarray(full.w), np.asarray(ref.w))
    assert np.array_equal(np.asarray(full.occ), np.asarray(ref.occ))
    # no prev and no mesh: explicit error
    with pytest.raises(ValueError):
        refresh_sharded_view(g2, None, None)


def test_build_query_inputs_roundtrip():
    g = _tombstoned_graph(n=32, edges=120)
    mesh = as_graph_mesh()
    fn, _, _ = make_distributed_query(mesh, "bfs", tile=16)
    args = build_query_inputs(g, mesh, [0, 2], tile=16)
    ok, dist, val_ecnt, agree = fn(*args)
    am, _, alive = dense_views(g)
    ref = queries.bfs_batched_dense(am, jnp.asarray([0, 2], jnp.int32), alive)
    assert np.array_equal(np.asarray(dist)[:, :32], np.asarray(ref))
    assert bool(agree)


# ------------------------- multi-device subprocess -------------------------

def test_sharded_view_refresh_multidevice():
    """Build + per-shard dirty-row refresh under an update stream, compact,
    and both grows: always bit-identical to a from-scratch sharded build."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, PUTV, REME, REMV, apply_ops, compact, grow_edges, grow_vertices
from repro.core.graph_state import densify
from repro.core.updates import dirty_vertices
from repro.data import load_rmat_graph
from repro.shard import (as_graph_mesh, build_sharded_view, refresh_sharded_view,
                         gather_view, sharded_occupancy_stats)

mesh = as_graph_mesh()
assert mesh.devices.size == 4
g = load_rmat_graph(64, 400, seed=2)
view = build_sharded_view(g, mesh, tile=16)
assert view.vp % (4 * 16) == 0 and view.band == view.vp // 4
stats = sharded_occupancy_stats(view)
assert len(stats["per_shard_tile_skip_rate"]) == 4

def check(state, v):
    full = gather_view(v)
    vcap = state.vcap
    w = np.asarray(full.w)
    assert np.array_equal(w[:vcap, :vcap], np.asarray(densify(state)))
    assert np.isinf(w[vcap:, :]).all() and np.isinf(w[:, vcap:]).all()
    ref = gather_view(build_sharded_view(state, mesh, tile=16))
    assert np.array_equal(w, np.asarray(ref.w))
    assert np.array_equal(np.asarray(full.occ), np.asarray(ref.occ))

check(g, view)
rng = np.random.default_rng(0)
for i in range(6):
    ops = [(PUTE, int(rng.integers(0, 64)), int(rng.integers(0, 64)),
            float(rng.integers(1, 9))) for _ in range(5)]
    ops += [(REME, int(rng.integers(0, 64)), int(rng.integers(0, 64))),
            (REMV, int(rng.integers(0, 64))) if i == 3 else
            (PUTV, int(rng.integers(0, 64)))]
    g2, _ = apply_ops(g, ops)
    view = refresh_sharded_view(g2, view, dirty_vertices(g, g2))
    check(g2, view)
    g = g2
g2 = compact(g)
view = refresh_sharded_view(g2, view, jnp.zeros((64,), jnp.bool_))
check(g2, view)
g = g2
g2 = grow_edges(g)
g3, _ = apply_ops(g2, [(PUTE, 1, 2, 4.0)])
view = refresh_sharded_view(g3, view, dirty_vertices(g2, g3))
check(g3, view)
g4 = grow_vertices(g3)
g5, _ = apply_ops(g4, [(PUTV, 100), (PUTE, 1, 100, 2.0)])
view = refresh_sharded_view(g5, view, jnp.ones((g5.vcap,), jnp.bool_))
check(g5, view)
print("VIEW OK")
""")
    assert "VIEW OK" in out


def test_sharded_queries_equal_local_multidevice():
    """Distributed bfs/sssp/bc on a 4-way mesh vs the single-device path on
    an R-MAT graph with tombstones and dead vertices, plus the legacy
    edge-sharded oracle cross-check on BFS."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import REME, REMV, apply_ops, dense_views, queries
from repro.core.partition import build_query_inputs, make_distributed_query
from repro.data import load_rmat_graph
from repro.shard import as_graph_mesh, build_sharded_view, bc_batched, bfs, sssp

mesh = as_graph_mesh()
assert mesh.devices.size == 4
g = load_rmat_graph(64, 400, seed=3)
g, _ = apply_ops(g, [(REME, int(g.esrc[5]), int(g.edst[5])),
                     (REME, int(g.esrc[40]), int(g.edst[40])),
                     (REMV, 7), (REMV, 33)])
view = build_sharded_view(g, mesh, tile=16)
am, wd, alive = dense_views(g)
srcs = jnp.asarray([0, 1, 7, 33, 12, 63, 5, 2], jnp.int32)

r = bfs(view, g, srcs)
ref = queries.bfs_batched_dense(am, srcs, alive)
assert np.array_equal(np.asarray(r.dist), np.asarray(ref))
assert bool(r.agree)
# per-source COO oracle too
one = queries.bfs(g, 0)
assert np.array_equal(np.asarray(r.dist[0]), np.asarray(one.dist))

r2 = sssp(view, g, srcs)
dref, negref = queries.sssp_batched_dense(wd, srcs, alive)
assert np.array_equal(np.asarray(r2.dist), np.asarray(dref))
assert np.array_equal(np.asarray(r2.negcycle), np.asarray(negref))
ones = queries.sssp(g, 0)
assert np.array_equal(np.asarray(r2.dist[0]), np.asarray(ones.dist))

r3 = bc_batched(view, g, srcs, src_chunk=2)
d, s, lv, ok = queries.bc_batched_dense(am, srcs, alive, src_chunk=2)
assert np.array_equal(np.asarray(r3.level), np.asarray(lv))
assert np.array_equal(np.asarray(r3.sigma), np.asarray(s))
assert np.array_equal(np.asarray(r3.ok), np.asarray(ok))
assert np.allclose(np.asarray(r3.delta), np.asarray(d), rtol=1e-5, atol=1e-5)

# the partition front end over the same mesh
fn, _, _ = make_distributed_query(mesh, "bc", tile=16, src_chunk=2)
args = build_query_inputs(g, mesh, srcs, tile=16)
okp, dp, sp, lp, scores, val, agree = fn(*args)
assert np.array_equal(np.asarray(lp)[:, :64], np.asarray(lv))
assert bool(agree)

# legacy edge-sharded oracle agrees on the same snapshot (BFS dist)
from jax.sharding import Mesh
from repro.core.partition_legacy import make_distributed_query as legacy_q
from repro.core.partition_legacy import shard_edges
lmesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
gl = shard_edges(g, 4)
lfn, _, _ = legacy_q(lmesh, "bfs")
lreached, ldist, lparent, lec = jax.jit(lfn)(
    gl.alive, gl.ecnt, gl.esrc, gl.edst, gl.ew, jnp.int32(0))
assert np.array_equal(np.asarray(ldist), np.asarray(r.dist[0]))
print("QUERIES OK")
""")
    assert "QUERIES OK" in out


def test_sharded_delta_queries_multidevice():
    """Sharded delta bfs/sssp/bc on a 4-way mesh under churn that poisons
    vertices across shard boundaries, with tombstones and dead vertices:
    bit-identical to the local engine's delta path AND to a full sharded
    recompute on the same snapshot."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, REME, REMV, apply_ops, queries
from repro.core.updates import dirty_vertices
from repro.data import load_rmat_graph
from repro.engine import delta_bfs, delta_sssp
from repro.shard import (as_graph_mesh, build_sharded_view, refresh_sharded_view,
                         bfs, sssp, bc_batched, delta_bfs_sharded,
                         delta_sssp_sharded, delta_bc_sharded,
                         validate_incremental_sharded)

mesh = as_graph_mesh()
assert mesh.devices.size == 4
g = load_rmat_graph(64, 400, seed=3)
g, _ = apply_ops(g, [(REME, int(g.esrc[5]), int(g.edst[5])),
                     (REMV, 7), (REMV, 33)])  # tombstones + dead vertices
view = build_sharded_view(g, mesh, tile=16)  # band = 16: shard i owns [16i, 16i+16)
srcs = jnp.asarray([0, 1, 7, 33, 12, 63, 5, 2], jnp.int32)
pb, ps = bfs(view, g, srcs), sssp(view, g, srcs)
pc = bc_batched(view, g, srcs, src_chunk=2)

# churn whose poison crosses shard boundaries: edges from shard 0/1 sources
# into shard 2/3 bands, plus a mid-band death
g2, _ = apply_ops(g, [(PUTE, 0, 40, 2.0), (REME, 1, int(g.edst[20])),
                      (PUTE, 20, 55, 1.0), (REMV, 12), (PUTE, 47, 18, 3.0)])
dirty = dirty_vertices(g, g2)
view2 = refresh_sharded_view(g2, view, dirty)

db = delta_bfs_sharded(view2, g2, pb, dirty, srcs)
ds = delta_sssp_sharded(view2, g2, ps, dirty, srcs)
dc = delta_bc_sharded(view2, g2, pc, dirty, srcs, src_chunk=2)
# (b) vs full sharded recompute: every field bit-equal
assert validate_incremental_sharded(view2, g2, srcs, db, 'bfs')
assert validate_incremental_sharded(view2, g2, srcs, ds, 'sssp')
assert validate_incremental_sharded(view2, g2, srcs, dc, 'bc', src_chunk=2)
# (a) vs the local engine's per-source delta path: dist AND parent bit-equal
for i, s in enumerate(np.asarray(srcs)):
    lb = delta_bfs(g2, queries.bfs(g, int(s)), dirty, int(s))
    assert np.array_equal(np.asarray(db.dist[i]), np.asarray(lb.dist)), s
    assert np.array_equal(np.asarray(db.parent[i]), np.asarray(lb.parent)), s
    ls = delta_sssp(g2, queries.sssp(g, int(s)), dirty, int(s))
    assert np.array_equal(np.asarray(ds.dist[i]), np.asarray(ls.dist)), s
    assert np.array_equal(np.asarray(ds.parent[i]), np.asarray(ls.parent)), s
# delta BC vs the local batched warm start on the gathered adjacency
from repro.core import dense_views
am2, _, alive2 = dense_views(g2)
dref, sref, lref, okref = queries.bc_batched_dense(am2, srcs, alive2, src_chunk=2)
assert np.array_equal(np.asarray(dc.level), np.asarray(lref))
assert np.array_equal(np.asarray(dc.sigma), np.asarray(sref))
print("DELTA OK")
""")
    assert "DELTA OK" in out


def test_sharded_service_delta_ladder_multidevice():
    """ShardedGraphService on a 4-way mesh climbs unchanged -> delta ->
    full with results bit-identical to the local GraphService at every
    step, and bc_scores rides the level-cut delta."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, REME, apply_ops
from repro.data import load_rmat_graph
from repro.engine import GraphService
from repro.shard import ShardedGraphService, as_graph_mesh

mesh = as_graph_mesh()
g = load_rmat_graph(64, 600, seed=5)
svc = ShardedGraphService(g, mesh, tile=16, batch_size=4)
local = GraphService(g, batch_size=4)

assert svc.query("bfs", [0, 1]).mode == "full"
assert svc.query("sssp", [0]).mode == "full"
local.query("bfs", 0); local.query("sssp", 0)  # prime the local caches

# localized churn inside the reached region: the delta path answers
ops = [(PUTE, 0, v, 1.0) for v in (9, 11, 13, 15)] + [(REME, 0, 9)]
svc.submit_many(ops); local.submit_many(ops)
svc.flush(); local.flush()
rb = svc.query("bfs", [0, 1])
assert rb.mode == "delta" and bool(rb.result.agree)
lb = local.query("bfs", 0)
assert lb.mode == "delta"
assert np.array_equal(np.asarray(rb.result.dist[0]), np.asarray(lb.result.dist))
assert np.array_equal(np.asarray(rb.result.parent[0]), np.asarray(lb.result.parent))
rs = svc.query("sssp", [0])
assert rs.mode == "delta"
ls = local.query("sssp", 0)
assert np.array_equal(np.asarray(rs.result.dist[0]), np.asarray(ls.result.dist))

# churn outside every cached region: unchanged, however large
svc.submit_many([(PUTE, 200, 201 + i, 1.0) for i in range(4)])
svc.flush()
assert svc.query("bfs", [0, 1]).mode == "unchanged"

# bc_scores: full once, then the level-cut delta, bit-identical to local
s0, v0 = svc.bc_scores()
svc.submit_many([(PUTE, 3, 17, 1.0)]); svc.flush()
s1, v1 = svc.bc_scores()
assert v1 > v0 and svc.stats.delta >= 3
ref, _ = GraphService(svc.ring.latest.state).bc_scores()
a, b = np.asarray(s1), np.asarray(ref)
assert np.array_equal(np.isnan(a), np.isnan(b))
assert np.allclose(np.nan_to_num(a), np.nan_to_num(b), rtol=1e-4, atol=1e-4)

# cn double collect over the delta path still validates
svc.submit_many([(PUTE, 0, 21, 1.0)]); local.submit_many([(PUTE, 0, 21, 1.0)])
svc.flush(); local.flush()
rcn = svc.query("sssp", [0], mode="cn")
assert rcn.validated
lcn = local.query("sssp", 0, mode="cn")
assert np.array_equal(np.asarray(rcn.result.dist[0]), np.asarray(lcn.result.dist))
print("LADDER OK")
""")
    assert "LADDER OK" in out


def test_bc_ring_multidevice():
    """Ring-rotation BC on a 4-way mesh: bit-identical level/sigma to the
    gathered path AND the single-device batched path (full + level-cut
    delta), delta/scores to f32 summation order, and the collective-byte
    regression — ring-permute bytes per rotation match the O(Vp^2/n)
    formula off the compiled HLO, alongside the existing BFS int8-pmax /
    SSSP f32-min-merge byte formulas."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, REME, REMV, apply_ops, dense_views, queries
from repro.core.updates import dirty_vertices
from repro.data import load_rmat_graph
from repro.shard import (as_graph_mesh, build_sharded_view, refresh_sharded_view,
                         bc_batched, bfs, sssp, delta_bc_sharded,
                         validate_incremental_sharded, query_fn)

mesh = as_graph_mesh()
assert mesh.devices.size == 4
g = load_rmat_graph(64, 400, seed=3)
g, _ = apply_ops(g, [(REME, int(g.esrc[5]), int(g.edst[5])),
                     (REMV, 7), (REMV, 33)])
view = build_sharded_view(g, mesh, tile=16)
am, wd, alive = dense_views(g)
srcs = jnp.asarray([0, 1, 7, 33, 12, 63, 5, 2], jnp.int32)

rg = bc_batched(view, g, srcs, src_chunk=2)
rr = bc_batched(view, g, srcs, src_chunk=2, bc_mode="ring")
d, s, lv, ok = queries.bc_batched_dense(am, srcs, alive, src_chunk=2)
for got, name in ((rg, "gather"), (rr, "ring")):
    assert np.array_equal(np.asarray(got.level), np.asarray(lv)), name
    assert np.array_equal(np.asarray(got.sigma), np.asarray(s)), name
    assert np.array_equal(np.asarray(got.ok), np.asarray(ok)), name
    assert np.allclose(np.asarray(got.delta), np.asarray(d),
                       rtol=1e-5, atol=1e-5), name
    assert bool(got.agree), name
assert np.allclose(np.asarray(rr.scores), np.asarray(rg.scores),
                   rtol=1e-5, atol=1e-5)
# unchunked sweep too
rr1 = bc_batched(view, g, srcs, bc_mode="ring")
assert np.array_equal(np.asarray(rr1.level), np.asarray(lv))

# level-cut delta under cross-shard churn, warm-started from a ring prior
g2, _ = apply_ops(g, [(PUTE, 0, 40, 2.0), (REME, 1, int(g.edst[20])),
                      (PUTE, 20, 55, 1.0), (REMV, 12), (PUTE, 47, 18, 3.0)])
dirty = dirty_vertices(g, g2)
view2 = refresh_sharded_view(g2, view, dirty)
dr = delta_bc_sharded(view2, g2, rr, dirty, srcs, src_chunk=2, bc_mode="ring")
assert validate_incremental_sharded(view2, g2, srcs, dr, "bc", src_chunk=2,
                                    bc_mode="ring")
dg = delta_bc_sharded(view2, g2, rg, dirty, srcs, src_chunk=2)
assert np.array_equal(np.asarray(dr.level), np.asarray(dg.level))
assert np.array_equal(np.asarray(dr.sigma), np.asarray(dg.sigma))
assert np.allclose(np.asarray(dr.scores), np.asarray(dg.scores),
                   rtol=1e-5, atol=1e-5)

# ---- collective-byte regression off the compiled HLO ----------------
from repro.launch.dryrun import parse_collective_bytes
def coll(kind, extra=(), src_chunk=None):
    fn = query_fn(mesh, kind, 16, False, src_chunk)
    lowered = fn.lower(view.w, view.occ, g.alive, g.ecnt, srcs, g.version,
                       *extra)
    return parse_collective_bytes(lowered.compile().as_text())

S, vp = int(srcs.shape[0]), view.vp
band, rows, nt = view.band, view.rows_per_shard, view.n_tiles
slack = 64  # version-agreement scalars ride the same program

c = coll("bfs")
assert S * vp <= c["all-reduce"] <= S * vp + slack, c          # int8 pmax
c = coll("sssp")
assert 4 * S * vp <= c["all-reduce"] <= 4 * S * vp + slack, c  # f32 min-merge

# ring: one rotation = the shard's own band (f32 weights + int32 occ grid)
# = O(Vp^2/n) bytes; the compiled program carries exactly TWO rotation
# sites (forward loop, backward loop) per sweep
per_rot = band * vp * 4 + rows * nt * 4
assert per_rot == 4 * vp * vp // 4 + 4 * nt * nt // 4
for kind, chunks in (("bc_ring", 1),):
    c = coll(kind)
    assert c["collective-permute"] == 2 * chunks * per_rot, (kind, c)
# chunked: one rotation-site pair per source chunk (S/n sources per shard)
c = coll("bc_ring", src_chunk=1)
assert c["collective-permute"] == 2 * (S // 4) * per_rot, c
# gather mode moves the same band bytes once per query, n-fold amplified
c = coll("bc")
assert c["all-gather"] == vp * vp * 4 + nt * nt * 4, c
print("RING OK")
""")
    assert "RING OK" in out


def test_sharded_service_multidevice():
    """ShardedGraphService on a 4-way mesh: unchanged-shortcut, per-version
    caches, cn double collect, and bc_scores vs the local engine service."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, REME, apply_ops
from repro.data import load_rmat_graph
from repro.engine import GraphService
from repro.shard import ShardedGraphService, as_graph_mesh

mesh = as_graph_mesh()
g = load_rmat_graph(64, 600, seed=5)
svc = ShardedGraphService(g, mesh, tile=16, batch_size=4)
local = GraphService(g, batch_size=4)

rep = svc.query("bfs", [0, 1])
assert rep.mode == "full" and bool(rep.result.agree)
lrep = local.query("bfs", 0)
assert np.array_equal(np.asarray(rep.result.dist[0]), np.asarray(lrep.result.dist))
assert svc.query("bfs", [0, 1]).mode == "unchanged"

# churn far from the reached region keeps the cached answer
svc.submit_many([(PUTE, 200, 201, 1.0)] * 4)
svc.flush()
rep2 = svc.query("sssp", [0])
assert rep2.mode == "full"
svc.submit_many([(PUTE, 200, 202, 1.0)] * 4)
svc.flush()
assert svc.query("sssp", [0]).mode == "unchanged"

# touching churn forces a fresh distributed collect, via cn double collect
ops = [(PUTE, 0, v, 1.0) for v in (9, 11, 13, 15)]
svc.submit_many(ops)
local.submit_many(ops)
svc.flush(); local.flush()
rep3 = svc.query("sssp", [0], mode="cn")
# the cn reply carries its FINAL collect's mode: the second collect sees
# the same ring version and reports unchanged (engine-service semantics)
assert rep3.validated and svc.stats.full >= 2
lrep3 = local.query("sssp", 0)
assert np.array_equal(np.asarray(rep3.result.dist[0]), np.asarray(lrep3.result.dist))

scores, ver = svc.bc_scores()
lscores, lver = local.bc_scores()
assert ver == svc.version and lver == local.version
a, b = np.asarray(scores), np.asarray(lscores)
assert np.array_equal(np.isnan(a), np.isnan(b))
assert np.allclose(np.nan_to_num(a), np.nan_to_num(b), rtol=1e-4, atol=1e-4)
print("SERVICE OK")
""")
    assert "SERVICE OK" in out
