"""Sharded tile-grid engine: sharded-vs-local equivalence.

The multi-device tests follow the ``test_checkpoint.py`` elastic-rescale
pattern: a subprocess sets ``--xla_force_host_platform_device_count=4``
BEFORE importing jax, so the placeholder devices never leak into other
tests.  Equivalence bar (the PR's acceptance): distributed bfs/sssp dist
and bc level/sigma are BIT-identical to the single-device ``core.queries``
batched path on the same snapshot — including tombstones and dead vertices
— while bc delta/scores match to f32 summation order (the same caveat
``bc_batched_dense`` documents vs per-source Brandes).
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    PUTE, REME, REMV, apply_ops, dense_views, queries,
)
from repro.core.partition import (
    SUPPORTED_KINDS, build_query_inputs, make_distributed_query,
)
from repro.core.updates import dirty_vertices
from repro.data import load_rmat_graph
from repro.shard import (
    as_graph_mesh,
    bc_batched,
    bfs,
    build_sharded_view,
    gather_view,
    refresh_sharded_view,
    sharded_occupancy_stats,
    sssp,
)


def _run_multidevice(script: str, n_devices: int = 4) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    prelude = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={n_devices}"\n')
    r = subprocess.run([sys.executable, "-c", prelude + script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def _tombstoned_graph(n=64, edges=400, seed=3):
    g = load_rmat_graph(n, edges, seed=seed)
    return apply_ops(g, [(REME, int(g.esrc[5]), int(g.edst[5])),
                         (REME, int(g.esrc[40]), int(g.edst[40])),
                         (REMV, 7), (REMV, 33)])[0]


# ------------------------ in-process (1-device mesh) -----------------------

def test_make_distributed_query_rejects_unknown_kind():
    mesh = as_graph_mesh()
    with pytest.raises(ValueError) as ei:
        make_distributed_query(mesh, "pagerank")
    msg = str(ei.value)
    assert "pagerank" in msg
    for kind in SUPPORTED_KINDS:
        assert kind in msg


def test_sharded_matches_local_single_device():
    """The shard_map programs are mesh-size-agnostic: on a 1-device mesh
    they must already be bit-identical to the local batched path."""
    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    am, wd, alive = dense_views(g)
    srcs = jnp.asarray([0, 1, 7, 33, 63], jnp.int32)  # incl. dead sources

    r = bfs(view, g, srcs)
    assert np.array_equal(np.asarray(r.dist),
                          np.asarray(queries.bfs_batched_dense(am, srcs,
                                                               alive)))
    assert bool(r.agree)
    r2 = sssp(view, g, srcs)
    dref, negref = queries.sssp_batched_dense(wd, srcs, alive)
    assert np.array_equal(np.asarray(r2.dist), np.asarray(dref))
    assert np.array_equal(np.asarray(r2.negcycle), np.asarray(negref))

    r3 = bc_batched(view, g, srcs, src_chunk=2)
    d, s, lv, ok = queries.bc_batched_dense(am, srcs, alive, src_chunk=2)
    assert np.array_equal(np.asarray(r3.level), np.asarray(lv))
    assert np.array_equal(np.asarray(r3.sigma), np.asarray(s))
    assert np.array_equal(np.asarray(r3.ok), np.asarray(ok))
    assert np.allclose(np.asarray(r3.delta), np.asarray(d),
                       rtol=1e-5, atol=1e-5)


def test_bc_source_padding_and_default_sources():
    """Source counts that don't divide the mesh are padded with -1 and the
    padding sliced back off; ``srcs=None`` means every vertex slot."""
    g = _tombstoned_graph(n=32, edges=120)
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    r = bc_batched(view, g, jnp.asarray([0, 5, 9], jnp.int32))
    assert r.delta.shape == (3, 32) and r.ok.shape == (3,)
    r_all = bc_batched(view, g, None)
    am, _, alive = dense_views(g)
    d, s, lv, ok = queries.bc_batched_dense(
        am, jnp.arange(32, dtype=jnp.int32), alive)
    scores = jnp.sum(jnp.where(ok[:, None], d, 0.0), axis=0)
    assert np.allclose(np.asarray(r_all.scores), np.asarray(scores),
                       rtol=1e-5, atol=1e-5)


def test_refresh_sharded_view_strategies():
    g = _tombstoned_graph()
    mesh = as_graph_mesh()
    view = build_sharded_view(g, mesh, tile=16)
    # empty dirty set: the very same view comes back
    same = refresh_sharded_view(g, view, jnp.zeros((64,), jnp.bool_))
    assert same is view
    # tile-size mismatch: falls back to a rebuild at the new grid
    g2, _ = apply_ops(g, [(PUTE, 3, 9, 2.0)])
    view2 = refresh_sharded_view(g2, view, dirty_vertices(g, g2), tile=32)
    assert view2.tile == 32
    full = gather_view(view2)
    ref = gather_view(build_sharded_view(g2, mesh, tile=32))
    assert np.array_equal(np.asarray(full.w), np.asarray(ref.w))
    assert np.array_equal(np.asarray(full.occ), np.asarray(ref.occ))
    # no prev and no mesh: explicit error
    with pytest.raises(ValueError):
        refresh_sharded_view(g2, None, None)


def test_build_query_inputs_roundtrip():
    g = _tombstoned_graph(n=32, edges=120)
    mesh = as_graph_mesh()
    fn, _, _ = make_distributed_query(mesh, "bfs", tile=16)
    args = build_query_inputs(g, mesh, [0, 2], tile=16)
    ok, dist, val_ecnt, agree = fn(*args)
    am, _, alive = dense_views(g)
    ref = queries.bfs_batched_dense(am, jnp.asarray([0, 2], jnp.int32), alive)
    assert np.array_equal(np.asarray(dist)[:, :32], np.asarray(ref))
    assert bool(agree)


# ------------------------- multi-device subprocess -------------------------

def test_sharded_view_refresh_multidevice():
    """Build + per-shard dirty-row refresh under an update stream, compact,
    and both grows: always bit-identical to a from-scratch sharded build."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, PUTV, REME, REMV, apply_ops, compact, grow_edges, grow_vertices
from repro.core.graph_state import densify
from repro.core.updates import dirty_vertices
from repro.data import load_rmat_graph
from repro.shard import (as_graph_mesh, build_sharded_view, refresh_sharded_view,
                         gather_view, sharded_occupancy_stats)

mesh = as_graph_mesh()
assert mesh.devices.size == 4
g = load_rmat_graph(64, 400, seed=2)
view = build_sharded_view(g, mesh, tile=16)
assert view.vp % (4 * 16) == 0 and view.band == view.vp // 4
stats = sharded_occupancy_stats(view)
assert len(stats["per_shard_tile_skip_rate"]) == 4

def check(state, v):
    full = gather_view(v)
    vcap = state.vcap
    w = np.asarray(full.w)
    assert np.array_equal(w[:vcap, :vcap], np.asarray(densify(state)))
    assert np.isinf(w[vcap:, :]).all() and np.isinf(w[:, vcap:]).all()
    ref = gather_view(build_sharded_view(state, mesh, tile=16))
    assert np.array_equal(w, np.asarray(ref.w))
    assert np.array_equal(np.asarray(full.occ), np.asarray(ref.occ))

check(g, view)
rng = np.random.default_rng(0)
for i in range(6):
    ops = [(PUTE, int(rng.integers(0, 64)), int(rng.integers(0, 64)),
            float(rng.integers(1, 9))) for _ in range(5)]
    ops += [(REME, int(rng.integers(0, 64)), int(rng.integers(0, 64))),
            (REMV, int(rng.integers(0, 64))) if i == 3 else
            (PUTV, int(rng.integers(0, 64)))]
    g2, _ = apply_ops(g, ops)
    view = refresh_sharded_view(g2, view, dirty_vertices(g, g2))
    check(g2, view)
    g = g2
g2 = compact(g)
view = refresh_sharded_view(g2, view, jnp.zeros((64,), jnp.bool_))
check(g2, view)
g = g2
g2 = grow_edges(g)
g3, _ = apply_ops(g2, [(PUTE, 1, 2, 4.0)])
view = refresh_sharded_view(g3, view, dirty_vertices(g2, g3))
check(g3, view)
g4 = grow_vertices(g3)
g5, _ = apply_ops(g4, [(PUTV, 100), (PUTE, 1, 100, 2.0)])
view = refresh_sharded_view(g5, view, jnp.ones((g5.vcap,), jnp.bool_))
check(g5, view)
print("VIEW OK")
""")
    assert "VIEW OK" in out


def test_sharded_queries_equal_local_multidevice():
    """Distributed bfs/sssp/bc on a 4-way mesh vs the single-device path on
    an R-MAT graph with tombstones and dead vertices, plus the legacy
    edge-sharded oracle cross-check on BFS."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import REME, REMV, apply_ops, dense_views, queries
from repro.core.partition import build_query_inputs, make_distributed_query
from repro.data import load_rmat_graph
from repro.shard import as_graph_mesh, build_sharded_view, bc_batched, bfs, sssp

mesh = as_graph_mesh()
assert mesh.devices.size == 4
g = load_rmat_graph(64, 400, seed=3)
g, _ = apply_ops(g, [(REME, int(g.esrc[5]), int(g.edst[5])),
                     (REME, int(g.esrc[40]), int(g.edst[40])),
                     (REMV, 7), (REMV, 33)])
view = build_sharded_view(g, mesh, tile=16)
am, wd, alive = dense_views(g)
srcs = jnp.asarray([0, 1, 7, 33, 12, 63, 5, 2], jnp.int32)

r = bfs(view, g, srcs)
ref = queries.bfs_batched_dense(am, srcs, alive)
assert np.array_equal(np.asarray(r.dist), np.asarray(ref))
assert bool(r.agree)
# per-source COO oracle too
one = queries.bfs(g, 0)
assert np.array_equal(np.asarray(r.dist[0]), np.asarray(one.dist))

r2 = sssp(view, g, srcs)
dref, negref = queries.sssp_batched_dense(wd, srcs, alive)
assert np.array_equal(np.asarray(r2.dist), np.asarray(dref))
assert np.array_equal(np.asarray(r2.negcycle), np.asarray(negref))
ones = queries.sssp(g, 0)
assert np.array_equal(np.asarray(r2.dist[0]), np.asarray(ones.dist))

r3 = bc_batched(view, g, srcs, src_chunk=2)
d, s, lv, ok = queries.bc_batched_dense(am, srcs, alive, src_chunk=2)
assert np.array_equal(np.asarray(r3.level), np.asarray(lv))
assert np.array_equal(np.asarray(r3.sigma), np.asarray(s))
assert np.array_equal(np.asarray(r3.ok), np.asarray(ok))
assert np.allclose(np.asarray(r3.delta), np.asarray(d), rtol=1e-5, atol=1e-5)

# the partition front end over the same mesh
fn, _, _ = make_distributed_query(mesh, "bc", tile=16, src_chunk=2)
args = build_query_inputs(g, mesh, srcs, tile=16)
okp, dp, sp, lp, scores, val, agree = fn(*args)
assert np.array_equal(np.asarray(lp)[:, :64], np.asarray(lv))
assert bool(agree)

# legacy edge-sharded oracle agrees on the same snapshot (BFS dist)
from jax.sharding import Mesh
from repro.core.partition_legacy import make_distributed_query as legacy_q
from repro.core.partition_legacy import shard_edges
lmesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
gl = shard_edges(g, 4)
lfn, _, _ = legacy_q(lmesh, "bfs")
lreached, ldist, lparent, lec = jax.jit(lfn)(
    gl.alive, gl.ecnt, gl.esrc, gl.edst, gl.ew, jnp.int32(0))
assert np.array_equal(np.asarray(ldist), np.asarray(r.dist[0]))
print("QUERIES OK")
""")
    assert "QUERIES OK" in out


def test_sharded_service_multidevice():
    """ShardedGraphService on a 4-way mesh: unchanged-shortcut, per-version
    caches, cn double collect, and bc_scores vs the local engine service."""
    out = _run_multidevice(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import PUTE, REME, apply_ops
from repro.data import load_rmat_graph
from repro.engine import GraphService
from repro.shard import ShardedGraphService, as_graph_mesh

mesh = as_graph_mesh()
g = load_rmat_graph(64, 600, seed=5)
svc = ShardedGraphService(g, mesh, tile=16, batch_size=4)
local = GraphService(g, batch_size=4)

rep = svc.query("bfs", [0, 1])
assert rep.mode == "full" and bool(rep.result.agree)
lrep = local.query("bfs", 0)
assert np.array_equal(np.asarray(rep.result.dist[0]), np.asarray(lrep.result.dist))
assert svc.query("bfs", [0, 1]).mode == "unchanged"

# churn far from the reached region keeps the cached answer
svc.submit_many([(PUTE, 200, 201, 1.0)] * 4)
svc.flush()
rep2 = svc.query("sssp", [0])
assert rep2.mode == "full"
svc.submit_many([(PUTE, 200, 202, 1.0)] * 4)
svc.flush()
assert svc.query("sssp", [0]).mode == "unchanged"

# touching churn forces a fresh distributed collect, via cn double collect
ops = [(PUTE, 0, v, 1.0) for v in (9, 11, 13, 15)]
svc.submit_many(ops)
local.submit_many(ops)
svc.flush(); local.flush()
rep3 = svc.query("sssp", [0], mode="cn")
# the cn reply carries its FINAL collect's mode: the second collect sees
# the same ring version and reports unchanged (engine-service semantics)
assert rep3.validated and svc.stats.full >= 2
lrep3 = local.query("sssp", 0)
assert np.array_equal(np.asarray(rep3.result.dist[0]), np.asarray(lrep3.result.dist))

scores, ver = svc.bc_scores()
lscores, lver = local.bc_scores()
assert ver == svc.version and lver == local.version
a, b = np.asarray(scores), np.asarray(lscores)
assert np.array_equal(np.isnan(a), np.isnan(b))
assert np.allclose(np.nan_to_num(a), np.nan_to_num(b), rtol=1e-4, atol=1e-4)
print("SERVICE OK")
""")
    assert "SERVICE OK" in out
