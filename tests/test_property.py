"""Hypothesis property tests: the ADT against the sequential oracle, and
semiring-query invariants on random graphs."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="optional dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import (
    PUTE, PUTV, REME, REMV, apply_ops, bfs, bc_dependencies, get_e, get_v,
    make_graph, num_edges, sssp,
)
from repro.kernels import ops as kops, ref as kref
from oracle import GraphOracle

N = 8

op_strategy = st.one_of(
    st.tuples(st.just(PUTV), st.integers(0, N - 1)),
    st.tuples(st.just(REMV), st.integers(0, N - 1)),
    st.tuples(st.just(PUTE), st.integers(0, N - 1), st.integers(0, N - 1),
              st.sampled_from([1.0, 2.0, 3.0])),
    st.tuples(st.just(REME), st.integers(0, N - 1), st.integers(0, N - 1)),
)


@settings(max_examples=20, deadline=None)
@given(st.lists(op_strategy, min_size=1, max_size=30))
def test_adt_matches_oracle_under_random_ops(ops_list):
    """One op per batch = strict sequential semantics vs the oracle."""
    g = make_graph(N, 64)
    o = GraphOracle()
    for op in ops_list:
        g, res = apply_ops(g, [op])
        ok = bool(np.asarray(res.ok)[0])
        val = float(np.asarray(res.val)[0])
        if op[0] == PUTV:
            assert ok == o.put_v(op[1])
        elif op[0] == REMV:
            assert ok == o.rem_v(op[1])
        elif op[0] == PUTE:
            eok, ev = o.put_e(op[1], op[2], op[3])
            assert (ok, val) == (eok, ev)
        elif op[0] == REME:
            eok, ev = o.rem_e(op[1], op[2])
            assert (ok, val) == (eok, ev)
    # final-state agreement
    assert int(num_edges(g)) == len(o.edges)
    for v in range(N):
        assert bool(get_v(g, v)) == o.get_v(v)
    for u in range(N):
        for v in range(N):
            ok, w = get_e(g, u, v)
            eok, ew = o.get_e(u, v)
            assert bool(ok) == eok and float(w) == ew


@settings(max_examples=15, deadline=None)
@given(st.lists(op_strategy, min_size=1, max_size=25),
       st.integers(0, N - 1))
def test_query_invariants_random_graphs(ops_list, src):
    g = make_graph(N, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(N)])
    g, _ = apply_ops(g, ops_list, batch_size=max(1, len(ops_list)))
    r = bfs(g, src)
    dist = np.asarray(r.dist)
    reached = np.asarray(r.reached)
    # invariant: reached <=> dist >= 0; source dist 0 when ok
    assert ((dist >= 0) == reached).all()
    if bool(r.ok):
        assert dist[src] == 0
        s = sssp(g, src)
        sd = np.asarray(s.dist)
        # unit-free invariant: hop count <= weighted distance is NOT general,
        # but: sssp-reachable set == bfs-reachable set (positive weights)
        if not bool(s.negcycle):
            assert ((sd < np.inf) == reached).all()
        b = bc_dependencies(g, src)
        assert (np.asarray(b.sigma)[reached] > 0).all()
        assert not np.isnan(np.asarray(b.delta)).any()


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 6), st.integers(1, 5),
       st.integers(0, 2**31 - 1))
def test_bool_mm_property(sb, kb, nb, seed):
    rng = np.random.default_rng(seed)
    s, k, n = sb * 17, kb * 23, nb * 19
    f = (rng.random((s, k)) < 0.2).astype(np.float32)
    a = (rng.random((k, n)) < 0.2).astype(np.float32)
    out = np.asarray(kops.bool_mm(jnp.asarray(f), jnp.asarray(a),
                                  bm=32, bn=32, bk=32))
    exp = np.asarray(kref.bool_mm_ref(jnp.asarray(f), jnp.asarray(a)))
    assert np.array_equal(out, exp)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_stream_differential_property(seed, negative):
    """Hypothesis roams the seed space of the randomized differential
    op-stream suite (``stream_differential``): mixed add/remove-edge/vertex
    commits + bfs/sssp/bc queries, every ladder answer checked against the
    sequential oracle.  The fixed-seed + sharded variants live in
    ``test_stream_differential.py``; any failing seed here reproduces with
    ``run_differential(seed, n=16, steps=3, ...)``."""
    from stream_differential import run_differential
    run_differential(seed, n=16, steps=3, ops_per_step=6,
                     neg_frac=0.1 if negative else 0.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_minplus_triangle_inequality_property(seed):
    """(D (x) W) (x) W >= D (x) (W (x) W) never violated elementwise up to
    fp error — associativity of the tropical semiring."""
    rng = np.random.default_rng(seed)
    d = rng.random((8, 16)).astype(np.float32) * 10
    w = rng.random((16, 16)).astype(np.float32) * 10
    lhs = kops.minplus_mm(kops.minplus_mm(jnp.asarray(d), jnp.asarray(w)),
                          jnp.asarray(w))
    rhs = kops.minplus_mm(jnp.asarray(d),
                          kops.minplus_mm(jnp.asarray(w), jnp.asarray(w)))
    assert np.allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)
