"""Per-architecture smoke tests: reduced same-family config, one forward /
train-style loss + one decode step on CPU; shapes + finiteness asserted.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and tests/test_dryrun_unit.py.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, reduced, shapes_for
from repro.models import get_model, input_specs

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=17):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 1,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s - 1)[None, None], (3, b, s - 1))
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_finite(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(KEY)
    loss = model.loss_fn(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(loss) > 0.5      # untrained model on random tokens


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_exist_and_finite(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(KEY)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, _batch(cfg))
    leaves = jax.tree.leaves(grads)
    assert leaves
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in leaves)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = reduced(get_config(arch))
    model = get_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 32, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 1,
                              cfg.vocab_size)
    kw = {}
    if cfg.mrope_sections:
        kw["positions"] = jnp.broadcast_to(jnp.arange(8)[None, None],
                                           (3, 2, 8))
    if cfg.family in ("encdec", "audio"):
        kw["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                         (2, cfg.encoder_seq, cfg.d_model))
    logits, cache = model.prefill(params, toks, cache, **kw)
    assert logits.shape == (2, 1, cfg.vocab_size)
    kw2 = {}
    if cfg.mrope_sections:
        kw2["positions"] = jnp.full((3, 2, 1), 8, jnp.int32)
    logits, cache = model.decode_step(params, toks[:, :1], cache, **kw2)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_input_specs_cover_every_live_cell():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape, (s, b, kind) in shapes_for(cfg).items():
            specs = input_specs(cfg, shape, b, s)
            assert "tokens" in specs
            if kind == "train":
                assert specs["tokens"].shape == (b, s)
            elif kind == "decode":
                assert specs["tokens"].shape == (b, 1)
            if cfg.family in ("encdec", "audio") and kind != "decode":
                assert "frames" in specs


def test_long_500k_skip_rule():
    live = {a: set(shapes_for(get_config(a))) for a in ARCHS}
    assert "long_500k" in live["mamba2_780m"]
    assert "long_500k" in live["zamba2_12b"]
    for a in ARCHS:
        if a not in ("mamba2_780m", "zamba2_12b"):
            assert "long_500k" not in live[a], a


def test_gemma_local_global_pattern():
    from repro.models.transformer import layer_windows, BIG_WINDOW
    cfg = get_config("gemma3_27b")
    w = np.asarray(layer_windows(cfg))
    assert (w[: 5] == 1024).all()
    assert w[5] == int(BIG_WINDOW)
    assert (w == int(BIG_WINDOW)).sum() == cfg.num_layers // 6


def test_mrope_equals_rope_for_identical_streams():
    from repro.models.layers import apply_rope
    x = jax.random.normal(KEY, (2, 3, 8, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    plain = apply_rope(x, pos, 1e4)
    mrope = apply_rope(x, jnp.broadcast_to(pos[None], (3, 2, 8)), 1e4,
                       sections=(4, 6, 6))
    assert np.allclose(np.asarray(plain), np.asarray(mrope), atol=1e-6)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 24, 2, 4, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.5 + 0.1, jnp.float32)
    a = -jnp.asarray(rng.random((h,)) * 0.5 + 0.2)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y1, f1 = ssd_chunked(x, dt, a, bm, cm, chunk=4)
    y2, f2 = ssd_chunked(x, dt, a, bm, cm, chunk=24)
    y3, f3 = ssd_chunked(x, dt, a, bm, cm, chunk=7)   # non-dividing chunk
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert np.allclose(np.asarray(y1), np.asarray(y3), atol=1e-4)
    assert np.allclose(np.asarray(f1), np.asarray(f2), atol=1e-4)
    assert np.allclose(np.asarray(f1), np.asarray(f3), atol=1e-4)


def test_ssd_state_carry_matches_recurrence():
    """Chunked SSD final state == step-by-step decode recurrence."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 12, 2, 4, 6
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.4 + 0.1, jnp.float32)
    a = -jnp.asarray(rng.random((h,)) * 0.4 + 0.2)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    _, final = ssd_chunked(x, dt, a, bm, cm, chunk=4)
    state = np.zeros((b, h, p, n), np.float32)
    for t in range(s):
        da = np.exp(np.asarray(dt)[:, t] * np.asarray(a)[None])   # [b,h]
        upd = np.einsum("bn,bh,bhp->bhpn", np.asarray(bm)[:, t],
                        np.asarray(dt)[:, t], np.asarray(x)[:, t])
        state = da[:, :, None, None] * state + upd
    assert np.allclose(np.asarray(final), state, atol=1e-3)
