"""Unit tests for the telemetry subsystem (``repro.obs``).

The integration path — a traced service stream asserting conservation and
oracle agreement — lives in ``test_stream_differential``; here the
instruments themselves are pinned: registry identity semantics, quantile
math, the attribute shims the legacy stats objects became, span nesting /
annotation, JSONL export through the ``repro.obs.report`` gate, and the
HLO cost accountant's compile-once cache.
"""
import json
import math

import jax
import jax.numpy as jnp

from repro.obs import (
    CounterStruct,
    HLOCostAccountant,
    MetricsRegistry,
    ModeCounters,
    Telemetry,
    Tracer,
    report,
)
from repro.obs.metrics import quantile
from repro.obs.trace import TRACE_SCHEMA, annotate, maybe_span


# ------------------------------- metrics -----------------------------------

def test_registry_get_or_create_identity():
    reg = MetricsRegistry()
    a = reg.counter("hits", service="local")
    b = reg.counter("hits", service="local")
    c = reg.counter("hits", service="sharded")
    assert a is b and a is not c
    a.inc(3)
    assert b.value == 3 and c.value == 0
    # same name, different instrument kind -> distinct
    h = reg.histogram("hits")
    assert h is not a


def test_registry_find_and_merged_quantiles():
    reg = MetricsRegistry()
    for mode, vals in (("delta", [1, 2, 3]), ("full", [10, 20, 30])):
        h = reg.histogram("wall", service="local", mode=mode)
        for v in vals:
            h.observe(v)
    assert len(reg.find("wall", service="local")) == 2
    assert reg.find("wall", mode="delta")[0].count == 3
    pooled = reg.merged_quantiles("wall", (0.0, 0.5, 1.0), service="local")
    assert pooled[0.0] == 1 and pooled[1.0] == 30
    assert math.isnan(reg.merged_quantiles("absent", (0.5,))[0.5])


def test_quantile_nearest_rank():
    s = list(range(1, 101))
    assert quantile(s, 0.5) == 51  # nearest rank on 0..99 index space
    assert quantile(s, 0.0) == 1
    assert quantile(s, 1.0) == 100
    assert math.isnan(quantile([], 0.5))


def test_histogram_reservoir_bounded():
    reg = MetricsRegistry()
    h = reg.histogram("w")
    h._samples = type(h._samples)(maxlen=4)
    for v in range(10):
        h.observe(v)
    assert h.count == 10 and h.total == sum(range(10))
    assert h.samples == [6, 7, 8, 9]


def test_counter_struct_shim():
    class S(CounterStruct):
        _FIELDS = ("a", "b")
        _PREFIX = "test_"

    reg = MetricsRegistry()
    s = S(reg, service="x")
    s.a += 2
    s.a += 1
    s.b = 7
    assert (s.a, s.b) == (3, 7)
    assert s.as_dict() == {"a": 3, "b": 7}
    # the values ARE registry counters, shared by key
    assert reg.counter("test_a", service="x").value == 3
    # private registry when none is given
    s2 = S()
    s2.a += 1
    assert s2.a == 1 and reg.counter("test_a", service="x").value == 3


def test_mode_counters_mapping():
    reg = MetricsRegistry()
    d = ModeCounters(reg, "bcq", service="local")
    d["delta"] += 2
    d["full"] = 5
    assert dict(d) == {"unchanged": 0, "delta": 2, "full": 5}
    assert reg.counter("bcq", mode="delta", service="local").value == 2


# -------------------------------- tracing ----------------------------------

def test_tracer_nesting_and_annotate():
    tr = Tracer()
    with tr.span("query", kind="bfs") as q:
        with tr.span("collect") as c:
            annotate(dirty=4)  # lands on the innermost span
        q.set(mode="delta")
    annotate(ignored=1)  # no active span: silently dropped
    child, parent = tr.records  # children exit (emit) first
    assert parent["span"] == "query" and parent["parent"] is None
    assert child["span"] == "collect" and child["parent"] == parent["id"]
    assert child["dirty"] == 4 and "ignored" not in parent
    assert parent["mode"] == "delta" and parent["wall_us"] >= 0


def test_maybe_span_null_path():
    with maybe_span(None, "query", kind="bfs") as sp:
        sp.set(mode="full")  # must not raise
        annotate(dirty=1)    # no tracer: no-op
    assert sp.id is None


def test_tracer_jsonl_and_report_gate(tmp_path):
    path = tmp_path / "t.jsonl"
    tr = Tracer(str(path))
    for mode in ("unchanged", "delta", "full"):
        with tr.span("query", service="local", kind="bfs", version=1,
                     mode=mode, coll_bytes=0, degraded=False,
                     device_us=12.5, flops=100.0):
            pass
    tr.close()
    records = report.load(str(path))
    assert [r["schema"] for r in records] == [TRACE_SCHEMA] * 3
    assert report.validate(
        records, require_modes=("unchanged", "delta", "full")) == []
    rows = report.summarize(records)
    assert {r["mode"] for r in rows} == {"unchanged", "delta", "full"}
    assert report.main([str(path), "--check",
                        "--require-modes", "unchanged,delta,full"]) == 0
    # missing mode and missing fields both trip the gate
    assert report.validate(records, require_modes=("nope",)) != []
    bad = [dict(r, **{"span": "query"}) for r in records]
    del bad[0]["version"]
    assert any("missing" in e for e in report.validate(bad))
    assert report.main([str(path), "--require-modes", "nope"]) == 1


# ---------------------------- HLO accounting --------------------------------

def test_hlo_accountant_caches_compiles():
    acct = HLOCostAccountant(shared=False)
    compiles = []

    def compile_fn():
        compiles.append(1)
        return jax.jit(lambda x: x * 2 + 1).lower(
            jnp.zeros((8,), jnp.float32)).compile()

    c1 = acct.account(("k", 1), compile_fn)
    c2 = acct.account(("k", 1), compile_fn)
    assert len(compiles) == 1 and c1 is c2 and acct.last is c2
    for f in ("collective_bytes", "temp_bytes", "flops"):
        assert f in c1
    assert acct.account(("k", 2), compile_fn) is not c1
    assert len(compiles) == 2
    assert len(acct.snapshot()) == 2


def test_hlo_accountant_shared_cache():
    a, b = HLOCostAccountant(), HLOCostAccountant()
    n0 = len(a.snapshot())
    a.account(("shared-probe", n0), lambda: jax.jit(lambda x: x + 1).lower(
        jnp.zeros((4,), jnp.float32)).compile())
    assert b.account(("shared-probe", n0), lambda: (_ for _ in ()).throw(
        AssertionError("cache miss"))) is a.last


# ----------------------------- service glue ---------------------------------

def test_local_service_trace_schema(tmp_path):
    from repro.core import PUTE, PUTV, make_graph
    from repro.engine import GraphService

    path = tmp_path / "svc.jsonl"
    tel = Telemetry.make(str(path), hlo=False)
    svc = GraphService(make_graph(16, 64), batch_size=4, telemetry=tel)
    for i in range(6):
        svc.submit((PUTV, i))
    for u, v in ((0, 1), (1, 2), (2, 3)):
        svc.submit((PUTE, u, v, 1.0))
    svc.flush()
    svc.query("bfs", 0)
    svc.query("bfs", 0)
    svc.submit((PUTE, 3, 4, 1.0))
    svc.flush()
    svc.query("bfs", 0)
    tel.close()

    records = [json.loads(line) for line in open(path)]
    qrecs = [r for r in records if r["span"] == "query"]
    assert len(qrecs) == svc.stats.queries == 3
    for r in qrecs:
        for f in report.QUERY_FIELDS:
            assert f in r, f
        assert r["service"] == "local"
    assert [r["mode"] for r in qrecs] == ["full", "unchanged", "delta"]
    # commits and collects traced too, collects nested under their query
    spans = {r["span"] for r in records}
    assert {"commit", "collect", "query"} <= spans
    collect = next(r for r in records if r["span"] == "collect")
    assert any(r["id"] == collect["parent"] for r in qrecs)
    # the latency histogram the benches read is fed once per query
    hist = tel.registry.find("query_wall_us", service="local")
    assert sum(h.count for h in hist) == 3


def test_local_service_device_and_flops_attribution(tmp_path):
    """With the accountant on, every local query span carries ``flops``
    from the compiled program that answered it (and zero collective
    bytes — the local engine has no collectives), and ``device_us`` from
    the per-collect dispatch-gap measurement.  The unchanged shortcut
    runs no program, so its span legitimately reports zero flops."""
    from repro.core import PUTE, PUTV, make_graph
    from repro.engine import GraphService

    path = tmp_path / "svc.jsonl"
    tel = Telemetry.make(str(path))
    svc = GraphService(make_graph(16, 64), batch_size=4, telemetry=tel)
    for i in range(6):
        svc.submit((PUTV, i))
    for u, v in ((0, 1), (1, 2), (2, 3)):
        svc.submit((PUTE, u, v, 1.0))
    svc.flush()
    svc.query("bfs", 0)   # full
    svc.query("bfs", 0)   # unchanged
    svc.submit((PUTE, 3, 4, 1.0))
    svc.flush()
    svc.query("bfs", 0)   # delta
    tel.close()

    qrecs = [json.loads(l) for l in open(path)]
    qrecs = [r for r in qrecs if r["span"] == "query"]
    assert [r["mode"] for r in qrecs] == ["full", "unchanged", "delta"]
    full, unchanged, delta = qrecs
    assert full["flops"] > 0 and delta["flops"] > 0
    assert unchanged["flops"] == 0        # no program dispatched
    for r in qrecs:
        assert r["coll_bytes"] == 0       # local engine: no collectives
        assert r["device_us"] >= 0
    assert full["device_us"] > 0          # the full sweep really ran
    # the device-time histogram only sees queries that dispatched work
    hists = tel.registry.find("query_device_us", service="local")
    assert sum(h.count for h in hists) == sum(
        1 for r in qrecs if r["device_us"] > 0)


# ------------------------- metrics edge cases (PR 8) ------------------------

def test_merged_quantiles_empty_reservoirs():
    """Histograms that exist but have no samples pool to NaN quantiles,
    and mixing an empty histogram into a populated pool is a no-op."""
    reg = MetricsRegistry()
    reg.histogram("w", mode="delta")          # registered, never observed
    pooled = reg.merged_quantiles("w", (0.5, 0.99))
    assert math.isnan(pooled[0.5]) and math.isnan(pooled[0.99])
    reg.histogram("w", mode="full").observe(7.0)
    pooled = reg.merged_quantiles("w", (0.5, 0.99))
    assert pooled[0.5] == 7.0 and pooled[0.99] == 7.0


def test_single_sample_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("w")
    h.observe(42.0)
    qs = h.quantiles((0.0, 0.5, 0.95, 0.99, 1.0))
    assert all(v == 42.0 for v in qs.values())


def test_counter_struct_label_collision():
    """Two shims over the same registry with identical labels share the
    underlying counters (keyed identity), while one distinct label splits
    them — so two services sharing one registry can never alias."""
    class S(CounterStruct):
        _FIELDS = ("a",)
        _PREFIX = "col_"

    reg = MetricsRegistry()
    s1 = S(reg, service="x")
    s2 = S(reg, service="x")
    s3 = S(reg, service="y")
    s1.a += 2
    assert s2.a == 2          # same (name, labels) -> same counter
    assert s3.a == 0
    s2.a += 1
    assert s1.a == 3


# ------------------------- OpenMetrics exposition ---------------------------

def test_openmetrics_render_and_validate():
    from repro.obs.expo import render_openmetrics, validate_openmetrics

    reg = MetricsRegistry()
    reg.counter("service_queries", service="local").inc(5)
    reg.gauge("adaptive_dirty_threshold", service="local", kind="bfs").set(
        0.25)
    h = reg.histogram("query_wall_us", service="local", kind="bfs",
                      mode="full")
    for v in (10.0, 20.0, 30.0):
        h.observe(v)
    text = render_openmetrics(reg, extra_counters={"trace_rotations": 2},
                              extra_gauges={"journal_depth": 7})
    assert validate_openmetrics(text) == []
    assert "# TYPE service_queries counter" in text
    assert 'service_queries_total{service="local"} 5' in text
    assert "# TYPE query_wall_us summary" in text
    assert 'quantile="0.5"' in text
    assert 'query_wall_us_count{kind="bfs",mode="full",service="local"} 3' \
        in text
    assert "trace_rotations_total 2" in text
    assert "journal_depth 7" in text
    assert text.rstrip().endswith("# EOF")


def test_openmetrics_label_escaping():
    """Label values containing ``"``, ``\\`` and newlines must round-trip
    through the escaper and still validate."""
    from repro.obs.expo import render_openmetrics, validate_openmetrics

    reg = MetricsRegistry()
    reg.counter("esc", what='say "hi"\nplease\\now').inc()
    text = render_openmetrics(reg)
    assert validate_openmetrics(text) == []
    assert r'what="say \"hi\"\nplease\\now"' in text


def test_openmetrics_validator_catches_breakage():
    from repro.obs.expo import validate_openmetrics

    good = ("# TYPE x counter\n# HELP x a counter.\nx_total 1\n# EOF\n")
    assert validate_openmetrics(good) == []
    # counter sample without _total
    bad = good.replace("x_total 1", "x 1")
    assert any("_total" in e for e in validate_openmetrics(bad))
    # missing EOF
    assert any("EOF" in e for e in validate_openmetrics(
        "# TYPE x counter\n# HELP x a.\nx_total 1\n"))
    # sample with no TYPE declaration
    assert any("TYPE" in e for e in validate_openmetrics(
        "y_total 1\n# EOF\n"))
    # non-numeric value
    assert any("non-numeric" in e for e in validate_openmetrics(
        "# TYPE x counter\n# HELP x a.\nx_total one\n# EOF\n"))
    # duplicate family
    assert any("twice" in e for e in validate_openmetrics(
        "# TYPE x counter\n# HELP x a.\n# TYPE x counter\nx_total 1\n"
        "# EOF\n"))


def test_expo_server_scrape_and_journal_depth(tmp_path):
    import urllib.request

    from repro.obs.expo import validate_openmetrics
    from repro.resil.journal import OpJournal

    jr = OpJournal(str(tmp_path / "wal.jsonl"))
    jr.append_op(0, ("pute", 0, 1, 1.0))
    jr.append_op(1, ("pute", 1, 2, 1.0))
    jr.commit_barrier(1, 2)
    jr.append_op(2, ("remv", 2))      # not yet barriered -> depth 1
    assert jr.depth == 1

    tel = Telemetry.make()
    tel.registry.counter("service_queries", service="local").inc(3)
    srv = tel.serve(port=0, journal=jr)
    try:
        body = urllib.request.urlopen(srv.url, timeout=10).read().decode()
    finally:
        srv.close()
        jr.close()
    assert validate_openmetrics(body) == []
    assert "journal_depth 1" in body
    assert "journal_ops_logged_total 3" in body
    assert 'service_queries_total{service="local"} 3' in body
    # a closed server refuses further scrapes (no dangling daemon port)
    tel.close()


def test_expo_cli_one_shot(tmp_path, capsys):
    """The offline twin: rebuild the exposition from trace JSONL and pass
    the same validator CI scrapes through."""
    from repro.obs import expo

    path = tmp_path / "t.jsonl"
    tr = Tracer(str(path))
    for mode, dev in (("full", 500.0), ("delta", 50.0), ("unchanged", 0.0)):
        with tr.span("query", service="local", kind="bfs", version=1,
                     mode=mode, coll_bytes=0, degraded=False,
                     device_us=dev, flops=1000.0):
            pass
    with tr.span("query", service="local", kind="bfs", error="Boom"):
        pass
    tr.close()
    assert expo.main([str(path), "--check"]) == 0
    out = capsys.readouterr().out
    assert "query_wall_us" in out and "query_device_us" in out
    assert 'service_errors_total{service="local"} 1' in out


# --------------------------- trace sink rotation ----------------------------

def test_trace_sink_rotation(tmp_path):
    """S1: a bounded JSONL sink rotates ``t.jsonl`` -> ``.1`` -> ``.2``
    (oldest dropped at ``keep``), counts rotations, keeps every record
    across the rotated set, and never interleaves a torn line."""
    import os

    path = tmp_path / "t.jsonl"
    tr = Tracer(str(path), max_bytes=2000, keep=2)
    n = 120
    for i in range(n):
        with tr.span("query", idx=i, pad="x" * 40):
            pass
    tr.close()
    assert tr.rotations > 1
    files = [str(path)] + [f"{path}.{i}" for i in (1, 2)]
    for f in files:
        assert os.path.exists(f), f
        assert os.path.getsize(f) <= 2000 + 200  # one record of slack
    assert not os.path.exists(f"{path}.3")       # keep=2 drops the rest
    survivors = []
    for f in files:
        for line in open(f):
            survivors.append(json.loads(line))   # no torn lines
    kept_idx = sorted(r["idx"] for r in survivors)
    # the newest records always survive; only the oldest rotated out
    assert kept_idx == list(range(n - len(kept_idx), n))
    # in-memory list saw everything regardless
    assert len(tr.records) == n and tr.sink_errors == 0


def test_trace_rotation_failure_keeps_stream(tmp_path, monkeypatch):
    """A failing rename must not kill the sink: the tracer reopens and
    keeps writing (best-effort telemetry, the WAL lesson)."""
    import os

    path = tmp_path / "t.jsonl"
    tr = Tracer(str(path), max_bytes=500, keep=2)

    real_replace = os.replace

    def boom(src, dst):
        raise OSError("disk says no")

    monkeypatch.setattr(os, "replace", boom)
    for i in range(40):
        with tr.span("query", idx=i, pad="y" * 40):
            pass
    assert tr.rotations == 0          # every rename failed...
    assert tr.sink_errors == 0        # ...yet no record was lost:
    lines = [json.loads(l) for l in open(path)]
    assert [r["idx"] for r in lines] == list(range(40))  # all appended
    monkeypatch.setattr(os, "replace", real_replace)
    with tr.span("query", idx=99):
        pass                          # oversized file: now rotates for real
    tr.close()
    assert tr.rotations == 1
    assert [json.loads(l)["idx"] for l in open(path)] == [99]
    assert json.loads(open(f"{path}.1").readlines()[-1])["idx"] == 39


# ------------------------------ report (PR 8) -------------------------------

def test_report_multi_file_and_json_format(tmp_path, capsys):
    """S2: rotated trace siblings merge (sorted by span id), ``--format
    json`` emits machine-readable rows, and the summary carries the
    device-time column."""
    p1, p2 = tmp_path / "t.jsonl.1", tmp_path / "t.jsonl"
    tr = Tracer(str(p1))
    common = dict(service="local", kind="bfs", version=1, coll_bytes=0,
                  degraded=False, flops=10.0)
    with tr.span("query", mode="full", device_us=400.0, **common):
        pass
    tr.close()
    tr2 = Tracer(str(p2))
    tr2._next_id = 50                  # rotated continuation: later ids
    with tr2.span("query", mode="delta", device_us=40.0, **common):
        pass
    tr2.close()

    records = report.load_many([str(p2), str(p1)])  # any order in
    assert [r["mode"] for r in records] == ["full", "delta"]  # id-sorted
    assert report.validate(records) == []
    rows = report.summarize(records)
    assert {r["mode"] for r in rows} == {"full", "delta"}
    by_mode = {r["mode"]: r for r in rows}
    assert by_mode["full"]["device_p50_us"] == 400.0
    assert by_mode["delta"]["device_p50_us"] == 40.0

    assert report.main([str(p2), str(p1), "--format", "json",
                        "--check"]) == 0
    out = capsys.readouterr().out
    data = json.loads(out[:out.rindex("]") + 1])
    assert len(data) == 2 and {r["mode"] for r in data} == {"full", "delta"}


def test_report_error_span_exemption():
    """Error-terminated query records stay exempt from the field check
    but are counted in the summary's errors column."""
    recs = [
        {"schema": TRACE_SCHEMA, "span": "query", "id": 0, "wall_us": 5.0,
         "service": "local", "kind": "bfs", "error": "Boom"},
        {"schema": TRACE_SCHEMA, "span": "query", "id": 1, "wall_us": 9.0,
         "service": "local", "kind": "bfs", "version": 1, "mode": "full",
         "coll_bytes": 0, "degraded": False, "device_us": 1.0,
         "flops": 2.0},
    ]
    assert report.validate(recs) == []
    rows = report.summarize(recs)
    err_row = next(r for r in rows if r["errors"])
    assert err_row["errors"] == 1


# --------------------------- device-time profiler ---------------------------

def test_device_timer_measures_and_accumulates():
    from repro.obs.profile import DeviceTimer, NullDeviceTimer

    t = DeviceTimer()
    x = jnp.arange(1024.0)
    y = jnp.dot(x, x)
    us = t.measure(y, name="dot")
    assert us >= 0.0 and t.measures == 1 and t.total_us == us
    t.measure(None, name="empty")          # nothing to block: fine
    assert t.measures == 2

    n = NullDeviceTimer()
    assert n.measure(y, name="dot") == 0.0
    assert not n.blocking and t.blocking


# ------------------------- adaptive thresholds ------------------------------

def _drive(ctl, kind, *, full_us, delta):
    """Feed synthetic observations: ``delta`` is (frac, wall_us) pairs."""
    for w in full_us:
        ctl.observe(kind, "full", w, None)
    for f, w in delta:
        ctl.observe(kind, "delta", w, f)


def test_adaptive_fits_crossover_and_steps():
    from repro.obs import AdaptiveThresholds

    ctl = AdaptiveThresholds(base=0.25, lo=0.02, hi=0.75, alpha=1.0,
                             period=8, min_full=2, min_delta=4,
                             probe_every=0)
    # delta cost = 100 + 1000*frac us; full cost = 600 us -> crossover 0.5
    _drive(ctl, "bfs", full_us=[600.0] * 3,
           delta=[(f, 100.0 + 1000.0 * f)
                  for f in (0.1, 0.2, 0.3, 0.4, 0.5)])
    thr = ctl.thresholds()["bfs"]
    assert abs(thr - 0.5) < 1e-6, thr
    assert ctl.adjustments == 1
    # other kinds untouched
    assert ctl.thresholds()["sssp"] == 0.25


def test_adaptive_clamps_and_damping():
    from repro.obs import AdaptiveThresholds

    # crossover far above hi -> clamp at hi even with alpha=1
    ctl = AdaptiveThresholds(base=0.25, lo=0.05, hi=0.4, alpha=1.0,
                             period=6, min_full=1, min_delta=3,
                             probe_every=0)
    _drive(ctl, "bfs", full_us=[10000.0] * 2,
           delta=[(f, 10.0 + 100.0 * f) for f in (0.1, 0.2, 0.3, 0.4)])
    assert ctl.thresholds()["bfs"] == 0.4
    # alpha damps the step: halfway to the target
    ctl2 = AdaptiveThresholds(base=0.25, lo=0.02, hi=0.75, alpha=0.5,
                              period=8, min_full=1, min_delta=4,
                              probe_every=0)
    _drive(ctl2, "bfs", full_us=[600.0] * 3,
           delta=[(f, 100.0 + 1000.0 * f)
                  for f in (0.1, 0.2, 0.3, 0.4, 0.5)])
    assert abs(ctl2.thresholds()["bfs"] - 0.375) < 1e-6  # 0.25 + 0.5*0.25


def test_adaptive_no_movement_without_signal():
    from repro.obs import AdaptiveThresholds

    ctl = AdaptiveThresholds(period=4, min_full=1, min_delta=2,
                             probe_every=0)
    # degenerate fit: every delta at the same fraction -> no movement
    _drive(ctl, "bfs", full_us=[500.0] * 2,
           delta=[(0.2, 100.0), (0.2, 120.0), (0.2, 90.0)])
    assert ctl.thresholds()["bfs"] == ctl.base["bfs"] and ctl.adjustments == 0
    # negative slope (delta CHEAPER when dirtier - noise): no movement
    _drive(ctl, "sssp", full_us=[500.0] * 2,
           delta=[(0.1, 300.0), (0.3, 200.0), (0.5, 100.0)])
    assert ctl.thresholds()["sssp"] == ctl.base["sssp"]
    # unchanged observations carry no crossover signal at all
    for _ in range(64):
        ctl.observe("bc", "unchanged", 1.0, None)
    assert ctl.adjustments == 0


def test_adaptive_probe_cadence():
    from repro.obs import AdaptiveThresholds

    ctl = AdaptiveThresholds(probe_every=4)
    got = [ctl.threshold("bfs") for _ in range(12)]
    assert got.count(0.0) == 3 and ctl.probes == 3
    assert all(t == ctl.base["bfs"] for t in got if t != 0.0)
    # probing disabled
    ctl2 = AdaptiveThresholds(probe_every=0)
    assert all(ctl2.threshold("bfs") != 0.0 for _ in range(20))
    # unknown kind: static base, never probed
    assert ctl.threshold("nope") == 0.25   # static fallback


def test_adaptive_emits_spans_and_gauges():
    from repro.obs import AdaptiveThresholds

    reg, tr = MetricsRegistry(), Tracer()
    ctl = AdaptiveThresholds(alpha=1.0, period=8, min_full=1, min_delta=4,
                             probe_every=0).bind(reg, tr, "local")
    assert reg.gauge("adaptive_dirty_threshold", service="local",
                     kind="bfs").value == ctl.base["bfs"]
    _drive(ctl, "bfs", full_us=[600.0] * 3,
           delta=[(f, 100.0 + 1000.0 * f)
                  for f in (0.1, 0.2, 0.3, 0.4, 0.5)])
    assert ctl.adjustments == 1
    assert reg.gauge("adaptive_dirty_threshold", service="local",
                     kind="bfs").value == ctl.thresholds()["bfs"]
    assert reg.counter("adaptive_adjustments", service="local",
                       kind="bfs").value == 1
    adj = [r for r in tr.records if r["span"] == "threshold_adjust"]
    assert len(adj) == 1
    r = adj[0]
    assert r["old"] == 0.25 and abs(r["new"] - 0.5) < 1e-6
    assert r["t_full_us"] == 600.0 and r["n_full"] == 3 and r["n_delta"] == 5
    assert not r["clamped"]


def test_adaptive_validation_and_telemetry_requirement():
    import pytest

    from repro.core import make_graph
    from repro.engine import GraphService
    from repro.obs import AdaptiveThresholds

    with pytest.raises(ValueError):
        AdaptiveThresholds(lo=0.5, base=0.25)   # lo > base
    with pytest.raises(ValueError):
        AdaptiveThresholds(alpha=0.0)
    with pytest.raises(ValueError):
        GraphService(make_graph(8, 16), adaptive=True)  # needs telemetry
