"""Unit tests for the telemetry subsystem (``repro.obs``).

The integration path — a traced service stream asserting conservation and
oracle agreement — lives in ``test_stream_differential``; here the
instruments themselves are pinned: registry identity semantics, quantile
math, the attribute shims the legacy stats objects became, span nesting /
annotation, JSONL export through the ``repro.obs.report`` gate, and the
HLO cost accountant's compile-once cache.
"""
import json
import math

import jax
import jax.numpy as jnp

from repro.obs import (
    CounterStruct,
    HLOCostAccountant,
    MetricsRegistry,
    ModeCounters,
    Telemetry,
    Tracer,
    report,
)
from repro.obs.metrics import quantile
from repro.obs.trace import TRACE_SCHEMA, annotate, maybe_span


# ------------------------------- metrics -----------------------------------

def test_registry_get_or_create_identity():
    reg = MetricsRegistry()
    a = reg.counter("hits", service="local")
    b = reg.counter("hits", service="local")
    c = reg.counter("hits", service="sharded")
    assert a is b and a is not c
    a.inc(3)
    assert b.value == 3 and c.value == 0
    # same name, different instrument kind -> distinct
    h = reg.histogram("hits")
    assert h is not a


def test_registry_find_and_merged_quantiles():
    reg = MetricsRegistry()
    for mode, vals in (("delta", [1, 2, 3]), ("full", [10, 20, 30])):
        h = reg.histogram("wall", service="local", mode=mode)
        for v in vals:
            h.observe(v)
    assert len(reg.find("wall", service="local")) == 2
    assert reg.find("wall", mode="delta")[0].count == 3
    pooled = reg.merged_quantiles("wall", (0.0, 0.5, 1.0), service="local")
    assert pooled[0.0] == 1 and pooled[1.0] == 30
    assert math.isnan(reg.merged_quantiles("absent", (0.5,))[0.5])


def test_quantile_nearest_rank():
    s = list(range(1, 101))
    assert quantile(s, 0.5) == 51  # nearest rank on 0..99 index space
    assert quantile(s, 0.0) == 1
    assert quantile(s, 1.0) == 100
    assert math.isnan(quantile([], 0.5))


def test_histogram_reservoir_bounded():
    reg = MetricsRegistry()
    h = reg.histogram("w")
    h._samples = type(h._samples)(maxlen=4)
    for v in range(10):
        h.observe(v)
    assert h.count == 10 and h.total == sum(range(10))
    assert h.samples == [6, 7, 8, 9]


def test_counter_struct_shim():
    class S(CounterStruct):
        _FIELDS = ("a", "b")
        _PREFIX = "test_"

    reg = MetricsRegistry()
    s = S(reg, service="x")
    s.a += 2
    s.a += 1
    s.b = 7
    assert (s.a, s.b) == (3, 7)
    assert s.as_dict() == {"a": 3, "b": 7}
    # the values ARE registry counters, shared by key
    assert reg.counter("test_a", service="x").value == 3
    # private registry when none is given
    s2 = S()
    s2.a += 1
    assert s2.a == 1 and reg.counter("test_a", service="x").value == 3


def test_mode_counters_mapping():
    reg = MetricsRegistry()
    d = ModeCounters(reg, "bcq", service="local")
    d["delta"] += 2
    d["full"] = 5
    assert dict(d) == {"unchanged": 0, "delta": 2, "full": 5}
    assert reg.counter("bcq", mode="delta", service="local").value == 2


# -------------------------------- tracing ----------------------------------

def test_tracer_nesting_and_annotate():
    tr = Tracer()
    with tr.span("query", kind="bfs") as q:
        with tr.span("collect") as c:
            annotate(dirty=4)  # lands on the innermost span
        q.set(mode="delta")
    annotate(ignored=1)  # no active span: silently dropped
    child, parent = tr.records  # children exit (emit) first
    assert parent["span"] == "query" and parent["parent"] is None
    assert child["span"] == "collect" and child["parent"] == parent["id"]
    assert child["dirty"] == 4 and "ignored" not in parent
    assert parent["mode"] == "delta" and parent["wall_us"] >= 0


def test_maybe_span_null_path():
    with maybe_span(None, "query", kind="bfs") as sp:
        sp.set(mode="full")  # must not raise
        annotate(dirty=1)    # no tracer: no-op
    assert sp.id is None


def test_tracer_jsonl_and_report_gate(tmp_path):
    path = tmp_path / "t.jsonl"
    tr = Tracer(str(path))
    for mode in ("unchanged", "delta", "full"):
        with tr.span("query", service="local", kind="bfs", version=1,
                     mode=mode, coll_bytes=0, degraded=False):
            pass
    tr.close()
    records = report.load(str(path))
    assert [r["schema"] for r in records] == [TRACE_SCHEMA] * 3
    assert report.validate(
        records, require_modes=("unchanged", "delta", "full")) == []
    rows = report.summarize(records)
    assert {r["mode"] for r in rows} == {"unchanged", "delta", "full"}
    assert report.main([str(path), "--check",
                        "--require-modes", "unchanged,delta,full"]) == 0
    # missing mode and missing fields both trip the gate
    assert report.validate(records, require_modes=("nope",)) != []
    bad = [dict(r, **{"span": "query"}) for r in records]
    del bad[0]["version"]
    assert any("missing" in e for e in report.validate(bad))
    assert report.main([str(path), "--require-modes", "nope"]) == 1


# ---------------------------- HLO accounting --------------------------------

def test_hlo_accountant_caches_compiles():
    acct = HLOCostAccountant(shared=False)
    compiles = []

    def compile_fn():
        compiles.append(1)
        return jax.jit(lambda x: x * 2 + 1).lower(
            jnp.zeros((8,), jnp.float32)).compile()

    c1 = acct.account(("k", 1), compile_fn)
    c2 = acct.account(("k", 1), compile_fn)
    assert len(compiles) == 1 and c1 is c2 and acct.last is c2
    for f in ("collective_bytes", "temp_bytes", "flops"):
        assert f in c1
    assert acct.account(("k", 2), compile_fn) is not c1
    assert len(compiles) == 2
    assert len(acct.snapshot()) == 2


def test_hlo_accountant_shared_cache():
    a, b = HLOCostAccountant(), HLOCostAccountant()
    n0 = len(a.snapshot())
    a.account(("shared-probe", n0), lambda: jax.jit(lambda x: x + 1).lower(
        jnp.zeros((4,), jnp.float32)).compile())
    assert b.account(("shared-probe", n0), lambda: (_ for _ in ()).throw(
        AssertionError("cache miss"))) is a.last


# ----------------------------- service glue ---------------------------------

def test_local_service_trace_schema(tmp_path):
    from repro.core import PUTE, PUTV, make_graph
    from repro.engine import GraphService

    path = tmp_path / "svc.jsonl"
    tel = Telemetry.make(str(path), hlo=False)
    svc = GraphService(make_graph(16, 64), batch_size=4, telemetry=tel)
    for i in range(6):
        svc.submit((PUTV, i))
    for u, v in ((0, 1), (1, 2), (2, 3)):
        svc.submit((PUTE, u, v, 1.0))
    svc.flush()
    svc.query("bfs", 0)
    svc.query("bfs", 0)
    svc.submit((PUTE, 3, 4, 1.0))
    svc.flush()
    svc.query("bfs", 0)
    tel.close()

    records = [json.loads(line) for line in open(path)]
    qrecs = [r for r in records if r["span"] == "query"]
    assert len(qrecs) == svc.stats.queries == 3
    for r in qrecs:
        for f in report.QUERY_FIELDS:
            assert f in r, f
        assert r["service"] == "local"
    assert [r["mode"] for r in qrecs] == ["full", "unchanged", "delta"]
    # commits and collects traced too, collects nested under their query
    spans = {r["span"] for r in records}
    assert {"commit", "collect", "query"} <= spans
    collect = next(r for r in records if r["span"] == "collect")
    assert any(r["id"] == collect["parent"] for r in qrecs)
    # the latency histogram the benches read is fed once per query
    hist = tel.registry.find("query_wall_us", service="local")
    assert sum(h.count for h in hist) == 3
