"""ADT semantics of the batched update engine vs the sequential oracle."""
import numpy as np
import pytest

from repro.core import (
    GETE, GETV, PUTE, PUTV, REME, REMV, NOKEY,
    apply_ops, compact, get_e, get_v, make_graph, num_edges, num_vertices,
)
from oracle import GraphOracle


def apply_and_check(g, oracle, ops):
    """Apply ops both ways; compare per-op return values."""
    g, res = apply_ops(g, ops)
    ok = np.asarray(res.ok)
    val = np.asarray(res.val)
    for i, op in enumerate(ops):
        kind = op[0]
        if kind == PUTV:
            exp = oracle.put_v(op[1])
            assert ok[i] == exp, (i, op)
        elif kind == REMV:
            exp = oracle.rem_v(op[1])
            assert ok[i] == exp, (i, op)
        elif kind == PUTE:
            e_ok, e_val = oracle.put_e(op[1], op[2], op[3])
            assert ok[i] == e_ok, (i, op)
            assert val[i] == pytest.approx(e_val), (i, op)
        elif kind == REME:
            e_ok, e_val = oracle.rem_e(op[1], op[2])
            assert ok[i] == e_ok, (i, op)
            assert val[i] == pytest.approx(e_val), (i, op)
    return g


def test_vertex_ops_basic():
    g = make_graph(16, 16)
    o = GraphOracle()
    g = apply_and_check(g, o, [(PUTV, 1), (PUTV, 2), (PUTV, 1), (REMV, 3),
                               (REMV, 1)])
    assert bool(get_v(g, 2))
    assert not bool(get_v(g, 1))
    assert int(num_vertices(g)) == 1


def test_edge_ops_full_adt():
    g = make_graph(16, 32)
    o = GraphOracle()
    g = apply_and_check(g, o, [(PUTV, 0), (PUTV, 1), (PUTV, 2)])
    # 4a add-new, 4b replace, 4c same-weight, 4d missing vertex
    g = apply_and_check(g, o, [
        (PUTE, 0, 1, 2.0),     # (True, inf)
        (PUTE, 0, 1, 2.0),     # (False, 2.0) same weight
        (PUTE, 0, 1, 5.0),     # (True, 2.0)  replace
        (PUTE, 0, 9, 1.0),     # (False, inf) vertex missing
        (REME, 0, 1),          # (True, 5.0)
        (REME, 0, 1),          # (False, inf)
        (REME, 1, 2),          # (False, inf) never existed
    ])
    ok, w = get_e(g, 0, 1)
    assert not bool(ok)


def test_remv_clears_incident_edges():
    g = make_graph(8, 16)
    o = GraphOracle()
    g = apply_and_check(g, o, [(PUTV, 0), (PUTV, 1), (PUTV, 2),
                               (PUTE, 0, 1, 1.0), (PUTE, 1, 2, 1.0),
                               (PUTE, 2, 0, 1.0)])
    g = apply_and_check(g, o, [(REMV, 1)])
    # re-adding 1 must give a fresh (empty) edge list, as in the paper
    g = apply_and_check(g, o, [(PUTV, 1)])
    ok, _ = get_e(g, 0, 1)
    assert not bool(ok)
    ok, _ = get_e(g, 2, 0)
    assert bool(ok)
    assert int(num_edges(g)) == 1


def test_intra_batch_chains():
    g = make_graph(8, 16)
    o = GraphOracle()
    g = apply_and_check(g, o, [(PUTV, 0), (PUTV, 1)])
    # put/rem/put same edge inside one batch: sequential semantics
    g = apply_and_check(g, o, [
        (PUTE, 0, 1, 1.0), (REME, 0, 1), (PUTE, 0, 1, 3.0),
        (PUTE, 0, 1, 3.0), (REME, 0, 1), (REME, 0, 1),
    ])
    ok, _ = get_e(g, 0, 1)
    assert not bool(ok)


def test_ecnt_bumps_on_out_edge_mutations():
    g = make_graph(8, 16)
    g, _ = apply_ops(g, [(PUTV, 0), (PUTV, 1)])
    e0 = int(np.asarray(g.ecnt)[0])
    g, _ = apply_ops(g, [(PUTE, 0, 1, 1.0)])
    g, _ = apply_ops(g, [(PUTE, 0, 1, 2.0)])   # weight update bumps
    g, _ = apply_ops(g, [(PUTE, 0, 1, 2.0)])   # same weight: NO bump
    g, _ = apply_ops(g, [(REME, 0, 1)])
    assert int(np.asarray(g.ecnt)[0]) == e0 + 3


def test_overflow_grow_and_compact():
    g = make_graph(8, 4)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(7)])
    g, res = apply_ops(g, [(PUTE, 0, i, 1.0) for i in range(1, 7)])
    assert all(np.asarray(res.ok))
    assert int(num_edges(g)) == 6
    g, _ = apply_ops(g, [(REME, 0, 1), (REME, 0, 2)])
    g = compact(g)
    assert int(num_edges(g)) == 4
    used = int((np.asarray(g.esrc) != NOKEY).sum())
    assert used == 4


def test_version_bumps_per_batch():
    g = make_graph(8, 8)
    v0 = int(g.version)
    g, _ = apply_ops(g, [(PUTV, 0)])
    g, _ = apply_ops(g, [(PUTV, 1)])
    assert int(g.version) == v0 + 2


def test_gets_linearize_at_batch_end():
    g = make_graph(8, 8)
    g, res = apply_ops(g, [(PUTV, 0), (GETV, 0), (REMV, 0), (GETV, 0)])
    ok = np.asarray(res.ok)
    # both GETVs see the post-batch state (0 removed)
    assert not ok[1] and not ok[3]
