"""Batched Brandes vs per-source bc_dependencies: RMAT graphs, tombstoned
edges, dead vertices, dead sources, and the tile-skipping kernel path."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    PUTE, PUTV, REME, REMV,
    apply_ops, bc, bc_batched_dense, bc_dependencies, bc_map,
    build_tile_view, dense_views, make_graph,
)
from repro.core.tiles import dense_views_from_tiles
from repro.data import load_rmat_graph


def _check_against_per_source(state, srcs, **kw):
    am, _, alive = dense_views(state)
    delta, sigma, level, ok = bc_batched_dense(
        am, jnp.asarray(srcs, jnp.int32), alive, **kw)
    for i, s in enumerate(srcs):
        r = bc_dependencies(state, s)
        assert bool(ok[i]) == bool(r.ok), s
        # levels and sigma are integer-valued: bit-exact
        assert np.array_equal(np.asarray(level[i]), np.asarray(r.level)), s
        assert np.array_equal(np.asarray(sigma[i]), np.asarray(r.sigma)), s
        # delta agrees up to float summation order (scatter-add vs MXU dot)
        assert np.allclose(np.asarray(delta[i]), np.asarray(r.delta),
                           rtol=1e-5, atol=1e-5), s


@pytest.mark.parametrize("seed", range(3))
def test_bc_batched_matches_per_source_rmat(seed):
    g = load_rmat_graph(64, 400, seed=seed, weighted=False)
    _check_against_per_source(g, [0, 3, 17, 40, 63])


def test_bc_batched_with_tombstones_and_dead_vertices():
    rng = np.random.default_rng(11)
    n = 40
    g = make_graph(64, 512)
    ops = [(PUTV, i) for i in range(n)]
    ops += [(PUTE, int(rng.integers(0, n)), int(rng.integers(0, n)), 1.0)
            for _ in range(160)]
    g, _ = apply_ops(g, ops)
    # tombstone some edges, kill some vertices (their incident edges die too)
    from repro.core.graph_state import live_edge_mask
    live = np.flatnonzero(np.asarray(live_edge_mask(g)))[:3]
    rems = [(REME, int(np.asarray(g.esrc)[i]), int(np.asarray(g.edst)[i]))
            for i in live]
    g, _ = apply_ops(g, rems + [(REMV, 7), (REMV, 23)])
    from repro.core.graph_state import NOKEY
    occupied = np.asarray(g.esrc) != NOKEY
    assert (occupied & np.isinf(np.asarray(g.ew))).sum() > 0  # real tombstones
    srcs = [0, 5, 7, 23, 39]  # includes the two dead sources
    _check_against_per_source(g, srcs)
    am, _, alive = dense_views(g)
    _, _, _, ok = bc_batched_dense(am, jnp.asarray(srcs, jnp.int32), alive)
    assert not bool(ok[2]) and not bool(ok[3])  # dead sources report !ok


@pytest.mark.parametrize("chunk", [1, 24, 64, 200])
def test_bc_batched_src_chunk_matches_unchunked(chunk):
    """Source-axis chunking (ragged tail included) changes peak scratch,
    not results: levels/sigma/ok bit-exact, delta to summation order."""
    g = load_rmat_graph(64, 400, seed=7, weighted=False)
    g, _ = apply_ops(g, [(REMV, 9)])
    am, _, alive = dense_views(g)
    srcs = jnp.arange(64, dtype=jnp.int32)
    base = bc_batched_dense(am, srcs, alive)
    got = bc_batched_dense(am, srcs, alive, src_chunk=chunk)
    assert np.array_equal(np.asarray(base[2]), np.asarray(got[2]))  # level
    assert np.array_equal(np.asarray(base[1]), np.asarray(got[1]))  # sigma
    assert np.array_equal(np.asarray(base[3]), np.asarray(got[3]))  # ok
    assert np.allclose(np.asarray(base[0]), np.asarray(got[0]),
                       rtol=1e-5, atol=1e-5)                        # delta


def test_bc_wrapper_src_chunk():
    g = load_rmat_graph(32, 160, seed=6, weighted=False)
    ref = float(bc(g, 9))
    assert float(bc(g, 9, src_chunk=10)) == pytest.approx(ref, rel=1e-4)
    am, _, alive = dense_views(g)
    with pytest.raises(ValueError):
        bc_batched_dense(am, jnp.arange(32, dtype=jnp.int32), alive,
                         src_chunk=0)


def test_bc_batched_out_of_range_sources():
    g = make_graph(16, 32)
    g, _ = apply_ops(g, [(PUTV, 0), (PUTV, 1), (PUTE, 0, 1, 1.0)])
    am, _, alive = dense_views(g)
    delta, _, _, ok = bc_batched_dense(
        am, jnp.asarray([-1, 0, 99], jnp.int32), alive)
    assert not bool(ok[0]) and bool(ok[1]) and not bool(ok[2])
    assert np.all(np.asarray(delta[0]) == 0)


def test_bc_batched_kernel_and_tile_mask_match_jnp():
    g = load_rmat_graph(64, 300, seed=4, weighted=False)
    view = build_tile_view(g, tile=16)
    am, _, alive = dense_views_from_tiles(g, view)
    srcs = jnp.arange(64, dtype=jnp.int32)
    base = bc_batched_dense(am, srcs, alive)
    masked = bc_batched_dense(am, srcs, alive, amask=view.occ, tile=16)
    kernel = bc_batched_dense(am, srcs, alive, use_kernel=True,
                              amask=view.occ, tile=16)
    for got in (masked, kernel):
        assert np.array_equal(np.asarray(base[2]), np.asarray(got[2]))  # level
        assert np.array_equal(np.asarray(base[1]), np.asarray(got[1]))  # sigma
        assert np.allclose(np.asarray(base[0]), np.asarray(got[0]),
                           rtol=1e-5, atol=1e-5)                        # delta
        assert np.array_equal(np.asarray(base[3]), np.asarray(got[3]))  # ok


def test_bc_wrapper_batched_equals_map():
    g = load_rmat_graph(32, 160, seed=6, weighted=False)
    for v in (0, 9, 31):
        ref = float(bc(g, v, method="map"))
        got = float(bc(g, v))
        if np.isnan(ref):
            assert np.isnan(got)
        else:
            assert got == pytest.approx(ref, rel=1e-4, abs=1e-4)
    with pytest.raises(ValueError):
        bc(g, 0, method="nope")


def test_bc_wrapper_dead_target_is_nan():
    g = make_graph(8, 16)
    g, _ = apply_ops(g, [(PUTV, 0), (PUTV, 1), (PUTE, 0, 1, 1.0), (REMV, 1)])
    assert np.isnan(float(bc(g, 1)))
    assert np.isnan(float(bc(g, 1, method="map")))


def test_bc_map_is_the_old_lax_map_baseline():
    g = make_graph(8, 16)
    g, _ = apply_ops(g, [(PUTV, 0), (PUTV, 1), (PUTV, 2),
                         (PUTE, 0, 1, 1.0), (PUTE, 1, 2, 1.0)])
    val = bc_map(g, 1, jnp.arange(3, dtype=jnp.int32))
    assert float(val) == pytest.approx(1.0)


def test_bc_batched_warm_start_bit_identical_to_cold():
    """The level-cut warm start (prior_level/prior_sigma/cut) reproduces the
    cold sweep bit-exactly on every source — including cut-0 rows (suspect
    sources restarting cold), untouched rows (pure tree reuse), dead
    vertices, and the chunked source axis."""
    from repro.core.queries import bc_level_cut
    from repro.core.updates import dirty_vertices

    g = load_rmat_graph(64, 400, seed=3, weighted=False)
    srcs = jnp.arange(64, dtype=jnp.int32)
    am, _, alive = dense_views(g)
    d0, s0, l0, _ = bc_batched_dense(am, srcs, alive)
    g2, _ = apply_ops(g, [(REMV, 13), (PUTE, 40, 2, 1.0),
                          (REME, 21, int(g.edst[100]))])
    dirty = dirty_vertices(g, g2)
    am2, _, alive2 = dense_views(g2)
    cut = bc_level_cut(l0, dirty, g2.alive)
    assert int(jnp.min(cut)) == 0  # the dirty sources themselves restart
    cold = bc_batched_dense(am2, srcs, alive2)
    for chunk in (None, 5):
        warm = bc_batched_dense(am2, srcs, alive2, src_chunk=chunk,
                                prior_level=l0, prior_sigma=s0, cut=cut)
        for a, b in zip(warm, cold):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        bc_batched_dense(am2, srcs, alive2, prior_level=l0)


def test_bc_batched_warm_start_revived_source_restarts_cold():
    """A source dead at prior time and alive now has an empty prior tree
    that no dirty vertex intersects; the warm start must force its cut to
    0 (cold restart) rather than reuse the empty row."""
    from repro.core.queries import bc_level_cut
    from repro.core.updates import dirty_vertices

    g = make_graph(16, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(8)]
                     + [(PUTE, 0, 1, 1.0), (PUTE, 1, 2, 1.0), (REMV, 5)])
    srcs = jnp.asarray([0, 5], jnp.int32)
    am, _, alive = dense_views(g)
    d0, s0, l0, ok0 = bc_batched_dense(am, srcs, alive)
    assert not bool(ok0[1])
    g2, _ = apply_ops(g, [(PUTV, 5), (PUTE, 5, 1, 1.0)])
    am2, _, alive2 = dense_views(g2)
    cut = bc_level_cut(l0, dirty_vertices(g, g2), g2.alive)
    warm = bc_batched_dense(am2, srcs, alive2, prior_level=l0,
                            prior_sigma=s0, cut=cut)
    cold = bc_batched_dense(am2, srcs, alive2)
    for a, b in zip(warm, cold):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert bool(warm[3][1]) and int(warm[2][1, 5]) == 0  # row restarted
