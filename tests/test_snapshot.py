"""The PANIGRAHAM snapshot protocol: double-collect validation + linearizability.

The system test at the bottom is the paper's correctness claim verified
operationally: every PG-Cn query result must equal the sequential-oracle
result at SOME committed version within the query's execution window.
"""
import numpy as np
import pytest

from repro.core import (
    PUTE, PUTV, REME, REMV, StateRef, apply_ops, cmp_tree, collect_bfs,
    collect_sssp, make_graph, op_inconsistent, op_linearizable,
)
from oracle import GraphOracle

INF = float("inf")


def base_graph():
    g = make_graph(16, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(6)]
                     + [(PUTE, 0, 1, 1.0), (PUTE, 1, 2, 1.0),
                        (PUTE, 2, 3, 1.0), (PUTE, 0, 4, 5.0),
                        (PUTE, 4, 3, 1.0)])
    return g


def test_stable_state_validates_in_two_collects():
    ref = StateRef(base_graph())
    for q in ("bfs", "sssp", "bc"):
        res, stats = op_linearizable(ref, q, 0)
        assert res is not None
        assert stats.collects == 2
        assert stats.validated


def test_dead_source_returns_null():
    g = base_graph()
    g, _ = apply_ops(g, [(REMV, 0)])
    res, stats = op_linearizable(StateRef(g), "bfs", 0)
    assert res is None


def test_cmp_tree_detects_path_change():
    g = base_graph()
    c1 = collect_bfs(g, 0)
    g2, _ = apply_ops(g, [(PUTE, 0, 3, 1.0)])       # new path into region
    c2 = collect_bfs(g2, 0)
    assert not bool(cmp_tree(c1, c2))


def test_cmp_tree_detects_remove_then_readd():
    """The ABA case ecnt exists for: same structure, bumped counter."""
    g = base_graph()
    c1 = collect_bfs(g, 0)
    g2, _ = apply_ops(g, [(REME, 0, 1)])
    g3, _ = apply_ops(g2, [(PUTE, 0, 1, 1.0)])      # back to same shape
    c3 = collect_bfs(g3, 0)
    assert np.array_equal(np.asarray(c1.reached), np.asarray(c3.reached))
    assert not bool(cmp_tree(c1, c3))               # ecnt caught it


def test_update_outside_region_does_not_invalidate():
    """Snapshot selectivity: the paper's SNode/ecnt design means a mutation
    in an unreachable part of the graph must NOT force a retry."""
    g = base_graph()
    c1 = collect_bfs(g, 0)
    g2, _ = apply_ops(g, [(PUTE, 5, 4, 1.0)])       # 5 -> 4: 5 unreachable,
    # but it adds an IN-edge to reached vertex 4 and bumps ecnt[5] only.
    c2 = collect_bfs(g2, 0)
    assert bool(cmp_tree(c1, c2))


def test_retry_until_quiescent():
    g = base_graph()
    weights = iter([2.0, 3.0, 4.0])

    def interrupt(ref):
        w = next(weights, None)
        if w is not None:
            ns, _ = apply_ops(ref.state, [(PUTE, 0, 1, w)])
            ref.commit(ns)

    ref = StateRef(g, on_read=[interrupt])
    res, stats = op_linearizable(ref, "bfs", 0)
    # BFS structure unchanged by weight updates BUT ecnt bumps invalidate;
    # after the stream dries up, two consecutive collects match.
    assert stats.validated
    assert stats.collects >= 2
    assert stats.interrupting_updates >= 3


def test_pg_icn_never_retries():
    g = base_graph()

    def interrupt(ref):
        ns, _ = apply_ops(ref.state, [(PUTE, 0, 1, 9.0)])
        ref.commit(ns)

    ref = StateRef(g, on_read=[interrupt])
    res, stats = op_inconsistent(ref, "sssp", 0)
    assert res is not None
    assert stats.collects == 1


# ------------------------- linearizability system test --------------------

def _oracle_at(history):
    """Replay committed batches into oracles, one per version."""
    o = GraphOracle()
    versions = []
    for batch in history:
        for op in batch:
            if op[0] == PUTV:
                o.put_v(op[1])
            elif op[0] == REMV:
                o.rem_v(op[1])
            elif op[0] == PUTE:
                o.put_e(op[1], op[2], op[3])
            elif op[0] == REME:
                o.rem_e(op[1], op[2])
        snap = GraphOracle()
        snap.vertices = set(o.vertices)
        snap.edges = dict(o.edges)
        versions.append(snap)
    return versions


def test_linearizability_of_concurrent_queries():
    """PG-Cn results equal the oracle at SOME version inside the window."""
    rng = np.random.default_rng(0)
    n = 12
    g = make_graph(16, 256)
    init = [(PUTV, i) for i in range(n)] + \
        [(PUTE, int(u), int(v), float(rng.integers(1, 5)))
         for u, v in rng.integers(0, n, (30, 2)) if u != v]
    g, _ = apply_ops(g, init)

    history = [init]
    batches = []
    for _ in range(12):
        ops = []
        for _ in range(3):
            kind = rng.choice([PUTE, REME, PUTV, REMV],
                              p=[0.5, 0.3, 0.1, 0.1])
            u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
            if kind == PUTE and u != v:
                ops.append((PUTE, u, v, float(rng.integers(1, 5))))
            elif kind == REME and u != v:
                ops.append((REME, u, v))
            elif kind == PUTV:
                ops.append((PUTV, u))
            elif kind == REMV and u != 0:
                ops.append((REMV, u))
        batches.append(ops)

    it = iter(batches)

    def interrupt(ref):
        ops = next(it, None)
        if ops:
            ns, _ = apply_ops(ref.state, ops)
            ref.commit(ns)
            history.append(ops)

    ref = StateRef(g, on_read=[interrupt])

    for _ in range(6):
        start_version = len(history)
        res, stats = op_linearizable(ref, "bfs", 0, max_collects=128)
        end_version = len(history)
        assert stats.validated
        if res is None:
            continue
        dist = np.asarray(res.result.dist)
        versions = _oracle_at(history)
        window = versions[start_version - 1:end_version]
        matched = False
        for o in window:
            exp = o.bfs(0)
            got = {v: int(dist[v]) for v in range(n) if dist[v] >= 0}
            if exp is not None and got == exp:
                matched = True
                break
        assert matched, "query result matches no state in its window"


def test_jitted_pgcn_on_device_retry_loop():
    """Beyond-paper: the full OP (commits + collects + CMPTREE retries)
    inside one jit — results must match the host-loop protocol."""
    import jax
    import jax.numpy as jnp
    from repro.core.snapshot import op_linearizable_jit
    from repro.core.updates import make_batch

    g = base_graph()
    b1 = make_batch([(PUTE, 0, 5, 1.0)], size=4)
    b2 = make_batch([(REME, 0, 5)], size=4)
    b3 = make_batch([], size=4)
    batches = jax.tree.map(lambda *xs: jnp.stack(xs), b1, b2, b3)
    fn = jax.jit(op_linearizable_jit, static_argnames=("max_collects",))
    st, coll, n, ok = fn(g, batches, jnp.int32(0))
    assert bool(ok)
    assert int(n) >= 3               # two interrupting batches forced retries
    from repro.core import bfs
    ref = bfs(st, 0)
    assert np.array_equal(np.asarray(coll.result.dist), np.asarray(ref.dist))


def test_flash_attention_model_path_matches_xla():
    import dataclasses
    import jax
    from repro.configs import get_config, reduced
    from repro.models import get_model

    cfg = reduced(get_config("qwen3_32b"))
    m_x = get_model(cfg)
    m_f = get_model(dataclasses.replace(cfg, attn_impl="flash"))
    params = m_x.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 1,
                              cfg.vocab_size)
    lx = float(m_x.loss_fn(params, {"tokens": toks}))
    lf = float(m_f.loss_fn(params, {"tokens": toks}))
    assert abs(lx - lf) / lx < 2e-2
