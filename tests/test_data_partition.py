"""Data pipeline determinism + distributed graph queries + dryrun units.

The distributed-query tests run the tile-grid path (``core.partition``,
rebased onto ``repro.shard`` in PR 3) on a single-device graph mesh — the
shard_map programs are mesh-size-agnostic, and ``tests/test_shard.py``
covers the 4-way host-platform mesh in a subprocess.  The pre-PR-3
round-robin edge sharding stays exercised via ``core.partition_legacy``.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import PUTE, PUTV, apply_ops, bfs, make_graph, queries, sssp
from repro.core.partition import (
    SUPPORTED_KINDS,
    build_query_inputs,
    distributed_query_specs,
    make_distributed_query,
)
from repro.core.partition_legacy import (
    make_distributed_query as legacy_distributed_query,
    shard_edges,
)
from repro.data import SyntheticTokens
from repro.shard import as_graph_mesh


def _ring_graph():
    g = make_graph(16, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(8)]
                     + [(PUTE, i, (i + 1) % 8, float(i + 1))
                        for i in range(8)]
                     + [(PUTE, 0, 5, 1.0)])
    return g


def test_pipeline_determinism_across_restarts():
    ds1 = SyntheticTokens(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    ds2 = SyntheticTokens(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    b1 = ds1.batch_at(41)["tokens"]
    b2 = ds2.batch_at(41)["tokens"]
    assert np.array_equal(b1, b2)
    assert b1.shape == (4, 17)   # seq_len + 1 (inputs+targets)
    assert not np.array_equal(b1, ds1.batch_at(42)["tokens"])
    assert b1.min() >= 1 and b1.max() < 100


def test_distributed_query_equals_local():
    """The tile-grid distributed path vs the local COO fixed points."""
    g = _ring_graph()
    mesh = as_graph_mesh()
    fn, _, _ = make_distributed_query(mesh, "bfs", tile=16)
    ok, dist, val_ecnt, agree = fn(*build_query_inputs(g, mesh, 0, tile=16))
    ref = bfs(g, 0)
    assert np.array_equal(np.asarray(dist)[0, :16], np.asarray(ref.dist))
    assert bool(agree)
    fn2, _, _ = make_distributed_query(mesh, "sssp", tile=16)
    ok2, neg, dist2, _, _ = fn2(*build_query_inputs(g, mesh, 0, tile=16))
    ref2 = sssp(g, 0)
    assert np.allclose(np.asarray(dist2)[0, :16], np.asarray(ref2.dist))
    assert bool(neg[0]) == bool(ref2.negcycle)


def test_distributed_bc_kind():
    """The PR-3 ``"bc"`` kind: level/sigma bit-equal to the local batched
    Brandes, delta to float summation order."""
    g = _ring_graph()
    mesh = as_graph_mesh()
    srcs = jnp.arange(8, dtype=jnp.int32)
    fn, _, _ = make_distributed_query(mesh, "bc", tile=16, src_chunk=4)
    ok, delta, sigma, level, scores, val_ecnt, agree = fn(
        *build_query_inputs(g, mesh, srcs, tile=16))
    am, _, alive = queries.dense_views(g)
    dref, sref, lref, okref = queries.bc_batched_dense(am, srcs, alive,
                                                       src_chunk=4)
    assert np.array_equal(np.asarray(level)[:, :16], np.asarray(lref))
    assert np.array_equal(np.asarray(sigma)[:, :16], np.asarray(sref))
    assert np.allclose(np.asarray(delta)[:, :16], np.asarray(dref),
                       rtol=1e-5, atol=1e-5)
    assert bool(agree)


def test_make_distributed_query_rejects_unknown_kind():
    mesh = as_graph_mesh()
    with pytest.raises(ValueError) as ei:
        make_distributed_query(mesh, "cc")
    msg = str(ei.value)
    assert "cc" in msg and all(k in msg for k in SUPPORTED_KINDS)


def test_distributed_query_specs_shapes():
    mesh = as_graph_mesh()
    specs = distributed_query_specs(100, mesh, tile=16, n_sources=4)
    w, occ, alive, ecnt, srcs, version = specs
    assert w.shape[0] % 16 == 0 and w.shape[0] >= 100
    assert occ.shape == (w.shape[0] // 16,) * 2
    assert alive.shape == (100,) and srcs.shape == (4,)


def test_legacy_edge_sharded_oracle_equals_local():
    """The pre-PR-3 edge-sharded decomposition is kept as a second,
    independent implementation; it must still match the local queries."""
    g = shard_edges(_ring_graph(), 1)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    fn, _, _ = legacy_distributed_query(mesh, "bfs")
    reached, dist, parent, ec = jax.jit(fn)(
        g.alive, g.ecnt, g.esrc, g.edst, g.ew, jnp.int32(0))
    ref = bfs(g, 0)
    assert np.array_equal(np.asarray(dist), np.asarray(ref.dist))
    assert np.array_equal(np.asarray(reached), np.asarray(ref.reached))
    fn2, _, _ = legacy_distributed_query(mesh, "sssp")
    _, dist2, neg, _ = jax.jit(fn2)(
        g.alive, g.ecnt, g.esrc, g.edst, g.ew, jnp.int32(0))
    ref2 = sssp(g, 0)
    assert np.allclose(np.asarray(dist2), np.asarray(ref2.dist))
    assert bool(neg) == bool(ref2.negcycle)


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
      %ag = bf16[8,512,336]{2,1,0} all-gather(%x), replica_groups=...
      %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
      %rs = f32[2,64]{1,0} reduce-scatter(%z), dimensions={0}
      %a2a = bf16[16,40,128]{2,1,0} all-to-all(%w), dimensions={0}
      %cp = u32[7]{0} collective-permute(%q), source_target_pairs=...
      %ars = f32[12]{0} all-reduce-start(%y2), to_apply=%sum
      %not_a_collective = f32[9999]{0} add(%a, %b)
    """
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 8 * 512 * 336 * 2
    assert out["all-reduce"] == 1024 * 4 + 12 * 4
    assert out["reduce-scatter"] == 128 * 4
    assert out["all-to-all"] == 16 * 40 * 128 * 2
    assert out["collective-permute"] == 7 * 4
    assert out["count"] == 6
    assert out["total"] == sum(v for k, v in out.items()
                               if k not in ("total", "count"))


def test_sanitize_spec_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import sanitize_spec
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    # axis absent from mesh -> dropped
    assert sanitize_spec(P("pod", "model"), (8, 8), mesh) == P(None, "model")
    # 1-sized axes always divide
    assert sanitize_spec(P("data"), (7,), mesh) == P("data")


def test_scale_depth_and_units():
    from repro.launch.dryrun import scale_depth, unit_count
    from repro.configs import get_config
    z = get_config("zamba2_12b")
    assert unit_count(z) == 6                      # 38 // 6
    z1 = scale_depth(z, 1)
    assert z1.num_layers == 1 * 6 + 2              # keeps the tail
    w = get_config("whisper_large_v3")
    w2 = scale_depth(w, 2)
    assert w2.num_layers == 2 and w2.encoder_layers == 2
    q = get_config("qwen3_32b")
    assert scale_depth(q, 2).num_layers == 2
    assert scale_depth(q, 2).scan_unroll