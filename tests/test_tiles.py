"""Tile view correctness: occupancy vs live_edge_mask, incremental refresh
under randomized update streams, compact/grow boundaries, and mask
consistency with the tile-skipping semiring contract."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    PUTE, PUTV, REME, REMV,
    apply_ops, build_tile_view, compact, dense_views, dirty_vertices,
    grow_edges, grow_vertices, make_graph, occupancy_stats,
    refresh_tile_view,
)
from repro.core.graph_state import densify, live_edge_mask
from repro.core.tiles import active_tile_mask, dense_views_from_tiles
from repro.data import load_rmat_graph


def _occ_ref(state, tile):
    """Host-side oracle: per-tile live-edge counts straight off the mask."""
    live = np.asarray(live_edge_mask(state))
    src = np.asarray(state.esrc)[live]
    dst = np.asarray(state.edst)[live]
    nt = -(-state.vcap // tile)
    occ = np.zeros((nt, nt), np.int64)
    np.add.at(occ, (src // tile, dst // tile), 1)
    return occ


def _assert_view_matches(state, view, tile):
    vcap = state.vcap
    w = np.asarray(view.w)
    assert w.shape[0] % tile == 0 and w.shape[0] >= vcap
    assert np.array_equal(w[:vcap, :vcap], np.asarray(densify(state)))
    assert np.isinf(w[vcap:, :]).all() and np.isinf(w[:, vcap:]).all()
    assert np.array_equal(np.asarray(view.occ), _occ_ref(state, tile))


def _random_ops(rng, n, k=12):
    ops = []
    for _ in range(k):
        r = rng.random()
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if r < 0.08:
            ops.append((REMV, u))
        elif r < 0.16:
            ops.append((PUTV, u))
        elif r < 0.6:
            ops.append((PUTE, u, v, float(rng.integers(1, 9))))
        else:
            ops.append((REME, u, v))
    return ops


@pytest.mark.parametrize("tile", [16, 128])
def test_build_tile_view_matches_oracle(tile):
    g = load_rmat_graph(64, 400, seed=2)
    view = build_tile_view(g, tile=tile)
    _assert_view_matches(g, view, tile)
    stats = occupancy_stats(view)
    assert stats["tiles_active"] == int((_occ_ref(g, tile) > 0).sum())
    assert stats["live_edges"] == int(_occ_ref(g, tile).sum())
    assert 0.0 <= stats["tile_skip_rate"] <= 1.0
    assert np.array_equal(np.asarray(active_tile_mask(view)),
                          _occ_ref(g, tile) > 0)


@pytest.mark.parametrize("seed", range(3))
def test_refresh_equals_full_rebuild_over_stream(seed):
    """Randomized update/refresh interleavings: the incrementally refreshed
    view is bit-identical to a from-scratch build at every commit."""
    rng = np.random.default_rng(seed)
    n, tile = 48, 16
    g = make_graph(n, 512)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(n)]
                     + [(PUTE, int(rng.integers(0, n)), int(rng.integers(0, n)),
                         float(rng.integers(1, 9))) for _ in range(150)])
    view = build_tile_view(g, tile=tile)
    for _ in range(12):
        g2, _ = apply_ops(g, _random_ops(rng, n))
        dirty = dirty_vertices(g, g2)
        view = refresh_tile_view(g2, view, dirty, tile=tile)
        _assert_view_matches(g2, view, tile)
        g = g2


def test_refresh_after_compact_is_noop():
    """compact() rearranges slots but moves no vertices: an empty dirty set
    must leave the refreshed view correct (and unchanged)."""
    g = make_graph(32, 128)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(32)]
                     + [(PUTE, i, (i + 1) % 32, 1.0) for i in range(32)]
                     + [(REME, 0, 1), (REME, 5, 6)])
    view = build_tile_view(g, tile=16)
    g2 = compact(g)
    view2 = refresh_tile_view(g2, view, jnp.zeros((32,), jnp.bool_), tile=16)
    _assert_view_matches(g2, view2, 16)
    assert np.array_equal(np.asarray(view.w), np.asarray(view2.w))


def test_refresh_survives_grow_edges():
    """grow_edges changes ecap only; the refresh path recompiles but the
    tile grid carries over."""
    g = make_graph(32, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(32)]
                     + [(PUTE, 0, i, 1.0) for i in range(1, 20)])
    view = build_tile_view(g, tile=16)
    g2 = grow_edges(g)
    g3, _ = apply_ops(g2, [(PUTE, 1, 2, 4.0)])
    view3 = refresh_tile_view(g3, view, dirty_vertices(g2, g3), tile=16)
    _assert_view_matches(g3, view3, 16)


def test_refresh_falls_back_on_vertex_growth():
    """grow_vertices resizes the tile grid: refresh must detect the shape
    change and rebuild from scratch."""
    g = make_graph(16, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(16)]
                     + [(PUTE, 0, 1, 1.0)])
    view = build_tile_view(g, tile=16)
    g2 = grow_vertices(g)
    g3, _ = apply_ops(g2, [(PUTV, 20), (PUTE, 1, 20, 2.0)])
    dirty = jnp.ones((g3.vcap,), jnp.bool_)
    view3 = refresh_tile_view(g3, view, dirty, tile=16)
    _assert_view_matches(g3, view3, 16)


def test_refresh_handles_remv_column_kills():
    """RemV tombstones edges *into* the removed vertex; the dirty sources
    must be enough for the refresh to drop those columns' cells."""
    g = make_graph(48, 256)
    ops = [(PUTV, i) for i in range(48)]
    ops += [(PUTE, i, 40, 1.0) for i in range(10)]  # fan-in to 40
    ops += [(PUTE, 40, i, 2.0) for i in range(10, 20)]
    g, _ = apply_ops(g, ops)
    view = build_tile_view(g, tile=16)
    g2, _ = apply_ops(g, [(REMV, 40)])
    view2 = refresh_tile_view(g2, view, dirty_vertices(g, g2), tile=16)
    _assert_view_matches(g2, view2, 16)
    # every cell of column 40 and row 40 went back to identity
    assert np.isinf(np.asarray(view2.w)[:, 40]).all()
    assert np.isinf(np.asarray(view2.w)[40, :]).all()


def test_refresh_falls_back_on_tile_size_mismatch():
    """Same padded dims, different grid: refreshing a tile=16 view at
    tile=128 must rebuild, not pile occupancy into the wrong rows."""
    g = make_graph(128, 256)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(128)]
                     + [(PUTE, i, (i + 31) % 128, 1.0) for i in range(100)])
    view16 = build_tile_view(g, tile=16)
    g2, _ = apply_ops(g, [(PUTE, 5, 77, 2.0)])
    view = refresh_tile_view(g2, view16, dirty_vertices(g, g2), tile=128)
    _assert_view_matches(g2, view, 128)


def test_dense_views_from_tiles_matches_dense_views():
    g = load_rmat_graph(64, 300, seed=5)
    view = build_tile_view(g, tile=16)
    am, wd, alive = dense_views(g)
    am2, wd2, alive2 = dense_views_from_tiles(g, view)
    assert np.array_equal(np.asarray(am), np.asarray(am2))
    assert np.array_equal(np.asarray(wd), np.asarray(wd2))
    assert np.array_equal(np.asarray(alive), np.asarray(alive2))
