"""Randomized differential op-stream suite (see ``stream_differential``).

Seeds are fixed here so CI is deterministic, and every run logs its seed
(the harness prints it) so a failure reproduces with
``run_differential(seed, ...)`` alone; the hypothesis-driven variant in
``test_property.py`` roams the seed space.  The multi-device cases run
through the shared ``conftest.run_multidevice`` subprocess helper (4
host-platform placeholder devices set before jax imports) against the
4-way ``ShardedGraphService`` in BOTH ``bc_mode`` values.

Every replay runs with telemetry attached: the harness itself asserts
ladder-mode conservation (``unchanged + delta + full == queries == #trace
records``) and per-query trace agreement with the oracle-validated
answers, on both services and both ``bc_mode``s — so the telemetry
invariants are exercised by every test below, not just the local one.
"""
from conftest import run_multidevice as _run_multidevice
from repro.shard import as_graph_mesh
from stream_differential import run_differential


def test_stream_differential_local(tmp_path):
    """Local GraphService vs the oracle over a mixed stream; the chosen
    seed exercises every rung of the ladder.  The trace is mirrored to
    JSONL and must pass the ``repro.obs.report`` schema/coverage gate."""
    trace = tmp_path / "trace.jsonl"
    modes = run_differential(7, n=24, steps=8, score_every=4,
                             trace_path=str(trace))
    for mode in ("unchanged", "delta", "full"):
        assert modes["local"][mode] > 0, (mode, modes)
    from repro.obs import report
    records = report.load(str(trace))
    problems = report.validate(records,
                               require_modes=("unchanged", "delta", "full"))
    assert problems == [], problems
    assert report.main([str(trace), "--check",
                        "--require-modes", "unchanged,delta,full"]) == 0


def test_stream_differential_negative_weights():
    """Negative weights breed negative cycles mid-stream: delta SSSP must
    fall back to the canonical full answer and flags must match the
    oracle's Bellman-Ford verdict at every version."""
    run_differential(11, n=24, steps=6, neg_frac=0.08)


def test_stream_differential_sharded_single_device():
    """1-device sharded service (in-process) rides the same ladder as the
    local service against the oracle — ring BC mode."""
    modes = run_differential(7, n=24, steps=5, mesh=as_graph_mesh(),
                             bc_mode="ring")
    assert modes["sharded"] == modes["local"]
    for mode in ("unchanged", "delta", "full"):
        assert modes["sharded"][mode] > 0, (mode, modes)


def test_stream_differential_multidevice():
    """4-way ShardedGraphService vs oracle vs local service, both bc_mode
    values, one stream with negative weights."""
    out = _run_multidevice(r"""
from repro.shard import as_graph_mesh
from stream_differential import run_differential

mesh = as_graph_mesh()
assert mesh.devices.size == 4
for bc_mode in ("gather", "ring"):
    modes = run_differential(7, n=32, steps=6, mesh=mesh, bc_mode=bc_mode,
                             score_every=6)
    for mode in ("unchanged", "delta", "full"):
        assert modes["sharded"][mode] > 0, (bc_mode, mode, modes)
run_differential(11, n=32, steps=4, mesh=mesh, bc_mode="ring",
                 neg_frac=0.08)
print("STREAM OK")
""")
    assert "STREAM OK" in out
