"""Randomized differential op-stream suite (see ``stream_differential``).

Seeds are fixed here so CI is deterministic, and every run logs its seed
(the harness prints it) so a failure reproduces with
``run_differential(seed, ...)`` alone; the hypothesis-driven variant in
``test_property.py`` roams the seed space.  The multi-device cases run
through the shared ``conftest.run_multidevice`` subprocess helper (4
host-platform placeholder devices set before jax imports) against the
4-way ``ShardedGraphService`` in BOTH ``bc_mode`` values.

Every replay runs with telemetry attached: the harness itself asserts
ladder-mode conservation (``unchanged + delta + full == queries == #trace
records``) and per-query trace agreement with the oracle-validated
answers, on both services and both ``bc_mode``s — so the telemetry
invariants are exercised by every test below, not just the local one.
"""
from conftest import run_multidevice as _run_multidevice
from repro.shard import as_graph_mesh
from stream_differential import run_differential


def test_stream_differential_local(tmp_path):
    """Local GraphService vs the oracle over a mixed stream; the chosen
    seed exercises every rung of the ladder.  The trace is mirrored to
    JSONL and must pass the ``repro.obs.report`` schema/coverage gate."""
    trace = tmp_path / "trace.jsonl"
    modes = run_differential(7, n=24, steps=8, score_every=4,
                             trace_path=str(trace))
    for mode in ("unchanged", "delta", "full"):
        assert modes["local"][mode] > 0, (mode, modes)
    from repro.obs import report
    records = report.load(str(trace))
    problems = report.validate(records,
                               require_modes=("unchanged", "delta", "full"))
    assert problems == [], problems
    assert report.main([str(trace), "--check",
                        "--require-modes", "unchanged,delta,full"]) == 0


def test_stream_differential_negative_weights():
    """Negative weights breed negative cycles mid-stream: delta SSSP must
    fall back to the canonical full answer and flags must match the
    oracle's Bellman-Ford verdict at every version."""
    run_differential(11, n=24, steps=6, neg_frac=0.08)


def test_stream_differential_sharded_single_device():
    """1-device sharded service (in-process) rides the same ladder as the
    local service against the oracle — ring BC mode."""
    modes = run_differential(7, n=24, steps=5, mesh=as_graph_mesh(),
                             bc_mode="ring")
    assert modes["sharded"] == modes["local"]
    for mode in ("unchanged", "delta", "full"):
        assert modes["sharded"][mode] > 0, (mode, modes)


def test_stream_differential_multidevice():
    """4-way ShardedGraphService vs oracle vs local service, both bc_mode
    values, one stream with negative weights."""
    out = _run_multidevice(r"""
from repro.shard import as_graph_mesh
from stream_differential import run_differential

mesh = as_graph_mesh()
assert mesh.devices.size == 4
for bc_mode in ("gather", "ring"):
    modes = run_differential(7, n=32, steps=6, mesh=mesh, bc_mode=bc_mode,
                             score_every=6)
    for mode in ("unchanged", "delta", "full"):
        assert modes["sharded"][mode] > 0, (bc_mode, mode, modes)
run_differential(11, n=32, steps=4, mesh=mesh, bc_mode="ring",
                 neg_frac=0.08)
print("STREAM OK")
""")
    assert "STREAM OK" in out


def test_stream_differential_adaptive(tmp_path):
    """Adaptive-threshold replay: with an aggressive controller moving the
    per-kind ``dirty_threshold`` mid-stream (and probes demoting every Nth
    would-be-delta to full), every answer still matches the oracle bit-
    for-bit and ladder-mode conservation holds — a moving threshold only
    re-routes queries between rungs, it can never change an answer.  The
    harness asserts the controller invariants (thresholds within clamps,
    one ``threshold_adjust`` span per adjustment); here we additionally
    demand the controller actually engaged, so the assertions are not
    vacuous."""
    trace = tmp_path / "adaptive.jsonl"
    modes = run_differential(7, n=24, steps=8, score_every=4,
                             trace_path=str(trace), adaptive=True)
    for mode in ("unchanged", "delta", "full"):
        assert modes["local"][mode] > 0, (mode, modes)
    snap = modes["local"]["adaptive"]
    assert snap["probes"] > 0, snap
    assert snap["samples"]["bfs"]["full"] >= 1, snap
    from repro.obs import report
    records = report.load(str(trace))
    problems = report.validate(records,
                               require_modes=("unchanged", "delta", "full"))
    assert problems == [], problems


# --------------------------------- chaos -----------------------------------

def test_stream_differential_chaos_local(tmp_path):
    """Seeded-random faults over the scheduler commits, the collect
    ladder, ring eviction and the cache stores: every answer is degraded-
    or-correct (the harness cross-checks degraded replies bit-for-bit
    against previously oracle-validated answers), ``verify_service``
    passes after every fault, and the traced stream passes the report
    gate including the new degraded/error fields."""
    from repro.obs import report
    from repro.resil import FaultPlan, ResiliencePolicy

    trace = tmp_path / "chaos.jsonl"
    plan = FaultPlan(seed=1, rate=0.3)
    modes = run_differential(7, n=24, steps=6, fault_plan=plan,
                             policy=ResiliencePolicy(max_retries=1),
                             trace_path=str(trace))
    assert plan.fired > 0
    local = modes["local"]
    assert local["degraded"] > 0, local   # the bottom rung was exercised
    assert local["full"] > 0 and local["unchanged"] > 0, local
    assert report.main([str(trace), "--check", "--require-degraded"]) == 0


def test_stream_differential_chaos_replays_from_schedule():
    """A random chaos run converts to an explicit schedule that replays
    the identical degraded/raised pattern — chaos flakes become
    regression tests."""
    from repro.resil import FaultPlan, ResiliencePolicy

    pol = ResiliencePolicy(max_retries=1)
    plan = FaultPlan(seed=2, rate=0.3)
    m1 = run_differential(7, n=24, steps=4, fault_plan=plan, policy=pol)
    replay = FaultPlan(plan.to_schedule())
    m2 = run_differential(7, n=24, steps=4, fault_plan=replay, policy=pol)
    assert m1 == m2
    assert replay.fired == plan.fired


def test_stream_differential_chaos_sharded_single_device():
    """The sharded service walks the same degrade ladder: dispatch/delta
    faults on the shard_map paths retry from a pinned snapshot and
    degrade to validated stale answers, never silently diverging."""
    from repro.resil import FaultPlan, ResiliencePolicy

    plan = FaultPlan(seed=3, rate=0.2)
    modes = run_differential(7, n=24, steps=4, mesh=as_graph_mesh(),
                             bc_mode="ring", fault_plan=plan,
                             policy=ResiliencePolicy(max_retries=1))
    assert plan.fired > 0
    assert modes["sharded"]["full"] > 0
    total = sum(modes["sharded"][m] for m in
                ("unchanged", "delta", "full", "degraded", "raised"))
    assert total == sum(modes["local"][m] for m in
                        ("unchanged", "delta", "full", "degraded", "raised"))


# ------------------------- durable recovery (WAL) ---------------------------

def test_stream_differential_journaled_recovery(tmp_path):
    """Journaled replay with rotation + compaction: the harness recovers
    the local AND (single-device) sharded WALs into fresh services and
    asserts bit-identical ring latests plus oracle-exact cold answers.
    The small segment/compaction knobs force real rotations and real
    segment truncation, not a single-file replay."""
    modes = run_differential(7, n=24, steps=6, mesh=as_graph_mesh(),
                             bc_mode="ring", journal_dir=str(tmp_path),
                             compact_every=3, segment_bytes=900)
    for name in ("local", "sharded"):
        rec = modes[name]["recovery"]
        assert rec["rotations"] > 0, (name, rec)
        assert rec["compactions"] > 0, (name, rec)
        assert rec["segments_dropped"] > 0, (name, rec)


def test_stream_differential_chaos_journaled(tmp_path):
    """Chaos + WAL: injected faults over the scheduler/ladder while the
    journal rotates and compacts underneath — recovery must still land
    bit-identically on the surviving service's ring."""
    from repro.resil import FaultPlan, ResiliencePolicy

    plan = FaultPlan(seed=3, rate=0.2)
    modes = run_differential(7, n=24, steps=4, fault_plan=plan,
                             policy=ResiliencePolicy(max_retries=1),
                             journal_dir=str(tmp_path),
                             compact_every=3, segment_bytes=800)
    assert plan.fired > 0
    rec = modes["local"]["recovery"]
    assert rec["compactions"] > 0 and rec["rotations"] > 0, rec


def test_stream_differential_multidevice_chaos_recovery():
    """Acceptance: the 4-device subprocess sharded service under a chaos
    plan whose faults fire during sharded collects (asserted via the
    sharded service's own retry/error tallies), with both WALs rotating
    and compacting mid-stream — recovery under the live mesh reproduces
    the sharded ring and query answers exactly."""
    out = _run_multidevice(r"""
import tempfile
from repro.shard import as_graph_mesh
from repro.resil import FaultPlan, ResiliencePolicy
from stream_differential import run_differential

mesh = as_graph_mesh()
assert mesh.devices.size == 4
plan = FaultPlan(seed=5, rate=0.2)
modes = run_differential(7, n=32, steps=4, mesh=mesh, bc_mode="ring",
                         fault_plan=plan,
                         policy=ResiliencePolicy(max_retries=1),
                         journal_dir=tempfile.mkdtemp(),
                         compact_every=3, segment_bytes=1200)
assert plan.fired > 0
sh = modes["sharded"]
# >=1 fault fired during a sharded collect: the sharded ladder itself
# retried or errored (commit faults never move these counters)
assert sh["errors"] + sh["retries"] > 0, sh
for name in ("local", "sharded"):
    rec = modes[name]["recovery"]
    assert rec["rotations"] > 0 and rec["compactions"] > 0, (name, rec)
print("CHAOS RECOVERY OK")
""")
    assert "CHAOS RECOVERY OK" in out


_CRASH_CHILD = r"""
import json, os, signal, sys
import numpy as np
from repro.core import PUTE, PUTV, make_graph
from repro.engine import GraphService
from repro.resil import OpJournal, journal_meta

path, mode, kill_at = sys.argv[1], sys.argv[2], int(sys.argv[3])
n = 24
rng = np.random.default_rng(21)
g0 = make_graph(n, 16 * n)
kw = dict(segment_bytes=700) if mode == "kill" else {}
journal = OpJournal(path, meta=journal_meta(g0, {"batch_size": 4}), **kw)
svc = GraphService(g0, batch_size=4, journal=journal,
                   compact_every=3 if mode == "kill" else None)
svc.submit_many([(PUTV, i) for i in range(n)])
svc.flush()
k = 0
for step in range(14):
    for _ in range(6):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        svc.submit((PUTE, u, v, float(rng.integers(1, 9))))
        k += 1
        if mode == "kill" and k == kill_at:
            os.kill(os.getpid(), signal.SIGKILL)
    svc.flush()
print(json.dumps({"version": svc.version}))
"""


def test_sigkill_crash_recovery(tmp_path):
    """SIGKILL a journaling service mid-stream (rotation + compaction
    active, ops pending past the last barrier); recovery from the killed
    WAL must be bit-identical to an uninterrupted twin's replay truncated
    at the recovered version."""
    import json
    import os
    import signal
    import subprocess
    import sys

    import jax
    import numpy as np

    from repro.core import apply_ops, make_graph
    from repro.resil import read_journal_versions, recover

    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here])
    env.pop("XLA_FLAGS", None)
    killed, full = str(tmp_path / "killed.jsonl"), str(tmp_path / "full.jsonl")
    r = subprocess.run([sys.executable, "-c", _CRASH_CHILD,
                        killed, "kill", "37"],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr)
    r2 = subprocess.run([sys.executable, "-c", _CRASH_CHILD,
                         full, "full", "0"],
                        env=env, capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    final_version = json.loads(r2.stdout)["version"]

    g0 = make_graph(24, 16 * 24)
    rec = recover(killed, g0, batch_size=4)
    assert 0 < rec.version < final_version
    # fold the uninterrupted twin's journal up to the recovered version:
    # the kill point must not have torn a batch
    _meta, twin_batches, _pending = read_journal_versions(full)
    expected = g0
    for version, chunk in twin_batches:
        if version > rec.version:
            break
        expected, _ = apply_ops(expected, list(chunk), batch_size=4)
    for a, b in zip(jax.tree_util.tree_leaves(expected),
                    jax.tree_util.tree_leaves(rec.ring.latest.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    from repro.resil import assert_service_ok
    assert_service_ok(rec)
    reply = rec.query("bfs", 0)
    assert reply.version == rec.version and not reply.degraded


# ------------------------- concurrent serving -------------------------------

def test_stream_differential_concurrent(tmp_path):
    """Concurrent-schedule replay: client threads race an updater through
    the async front end; every reply is oracle-checked (semantic + bit-
    equal) at its own pinned version, conservation survives concurrency,
    and at least one compiled dispatch actually batched."""
    from stream_differential import run_concurrent_differential

    trace = tmp_path / "concurrent.jsonl"
    modes = run_concurrent_differential(11, trace_path=str(trace))
    assert modes["raised"] == 0 and modes["degraded"] == 0, modes
    assert modes["full"] > 0 and modes["unchanged"] > 0, modes
    serve = modes["serve"]
    assert serve["batched_dispatches"] > 0, serve
    assert serve["deadline_expired"] == 0, serve


def test_stream_differential_concurrent_chaos():
    """The concurrent replay under seeded faults: dispatch-level faults
    (propagated into the dispatcher via its copied context) degrade to
    the per-request ladder, commits retry, and every resolved reply is
    still degraded-or-correct at its pinned (or stale) version."""
    from repro.resil import FaultPlan, ResiliencePolicy
    from stream_differential import run_concurrent_differential

    plan = FaultPlan(seed=13, rate=0.25)
    modes = run_concurrent_differential(
        12, fault_plan=plan, policy=ResiliencePolicy(max_retries=1))
    assert plan.fired > 0
    assert modes["full"] > 0, modes
    assert modes["serve"]["admitted"] > 0, modes
