"""Randomized differential op-stream suite (see ``stream_differential``).

Seeds are fixed here so CI is deterministic, and every run logs its seed
(the harness prints it) so a failure reproduces with
``run_differential(seed, ...)`` alone; the hypothesis-driven variant in
``test_property.py`` roams the seed space.  The multi-device cases run
through the shared ``conftest.run_multidevice`` subprocess helper (4
host-platform placeholder devices set before jax imports) against the
4-way ``ShardedGraphService`` in BOTH ``bc_mode`` values.

Every replay runs with telemetry attached: the harness itself asserts
ladder-mode conservation (``unchanged + delta + full == queries == #trace
records``) and per-query trace agreement with the oracle-validated
answers, on both services and both ``bc_mode``s — so the telemetry
invariants are exercised by every test below, not just the local one.
"""
from conftest import run_multidevice as _run_multidevice
from repro.shard import as_graph_mesh
from stream_differential import run_differential


def test_stream_differential_local(tmp_path):
    """Local GraphService vs the oracle over a mixed stream; the chosen
    seed exercises every rung of the ladder.  The trace is mirrored to
    JSONL and must pass the ``repro.obs.report`` schema/coverage gate."""
    trace = tmp_path / "trace.jsonl"
    modes = run_differential(7, n=24, steps=8, score_every=4,
                             trace_path=str(trace))
    for mode in ("unchanged", "delta", "full"):
        assert modes["local"][mode] > 0, (mode, modes)
    from repro.obs import report
    records = report.load(str(trace))
    problems = report.validate(records,
                               require_modes=("unchanged", "delta", "full"))
    assert problems == [], problems
    assert report.main([str(trace), "--check",
                        "--require-modes", "unchanged,delta,full"]) == 0


def test_stream_differential_negative_weights():
    """Negative weights breed negative cycles mid-stream: delta SSSP must
    fall back to the canonical full answer and flags must match the
    oracle's Bellman-Ford verdict at every version."""
    run_differential(11, n=24, steps=6, neg_frac=0.08)


def test_stream_differential_sharded_single_device():
    """1-device sharded service (in-process) rides the same ladder as the
    local service against the oracle — ring BC mode."""
    modes = run_differential(7, n=24, steps=5, mesh=as_graph_mesh(),
                             bc_mode="ring")
    assert modes["sharded"] == modes["local"]
    for mode in ("unchanged", "delta", "full"):
        assert modes["sharded"][mode] > 0, (mode, modes)


def test_stream_differential_multidevice():
    """4-way ShardedGraphService vs oracle vs local service, both bc_mode
    values, one stream with negative weights."""
    out = _run_multidevice(r"""
from repro.shard import as_graph_mesh
from stream_differential import run_differential

mesh = as_graph_mesh()
assert mesh.devices.size == 4
for bc_mode in ("gather", "ring"):
    modes = run_differential(7, n=32, steps=6, mesh=mesh, bc_mode=bc_mode,
                             score_every=6)
    for mode in ("unchanged", "delta", "full"):
        assert modes["sharded"][mode] > 0, (bc_mode, mode, modes)
run_differential(11, n=32, steps=4, mesh=mesh, bc_mode="ring",
                 neg_frac=0.08)
print("STREAM OK")
""")
    assert "STREAM OK" in out


def test_stream_differential_adaptive(tmp_path):
    """Adaptive-threshold replay: with an aggressive controller moving the
    per-kind ``dirty_threshold`` mid-stream (and probes demoting every Nth
    would-be-delta to full), every answer still matches the oracle bit-
    for-bit and ladder-mode conservation holds — a moving threshold only
    re-routes queries between rungs, it can never change an answer.  The
    harness asserts the controller invariants (thresholds within clamps,
    one ``threshold_adjust`` span per adjustment); here we additionally
    demand the controller actually engaged, so the assertions are not
    vacuous."""
    trace = tmp_path / "adaptive.jsonl"
    modes = run_differential(7, n=24, steps=8, score_every=4,
                             trace_path=str(trace), adaptive=True)
    for mode in ("unchanged", "delta", "full"):
        assert modes["local"][mode] > 0, (mode, modes)
    snap = modes["local"]["adaptive"]
    assert snap["probes"] > 0, snap
    assert snap["samples"]["bfs"]["full"] >= 1, snap
    from repro.obs import report
    records = report.load(str(trace))
    problems = report.validate(records,
                               require_modes=("unchanged", "delta", "full"))
    assert problems == [], problems


# --------------------------------- chaos -----------------------------------

def test_stream_differential_chaos_local(tmp_path):
    """Seeded-random faults over the scheduler commits, the collect
    ladder, ring eviction and the cache stores: every answer is degraded-
    or-correct (the harness cross-checks degraded replies bit-for-bit
    against previously oracle-validated answers), ``verify_service``
    passes after every fault, and the traced stream passes the report
    gate including the new degraded/error fields."""
    from repro.obs import report
    from repro.resil import FaultPlan, ResiliencePolicy

    trace = tmp_path / "chaos.jsonl"
    plan = FaultPlan(seed=1, rate=0.3)
    modes = run_differential(7, n=24, steps=6, fault_plan=plan,
                             policy=ResiliencePolicy(max_retries=1),
                             trace_path=str(trace))
    assert plan.fired > 0
    local = modes["local"]
    assert local["degraded"] > 0, local   # the bottom rung was exercised
    assert local["full"] > 0 and local["unchanged"] > 0, local
    assert report.main([str(trace), "--check", "--require-degraded"]) == 0


def test_stream_differential_chaos_replays_from_schedule():
    """A random chaos run converts to an explicit schedule that replays
    the identical degraded/raised pattern — chaos flakes become
    regression tests."""
    from repro.resil import FaultPlan, ResiliencePolicy

    pol = ResiliencePolicy(max_retries=1)
    plan = FaultPlan(seed=2, rate=0.3)
    m1 = run_differential(7, n=24, steps=4, fault_plan=plan, policy=pol)
    replay = FaultPlan(plan.to_schedule())
    m2 = run_differential(7, n=24, steps=4, fault_plan=replay, policy=pol)
    assert m1 == m2
    assert replay.fired == plan.fired


def test_stream_differential_chaos_sharded_single_device():
    """The sharded service walks the same degrade ladder: dispatch/delta
    faults on the shard_map paths retry from a pinned snapshot and
    degrade to validated stale answers, never silently diverging."""
    from repro.resil import FaultPlan, ResiliencePolicy

    plan = FaultPlan(seed=3, rate=0.2)
    modes = run_differential(7, n=24, steps=4, mesh=as_graph_mesh(),
                             bc_mode="ring", fault_plan=plan,
                             policy=ResiliencePolicy(max_retries=1))
    assert plan.fired > 0
    assert modes["sharded"]["full"] > 0
    total = sum(modes["sharded"][m] for m in
                ("unchanged", "delta", "full", "degraded", "raised"))
    assert total == sum(modes["local"][m] for m in
                        ("unchanged", "delta", "full", "degraded", "raised"))
