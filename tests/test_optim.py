"""Optimizer, schedule, and gradient-compression tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (
    adamw_init, adamw_update, compress_grads, compress_init, warmup_cosine,
)


def test_adamw_converges_on_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
        return params, opt, loss

    for _ in range(300):
        params, opt, loss = step(params, opt)
    assert float(loss) < 1e-2
    assert int(opt.step) == 300


def test_adamw_stacked_leaf_scan_path_matches_flat():
    """ndim>=3 leaves take the sliced-scan path; results must match."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8))}
    p = {"w": jnp.ones((4, 8, 8))}
    opt = adamw_init(p)
    p1, o1 = adamw_update(g, opt, p, lr=0.1)
    # same update computed leaf-flattened (2D -> direct path)
    gf = {"w": g["w"].reshape(32, 8)}
    pf = {"w": p["w"].reshape(32, 8)}
    optf = adamw_init(pf)
    p2, o2 = adamw_update(gf, optf, pf, lr=0.1)
    assert np.allclose(np.asarray(p1["w"]).reshape(32, 8),
                       np.asarray(p2["w"]), atol=1e-6)


def test_grad_clipping():
    p = {"w": jnp.zeros(4)}
    opt = adamw_init(p)
    big = {"w": jnp.full((4,), 1e6)}
    p1, _ = adamw_update(big, opt, p, lr=1.0, weight_decay=0.0,
                         clip_norm=1.0)
    small = {"w": big["w"] / jnp.sqrt(jnp.sum(big["w"] ** 2))}
    p2, _ = adamw_update(small, opt, p, lr=1.0, weight_decay=0.0,
                         clip_norm=1.0)
    assert np.allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), atol=1e-5)


def test_bf16_moments_roundtrip():
    p = {"w": jnp.ones((8,), jnp.bfloat16)}
    opt = adamw_init(p, moment_dtype=jnp.bfloat16)
    g = {"w": jnp.full((8,), 0.5, jnp.bfloat16)}
    p2, opt2 = adamw_update(g, opt, p, lr=0.01)
    assert opt2.m["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16
    assert not np.allclose(np.asarray(p2["w"], np.float32), 1.0)


def test_schedule_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1e-3, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[99] < lrs[50] < lrs[10]
    assert lrs[99] >= 1e-4 * 0.99     # min_ratio floor


def test_compress_error_feedback():
    """Quantization error must be carried, not lost: sum of dequantized
    grads over steps converges to the sum of true grads."""
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((64,))}
    state = compress_init(params)
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
        dq, state = compress_grads(g, state)
        true_sum += np.asarray(g["w"])
        deq_sum += np.asarray(dq["w"])
    # residual bounds the accumulated error to one step's quantization
    err = np.abs(true_sum - deq_sum).max()
    resid = np.abs(np.asarray(state.residual["w"])).max()
    assert err <= resid + 1e-5
    assert err < 0.2


def test_compress_int8_range():
    g = {"w": jnp.asarray([1000.0, -500.0, 0.25])}
    state = compress_init(g)
    dq, _ = compress_grads(g, state)
    got = np.asarray(dq["w"])
    assert abs(got[0] - 1000.0) / 1000.0 < 0.01
