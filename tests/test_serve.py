"""Async serving front end + the thread-safety bugfixes it exposed.

Four regression suites pin the bugfixes that concurrent serving forced:

  * **pin refcounting** — ``VersionRing`` pins are shared counters;
    ``PinnedSnapshot.release()`` is idempotent under concurrency and can
    never steal a pin another in-flight query holds;
  * **atomic stale serve** — ``_stale_reply`` pins the cached slot's
    version in the same critical section that checks residency, so a
    degraded reply never names a version that eviction already dropped;
  * **pin-aware cache pruning** — ``prune_result_cache`` exempts slots
    at pinned versions from both sweeps (an admitted query's rung must
    not be evicted out from under it);
  * **per-kind dirty thresholds** — BC's delta ladder crossover sits at
    a few percent dirty, far below BFS/SSSP's; the old shared 0.25
    default routed BC into guaranteed delta losses
    (``engine_bc_incr < 1x``) and the adaptive clamp couldn't reach the
    true crossover.

The front-end tests then cover the tentpole itself: batched compatible
queries bit-identical to sequential collects, delta-rung batching, the
dispatch-failure fallback, and per-request deadlines.  The randomized
concurrent differential (multi-client, mixed update+query) lives in
``test_stream_differential``.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import PUTE, PUTV, apply_ops, make_graph
from repro.core.queries import bc_dependencies, bfs, sssp
from repro.engine import GraphService
from repro.engine.incremental import results_equal
from repro.engine.service import (
    DEFAULT_DIRTY_THRESHOLDS,
    prune_result_cache,
    resolve_dirty_thresholds,
    _CacheSlot,
)
from repro.engine.version_ring import VersionRing
from repro.obs import AdaptiveThresholds, Telemetry
from repro.resil import (
    FaultPlan,
    P_SERVE_DISPATCH,
    ResiliencePolicy,
    fault_scope,
)
from repro.serve import AsyncGraphService, pad_pow2

VCAP, ECAP = 64, 256


def _seed_graph(rng, n=24, m=96):
    g = make_graph(VCAP, ECAP)
    ops = [(PUTV, i) for i in range(n)]
    for _ in range(m):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        ops.append((PUTE, u, v, float(rng.integers(1, 9))))
    g, _ = apply_ops(g, ops)
    return g


def _path_graph(n=24):
    """0 -> 1 -> ... -> n-1: reachability from 0 is known exactly."""
    g = make_graph(VCAP, ECAP)
    ops = [(PUTV, i) for i in range(n)]
    ops += [(PUTE, i, i + 1, 1.0) for i in range(n - 1)]
    g, _ = apply_ops(g, ops)
    return g


def _states(g0, k):
    """k successive committed-looking states (one edge tweak each)."""
    out = []
    state = g0
    for i in range(k):
        state, _ = apply_ops(state, [(PUTE, i % 4, (i + 1) % 4,
                                      float(1 + i % 3))])
        out.append(state)
    return out


# --------------------- bugfix 1: pin refcounting ---------------------------

def test_pin_is_refcounted_and_handle_release_idempotent():
    rng = np.random.default_rng(0)
    g0 = _seed_graph(rng)
    ring = VersionRing(g0, depth=2)
    p1 = ring.pin()          # v0, count 1
    p2 = ring.pin(0)         # v0, count 2 — shared entry
    assert ring.pin_count(0) == 2
    for st in _states(g0, 3):
        ring.commit(st)      # v0 rotates out but is parked (pinned)
    assert ring.get_entry(0) is not None, "pinned version must survive"
    p1.release()
    p1.release()             # double release: idempotent no-op
    with p1:                 # context-manager exit: still a no-op
        pass
    assert ring.pin_count(0) == 1, "double release must not steal p2's pin"
    assert ring.get_entry(0) is not None
    p2.release()
    assert ring.pin_count(0) == 0
    assert ring.get_entry(0) is None, "last release evicts the parked entry"


def test_release_by_version_is_idempotent():
    rng = np.random.default_rng(1)
    ring = VersionRing(_seed_graph(rng), depth=2)
    ring.release(0)          # never pinned: no-op, no going negative
    ring.pin(0)
    ring.release(0)
    ring.release(0)          # extra: no-op
    assert ring.pin_count(0) == 0
    assert ring.pinned_versions() == []


def test_concurrent_pin_release_hammer():
    """Many threads pinning/releasing (incl. racing double-releases of
    shared handles) while commits rotate the window: counts must end at
    zero with nothing parked and no exceptions."""
    rng = np.random.default_rng(2)
    g0 = _seed_graph(rng)
    ring = VersionRing(g0, depth=3)
    states = _states(g0, 12)
    errs = []

    def pinner():
        try:
            for _ in range(50):
                p = ring.pin()
                time.sleep(0)
                # two racing releases of the SAME handle
                t = threading.Thread(target=p.release)
                t.start()
                p.release()
                t.join()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=pinner) for _ in range(4)]
    for t in threads:
        t.start()
    for st in states:
        ring.commit(st)
        time.sleep(0.001)
    for t in threads:
        t.join()
    assert not errs, errs
    assert ring.pinned_versions() == []
    assert ring._parked == {}
    assert all(c > 0 for c in ring._pins.values())  # no zombie zeros


def test_try_pin_atomic_check_then_pin():
    rng = np.random.default_rng(3)
    g0 = _seed_graph(rng)
    ring = VersionRing(g0, depth=2)
    for st in _states(g0, 3):
        ring.commit(st)
    assert ring.try_pin(0) is None          # evicted: no handle
    with pytest.raises(KeyError):
        ring.pin(0)
    p = ring.try_pin()                      # latest
    assert p is not None and p.version == ring.latest.version
    p.release()


# --------------------- bugfix 2: atomic stale serve ------------------------

def test_stale_reply_none_once_version_evicted():
    rng = np.random.default_rng(4)
    svc = GraphService(_seed_graph(rng), ring_depth=2, batch_size=4,
                       policy=ResiliencePolicy())
    svc.query("bfs", 0)                     # slot cached at v0
    for _ in range(3):                      # rotate v0 out of the ring
        svc.submit_many([(PUTE, 1, 2, 1.0)] * 4)
        svc.flush()
    assert svc.ring.get_entry(0) is None
    assert svc._stale_reply("bfs", 0) is None, \
        "stale serve must refuse a version the ring no longer holds"
    svc.query("bfs", 0)                     # re-cache at the latest version
    reply = svc._stale_reply("bfs", 0)
    assert reply is not None and reply.degraded
    assert reply.stale_version == reply.version
    assert svc.ring.get_entry(reply.version) is not None


def test_stale_reply_vs_concurrent_eviction_hammer():
    """Commits rotating the ring race ``_stale_reply``: every reply that
    comes back must be the cached result at its claimed (then-resident)
    version — and the ring ends with no leaked pins."""
    rng = np.random.default_rng(5)
    svc = GraphService(_seed_graph(rng), ring_depth=2, batch_size=2,
                       policy=ResiliencePolicy())
    svc.query("bfs", 0)
    stop = threading.Event()
    errs = []

    def committer():
        try:
            while not stop.is_set():
                svc.submit_many([(PUTE, 1, 2, 1.0), (PUTE, 2, 3, 1.0)])
                svc.flush()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    t = threading.Thread(target=committer)
    t.start()
    try:
        for i in range(200):
            reply = svc._stale_reply("bfs", 0)
            if reply is not None:
                assert reply.stale_version == reply.version
            if i % 50 == 0:     # refresh the slot so it stays servable
                svc.query("bfs", 0)
    finally:
        stop.set()
        t.join()
    assert not errs, errs
    assert svc.ring.pinned_versions() == []


# --------------------- bugfix 3: pin-aware pruning -------------------------

def test_prune_result_cache_exempts_pinned_versions():
    mk = lambda v: _CacheSlot(v, object())  # noqa: E731
    cache = {("bfs", i): mk(i) for i in range(5)}
    # floor sweep: version 1 is below floor but pinned -> survives
    prune_result_cache(cache, max_cached=3, floor=3, pinned=(1,))
    assert ("bfs", 1) in cache and ("bfs", 0) not in cache
    # eviction sweep never touches pinned slots even over budget
    cache = {("bfs", i): mk(5) if i < 3 else mk(i) for i in range(6)}
    prune_result_cache(cache, max_cached=2, floor=0, pinned=(5,))
    assert all(cache[k].version == 5 for k in cache), cache
    # everything pinned: cache may transiently exceed max_cached
    cache = {("bfs", i): mk(7) for i in range(4)}
    prune_result_cache(cache, max_cached=2, floor=0, pinned=(7,))
    assert len(cache) == 4


def test_service_prune_respects_admission_pins():
    rng = np.random.default_rng(6)
    svc = GraphService(_seed_graph(rng), batch_size=4, max_cached=2)
    pin = svc.ring.pin()                    # an admitted query's pin at v0
    for src in range(4):
        svc.query("bfs", src)               # all slots land at pinned v0
    assert len(svc._cache) == 4, "pinned-version slots must not be evicted"
    pin.release()
    svc.query("bfs", 5)                     # next store prunes normally
    assert len(svc._cache) <= 2


# --------------------- bugfix 4: per-kind thresholds -----------------------

def test_default_thresholds_are_per_kind():
    assert DEFAULT_DIRTY_THRESHOLDS["bc"] == 0.05
    assert DEFAULT_DIRTY_THRESHOLDS["bfs"] == 0.25
    kinds = ("bfs", "sssp", "bc")
    assert resolve_dirty_thresholds(None, kinds) == {
        "bfs": 0.25, "sssp": 0.25, "bc": 0.05}
    assert resolve_dirty_thresholds(0.1, kinds) == {
        k: 0.1 for k in kinds}
    assert resolve_dirty_thresholds({"bc": 0.02}, kinds) == {
        "bfs": 0.25, "sssp": 0.25, "bc": 0.02}
    rng = np.random.default_rng(7)
    svc = GraphService(_seed_graph(rng))
    assert svc.dirty_thresholds["bc"] == 0.05
    assert svc._threshold("bc") == 0.05 and svc._threshold("bfs") == 0.25
    svc2 = GraphService(_seed_graph(rng), dirty_threshold=0.3)
    assert svc2._threshold("bc") == 0.3


def test_bc_threshold_routes_marginal_fracs_to_full():
    """~8% dirty: below the old shared 0.25 (delta — a guaranteed loss
    for BC's full backward sweep), above the new 0.05 default (full)."""
    g0 = _path_graph()
    svc = GraphService(g0, batch_size=2)
    svc.query("bc", 0)
    # two NEW edges dirty two reached sources: 2/64 (vcap) ~ 3.1% -> delta
    svc.submit_many([(PUTE, 5, 7, 1.0), (PUTE, 9, 11, 1.0)])
    svc.flush()
    assert svc.query("bc", 0).mode == "delta"
    # eight new edges dirty 8 reached sources: 12.5% -> full under 0.05
    svc.submit_many([(PUTE, 2 * i, 2 * i + 3, 1.0) for i in range(8)])
    svc.flush()
    assert svc.query("bc", 0).mode == "full"


def test_adaptive_clamp_reaches_bc_crossover():
    ctl = AdaptiveThresholds()
    assert ctl.lo == 0.005, "clamp floor must reach BC's few-percent " \
        "crossover"
    ctl2 = AdaptiveThresholds(base={"bfs": 0.25, "sssp": 0.25, "bc": 0.05})
    assert ctl2.thresholds() == {"bfs": 0.25, "sssp": 0.25, "bc": 0.05}
    with pytest.raises(ValueError):
        AdaptiveThresholds(base={"bfs": 0.25, "sssp": 0.25, "bc": 0.001})


# ------------------------- async front end ---------------------------------

def test_pad_pow2():
    assert [pad_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_batched_full_dispatch_bit_identical():
    """A burst of same-kind queries at one version runs as ONE compiled
    vmapped dispatch whose per-lane answers are bit-equal to the
    sequential single-source collects."""
    rng = np.random.default_rng(8)
    g0 = _seed_graph(rng)
    tel = Telemetry(block=False)
    svc = GraphService(g0, batch_size=4, telemetry=tel)
    fresh = {"bfs": bfs, "sssp": sssp, "bc": bc_dependencies}
    with AsyncGraphService(svc, max_batch=16) as srv:
        for kind in ("bfs", "sssp", "bc"):
            futs = [(s, srv.query_async(kind, s)) for s in range(6)]
            for s, f in futs:
                reply = f.result(timeout=120)
                assert reply.version == 0 and reply.mode == "full"
                assert results_equal(reply.result, fresh[kind](g0, s)), \
                    (kind, s)
    assert srv.stats.batched_dispatches >= 1
    assert srv.stats.max_batch_seen >= 2
    sizes = [s for h in tel.registry.find("serve_batch_size")
             for s in h.samples]
    assert sizes and max(sizes) >= 2
    st = svc.stats
    assert st.unchanged + st.delta + st.full == st.queries == 18


def test_batched_delta_rung_bit_identical():
    """Cached priors + a small committed churn: the dispatcher batches
    the delta lanes (one vmapped delta kernel call) and each lane equals
    the sequential full collect on the new snapshot."""
    g0 = _path_graph()
    tel = Telemetry(block=False)
    svc = GraphService(g0, batch_size=2, telemetry=tel)
    srcs = (0, 1, 2)
    with AsyncGraphService(svc, max_batch=16) as srv:
        for s in srcs:                       # warm priors at v0
            srv.query("bfs", s, timeout=120)
        svc.submit_many([(PUTE, 5, 7, 1.0), (PUTE, 9, 11, 1.0)])
        svc.flush()
        g1 = svc.ring.latest.state
        futs = [(s, srv.query_async("bfs", s)) for s in srcs]
        replies = [(s, f.result(timeout=120)) for s, f in futs]
    for s, reply in replies:
        assert reply.version == 1
        assert reply.mode == "delta", (s, reply.mode)
        assert results_equal(reply.result, bfs(g1, s)), s
    delta_sizes = [s for h in tel.registry.find("serve_batch_size",
                                                rung="delta")
                   for s in h.samples]
    assert delta_sizes and max(delta_sizes) >= 2, \
        "delta lanes must share one compiled dispatch"


def test_dispatch_fault_degrades_to_per_request_path():
    """An injected fault at ``serve.dispatch`` poisons the batch, not the
    requests: each falls back to the sequential resilient path and every
    answer is still exact."""
    rng = np.random.default_rng(9)
    g0 = _seed_graph(rng)
    svc = GraphService(g0, batch_size=4, policy=ResiliencePolicy())
    plan = FaultPlan({P_SERVE_DISPATCH: [0]})
    with fault_scope(plan):
        with AsyncGraphService(svc, max_batch=16) as srv:
            futs = [(s, srv.query_async("bfs", s)) for s in range(4)]
            for s, f in futs:
                reply = f.result(timeout=120)
                assert not reply.degraded
                assert results_equal(reply.result, bfs(g0, s)), s
    assert plan.fired == 1, "the dispatcher must see the activating " \
        "thread's fault plan (context propagation)"
    assert srv.stats.fallbacks >= 1
    st = svc.stats
    assert st.unchanged + st.delta + st.full == st.queries


def test_deadline_expiry_stale_serves_or_raises():
    rng = np.random.default_rng(10)
    g0 = _seed_graph(rng)
    svc = GraphService(g0, batch_size=4,
                       policy=ResiliencePolicy(deadline_ms=60_000))
    with AsyncGraphService(svc, max_batch=8) as srv:
        srv.query("bfs", 0, timeout=120)    # cache a servable slot
        svc.policy = ResiliencePolicy(deadline_ms=0.0)   # expire instantly
        reply = srv.query("bfs", 0, timeout=120)
        assert reply.degraded and reply.mode == "degraded"
        assert svc.ring.get_entry(reply.version) is not None
        svc.policy = ResiliencePolicy(deadline_ms=0.0, allow_stale=False)
        with pytest.raises(TimeoutError):
            srv.query("bfs", 1, timeout=120)
    assert srv.stats.deadline_expired >= 2
    assert svc.stats.degraded == 1


def test_admission_contract():
    rng = np.random.default_rng(11)
    svc = GraphService(_seed_graph(rng), batch_size=4)
    srv = AsyncGraphService(svc)
    with pytest.raises(RuntimeError):
        srv.query_async("bfs", 0)           # not started
    with pytest.raises(ValueError):
        AsyncGraphService(svc, max_batch=0)
    with srv:
        with pytest.raises(KeyError):
            srv.query_async("nope", 0)
        with pytest.raises(ValueError):
            srv.query_async("bfs", 0, mode="cn")   # cn needs the sync path
        with pytest.raises(ValueError):
            srv.query_async("bfs", None)
        # out-of-range source: served, flagged not-ok (same as sync path)
        assert not bool(srv.query("bfs", VCAP + 7, timeout=120).result.ok)
        assert srv.query("bfs", 0, timeout=120).version == 0
    # stopped cleanly: no pins leaked, a second start works
    assert svc.ring.pinned_versions() == []
    with srv:
        assert srv.query("sssp", 1, timeout=120).version == 0


def test_updates_overlap_pinned_reads():
    """Commits land while older-version queries are still pinned and
    in flight: the ring parks pinned versions instead of blocking the
    writer, and both sides finish."""
    rng = np.random.default_rng(12)
    g0 = _seed_graph(rng)
    svc = GraphService(g0, ring_depth=2, batch_size=2)
    with AsyncGraphService(svc, max_batch=4) as srv:
        futs = [srv.query_async("bfs", s) for s in range(4)]
        for _ in range(4):                   # rotate the window twice over
            srv.submit_many([(PUTE, 1, 2, 1.0), (PUTE, 3, 4, 1.0)])
        srv.flush()
        assert svc.version == 4
        for f in futs:
            reply = f.result(timeout=120)
            assert reply.version in (0, 1, 2, 3, 4)
    assert svc.ring.pinned_versions() == []
