"""Engine subsystem: delta-query equivalence, version-ring semantics,
scheduler order/coalescing guarantees, and the GraphService front end."""
import numpy as np
import pytest

from repro.core import (
    PUTE, PUTV, REME, REMV,
    apply_ops, dirty_vertices, make_graph, queries,
)
from repro.core.graph_state import NOKEY, live_edge_mask
from repro.core.queries import bc_level_cut
from repro.engine import (
    GraphService,
    StreamScheduler,
    VersionRing,
    incremental_bc,
    incremental_bfs,
    incremental_sssp,
    validate_incremental,
)

VCAP, ECAP = 96, 512


def _seed_graph(rng, n=VCAP, m=4 * VCAP):
    g = make_graph(VCAP, ECAP)
    ops = [(PUTV, i) for i in range(n)]
    for _ in range(m):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        ops.append((PUTE, u, v, float(rng.integers(1, 9))))
    g, _ = apply_ops(g, ops)
    return g


def _random_commit(rng, n=VCAP, n_ops=8, vertex_churn=True):
    """One commit's worth of randomized inserts/deletes."""
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        if vertex_churn and r < 0.06:
            ops.append((REMV, u))
        elif vertex_churn and r < 0.12:
            ops.append((PUTV, u))
        elif r < 0.6:
            ops.append((PUTE, u, v, float(rng.integers(1, 9))))
        else:
            ops.append((REME, u, v))
    return ops


def _edge_set(state):
    live = np.asarray(live_edge_mask(state))
    src = np.asarray(state.esrc)[live]
    dst = np.asarray(state.edst)[live]
    w = np.asarray(state.ew)[live]
    return {(int(u), int(v), float(x)) for u, v, x in zip(src, dst, w)}


def _assert_bit_identical(res, fresh):
    for a, b in zip(res, fresh):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------- incremental equivalence -------------------------

@pytest.mark.parametrize("kind,incr,full", [
    ("bfs", incremental_bfs, queries.bfs),
    ("sssp", incremental_sssp, queries.sssp),
    ("bc", incremental_bc, queries.bc_dependencies),
])
def test_incremental_matches_fresh_over_randomized_stream(kind, incr, full):
    """>= 20 randomized update/query interleavings, bit-identical results."""
    rng = np.random.default_rng(7)
    state = _seed_graph(rng)
    src = 0
    prior, stats = incr(state, None, None, src)
    assert stats.mode == "full"
    _assert_bit_identical(prior, full(state, src))
    modes = {"unchanged": 0, "delta": 0, "full": 0}
    for _ in range(24):
        new_state, _ = apply_ops(state, _random_commit(rng))
        dirty = dirty_vertices(state, new_state)
        res, stats = incr(new_state, prior, dirty, src)
        modes[stats.mode] += 1
        _assert_bit_identical(res, full(new_state, src))
        assert validate_incremental(new_state, src, res, kind)
        state, prior = new_state, res
    assert modes["delta"] > 0  # the delta path actually exercised


def test_incremental_unchanged_shortcut():
    rng = np.random.default_rng(1)
    state = _seed_graph(rng)
    prior, _ = incremental_bfs(state, None, None, 0)
    res, stats = incremental_bfs(
        state, prior, np.zeros(state.vcap, bool), 0)
    assert stats.mode == "unchanged" and res is prior


def test_incremental_threshold_falls_back_to_full():
    rng = np.random.default_rng(2)
    state = _seed_graph(rng)
    prior, _ = incremental_bfs(state, None, None, 0)
    all_dirty = np.ones(state.vcap, bool)
    res, stats = incremental_bfs(state, prior, all_dirty, 0,
                                 dirty_threshold=0.25)
    assert stats.mode == "full"
    _assert_bit_identical(res, queries.bfs(state, 0))


def test_incremental_unchanged_beats_threshold():
    """Heavy churn entirely outside the reached region: the cached answer
    is still valid, however large the dirty set."""
    g = make_graph(64, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(64)] + [(PUTE, 0, 1, 1.0)])
    prior, _ = incremental_bfs(g, None, None, 0)  # reaches only {0, 1}
    dirty = np.arange(64) >= 2  # 97% dirty, none of it reached
    res, stats = incremental_bfs(g, prior, dirty, 0, dirty_threshold=0.25)
    assert stats.mode == "unchanged" and res is prior


def test_incremental_sssp_zero_weight_parent_cycle():
    """Zero-weight tight edges can make the prior parent 'tree' cyclic;
    poison must still reach the cycle when its feeding edge is removed."""
    g = make_graph(8, 16)
    g, _ = apply_ops(g, [(PUTV, 0), (PUTV, 1), (PUTV, 2),
                         (PUTE, 2, 0, 1.0),
                         (PUTE, 0, 1, 0.0), (PUTE, 1, 0, 0.0)])
    prior, _ = incremental_sssp(g, None, None, 2)
    par = np.asarray(prior.parent)
    assert par[0] == 1 and par[1] == 0  # the parent cycle actually formed
    g2, _ = apply_ops(g, [(REME, 2, 0)])  # cut the cycle's only feed
    res, stats = incremental_sssp(g2, prior, dirty_vertices(g, g2), 2)
    assert stats.mode == "delta"
    _assert_bit_identical(res, queries.sssp(g2, 2))  # 0 and 1 unreachable


def _chain_graph(depth=8, width=2):
    """Layered DAG: vertex l*width+j sits at BFS level l from source 0."""
    n = depth * width
    ops = [(PUTV, i) for i in range(n)]
    ops += [(PUTE, 0, j, 1.0) for j in range(1, width)]  # level-0 clique seed
    for l in range(depth - 1):
        for j in range(width):
            for k in range(width):
                ops.append((PUTE, l * width + j, (l + 1) * width + k, 1.0))
    g = make_graph(n, 4 * n * width)
    g, _ = apply_ops(g, ops)
    return g, n


def test_bc_level_cut_semantics():
    """Edge churn at level l cuts at l+1; a death at level l cuts at l;
    untouched sources cut past every level."""
    g, n = _chain_graph(depth=6, width=2)
    prior = queries.bc_dependencies(g, 0)
    lvl = np.asarray(prior.level)
    deep = int(np.flatnonzero(lvl == 4)[0])
    dirty = np.zeros(n, bool)
    dirty[deep] = True
    cut = int(bc_level_cut(prior.level, dirty, g.alive))
    assert cut == 5  # out-edge churn at level 4 can only disturb level >= 5
    g2, _ = apply_ops(g, [(REMV, deep)])
    cut2 = int(bc_level_cut(prior.level, dirty_vertices(g, g2), g2.alive))
    assert cut2 == 4  # the vertex itself died: its own level is suspect
    assert int(bc_level_cut(prior.level, np.zeros(n, bool), g.alive)) > 5


def test_incremental_bc_deep_cut_is_delta_and_exact():
    """Churn confined below the median level takes the delta path and is
    bit-identical to a fresh bc_dependencies (level/sigma/delta all)."""
    g, n = _chain_graph(depth=8, width=2)
    prior, st = incremental_bc(g, None, None, 0)
    assert st.mode == "full"
    deep = int(np.flatnonzero(np.asarray(prior.level) == 6)[0])
    g2, _ = apply_ops(g, [(REME, deep, int(np.flatnonzero(
        np.asarray(prior.level) == 7)[0]))])
    res, st = incremental_bc(g2, prior, dirty_vertices(g, g2), 0)
    assert st.mode == "delta"
    _assert_bit_identical(res, queries.bc_dependencies(g2, 0))
    assert validate_incremental(g2, 0, res, "bc")


def test_incremental_bc_source_level_dirt_falls_back_to_full():
    """A cut of 0 (the source itself suspect) cannot warm-start: full."""
    g, n = _chain_graph(depth=4, width=2)
    prior, _ = incremental_bc(g, None, None, 0)
    g2, _ = apply_ops(g, [(PUTE, 0, 5, 1.0)])  # source out-list churn
    res, st = incremental_bc(g2, prior, dirty_vertices(g, g2), 0)
    # source dirty at level 0 -> cut 1 is still a valid warm start (only
    # level 0 is reused); dirt at the source's own liveness would cut 0
    assert st.mode in ("delta", "full")
    _assert_bit_identical(res, queries.bc_dependencies(g2, 0))
    g3, _ = apply_ops(g, [(REMV, 0)])
    res3, st3 = incremental_bc(g3, prior, dirty_vertices(g, g3), 0)
    assert st3.mode == "full"  # dead source: cut 0
    _assert_bit_identical(res3, queries.bc_dependencies(g3, 0))


def test_service_bc_scores_revived_source_not_unchanged():
    """Resurrecting a dead vertex gives it a non-empty forward tree, but
    its cached row is empty and intersects no dirty set — bc_scores must
    still recompute it (cold row inside the warm sweep)."""
    g = make_graph(16, 64)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(8)]
                     + [(PUTE, 0, 1, 1.0), (PUTE, 1, 2, 1.0)])
    g, _ = apply_ops(g, [(REMV, 5)])
    svc = GraphService(g, batch_size=4)
    svc.bc_scores()
    svc.submit_many([(PUTV, 5), (PUTE, 5, 1, 1.0)])
    svc.flush()
    scores, _ = svc.bc_scores()
    assert svc.bc_scores_stats["unchanged"] == 0
    ref, _ = GraphService(svc.ring.latest.state).bc_scores()
    a, b = np.asarray(scores), np.asarray(ref)
    assert np.array_equal(np.isnan(a), np.isnan(b))
    assert np.array_equal(np.nan_to_num(a), np.nan_to_num(b))


def test_service_bc_scores_delta_bit_identical():
    """GraphService.bc_scores warm-starts all-source BC through the
    per-source level cut and stays bit-identical to a cold recompute."""
    rng = np.random.default_rng(21)
    svc = _service(rng)
    svc.bc_scores()
    svc.submit_many([(PUTE, 3, 9, 2.0), (REME, 5, 11), (PUTE, 40, 7, 1.0)])
    svc.flush()
    scores, ver = svc.bc_scores()
    assert svc.bc_scores_stats["delta"] == 1
    cold = GraphService(svc.ring.latest.state)
    ref, _ = cold.bc_scores()
    a, b = np.asarray(scores), np.asarray(ref)
    assert np.array_equal(np.isnan(a), np.isnan(b))
    assert np.array_equal(np.nan_to_num(a), np.nan_to_num(b))


def test_incremental_sssp_negative_cycle_matches_full():
    g = make_graph(8, 16)
    g, _ = apply_ops(g, [(PUTV, 0), (PUTV, 1), (PUTV, 2),
                         (PUTE, 0, 1, 1.0), (PUTE, 1, 2, 1.0)])
    prior, _ = incremental_sssp(g, None, None, 0)
    ops = [(PUTE, 2, 1, -5.0)]  # closes a negative cycle 1->2->1
    g2, _ = apply_ops(g, ops)
    res, stats = incremental_sssp(g2, prior, dirty_vertices(g, g2), 0)
    assert stats.mode == "full"  # negcycle forces the canonical full answer
    _assert_bit_identical(res, queries.sssp(g2, 0))
    assert bool(res.negcycle)


# ------------------------------ version ring ------------------------------

def test_ring_rotation_and_eviction():
    rng = np.random.default_rng(3)
    state = _seed_graph(rng)
    ring = VersionRing(state, depth=3)
    for _ in range(4):
        state, _ = apply_ops(state, _random_commit(rng))
        ring.commit(state)
    assert ring.latest.version == 4
    assert ring.oldest_version == 2
    assert ring.get(1) is None  # rotated out
    assert ring.get(3) is not None
    assert ring.evictions == 2  # versions 0 and 1


def test_ring_pin_survives_rotation():
    rng = np.random.default_rng(4)
    state = _seed_graph(rng)
    ring = VersionRing(state, depth=2)
    pin = ring.pin()  # pins version 0
    pinned_edges = _edge_set(pin.state)
    for _ in range(3):
        state, _ = apply_ops(state, _random_commit(rng))
        ring.commit(state)
    assert ring.get(0) is not None  # parked, not evicted
    assert _edge_set(pin.state) == pinned_edges  # snapshot is immutable
    pin.release()
    assert ring.get(0) is None
    with pytest.raises(KeyError):
        ring.pin(0)


def test_ring_dirty_between():
    rng = np.random.default_rng(5)
    state = _seed_graph(rng)
    ring = VersionRing(state, depth=8)
    states = [state]
    for _ in range(3):
        state, _ = apply_ops(state, _random_commit(rng))
        ring.commit(state)
        states.append(state)
    span = np.asarray(ring.dirty_between(0, 3))
    direct = np.asarray(dirty_vertices(states[0], states[3]))
    # the ORed span covers every actual change (it may be a superset:
    # a vertex touched then reverted is dirty per-commit but not end-to-end)
    assert not np.any(direct & ~span)
    assert not np.any(np.asarray(ring.dirty_between(3, 3)))
    assert ring.dirty_between(0, 99) is None  # future version unknown
    with pytest.raises(ValueError):
        ring.dirty_between(3, 0)


def test_ring_dirty_between_evicted_span_is_none():
    rng = np.random.default_rng(6)
    state = _seed_graph(rng)
    ring = VersionRing(state, depth=2)
    for _ in range(4):
        state, _ = apply_ops(state, _random_commit(rng))
        ring.commit(state)
    assert ring.dirty_between(0, ring.latest.version) is None
    assert ring.dirty_between(0, 0) is None  # empty span, evicted version
    assert ring.dirty_between(ring.latest.version - 1,
                              ring.latest.version) is not None


# ------------------------------- scheduler --------------------------------

def test_scheduler_auto_commits_full_batches():
    rng = np.random.default_rng(8)
    ring = VersionRing(_seed_graph(rng), depth=8)
    sched = StreamScheduler(ring, batch_size=4)
    for op in [(PUTE, 0, i, 1.0) for i in range(3)]:
        sched.submit(op)
    assert ring.latest.version == 0 and sched.pending() == 3
    sched.submit((PUTE, 0, 3, 1.0))  # fills the batch
    assert ring.latest.version == 1 and sched.pending() == 0
    assert sched.stats.batches_committed == 1
    sched.submit((REME, 0, 1))
    entries = sched.flush()  # drains the partial tail
    assert len(entries) == 1 and ring.latest.version == 2
    assert sched.stats.ops_committed == 5


def test_scheduler_rejects_reads():
    rng = np.random.default_rng(8)
    sched = StreamScheduler(VersionRing(_seed_graph(rng)), batch_size=4)
    with pytest.raises(ValueError):
        sched.submit(("GETV", 0))


def _committed_state(ops, **kw):
    ring = VersionRing(make_graph(16, 64), depth=64)
    sched = StreamScheduler(ring, **kw)
    sched.submit_many(ops)
    sched.flush()
    return ring.latest.state, sched


def test_scheduler_strict_order_equals_sequential():
    """strict_order history == applying every op one at a time, in order."""
    rng = np.random.default_rng(9)
    ops = [(PUTV, i) for i in range(8)]
    for _ in range(40):
        r = rng.random()
        u, v = int(rng.integers(0, 8)), int(rng.integers(0, 8))
        if r < 0.15:
            ops.append((REMV, u))
        elif r < 0.3:
            ops.append((PUTV, u))
        elif r < 0.7:
            ops.append((PUTE, u, v, float(rng.integers(1, 5))))
        else:
            ops.append((REME, u, v))
    strict, sched = _committed_state(ops, batch_size=8, strict_order=True)
    assert sched.stats.strict_cuts > 0  # the guarantee was actually needed
    seq = make_graph(16, 64)
    for op in ops:
        seq, _ = apply_ops(seq, [op])
    assert _edge_set(strict) == _edge_set(seq)
    assert np.array_equal(np.asarray(strict.alive), np.asarray(seq.alive))


def test_scheduler_coalesce_preserves_state():
    ops = [(PUTV, 0), (PUTV, 1), (PUTV, 2)]
    ops += [(PUTE, 0, 1, float(w)) for w in (1, 2, 3)]  # same key x3
    ops += [(PUTE, 1, 2, 9.0), (REME, 1, 2)]            # put then rem
    plain, _ = _committed_state(list(ops), batch_size=32)
    coal, sched = _committed_state(list(ops), batch_size=32, coalesce=True)
    assert sched.stats.ops_coalesced == 3
    assert _edge_set(plain) == _edge_set(coal) == {(0, 1, 3.0)}


# ------------------------------ GraphService ------------------------------

def _service(rng, **kw):
    return GraphService(_seed_graph(rng), batch_size=8, ring_depth=8, **kw)


def test_service_icn_incremental_path_matches_fresh():
    rng = np.random.default_rng(10)
    svc = _service(rng)
    r0 = svc.query("bfs", 0)
    assert r0.mode == "full" and r0.version == 0
    r1 = svc.query("bfs", 0)  # nothing committed since: cached answer
    assert r1.mode == "unchanged"
    for _ in range(3):
        svc.submit_many(_random_commit(rng, vertex_churn=False))
        svc.flush()
        r = svc.query("bfs", 0)
        assert r.version == svc.version
        _assert_bit_identical(r.result, queries.bfs(svc.ring.latest.state, 0))
    assert svc.stats.delta > 0


def test_service_cn_double_collect_validates():
    rng = np.random.default_rng(11)
    svc = _service(rng)
    svc.submit_many(_random_commit(rng))
    svc.flush()
    r = svc.query("sssp", 0, mode="cn")
    assert r.validated and r.scan.collects >= 2
    _assert_bit_identical(r.result,
                          queries.sssp(svc.ring.latest.state, 0))


def test_service_cn_consumes_pending_updates_between_collects():
    rng = np.random.default_rng(12)
    svc = _service(rng)
    svc.query("bfs", 0)
    # leave updates pending (no flush): cn's interrupting commit_one drains
    # one batch between collects, so the answer lands on a newer version
    svc.submit_many([(PUTE, 0, i, 1.0) for i in range(1, 6)])
    assert svc.scheduler.pending() > 0
    r = svc.query("bfs", 0, mode="cn")
    assert r.validated
    assert r.version > 0
    _assert_bit_identical(r.result, queries.bfs(svc.ring.latest.state, 0))


def test_service_cache_eviction_is_lru():
    rng = np.random.default_rng(14)
    svc = _service(rng, max_cached=2)
    svc.query("bfs", 0)
    svc.query("bfs", 1)
    svc.query("bfs", 0)  # refresh 0: it is now the most recent
    svc.query("bfs", 2)  # evicts 1, not 0
    assert ("bfs", 0) in svc._cache and ("bfs", 1) not in svc._cache
    r = svc.query("bfs", 0)
    assert r.mode == "unchanged"  # the hot key survived eviction


def test_service_rejects_unknown_kind_and_mode():
    rng = np.random.default_rng(13)
    svc = _service(rng)
    with pytest.raises(KeyError):
        svc.query("pagerank", 0)
    for kind in ("bfs", "bc"):
        with pytest.raises(ValueError):
            svc.query(kind, 0, mode="maybe")


def test_service_bc_supports_cn_double_collect():
    rng = np.random.default_rng(15)
    svc = _service(rng)
    r = svc.query("bc", 0, mode="cn")
    # The first collect recomputes; the second lands on the same version and
    # the (kind, src) cache answers it as "unchanged" — BC now shares the
    # BFS/SSSP snapshot/cache semantics.
    assert r.validated and r.scan.collects >= 2
    _assert_bit_identical(r.result,
                          queries.bc_dependencies(svc.ring.latest.state, 0))


def test_service_bc_cache_semantics_match_bfs():
    """BC is a cached query kind with the full unchanged/delta/full ladder:
    every mode is bit-identical to a fresh ``bc_dependencies``."""
    rng = np.random.default_rng(16)
    svc = _service(rng)
    r0 = svc.query("bc", 0)
    assert r0.mode == "full"
    r1 = svc.query("bc", 0)  # nothing committed since
    assert r1.mode == "unchanged" and r1.result is r0.result
    modes = set()
    for _ in range(6):
        svc.submit_many(_random_commit(rng, vertex_churn=False))
        svc.flush()
        r = svc.query("bc", 0)
        modes.add(r.mode)
        assert r.version == svc.version
        _assert_bit_identical(
            r.result, queries.bc_dependencies(svc.ring.latest.state, 0))
    assert "delta" in modes  # the level-cut path actually exercised


def test_service_bc_unchanged_outside_reached_region():
    g = make_graph(64, 256)
    g, _ = apply_ops(g, [(PUTV, i) for i in range(64)] + [(PUTE, 0, 1, 1.0)])
    svc = GraphService(g, batch_size=4, ring_depth=8)
    r0 = svc.query("bc", 0)  # reaches only {0, 1}
    svc.submit_many([(PUTE, 10, i, 1.0) for i in range(20, 24)])
    svc.flush()
    r1 = svc.query("bc", 0)
    assert r1.mode == "unchanged" and r1.result is r0.result


def test_service_bc_scores_incremental_tile_view():
    """bc_scores runs the batched Brandes over an incrementally refreshed
    tile view and matches the per-source map baseline."""
    from repro.core import build_tile_view
    rng = np.random.default_rng(17)
    svc = _service(rng)
    scores0, v0 = svc.bc_scores()
    svc.submit_many(_random_commit(rng))
    svc.flush()
    scores1, v1 = svc.bc_scores()
    assert v1 > v0
    state = svc.ring.latest.state
    # the incrementally refreshed view is identical to a fresh build
    fresh = build_tile_view(state)
    assert np.array_equal(np.asarray(svc._tiles.w), np.asarray(fresh.w))
    assert np.array_equal(np.asarray(svc._tiles.occ), np.asarray(fresh.occ))
    for v in (0, 7, 33):
        ref = float(queries.bc(state, v, method="map"))
        got = float(np.asarray(scores1)[v])
        if np.isnan(ref):
            assert np.isnan(got)
        else:
            assert got == pytest.approx(ref, rel=1e-4, abs=1e-4)


# ------------------------- ring edge semantics ----------------------------

def test_ring_release_is_idempotent_and_tolerates_unpinned():
    """Double release of a pin and release of a never-pinned version are
    both no-ops: counts never go negative, residency never changes."""
    rng = np.random.default_rng(20)
    state = _seed_graph(rng)
    ring = VersionRing(state, depth=3)
    ring.release(0)     # never pinned: no-op
    ring.release(99)    # never existed: no-op
    assert ring.pinned_versions() == [] and ring.get(0) is not None

    pin = ring.pin(0)
    pin.release()
    pin.release()       # handle-level idempotence
    ring.release(0)     # and a third, direct, release: still a no-op
    assert ring.pinned_versions() == []
    assert ring.get(0) is not None  # still resident: release != evict

    # two pins on one version need two releases
    ring.pin(0)
    ring.pin(0)
    ring.release(0)
    assert ring.pinned_versions() == [0]
    ring.release(0)
    assert ring.pinned_versions() == []


def test_ring_parked_entry_keeps_serving_after_rotation():
    """A pinned version rotated out of the window parks: get/get_entry and
    snapshot reads keep working until the last release, which evicts it."""
    rng = np.random.default_rng(21)
    state = _seed_graph(rng)
    ring = VersionRing(state, depth=2)
    pin = ring.pin(0)
    for _ in range(4):
        state, _ = apply_ops(state, _random_commit(rng))
        ring.commit(state)
    assert ring.oldest_version == 3        # 0 long gone from the window
    entry = ring.get_entry(0)
    assert entry is not None and entry.version == 0
    assert _edge_set(pin.state) == _edge_set(entry.state)
    # dirty history is window-only: parked entries never resurrect spans
    assert ring.dirty_between(0, ring.latest.version) is None
    evictions = ring.evictions
    pin.release()
    assert ring.get_entry(0) is None and ring.evictions == evictions + 1


def test_ring_dirty_between_across_vcap_growth():
    """A span that crosses a vertex-table growth pads the narrower masks:
    the result is sized to the newest state's vcap with no phantom dirt in
    the grown region."""
    from repro.core import grow_vertices
    rng = np.random.default_rng(22)
    state = _seed_graph(rng)
    vcap0 = state.vcap
    ring = VersionRing(state, depth=8)
    state, _ = apply_ops(state, _random_commit(rng))
    ring.commit(state)                         # v1 @ vcap0
    state = grow_vertices(state)
    state, _ = apply_ops(state, _random_commit(rng))
    ring.commit(state)                         # v2 @ 2*vcap0
    assert state.vcap > vcap0
    span = ring.dirty_between(0, 2)
    assert span is not None and span.shape[0] == state.vcap
    # commits only touched ids < vcap0: the grown region must be clean
    assert not bool(np.asarray(span)[vcap0:].any())
    # the padded span still covers the end-to-end dirty set
    per = [np.asarray(ring.get_entry(v).dirty) for v in (1, 2)]
    ored = np.zeros((state.vcap,), bool)
    for m in per:
        ored[: m.shape[0]] |= m
    assert np.array_equal(np.asarray(span), ored)
    # an empty span anchored at the narrow version sizes to THAT vcap
    assert np.asarray(ring.dirty_between(1, 1)).shape[0] == vcap0


# ----------------------- heartbeat / straggler wiring ----------------------

def test_scheduler_heartbeat_flags_slow_commits():
    """A HeartbeatMonitor handed to the scheduler watches commit latency:
    with factor=0 every commit after the 8-sample warmup is a straggler —
    counted on the monitor, mirrored into scheduler_stragglers, and
    annotated on the commit's trace span."""
    from repro.obs import Telemetry
    from repro.runtime.fault_tolerance import HeartbeatMonitor

    rng = np.random.default_rng(23)
    flagged = []
    mon = HeartbeatMonitor(window=32, factor=0.0,
                           on_straggler=lambda v, dt, med: flagged.append(v))
    tel = Telemetry.make(None)
    svc = GraphService(_seed_graph(rng), batch_size=4, telemetry=tel,
                       monitor=mon)
    for _ in range(6):
        svc.submit_many(_random_commit(rng, n_ops=8))
        svc.flush()
    n = svc.scheduler.stats.batches_committed
    assert n >= 10
    assert mon.stragglers == n - 8 == svc.scheduler.stats.stragglers
    assert flagged and flagged[0] == 9  # ring version of the 9th commit
    commits = [r for r in tel.tracer.records if r["span"] == "commit"]
    assert sum(bool(r.get("straggler")) for r in commits) == mon.stragglers
    assert len(mon.window) == n
    tel.close()
