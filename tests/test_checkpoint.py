"""Checkpoint store: roundtrip, double-collect validation, elastic restore,
async writer, and the restart loop."""
import json
import os
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (
    Checkpointer, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.runtime import HeartbeatMonitor, RestartableLoop


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 3, t, version=1)
    assert latest_step(str(tmp_path)) == 3
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out = restore_checkpoint(str(tmp_path), 3, sds)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_double_collect_retry_on_concurrent_writer(tmp_path):
    """A version bump between the two manifest reads forces a retry —
    the paper's SCAN/CMPTREE on files."""
    t = tree()
    save_checkpoint(str(tmp_path), 1, t, version=1)
    d = os.path.join(str(tmp_path), "step_00000001")
    manifest = json.load(open(os.path.join(d, "manifest.json")))

    calls = {"n": 0}
    orig_load = np.load

    def racy_load(path, *a, **k):
        calls["n"] += 1
        if calls["n"] == 1:   # concurrent writer commits mid-restore
            manifest["version"] = 2
            with open(os.path.join(d, "manifest.json"), "w") as f:
                json.dump(manifest, f)
        return orig_load(path, *a, **k)

    np.load = racy_load
    try:
        sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           t)
        out = restore_checkpoint(str(tmp_path), 1, sds)
    finally:
        np.load = orig_load
    # retried and succeeded against the new stable version
    assert calls["n"] > len(jax.tree.leaves(t))
    assert np.array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_async_checkpointer_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = tree()
    for s in (10, 20, 30):
        ck.save(s, t)
    ck.wait()
    steps = sorted(n for n in os.listdir(str(tmp_path))
                   if n.startswith("step_"))
    assert steps == ["step_00000020", "step_00000030"]
    step, out = ck.restore_latest(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 30


def test_elastic_reshard_restore(tmp_path):
    """Leaves are stored unsharded: restoring under a different device
    layout is just device_put with new shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, t, version=1)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out = restore_checkpoint(str(tmp_path), 1, sds, mesh=mesh,
                             specs={"w": P("data", None)})
    assert np.array_equal(np.asarray(out["w"]), np.arange(16.0).reshape(4, 4))
    assert out["w"].sharding.spec == P("data", None)


def test_restartable_loop_resumes_after_crash(tmp_path):
    calls = []

    def step_fn(state, step):
        calls.append(step)
        return {"x": state["x"] + 1}, None

    def step_fn2(state, step):
        calls.append(step)
        return {"x": state["x"] + 1}

    state0 = {"x": jnp.float32(0)}
    loop = RestartableLoop(str(tmp_path), step_fn2, state0, ckpt_every=5)
    with pytest.raises(RuntimeError):
        loop.run(state0, total_steps=20, fail_at=12)
    # crash at step 12; checkpoint exists at 10
    assert latest_step(str(tmp_path)) == 10
    loop2 = RestartableLoop(str(tmp_path), step_fn2, state0, ckpt_every=5)
    final, done = loop2.run(state0, total_steps=20)
    assert done == 20
    assert float(final["x"]) == 20.0           # no lost or repeated steps
    assert calls.count(11) == 2                 # 11 replayed from ckpt 10
    assert calls.count(4) == 1                  # pre-ckpt steps not replayed


def test_heartbeat_straggler_detection():
    events = []
    mon = HeartbeatMonitor(window=16, factor=3.0,
                           on_straggler=lambda *a: events.append(a))
    for i in range(12):
        mon.start()
        time.sleep(0.002)
        mon.stop(i)
    mon.start()
    time.sleep(0.05)     # 25x median: a straggler
    mon.stop(99)
    assert mon.stragglers == 1
    assert events and events[0][0] == 99


def test_elastic_rescale_to_multidevice_mesh(tmp_path):
    """Train-state saved single-device restores sharded onto a 2x2 mesh —
    the elastic-scaling path (mesh size is not part of the format).
    Subprocess so the 4 placeholder devices never leak into other tests."""
    import subprocess
    import sys
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint

tree = {{"w": jnp.arange(64.0).reshape(8, 8),
         "m": jnp.ones((8, 8), jnp.float32)}}
save_checkpoint(r"{tmp_path}", 5, tree, version=1)
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
out = restore_checkpoint(r"{tmp_path}", 5, sds, mesh=mesh,
                         specs={{"w": P("data", "model"), "m": P("data", None)}})
assert np.array_equal(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
assert len(out["w"].sharding.device_set) == 4
# and it is usable under the mesh straight away
with mesh:
    y = jax.jit(lambda a, b: a @ b)(out["w"], out["m"])
assert np.isfinite(np.asarray(y)).all()
print("ELASTIC OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ELASTIC OK" in r.stdout
