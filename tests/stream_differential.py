"""Randomized differential op-stream harness.

One seeded RNG drives a mixed stream of vertex/edge mutations and
bfs/sssp/bc queries; the stream is replayed simultaneously against

  * the pure-python sequential oracle (``tests/oracle.py`` — the paper's
    ADT semantics, trusted by the PR-1 property tests),
  * the local :class:`repro.engine.GraphService`, and
  * (when given a mesh) the distributed
    :class:`repro.shard.ShardedGraphService` in either ``bc_mode``,

asserting after EVERY query that the service's answer — whatever rung of
the unchanged → delta → full ladder produced it — equals the oracle's at
that version.  The churn alternates between the half of the vertex range
the pinned sources live in and the far half, so one stream naturally
exercises all three ladder modes (the per-service mode tallies are
returned for the caller to assert on), plus the delta fallbacks: negative
weights (``neg_frac``) breed negative cycles mid-stream (delta SSSP must
fall back to the canonical full answer) and REMV/PUTV pairs resurrect
sources whose empty cached rows must restart cold.

Every replay also runs with telemetry attached (one shared
:class:`repro.obs.Telemetry` across the services): after the stream the
harness asserts ladder-mode *conservation* — ``unchanged + delta + full ==
stats.queries == #query trace records`` per service — and that the trace
records agree, in order, with every oracle-validated answer's
(kind, version, ladder mode).  ``trace_path`` additionally streams the
records to a JSONL file for ``python -m repro.obs.report``.

**Chaos mode** (``fault_plan=...``): the replay runs inside a
``repro.resil.fault_scope`` with a :class:`~repro.resil.ResiliencePolicy`
attached to every service, so injected faults hit the scheduler commits,
the collect ladder, ring eviction, and the result-cache stores mid-
stream.  The contract is **degraded-or-correct, never silently wrong**:

  * a commit that faults is retried until it lands — the scheduler's
    atomicity guarantee means the retry replays the identical prefix;
  * a successful (non-degraded) answer is checked against the oracle
    exactly as in a clean run;
  * a ``degraded=True`` answer must reproduce, bit-for-bit
    (``results_equal``), a previously oracle-validated answer at its
    still-resident ``stale_version``;
  * a query that raises (ladder exhausted, nothing cached) is counted —
    and ``verify_service`` must pass after EVERY injected fault.

Everything is keyed on the integer ``seed`` (logged on entry), so any
failure is reproducible with ``run_differential(seed, ...)`` alone; a
chaos failure additionally reproduces from
``FaultPlan(plan.to_schedule())``.
"""
from __future__ import annotations

import os

import numpy as np

from repro.core import PUTE, PUTV, REME, REMV, make_graph
from repro.engine import GraphService
from repro.engine.incremental import results_equal
from repro.obs import AdaptiveThresholds, Telemetry
from repro.resil import (
    InjectedFault,
    OpJournal,
    ResiliencePolicy,
    assert_service_ok,
    fault_scope,
    journal_meta,
    recover,
)
from oracle import GraphOracle

INF = float("inf")
WEIGHTS = (1.0, 2.0, 3.0)


# ------------------------------ stream gen ---------------------------------

def gen_ops(rng, lo: int, hi: int, count: int, neg_frac: float = 0.0):
    """One commit's worth of mixed ops confined to vertex range [lo, hi)."""
    ops = []
    for _ in range(count):
        r = float(rng.random())
        u = int(rng.integers(lo, hi))
        v = int(rng.integers(lo, hi))
        if r < 0.15:
            ops.append((PUTV, u))
        elif r < 0.25:
            ops.append((REMV, u))
        elif r < 0.85:
            w = (-1.0 if float(rng.random()) < neg_frac
                 else float(WEIGHTS[int(rng.integers(0, len(WEIGHTS)))]))
            ops.append((PUTE, u, v, w))
        else:
            ops.append((REME, u, v))
    return ops


def _apply_oracle(oracle: GraphOracle, ops) -> None:
    for op in ops:
        if op[0] == PUTV:
            oracle.put_v(op[1])
        elif op[0] == REMV:
            oracle.rem_v(op[1])
        elif op[0] == PUTE:
            oracle.put_e(op[1], op[2], op[3])
        else:
            oracle.rem_e(op[1], op[2])


# ------------------------------ checkers -----------------------------------

def _dense(m: dict, vcap: int, fill: float) -> np.ndarray:
    out = np.full((vcap,), fill, np.float64)
    for v, d in m.items():
        out[v] = d
    return out


def _first(x, sharded: bool):
    return x[0] if sharded else x


def check_bfs(ctx, reply, oracle, src, vcap, sharded):
    ref = oracle.bfs(src)
    ok = bool(_first(reply.result.ok, sharded))
    assert ok == (ref is not None), ctx
    dist = np.asarray(_first(reply.result.dist, sharded), np.float64)
    exp = _dense(ref or {}, vcap, -1.0)
    assert np.array_equal(dist, exp), ctx


def check_sssp(ctx, reply, oracle, src, vcap, sharded):
    ref, refneg = oracle.sssp(src)
    neg = bool(_first(reply.result.negcycle, sharded))
    ok = bool(_first(reply.result.ok, sharded))
    assert neg == refneg, ctx
    assert ok == (ref is not None and not refneg), ctx
    if ref is None or refneg:
        # a negative-cycle answer's partially-relaxed distances are only
        # canonical per implementation; the flag is the contract
        return
    dist = np.asarray(_first(reply.result.dist, sharded), np.float64)
    assert np.array_equal(dist, _dense(ref, vcap, INF)), ctx


def check_bc(ctx, reply, oracle, src, vcap, sharded):
    ref = oracle.bc_dependencies(src)
    ok = bool(_first(reply.result.ok, sharded))
    assert ok == (ref is not None), ctx
    if ref is None:
        return
    # levels ARE the oracle's BFS distances (hop metric), exactly
    level = np.asarray(_first(reply.result.level, sharded), np.float64)
    assert np.array_equal(level, _dense(oracle.bfs(src), vcap, -1.0)), ctx
    delta = np.asarray(_first(reply.result.delta, sharded), np.float64)
    assert np.allclose(delta, _dense(ref, vcap, 0.0),
                       rtol=1e-5, atol=1e-5), ctx


def check_scores(ctx, scores, oracle, vcap):
    ref = oracle.bc_scores()
    sc = np.asarray(scores, np.float64)
    for v in range(vcap):
        if v in ref:
            assert abs(sc[v] - ref[v]) <= 1e-4 * (1.0 + abs(ref[v])), (ctx, v)
        else:
            assert np.isnan(sc[v]), (ctx, v)


_CHECK = {"bfs": check_bfs, "sssp": check_sssp, "bc": check_bc}


# -------------------------------- runner -----------------------------------

def run_differential(seed: int, *, n: int = 24, steps: int = 8,
                     ops_per_step: int = 8, neg_frac: float = 0.0,
                     mesh=None, tile: int = 8, bc_mode: str = "gather",
                     batch_size: int = 4, score_every: int = 0,
                     trace_path=None, fault_plan=None, policy=None,
                     adaptive: bool = False, journal_dir=None,
                     compact_every=None, segment_bytes=None):
    """Replay one seeded stream against oracle + service(s).

    Returns ``{service_name: {"unchanged": k, "delta": k, "full": k,
    "degraded": k, "raised": k}}`` so callers can assert ladder-mode (and,
    in chaos runs, degradation) coverage.  Raises AssertionError (with
    the offending (service, kind, src, step, mode) context) on the first
    divergence from the oracle, and at the end on any telemetry
    inconsistency (mode-conservation or trace/answer disagreement — see
    module docstring).  ``trace_path`` mirrors the trace to a JSONL file.

    ``fault_plan`` (a ``repro.resil.FaultPlan``) turns on chaos mode: the
    whole replay runs inside its ``fault_scope`` and every service gets
    ``policy`` (default: 2 retries, stale serving on) — see the module
    docstring for the degraded-or-correct contract enforced per query.

    ``adaptive=True`` attaches an aggressive per-service
    :class:`~repro.obs.AdaptiveThresholds` controller (tight period,
    frequent probes) so the per-kind ``dirty_threshold`` actually moves
    mid-stream — every per-query oracle check then doubles as the proof
    that a moving threshold only re-routes queries between (bit-identical)
    ladder rungs.  The harness additionally asserts the controller
    invariants at the end (thresholds within clamps, one
    ``threshold_adjust`` span per adjustment) and returns each
    controller's snapshot under ``modes[name]["adaptive"]``.

    ``journal_dir`` attaches a durable :class:`~repro.resil.OpJournal`
    (``<dir>/<service>.jsonl``) to every service — with ``segment_bytes``
    rotation and ``compact_every`` auto-compaction if given — and after
    the stream runs the **recovery differential**: each journal is
    recovered into a fresh service (the sharded one under the same live
    mesh) whose ring latest must be bit-identical to the survivor's and
    whose cold query answers must match the oracle at the final version.
    The per-journal rotation/compaction tallies come back under
    ``modes[name]["recovery"]``.
    """
    print(f"[stream-differential] seed={seed} n={n} steps={steps} "
          f"ops_per_step={ops_per_step} neg_frac={neg_frac} "
          f"bc_mode={bc_mode} chaos={fault_plan is not None}", flush=True)
    rng = np.random.default_rng(seed)
    g0 = make_graph(n, 16 * n)
    oracle = GraphOracle()
    telemetry = Telemetry.make(trace_path, hlo=mesh is not None)
    if fault_plan is not None and policy is None:
        policy = ResiliencePolicy(max_retries=2)

    def make_adaptive():
        # Aggressive settings: small graphs + short streams must still see
        # adjustments and probes, or the adaptive assertions test nothing.
        return (AdaptiveThresholds(period=6, min_full=1, min_delta=3,
                                   probe_every=7) if adaptive else None)

    journals = {}

    def make_journal(name):
        if journal_dir is None:
            return None
        path = os.path.join(str(journal_dir), f"{name}.jsonl")
        journals[name] = path
        return OpJournal(path,
                         meta=journal_meta(g0, {"batch_size": batch_size}),
                         segment_bytes=segment_bytes)

    services = [("local", GraphService(g0, batch_size=batch_size,
                                       telemetry=telemetry, policy=policy,
                                       adaptive=make_adaptive(),
                                       journal=make_journal("local"),
                                       compact_every=compact_every),
                 False)]
    if mesh is not None:
        from repro.shard import ShardedGraphService
        services.append(("sharded", ShardedGraphService(
            g0, mesh, tile=tile, batch_size=batch_size, bc_mode=bc_mode,
            src_chunk=2, telemetry=telemetry, policy=policy,
            adaptive=make_adaptive(), journal=make_journal("sharded"),
            compact_every=compact_every), True))
    modes = {name: {"unchanged": 0, "delta": 0, "full": 0, "degraded": 0,
                    "raised": 0}
             for name, _, _ in services}
    # Every oracle-validated explicit query's (kind, version, mode), in
    # submission order, per service — checked against the trace at the end.
    expected = {name: [] for name, _, _ in services}
    # Every oracle-validated answer, keyed (kind, src, version), per
    # service — the reference a degraded reply must reproduce exactly.
    validated = {name: {} for name, _, _ in services}

    def commit(ops):
        _apply_oracle(oracle, ops)
        for name, svc, _ in services:
            for op in ops:
                # A submit can fault in its auto-commit; the op itself is
                # already in the log (append precedes commit), and the
                # failed chunk went back — a later commit drains both.
                try:
                    svc.submit(op)
                except InjectedFault:
                    assert_service_ok(svc)
            # Under faults a commit may fail mid-flush; atomicity puts the
            # chunk back, so retrying drains the identical prefix.  The
            # service must verify clean after EVERY injected failure.
            # (Progress is monotone: every retry that lands >= 1 batch
            # shrinks the log, so the bound only guards a pathological
            # plan that fails every single attempt.)
            for _ in range(256):
                try:
                    svc.flush()
                    break
                except InjectedFault:
                    assert_service_ok(svc)
            else:
                raise AssertionError(
                    (seed, name, "commit never succeeded under faults"))

    def run_query(name, svc, sharded, kind, src, step):
        ctx = (name, kind, src, step, seed)
        try:
            reply = svc.query(kind, [src] if sharded else src)
        except InjectedFault:
            # ladder exhausted with nothing servable cached: a LOUD
            # failure (never a wrong answer); service must still verify
            modes[name]["raised"] += 1
            assert_service_ok(svc)
            return
        if reply.degraded:
            modes[name]["degraded"] += 1
            assert reply.stale_version == reply.version, (ctx, reply)
            assert svc.ring.get_entry(reply.stale_version) is not None, ctx
            prev = validated[name].get((kind, src, reply.stale_version))
            assert prev is not None, (ctx, "degraded reply at a version "
                                      "that was never validated")
            assert results_equal(reply.result, prev), (
                ctx, "degraded reply differs from the validated answer "
                "at its claimed version")  # the no-torn-reads check
        else:
            modes[name][reply.mode] += 1
            _CHECK[kind]((*ctx, reply.mode), reply, oracle, src, n, sharded)
            validated[name][(kind, src, reply.version)] = reply.result
            expected[name].append((kind, reply.version, reply.mode))
        if fault_plan is not None:
            assert_service_ok(svc)

    with fault_scope(fault_plan):
        # Base population: every vertex alive, a random edge set per HALF
        # of the range — churn then alternates halves, so queries pinned
        # in the lower half see far commits (unchanged), near commits
        # (delta), and their own cold collects (full).
        half = n // 2
        base = [(PUTV, i) for i in range(n)]
        for lo, hi in ((0, half), (half, n)):
            for _ in range(3 * half):
                base.append((PUTE, int(rng.integers(lo, hi)),
                             int(rng.integers(lo, hi)),
                             float(WEIGHTS[int(
                                 rng.integers(0, len(WEIGHTS)))])))
        commit(base)

        pinned = [0, 1]
        for step in range(steps):
            lo, hi = ((half, n) if step % 2 else (0, half))
            commit(gen_ops(rng, lo, hi, ops_per_step, neg_frac))
            for src in pinned + [int(rng.integers(0, n))]:
                for kind in ("bfs", "sssp", "bc"):
                    for name, svc, sharded in services:
                        run_query(name, svc, sharded, kind, src, step)
            if score_every and (step + 1) % score_every == 0:
                for name, svc, _ in services:
                    scores, _ = svc.bc_scores()
                    check_scores((name, "bc_scores", step, seed), scores,
                                 oracle, n)
    for name, svc, _ in services:
        # fault attribution for chaos callers: retries/errors only move
        # when the ladder (i.e. a collect) actually failed on THIS service
        modes[name]["errors"] = svc.stats.errors
        modes[name]["retries"] = svc.stats.retries
    _check_telemetry(seed, telemetry, services, modes, expected)
    if adaptive:
        _check_adaptive(seed, telemetry, services, modes)
    if journal_dir is not None:
        _check_recovery(seed, services, journals, g0, oracle, n, modes,
                        mesh=mesh, tile=tile, bc_mode=bc_mode,
                        batch_size=batch_size)
    telemetry.close()
    return modes


def _check_recovery(seed, services, journals, g0, oracle, n, modes, *,
                    mesh, tile, bc_mode, batch_size):
    """Recovery differential: every journaled service must rebuild — from
    its (possibly rotated + compacted) WAL alone — into a fresh service
    whose ring latest is bit-identical to the survivor's, whose pending
    log depth matches, and whose cold query answers (full collects, no
    cache) equal the oracle's at the final version.  The sharded journal
    recovers under the same live mesh, proving replayed commits reproduce
    sharded query answers exactly."""
    import jax

    for name, svc, sharded in services:
        ctx = (seed, name, "recovery")
        path = journals[name]
        if sharded:
            from repro.shard import ShardedGraphService

            def make_service(state, **kw):
                return ShardedGraphService(state, mesh, tile=tile,
                                           bc_mode=bc_mode, src_chunk=2,
                                           **kw)
        else:
            make_service = None
        rec = recover(path, g0, make_service=make_service,
                      batch_size=batch_size)
        assert rec.version == svc.version, (ctx, rec.version, svc.version)
        for a, b in zip(jax.tree_util.tree_leaves(svc.ring.latest.state),
                        jax.tree_util.tree_leaves(rec.ring.latest.state)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), ctx
        assert rec.scheduler.pending() == svc.scheduler.pending(), ctx
        assert_service_ok(rec)
        for kind in ("bfs", "sssp", "bc"):
            for src in (0, 1):
                reply = rec.query(kind, [src] if sharded else src)
                assert reply.version == svc.version, (ctx, kind, src)
                _CHECK[kind]((*ctx, kind, src), reply, oracle, src, n,
                             sharded)
        j = svc.scheduler.journal
        modes[name]["recovery"] = {
            "version": int(rec.version),
            "rotations": j.rotations,
            "compactions": j.compactions,
            "segments_dropped": j.segments_dropped,
        }


def _check_telemetry(seed, telemetry, services, modes, expected):
    """Telemetry invariants over the whole replay (see module docstring).

    Records partition into *clean* (a successful collect — one per
    ``stats.queries``), *degraded* (stale serves — one per
    ``stats.degraded``), and *error* (the query raised; the record
    carries ``error`` and no version/mode) — each reconciled against its
    own counter, so the conservation invariants survive chaos runs.
    """
    assert telemetry.tracer.dropped == 0, seed
    for name, svc, _ in services:
        tally = modes[name]
        recs = [r for r in telemetry.tracer.records
                if r["span"] == "query" and r["service"] == name]
        err_recs = [r for r in recs if "error" in r]
        deg_recs = [r for r in recs if r.get("degraded")]
        clean = [r for r in recs
                 if "error" not in r and not r.get("degraded")]
        # Ladder-mode conservation: every successful query took exactly
        # one rung; degraded and error replies tally separately.
        assert (svc.stats.unchanged + svc.stats.delta + svc.stats.full
                == svc.stats.queries), (seed, name)
        assert len(clean) == svc.stats.queries, (seed, name)
        assert len(deg_recs) == svc.stats.degraded == tally["degraded"], \
            (seed, name)
        assert len(err_recs) == tally["raised"], (seed, name)
        # The explicit (oracle-validated) queries must appear in the trace
        # in order with matching kind/version/mode; bc_scores() on the
        # sharded service rides through query() and may interleave extra
        # "bc" records, hence subsequence rather than equality.
        it = iter(clean)
        for want in expected[name]:
            for rec in it:
                if (rec["kind"], rec["version"], rec["mode"]) == want:
                    break
            else:
                raise AssertionError((seed, name, "missing trace", want))
        per_mode = {m: sum(1 for r in clean if r["mode"] == m)
                    for m in ("unchanged", "delta", "full")}
        for m in per_mode:
            assert per_mode[m] >= tally[m], (seed, name, m)
        assert sum(per_mode.values()) == len(clean), (seed, name)


# ------------------------- concurrent replay -------------------------------

def run_concurrent_differential(seed: int, *, n: int = 24, chunks: int = 10,
                                ops_per_chunk: int = 4, clients: int = 3,
                                queries_per_client: int = 12,
                                neg_frac: float = 0.0, fault_plan=None,
                                policy=None, max_batch: int = 16,
                                trace_path=None):
    """Concurrent-schedule replay through the async serving front end.

    One seeded RNG fixes everything decidable up front — the base graph,
    the per-commit op chunks, and each client thread's query schedule —
    then ``clients`` query threads and one updater thread run against a
    single :class:`repro.serve.AsyncGraphService` concurrently.  The OS
    interleaving is NOT controlled (that is the point); correctness must
    not depend on it, because every reply pins the ring version it was
    admitted at:

      * each resolved reply is checked **at its own version** — the
        sequential oracle (``tests/oracle.py``) replays the committed
        chunk prefix to that version and the answer must match it
        semantically AND be bit-equal (``results_equal``) to a fresh
        sequential full collect on the reconstructed snapshot — the
        vmap/batched-dispatch bit-identity claim, enforced per reply;
      * chunk boundaries equal commit boundaries by construction (each
        chunk is exactly ``batch_size`` ops, auto-committed), so the
        state at version ``v`` is reproducible as ``apply_ops`` over the
        chunk prefix regardless of thread timing;
      * conservation must survive concurrency: ``unchanged + delta +
        full == stats.queries == #clean query trace records`` and
        degraded records == ``stats.degraded``.

    **Chaos mode** (``fault_plan=``): the whole run — admission, the
    dispatcher (which inherits the fault scope via its copied context),
    and the client commits — executes under the plan; the contract is
    the sequential harness's *degraded-or-correct, never silently
    wrong*: degraded replies are checked bit-exactly at their
    ``stale_version``; raising queries only count (``raised``) and must
    verify clean afterwards.

    Returns the mode tallies plus the front end's own counters
    (``serve`` key) so callers can assert batching actually happened.
    """
    print(f"[concurrent-differential] seed={seed} n={n} chunks={chunks} "
          f"ops_per_chunk={ops_per_chunk} clients={clients} "
          f"chaos={fault_plan is not None}", flush=True)
    import threading

    from repro.core import apply_ops
    from repro.core.queries import bc_dependencies, bfs, sssp
    from repro.serve import AsyncGraphService

    rng = np.random.default_rng(seed)
    half = n // 2
    base = [(PUTV, i) for i in range(n)]
    for lo, hi in ((0, half), (half, n)):
        for _ in range(3 * half):
            base.append((PUTE, int(rng.integers(lo, hi)),
                         int(rng.integers(lo, hi)),
                         float(WEIGHTS[int(rng.integers(0, len(WEIGHTS)))])))
    # Base population goes into version 0 directly (not through the
    # scheduler): v0 is then the well-known starting snapshot every
    # warm-up query pins, and version v == chunk prefix [0, v).
    g0, _ = apply_ops(make_graph(n, 16 * n), base)
    oracle = GraphOracle()
    _apply_oracle(oracle, base)

    chunk_list = [gen_ops(rng, *((half, n) if c % 2 else (0, half)),
                          ops_per_chunk, neg_frac)
                  for c in range(chunks)]
    pinned = [0, 1]
    schedules = []
    for _ in range(clients):
        sched = []
        for q in range(queries_per_client):
            kind = ("bfs", "sssp", "bc")[int(rng.integers(0, 3))]
            src = (pinned[int(rng.integers(0, len(pinned)))]
                   if float(rng.random()) < 0.7 else int(rng.integers(0, n)))
            sched.append((kind, src))
        schedules.append(sched)

    if fault_plan is not None and policy is None:
        policy = ResiliencePolicy(max_retries=2)
    telemetry = Telemetry.make(trace_path)
    svc = GraphService(g0, batch_size=ops_per_chunk, telemetry=telemetry,
                       policy=policy)

    results = [[] for _ in range(clients)]   # (kind, src, future)
    errs = []

    def updater(srv):
        try:
            for chunk in chunk_list:
                for op in chunk:
                    # a submit can fault inside its auto-commit; the op
                    # itself is already logged, and atomicity returned
                    # the chunk — a later commit drains it
                    try:
                        srv.submit(op)
                    except InjectedFault:
                        pass
            for _ in range(256):
                try:
                    srv.flush()
                    return
                except InjectedFault:
                    continue
            errs.append(AssertionError("flush never succeeded"))
        except Exception as e:  # pragma: no cover - harness guard
            errs.append(e)

    def querier(srv, idx):
        try:
            for kind, src in schedules[idx]:
                results[idx].append((kind, src,
                                     srv.query_async(kind, src)))
        except Exception as e:  # pragma: no cover - harness guard
            errs.append(e)

    with fault_scope(fault_plan):
        with AsyncGraphService(svc, max_batch=max_batch) as srv:
            # Warm-up burst at v0: populates the result cache (enabling
            # unchanged/delta rungs mid-stream) and is itself a batched
            # dispatch (many sources, one kind, one version).
            warm = [(k, s, srv.query_async(k, s))
                    for k in ("bfs", "sssp", "bc") for s in pinned]
            for _, _, f in warm:
                try:
                    f.result(timeout=120)
                except Exception:
                    assert fault_plan is not None, (seed, "warm raised")
            threads = [threading.Thread(target=updater, args=(srv,))]
            threads += [threading.Thread(target=querier, args=(srv, i))
                        for i in range(clients)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errs, (seed, errs)
            assert srv.drain(timeout=300), (seed, "drain timed out")
    assert svc.version == chunks, (seed, svc.version, chunks)

    # ---- collect replies; only chaos runs may raise ----
    modes = {"unchanged": 0, "delta": 0, "full": 0, "degraded": 0,
             "raised": 0}
    by_version = {}
    for kind, src, fut in warm + [r for res in results for r in res]:
        try:
            reply = fut.result(timeout=120)
        except Exception as e:
            assert fault_plan is not None, (seed, kind, src, e)
            modes["raised"] += 1
            continue
        if reply.degraded:
            modes["degraded"] += 1
            assert reply.stale_version == reply.version, (seed, reply)
        else:
            modes[reply.mode] += 1
        assert 0 <= reply.version <= chunks, (seed, reply.version)
        by_version.setdefault(reply.version, []).append((kind, src, reply))

    # ---- sequential oracle replay: check every reply at its version ----
    fresh = {"bfs": bfs, "sssp": sssp, "bc": bc_dependencies}
    state = g0
    for v in range(0, chunks + 1):
        if v > 0:
            chunk = chunk_list[v - 1]
            _apply_oracle(oracle, chunk)
            state, _ = apply_ops(state, chunk, batch_size=ops_per_chunk)
        for kind, src, reply in by_version.get(v, ()):
            ctx = (seed, kind, src, v,
                   "degraded" if reply.degraded else reply.mode)
            _CHECK[kind](ctx, reply, oracle, src, n, False)
            # the bit-identity claim: every batched/pinned answer equals
            # a sequential full collect on the reconstructed snapshot
            assert results_equal(reply.result, fresh[kind](state, src)), \
                (ctx, "batched reply not bit-equal to sequential collect")
    assert_service_ok(svc)

    # ---- conservation under concurrency ----
    st = svc.stats
    assert st.unchanged + st.delta + st.full == st.queries, (seed, st)
    recs = [r for r in telemetry.tracer.records if r["span"] == "query"]
    clean = [r for r in recs if "error" not in r and not r.get("degraded")]
    deg = [r for r in recs if r.get("degraded")]
    assert len(clean) == st.queries, (seed, len(clean), st.queries)
    assert len(deg) == st.degraded == modes["degraded"], (seed, st.degraded)
    if fault_plan is None:
        assert modes["raised"] == 0 and st.errors == 0, (seed, modes)

    modes["errors"] = st.errors
    modes["retries"] = st.retries
    modes["serve"] = {
        "admitted": srv.stats.admitted,
        "dispatches": srv.stats.dispatches,
        "batched_dispatches": srv.stats.batched_dispatches,
        "fallbacks": srv.stats.fallbacks,
        "deadline_expired": srv.stats.deadline_expired,
        "max_batch_seen": srv.stats.max_batch_seen,
    }
    telemetry.close()
    return modes


def _check_adaptive(seed, telemetry, services, modes):
    """Controller invariants after an ``adaptive=True`` replay: every
    tuned threshold within its clamps, one ``threshold_adjust`` trace
    span per counted adjustment (carrying the decision inputs), and the
    gauge on the scrape surface agreeing with the controller."""
    for name, svc, _ in services:
        ctl = svc.adaptive
        assert ctl is not None, (seed, name)
        snap = ctl.snapshot()
        for kind, thr in snap["thresholds"].items():
            assert ctl.lo <= thr <= ctl.hi, (seed, name, kind, thr)
        adj_recs = [r for r in telemetry.tracer.records
                    if r["span"] == "threshold_adjust"
                    and r["service"] == name]
        assert len(adj_recs) == snap["adjustments"], (seed, name)
        for r in adj_recs:
            for f in ("old", "new", "t_full_us", "fit_slope_us",
                      "crossover", "n_full", "n_delta"):
                assert f in r, (seed, name, f)
            assert ctl.lo <= r["new"] <= ctl.hi, (seed, name, r)
        for kind in ctl.kinds:
            g = telemetry.registry.find("adaptive_dirty_threshold",
                                        service=name, kind=kind)
            assert len(g) == 1, (seed, name, kind)
            assert abs(g[0].value - snap["thresholds"][kind]) < 1e-9, \
                (seed, name, kind)
        modes[name]["adaptive"] = snap
